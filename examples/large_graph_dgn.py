"""Large Graph Extension (paper §4.6 / Fig. 8): DGN node classification on
a PubMed-sized graph that exceeds any single on-chip buffer, streamed
through the tiled message-passing core.

  PYTHONPATH=src python examples/large_graph_dgn.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import from_numpy
from repro.gnn import apply, init, paper_config


def main():
    n, e, f = 19717, 88648, 500  # PubMed (Table 5)
    rng = np.random.default_rng(0)
    s = rng.integers(0, n, e).astype(np.int32)
    r = rng.integers(0, n, e).astype(np.int32)
    nf = (rng.random((n, f)) < 0.01).astype(np.float32)
    cfg = paper_config("dgn", feat_dim=f, task="node", out_dim=3, edge_dim=1)
    params = init(jax.random.PRNGKey(0), cfg)
    g = from_numpy(s, r, nf, None, n_pad=-(-n // 128) * 128, e_pad=-(-e // 128) * 128)
    eig = jnp.asarray(rng.normal(size=(g.num_nodes,)), jnp.float32)

    fn = jax.jit(lambda p, gg, ee: apply(p, gg, cfg, eigvec=ee))
    out = fn(params, g, eig)
    out.block_until_ready()
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(params, g, eig))
    dt = time.perf_counter() - t0
    print(f"PubMed-sized DGN: {n} nodes, {e} edges, feat {f}")
    print(f"forward {dt*1e3:.1f} ms ({dt/n*1e6:.2f} us/node); output {out.shape}, "
          f"NaNs: {bool(jnp.isnan(out).any())}")


if __name__ == "__main__":
    main()
