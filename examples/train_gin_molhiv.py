"""Train GIN end-to-end on a synthetic MolHIV-statistics stream for a few
hundred steps (binary graph classification, BCE loss, AdamW) with
checkpoints — the training-driver example.

  PYTHONPATH=src python examples/train_gin_molhiv.py [steps]
"""
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gengnn_models import get_gnn_config
from repro.core.graph import batch_graphs
from repro.data.pipeline import MOLHIV, MoleculeStream
from repro.gnn import apply, init
from repro.checkpoint.manager import CheckpointManager
from repro.optim import adamw


def make_batch(stream, rng, step, batch=16):
    gs, labels = [], []
    for i in range(batch):
        s, r, nf, ef, y = stream.graph_at(step * batch + i)
        gs.append((s, r, nf, ef))
        labels.append(y)
    g = batch_graphs(gs, n_pad=batch * 64, e_pad=batch * 192)
    return g, jnp.asarray(labels)


def main():
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 200
    cfg = get_gnn_config("gin")
    params = init(jax.random.PRNGKey(0), cfg)
    stream = MoleculeStream(MOLHIV, seed=0)
    opt_cfg = adamw.AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=steps,
                                weight_decay=0.01)
    opt = adamw.init(params)

    def loss_fn(p, g, y):
        logits = apply(p, g, cfg)[: y.shape[0], 0]
        return jnp.mean(jnp.maximum(logits, 0) - logits * y +
                        jnp.log1p(jnp.exp(-jnp.abs(logits))))

    @jax.jit
    def step_fn(p, o, g, y):
        loss, grads = jax.value_and_grad(loss_fn)(p, g, y)
        p, o, m = adamw.update(opt_cfg, grads, o, p)
        acc = jnp.mean(((apply(p, g, cfg)[: y.shape[0], 0] > 0)) == (y > 0.5))
        return p, o, loss, acc

    rng = np.random.default_rng(0)
    ckpt = CheckpointManager(tempfile.mkdtemp(prefix="gin_ckpt_"), keep=2)
    for step in range(steps):
        g, y = make_batch(stream, rng, step)
        params, opt, loss, acc = step_fn(params, opt, g, y)
        if step % max(steps // 10, 1) == 0 or step == steps - 1:
            print(f"step {step:4d}  bce {float(loss):.4f}  acc {float(acc):.2f}", flush=True)
        if step == steps - 1:
            ckpt.save(step, {"params": params}, blocking=True)
    print("final checkpoint at:", ckpt.dir)


if __name__ == "__main__":
    main()
