"""End-to-end serving driver (the paper's real-time scenario): a stream of
raw COO molecule graphs is classified one by one — batch size 1, zero
preprocessing, on-device COO->CSC conversion inside the compiled step —
and latency percentiles are reported, plus the batched-mode comparison.

  PYTHONPATH=src python examples/serve_realtime_stream.py [n_graphs]
"""
import sys
import time

import jax
import numpy as np

from repro.configs.gengnn_models import get_gnn_config
from repro.data.pipeline import MOLHIV, MoleculeStream
from repro.gnn import init
from repro.serve.gnn_engine import GNNEngine


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 64
    cfg = get_gnn_config("gin_vn")  # GIN + virtual node, paper §4.5
    params = init(jax.random.PRNGKey(0), cfg)
    engine = GNNEngine(cfg, params)
    stream = MoleculeStream(MOLHIV, seed=0)

    graphs = stream.take(n)
    t0 = time.perf_counter()
    outs, lats, compile_s = engine.infer_stream([g[:4] for g in graphs])
    wall = time.perf_counter() - t0
    # simple correctness proxy: the synthetic label is linearly separable
    preds = np.array([float(o[0, 0]) > 0 for o in outs])
    labels = np.array([bool(g[4]) for g in graphs])
    print(f"streamed {n} graphs in {wall:.2f}s ({compile_s:.1f}s compile, excluded from latency)")
    print(f"latency us: mean {np.mean(lats)*1e6:.0f}  p50 {np.percentile(lats,50)*1e6:.0f}  "
          f"p99 {np.percentile(lats,99)*1e6:.0f}")
    print(f"untrained-model label agreement (chance ~0.5): {np.mean(preds == labels):.2f}")

    outs_b, per_graph = engine.infer_batched(graphs, batch_size=8,
                                             n_pad=8 * 64, e_pad=8 * 192)
    print(f"batched mode: {per_graph*1e6:.0f} us/graph "
          f"({np.mean(lats)/per_graph:.1f}x throughput vs stream)")


if __name__ == "__main__":
    main()
