"""Quickstart: run all six GenGNN models through the one generic engine.

The paper's core claim — a single message-passing architecture serves
GCN / GIN(+VN) / GAT / PNA / DGN unchanged — in ~30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.gengnn_models import GNN_MODELS, get_gnn_config
from repro.data.pipeline import MOLHIV, MoleculeStream
from repro.gnn import init
from repro.serve.gnn_engine import GNNEngine


def main():
    graphs = MoleculeStream(MOLHIV, seed=0).take(8)  # raw COO, zero preprocessing
    for name in GNN_MODELS:
        cfg = get_gnn_config(name)
        params = init(jax.random.PRNGKey(0), cfg)
        engine = GNNEngine(cfg, params)
        outs, lats, _ = engine.infer_stream(
            [g[:4] for g in graphs], with_eigvec=(name == "dgn")
        )
        print(f"{name:7s} -> {len(outs)} graphs, "
              f"mean latency {np.mean(lats)*1e6:7.0f} us, "
              f"first output {float(outs[0][0,0]):+.4f}")


if __name__ == "__main__":
    main()
