"""Deterministic synthetic + binary data pipelines (tokens, molecules)."""
from repro.data.pipeline import (
    SyntheticTokens, BinTokenDataset, TokenPipelineConfig,
    MoleculeStream, MOLHIV, MOLPCBA, write_synthetic_corpus,
)
