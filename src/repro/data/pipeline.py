"""Deterministic data pipelines: synthetic token streams, binary corpus
reader, and synthetic molecular-graph streams (MolHIV/MolPCBA statistics).

Determinism contract: batch ``i`` is a pure function of (seed, i, shard),
so a restarted job resumes mid-epoch without coordination — required for
elastic restarts (checkpoint stores only the step counter).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


# ---------------------------------------------------------------------------
# token streams (LM substrate)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    shard_index: int = 0
    shard_count: int = 1
    zipf_a: float = 1.2  # synthetic vocabulary skew


class SyntheticTokens:
    """Zipf-distributed tokens with short-range structure (bigram mixing) —
    enough signal for loss-goes-down integration tests."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.shard_index])
        )
        z = rng.zipf(cfg.zipf_a, size=(cfg.batch, cfg.seq_len))
        tokens = (z - 1) % cfg.vocab_size
        # short-range structure: with p=0.5, token t+1 = f(token t)
        repeat = rng.random((cfg.batch, cfg.seq_len)) < 0.5
        shifted = (tokens * 31 + 7) % cfg.vocab_size
        tokens[:, 1:] = np.where(repeat[:, 1:], shifted[:, :-1], tokens[:, 1:])
        return {"tokens": tokens.astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class BinTokenDataset:
    """Memory-mapped flat-binary token corpus (uint16/uint32), sharded by
    host: shard k reads window k of every batch — the production path."""

    def __init__(self, path: str, cfg: TokenPipelineConfig, dtype=np.uint16):
        self.cfg = cfg
        self.data = np.memmap(path, dtype=dtype, mode="r")

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        n = len(self.data) - cfg.seq_len - 1
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
        starts = rng.integers(0, n, size=cfg.batch * cfg.shard_count)
        starts = starts[cfg.shard_index :: cfg.shard_count][: cfg.batch]
        out = np.stack([self.data[s : s + cfg.seq_len] for s in starts])
        return {"tokens": out.astype(np.int32) % cfg.vocab_size}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def write_synthetic_corpus(path: str, n_tokens: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    arr = ((rng.zipf(1.2, size=n_tokens) - 1) % vocab).astype(np.uint16)
    arr.tofile(path)
    return path


# ---------------------------------------------------------------------------
# molecular graph streams (GNN engine)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoleculeStats:
    """Size statistics matching OGB molecular property datasets."""

    name: str
    mean_nodes: float
    std_nodes: float
    mean_degree: float  # undirected edges per node ~ 1.05-1.1 (molecules)
    feat_dim: int = 9
    edge_dim: int = 3


MOLHIV = MoleculeStats("molhiv", 25.5, 12.0, 2.2)
MOLPCBA = MoleculeStats("molpcba", 26.0, 6.5, 2.2)


def synthetic_molecule(rng: np.random.Generator, stats: MoleculeStats):
    """One random molecule-like graph: a random tree (connected backbone)
    plus ring-closing extra edges, symmetric COO."""
    n = max(int(rng.normal(stats.mean_nodes, stats.std_nodes)), 4)
    # random tree
    parents = np.array([rng.integers(0, max(i, 1)) for i in range(1, n)])
    s = np.concatenate([np.arange(1, n), parents])
    r = np.concatenate([parents, np.arange(1, n)])
    # ring closures
    extra = max(int(n * (stats.mean_degree - 2.0) / 2.0), 0)
    if extra:
        a = rng.integers(0, n, extra)
        b = rng.integers(0, n, extra)
        s = np.concatenate([s, a, b])
        r = np.concatenate([r, b, a])
    nf = rng.normal(size=(n, stats.feat_dim)).astype(np.float32)
    ef = rng.normal(size=(len(s), stats.edge_dim)).astype(np.float32)
    label = (nf.sum() + 0.1 * len(s)) > 0  # synthetic separable target
    return s.astype(np.int32), r.astype(np.int32), nf, ef, np.float32(label)


def laplacian_eigvec(s: np.ndarray, r: np.ndarray, n: int,
                     n_pad: Optional[int] = None) -> np.ndarray:
    """First non-trivial Laplacian eigenvector — DGN's precomputed *input*
    (the paper passes eigenvectors as a model parameter; synthetic streams
    compute it host-side as part of data generation)."""
    a = np.zeros((n, n))
    a[np.asarray(r), np.asarray(s)] = 1.0
    a = np.maximum(a, a.T)
    lap = np.diag(a.sum(1)) - a
    _, v = np.linalg.eigh(lap)
    vec = v[:, min(1, v.shape[1] - 1)]
    out = np.zeros((n_pad if n_pad is not None else n,), np.float32)
    out[:n] = vec
    return out


class MoleculeStream:
    """Deterministic stream of raw COO graphs — the paper's real-time input
    (graphs arrive consecutively, no preprocessing allowed)."""

    def __init__(self, stats: MoleculeStats, seed: int = 0):
        self.stats = stats
        self.seed = seed

    def graph_at(self, i: int):
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, i]))
        return synthetic_molecule(rng, self.stats)

    def take(self, n: int):
        return [self.graph_at(i) for i in range(n)]
