"""Qwen3-MoE 30B-A3B [moe; hf:Qwen/Qwen3-30B-A3B].

48 layers, GQA 32 heads / 4 kv (head_dim 128, QK-norm), MoE on every
layer: 128 experts, top-8 (renormalized), expert d_ff 768, vocab 151936.
"""
from repro.models.config import ModelConfig


def get_config(**kw) -> ModelConfig:
    base = dict(
        name="qwen3-moe-30b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=128,
        d_ff=768, vocab_size=151936,
        kv_pad_to=16,
        num_experts=128, experts_per_token=8, norm_topk=True, qk_norm=True,
        mlp_type="swiglu", tie_embeddings=False, rope_theta=1e6,
    )
    base.update(kw)
    return ModelConfig(**base).validate()


def reduced_config(**kw) -> ModelConfig:
    base = dict(
        name="qwen3-moe-reduced", family="moe",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=128,
        num_experts=8, experts_per_token=2, norm_topk=True, qk_norm=True,
        mlp_type="swiglu", tie_embeddings=False, attn_chunk=16, loss_chunk=16, remat=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()
