"""RWKV-6 'Finch' 1.6B [ssm; arXiv:2404.05892].

24 attention-free layers with data-dependent-decay time mixing (32 heads
of dim 64) and squared-ReLU channel mixing d_ff 7168, d_model 2048,
vocab 65536.
"""
from repro.models.config import ModelConfig


def get_config(**kw) -> ModelConfig:
    base = dict(
        name="rwkv6-1.6b", family="ssm", attention="none", ssm_type="rwkv6",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        rwkv_head_dim=64, d_ff=7168, vocab_size=65536,
        mlp_type="relu_sq", tie_embeddings=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()


def reduced_config(**kw) -> ModelConfig:
    base = dict(
        name="rwkv6-reduced", family="ssm", attention="none", ssm_type="rwkv6",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        rwkv_head_dim=16, d_ff=224, vocab_size=128,
        mlp_type="relu_sq", tie_embeddings=False, attn_chunk=16, loss_chunk=16, remat=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()
