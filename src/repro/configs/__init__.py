"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full published configuration;
``get_reduced(arch_id)`` returns the same-family smoke-test reduction.
"""
from importlib import import_module

REGISTRY = {
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "whisper-base": "repro.configs.whisper_base",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
}

ARCHS = tuple(REGISTRY)


def get_config(arch: str, **kw):
    return import_module(REGISTRY[arch]).get_config(**kw)


def get_reduced(arch: str, **kw):
    return import_module(REGISTRY[arch]).reduced_config(**kw)
