"""Whisper-base [audio; arXiv:2212.04356].

Encoder-decoder, 6+6 layers, d_model 512, 8 heads, GELU d_ff 2048, vocab
51865.  The conv frontend is a STUB: input_specs provides 1500 precomputed
log-mel frame embeddings (post-conv).  Adaptation: RoPE replaces whisper's
learned/sinusoidal positions (noted in DESIGN.md).
"""
from repro.models.config import ModelConfig


def get_config(**kw) -> ModelConfig:
    base = dict(
        name="whisper-base", family="audio",
        num_layers=6, encoder_layers=6, encoder_seq=1500,
        d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
        d_ff=2048, vocab_size=51865,
        mlp_type="gelu", tie_embeddings=True,
    )
    base.update(kw)
    return ModelConfig(**base).validate()


def reduced_config(**kw) -> ModelConfig:
    base = dict(
        name="whisper-reduced", family="audio",
        num_layers=2, encoder_layers=2, encoder_seq=12,
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128,
        mlp_type="gelu", tie_embeddings=True, attn_chunk=16, loss_chunk=16, remat=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()
