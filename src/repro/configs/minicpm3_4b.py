"""MiniCPM3-4B [dense, MLA; hf:openbmb/MiniCPM3-4B].

62 layers, multi-head latent attention (q_lora 768, kv_lora 256, nope 64 +
rope 32 per head, v 64), 40 heads, d_model 2560, d_ff 6400, vocab 73448.
"""
from repro.models.config import ModelConfig


def get_config(**kw) -> ModelConfig:
    base = dict(
        name="minicpm3-4b", family="dense", attention="mla",
        num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
        d_ff=6400, vocab_size=73448,
        q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64, qk_rope_dim=32,
        v_head_dim=64, head_dim=96,
        mlp_type="swiglu", tie_embeddings=True,
    )
    base.update(kw)
    return ModelConfig(**base).validate()


def reduced_config(**kw) -> ModelConfig:
    base = dict(
        name="minicpm3-reduced", family="dense", attention="mla",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, head_dim=24,
        mlp_type="swiglu", tie_embeddings=True, attn_chunk=16, loss_chunk=16, remat=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()
