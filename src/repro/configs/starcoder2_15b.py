"""StarCoder2-15B [dense; arXiv:2402.19173].

40 layers, GQA 48 heads / 4 kv (head_dim 128), non-gated GELU MLP
d_ff 24576, RoPE, vocab 49152.  (HF config also uses a 4k sliding window;
the assigned spec says plain GQA+RoPE, so full attention here.)
"""
from repro.models.config import ModelConfig


def get_config(**kw) -> ModelConfig:
    base = dict(
        name="starcoder2-15b", family="dense",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4, head_dim=128,
        d_ff=24576, vocab_size=49152,
        kv_pad_to=16,
        mlp_type="gelu", tie_embeddings=True,
    )
    base.update(kw)
    return ModelConfig(**base).validate()


def reduced_config(**kw) -> ModelConfig:
    base = dict(
        name="starcoder2-reduced", family="dense",
        num_layers=4, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=192, vocab_size=128,
        mlp_type="gelu", tie_embeddings=True, attn_chunk=16, loss_chunk=16, remat=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()
