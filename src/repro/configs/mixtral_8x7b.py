"""Mixtral 8x7B [moe; arXiv:2401.04088].

32 layers, GQA 32 heads / 8 kv, sliding-window 4096 attention, MoE on
every layer: 8 experts top-2, d_ff 14336, vocab 32000.
"""
from repro.models.config import ModelConfig


def get_config(**kw) -> ModelConfig:
    base = dict(
        name="mixtral-8x7b", family="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=32000,
        kv_pad_to=16,
        num_experts=8, experts_per_token=2, sliding_window=4096,
        mlp_type="swiglu", tie_embeddings=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()


def reduced_config(**kw) -> ModelConfig:
    base = dict(
        name="mixtral-reduced", family="moe",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=128,
        num_experts=4, experts_per_token=2, sliding_window=8,
        mlp_type="swiglu", tie_embeddings=False, attn_chunk=16, loss_chunk=16, remat=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()
