"""InternVL2-26B backbone [vlm; arXiv:2404.16821].

The InternLM2-20B language backbone: 48 layers, GQA 48 heads / 8 kv,
d_model 6144, d_ff 16384, vocab 92553.  The InternViT vision frontend is a
STUB per the brief: input_specs provides 1024 precomputed patch embeddings
prepended to the token sequence.
"""
from repro.models.config import ModelConfig


def get_config(**kw) -> ModelConfig:
    base = dict(
        name="internvl2-26b", family="vlm",
        num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
        d_ff=16384, vocab_size=92553, num_patches=1024,
        kv_pad_to=16,
        mlp_type="swiglu", tie_embeddings=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()


def reduced_config(**kw) -> ModelConfig:
    base = dict(
        name="internvl2-reduced", family="vlm",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, num_patches=4,
        mlp_type="swiglu", tie_embeddings=False, attn_chunk=16, loss_chunk=16, remat=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()
