"""ChatGLM3-6B [dense; arXiv:2406.12793].

28 layers, GQA 32 heads / 2 kv, 2d-RoPE (rotary on half the head dims),
SwiGLU d_ff 13696, vocab 65024.
"""
from repro.models.config import ModelConfig


def get_config(**kw) -> ModelConfig:
    base = dict(
        name="chatglm3-6b", family="dense",
        num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2, head_dim=128,
        d_ff=13696, vocab_size=65024,
        kv_pad_to=16,
        rope_fraction=0.5, mlp_type="swiglu", tie_embeddings=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()


def reduced_config(**kw) -> ModelConfig:
    base = dict(
        name="chatglm3-reduced", family="dense",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128,
        rope_fraction=0.5, mlp_type="swiglu", tie_embeddings=False,
        attn_chunk=16, loss_chunk=16, remat=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()
