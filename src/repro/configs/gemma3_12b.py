"""Gemma-3 12B [dense; hf:google/gemma-3 family].

48 layers, 5 local (sliding-window 1024) : 1 global pattern, d_model 3840,
16 heads / 8 kv with head_dim 256, GeGLU d_ff 15360, vocab 262144.
RoPE theta 1e6 (single theta for both layer kinds — adaptation noted).
"""
from repro.models.config import ModelConfig


def get_config(**kw) -> ModelConfig:
    base = dict(
        name="gemma3-12b", family="dense",
        num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8, head_dim=256,
        d_ff=15360, vocab_size=262144,
        kv_pad_to=16,
        global_every=6, global_offset=5, sliding_window=1024,
        mlp_type="geglu", tie_embeddings=True, rope_theta=1e6,
    )
    base.update(kw)
    return ModelConfig(**base).validate()


def reduced_config(**kw) -> ModelConfig:
    base = dict(
        name="gemma3-reduced", family="dense",
        num_layers=6, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
        global_every=6, global_offset=5, sliding_window=8,
        mlp_type="geglu", tie_embeddings=True, attn_chunk=16, loss_chunk=16, remat=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()
