"""Jamba-v0.1 52B [hybrid; arXiv:2403.19887].

32 layers, attention:Mamba 1:7 interleave (attention at position 4 of each
8-layer period, as in the paper), MoE (16 experts, top-2) on every other
layer.  d_model 4096, 32 heads / 8 kv, d_ff 14336, vocab 65536.
"""
from repro.models.config import ModelConfig


def get_config(**kw) -> ModelConfig:
    base = dict(
        name="jamba-v0.1-52b", family="hybrid",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=65536,
        kv_pad_to=16,
        attn_every=8, attn_offset=4, ssm_type="mamba",
        d_state=16, d_conv=4, expand=2, ssm_chunk=256,
        num_experts=16, experts_per_token=2, moe_every=2, moe_offset=1,
        mlp_type="swiglu", tie_embeddings=False, rope_theta=1e4,
    )
    base.update(kw)
    return ModelConfig(**base).validate()


def reduced_config(**kw) -> ModelConfig:
    base = dict(
        name="jamba-reduced", family="hybrid",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=128,
        attn_every=8, attn_offset=4, ssm_type="mamba",
        d_state=4, d_conv=4, expand=2, ssm_chunk=8,
        num_experts=4, experts_per_token=2, moe_every=2, moe_offset=1,
        mlp_type="swiglu", tie_embeddings=False, attn_chunk=16, loss_chunk=16, remat=False,
    )
    base.update(kw)
    return ModelConfig(**base).validate()
