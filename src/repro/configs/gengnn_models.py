"""The paper's own six GNN models (Table 2 / §5.1 hyperparameters) as
selectable configs for the GNN engine."""
from repro.gnn.models import GNNConfig, paper_config

GNN_MODELS = ("gcn", "gin", "gin_vn", "gat", "pna", "dgn")


def get_gnn_config(name: str, **kw) -> GNNConfig:
    if name == "gin_vn":
        return paper_config("gin", virtual_node=True, **kw)
    return paper_config(name, **kw)
