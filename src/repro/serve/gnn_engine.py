"""GNN serving engine — the paper's real-time inference mode.

Raw COO graphs are streamed in consecutively with *zero preprocessing*:
the engine pads each graph into a (N_pad, E_pad) bucket (static shapes for
the compiled program; the paper's analogue is the fixed on-chip buffer
size), converts COO->CSC *on device inside the compiled step* (the
paper's on-chip converter), and runs any registered model through the one
generic message-passing program.

Two modes, both measured by benchmarks/bench_fig7_latency.py:
  * ``infer_stream``  — batch-size-1, per-graph latency (paper Fig. 7)
  * ``infer_batched`` — padded batching (the TPU-efficient mode)
"""
from __future__ import annotations

import time
from functools import partial
from typing import Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.gnn import models as M

DEFAULT_BUCKETS: Sequence[tuple] = ((32, 96), (64, 192), (128, 384), (256, 768))


class GNNEngine:
    def __init__(
        self,
        cfg: M.GNNConfig,
        params: dict,
        buckets: Sequence[tuple] = DEFAULT_BUCKETS,
        eigvec_dim: bool = None,
    ):
        self.cfg = cfg
        self.params = params
        self.buckets = sorted(buckets)
        self._compiled = {}

    def _bucket_for(self, n: int, e: int) -> tuple:
        for nb, eb in self.buckets:
            if n <= nb and e <= eb:
                return nb, eb
        raise ValueError(f"graph ({n},{e}) exceeds largest bucket {self.buckets[-1]}")

    def _fn(self, bucket: tuple):
        if bucket not in self._compiled:

            @jax.jit
            def run(params, g: G.Graph, eigvec):
                return M.apply(params, g, self.cfg, eigvec=eigvec)

            self._compiled[bucket] = run
        return self._compiled[bucket]

    def infer_stream(self, graphs: Iterable[tuple], with_eigvec: bool = False):
        """graphs: iterable of raw (senders, receivers, node_feat, edge_feat
        [, label]) tuples.  Returns (outputs, per-graph latencies seconds).
        The first call per bucket includes compilation (excluded from
        latency, reported separately)."""
        outs: List[np.ndarray] = []
        lats: List[float] = []
        compile_time = 0.0
        for graph in graphs:
            s, r, nf, ef = graph[:4]
            nb, eb = self._bucket_for(nf.shape[0], len(s))
            g = G.from_numpy(s, r, nf, ef, n_pad=nb, e_pad=eb)
            eig = self._eigvec(s, r, nf.shape[0], nb) if with_eigvec else None
            fn = self._fn((nb, eb))
            key = ((nb, eb), with_eigvec)
            if key not in getattr(self, "_warm", set()):
                t0 = time.perf_counter()
                fn(self.params, g, eig)[0].block_until_ready()
                compile_time += time.perf_counter() - t0
                self._warm = getattr(self, "_warm", set()) | {key}
            t0 = time.perf_counter()
            out = fn(self.params, g, eig)
            out = jax.block_until_ready(out)
            lats.append(time.perf_counter() - t0)
            outs.append(np.asarray(out[:1]))
        return outs, np.asarray(lats), compile_time

    def infer_batched(self, graphs: Sequence[tuple], batch_size: int,
                      n_pad: int, e_pad: int, with_eigvec: bool = False):
        """Padded-batch mode.  Returns (outputs (n_graphs, out), seconds/graph)."""
        fn = self._fn((n_pad, e_pad, batch_size))
        outs = []
        total = 0.0
        for i in range(0, len(graphs), batch_size):
            chunk = graphs[i : i + batch_size]
            gs = [(g[0], g[1], g[2], g[3]) for g in chunk]
            g = G.batch_graphs(gs, n_pad=n_pad, e_pad=e_pad)
            eig = None
            if with_eigvec:
                eig = jnp.zeros((n_pad,), jnp.float32)
            if i == 0:
                fn(self.params, g, eig)[0].block_until_ready()  # compile
            t0 = time.perf_counter()
            out = jax.block_until_ready(fn(self.params, g, eig))
            total += time.perf_counter() - t0
            outs.append(np.asarray(out[: len(chunk)]))
        return np.concatenate(outs), total / len(graphs)

    def _eigvec(self, s, r, n, n_pad):
        """First non-trivial Laplacian eigenvector — DGN's *input* (the
        paper passes precomputed eigenvectors as a parameter; for synthetic
        streams we compute it on the host as part of data generation)."""
        import numpy.linalg as la

        a = np.zeros((n, n))
        a[r, s] = 1.0
        a = np.maximum(a, a.T)
        d = np.diag(a.sum(1))
        lap = d - a
        w, v = la.eigh(lap)
        vec = v[:, min(1, v.shape[1] - 1)]
        out = np.zeros((n_pad,), np.float32)
        out[:n] = vec
        return jnp.asarray(out)
