"""GNN serving engine — the single-tenant facade over ``serve.executor``.

Raw COO graphs are streamed in consecutively with *zero preprocessing*:
each graph is padded into a (N_pad, E_pad) bucket (static shapes for the
compiled program; the paper's analogue is the fixed on-chip buffer size),
COO is converted to the destination-ordered layout once per forward (the
paper's on-chip converter, §3.4), and any registered model runs through
the one generic message-passing program.

Three modes, measured by benchmarks/bench_fig7_latency.py and
benchmarks/bench_stream_throughput.py:
  * ``infer_stream``  — batch-size-1, per-graph latency (paper Fig. 7)
  * ``infer_batched`` — fixed-size padded batching (the TPU-efficient mode)
  * ``infer_packed``  — one already-packed multi-graph batch (built by
    ``core.batching.pack_graphs``; fed by ``serve.scheduler``'s
    micro-batcher), the streaming-throughput mode

This module contains **no** compile-cache, warm, timing, or mesh-scope
logic of its own (``tools/check_engine_singlepath.py`` enforces that):
every mode is a thin wrapper that *prepares* input through the executor's
``prepare_stream`` / ``prepare_batched`` / ``prepare_packed`` family and
*runs* it through the executor's one warm-before-timing path.  The
engine's constructor registers exactly one tenant; multi-model serving
registers several tenants on one ``Executor`` directly and shares the
bucket ladder, compile cache, and scheduler across them.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.gnn import models as M
from repro.serve.executor import DEFAULT_BUCKETS, Executor, _CompiledBucket

__all__ = ["GNNEngine", "DEFAULT_BUCKETS"]


class GNNEngine:
    def __init__(
        self,
        cfg: M.GNNConfig,
        params: dict,
        buckets: Sequence[tuple] = DEFAULT_BUCKETS,
        mesh=None,
        rules: Optional[dict] = None,
        precision: str = "fp32",
        calib_graphs: Optional[Sequence[tuple]] = None,
        qconfig=None,
        share_layout: bool = True,
        fused: bool = False,
        executor: Optional[Executor] = None,
        name: str = "default",
        aot_cache=None,
        xla_flags=None,
    ):
        """``precision`` selects the serving arithmetic: "fp32" (default),
        "int8" (W8A8 with dynamic per-node activation scales; no
        calibration needed), "int8-static" (calibrated per-tensor
        activation scales; requires ``calib_graphs``, a few raw COO
        tuples), or "fixed" (the paper's ap_fixed<W,I> emulation).
        Quantization happens once at registration — every mode (stream /
        batched / packed, with or without a mesh) then serves the
        transformed params through the identical bucket/compile machinery.

        ``share_layout`` (default on) threads one ``GraphLayout`` plan per
        forward through every model layer; off = the seed per-call-sort
        path, retained only for parity tests and A/B benchmarks.

        ``fused`` (default off) lowers eligible layers through the
        ``kernels.ops.fused_mp`` megakernel — one pass for message
        transform, aggregation, and node update.  Requires
        ``share_layout``; layers that cannot fuse (GAT, int8-static /
        "fixed" params) silently keep the unfused path (docs/KERNELS.md).

        ``executor`` attaches this engine as tenant ``name`` on an
        existing :class:`Executor` (sharing its bucket ladder and compile
        cache with other tenants); by default the engine owns a fresh
        single-tenant executor built from ``buckets`` / ``mesh`` /
        ``rules`` — those three belong to the executor, so passing them
        alongside ``executor`` is rejected rather than silently ignored.

        ``aot_cache`` / ``xla_flags`` pass a :class:`serve.aot.AOTCache`
        and :class:`serve.aot.XlaFlagConfig` to the internally-built
        executor — the restart-fast path (docs/SERVING.md).  They belong
        to the executor like ``buckets`` do, so combining them with an
        explicit ``executor`` is rejected the same way."""
        if executor is not None and (
            tuple(buckets) != tuple(DEFAULT_BUCKETS)
            or mesh is not None or rules is not None
            or aot_cache is not None or xla_flags is not None
        ):
            raise ValueError(
                "buckets/mesh/rules/aot_cache/xla_flags belong to the "
                "executor: configure them on the Executor you pass, not "
                "on the facade"
            )
        self.executor = executor or Executor(
            buckets=buckets, mesh=mesh, rules=rules,
            aot_cache=aot_cache, xla_flags=xla_flags,
        )
        self._tenant = self.executor.register(
            name, cfg, params, precision=precision,
            calib_graphs=calib_graphs, qconfig=qconfig,
            share_layout=share_layout, fused=fused,
        )
        self.cfg = cfg

    # ---------------------------------------------------------- plumbing
    # (facade views only — the state itself lives on the executor)

    @property
    def name(self) -> str:
        return self._tenant.name

    @property
    def params(self) -> dict:
        return self._tenant.params

    @property
    def precision(self) -> str:
        return self._tenant.precision

    @property
    def share_layout(self) -> bool:
        return self._tenant.share_layout

    @property
    def fused(self) -> bool:
        return self._tenant.fused

    @property
    def quant_report(self):
        return self._tenant.quant_report

    @property
    def buckets(self) -> Sequence[tuple]:
        return self.executor.buckets

    @property
    def mesh(self):
        return self.executor.mesh

    @property
    def rules(self):
        return self.executor.rules

    @property
    def compile_seconds(self) -> float:
        """Compile/warm-up time across this tenant's buckets (excluded
        from every reported latency).  Filtered by program key like
        ``_compiled``, so two facades sharing one executor never see each
        other's warm cost (for same-architecture tenants the program —
        and hence its pooled warm cost — is genuinely shared)."""
        return sum(cb.compile_s for cb in self._compiled.values())

    @property
    def warm_seconds(self) -> float:
        """First-run warm time across this tenant's buckets — the half of
        the untimed cost the AOT cache cannot eliminate (the executable
        must still execute once before timing starts)."""
        return sum(cb.warm_s for cb in self._compiled.values())

    @property
    def _compiled(self) -> Dict[tuple, _CompiledBucket]:
        """This tenant's compile-cache records, keyed by bucket key —
        the view tests and benchmarks inspect."""
        pk = self._tenant.program_key
        return {
            bucket_key: cb
            for (prog_key, bucket_key, _ng), cb in self.executor._compiled.items()
            if prog_key == pk
        }

    def _bucket_for(self, n: int, e: int) -> tuple:
        return self.executor.bucket_for(n, e)

    def _eigvec(self, s, r, n, n_pad):
        return self.executor._eigvec(s, r, n, n_pad)

    # ------------------------------------------------------------- modes

    def infer_stream(self, graphs: Iterable[tuple], with_eigvec: bool = False):
        """graphs: iterable of raw (senders, receivers, node_feat, edge_feat
        [, label]) tuples.  Returns (outputs, per-graph latencies seconds,
        compile seconds).  Compilation per bucket is warmed outside the
        timed region and reported separately."""
        ex = self.executor
        outs: List[np.ndarray] = []
        lats: List[float] = []
        # this tenant's untimed total (compile + first-run warm) only
        compile_before = self.compile_seconds + self.warm_seconds
        for graph in graphs:
            p = ex.prepare_stream(graph, with_eigvec=with_eigvec)
            out, dt = ex.run(p, model=self.name)
            lats.append(dt)
            outs.append(out[:1])
        untimed = self.compile_seconds + self.warm_seconds - compile_before
        return outs, np.asarray(lats), untimed

    def infer_batched(self, graphs: Sequence[tuple], batch_size: int,
                      n_pad: int, e_pad: int, with_eigvec: bool = False):
        """Padded-batch mode.  Returns (outputs (n_graphs, out), seconds/graph)."""
        ex = self.executor
        outs = []
        total = 0.0
        for i in range(0, len(graphs), batch_size):
            chunk = graphs[i : i + batch_size]
            p = ex.prepare_batched(chunk, batch_size, n_pad, e_pad,
                                   with_eigvec=with_eigvec)
            out, dt = ex.run(p, model=self.name)
            total += dt
            outs.append(out[: len(chunk)])
        return np.concatenate(outs), total / len(graphs)

    def infer_packed(self, packed, budget, eigvec=None,
                     warm_only: bool = False, layout=None):
        """Run one already-packed multi-graph batch (``core.batching``).

        ``budget`` is the ``BucketBudget`` the batch was packed against —
        it is the compile-cache key, so every batch packed to the same
        budget reuses one compiled program regardless of how many real
        graphs it carries.  Works identically with and without a mesh.
        Returns (outputs (G_pad, out), compute seconds) with warm/compile
        time excluded and tracked in ``compile_seconds``.

        ``layout`` is the batch's ``GraphLayout`` plan, normally emitted
        by the packer (``core.batching.pack_layout`` /
        ``core.batching.pack_prepared``) so the compiled program contains
        zero on-device sorts; when absent (and layout sharing is on) the
        executor builds the host plan during prepare.

        ``warm_only`` compiles/warms this batch's signature and returns
        (None, 0.0) without a second timed execution — the scheduler uses
        it to pre-warm budget-ladder rungs.
        """
        ex = self.executor
        p = ex.prepare_packed(packed, budget, eigvec=eigvec, layout=layout,
                              model=self.name)
        if warm_only:
            ex.warm(p, model=self.name)
            return None, 0.0
        return ex.run(p, model=self.name)
