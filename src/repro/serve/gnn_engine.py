"""GNN serving engine — the paper's real-time inference mode.

Raw COO graphs are streamed in consecutively with *zero preprocessing*:
the engine pads each graph into a (N_pad, E_pad) bucket (static shapes for
the compiled program; the paper's analogue is the fixed on-chip buffer
size), converts COO->CSC *on device inside the compiled step* (the
paper's on-chip converter), and runs any registered model through the one
generic message-passing program.

Three modes, measured by benchmarks/bench_fig7_latency.py and
benchmarks/bench_stream_throughput.py:
  * ``infer_stream``  — batch-size-1, per-graph latency (paper Fig. 7)
  * ``infer_batched`` — fixed-size padded batching (the TPU-efficient mode)
  * ``infer_packed``  — one already-packed multi-graph batch (built by
    ``core.batching.pack_graphs``; fed by ``serve.scheduler``'s
    micro-batcher), the streaming-throughput mode

Both run through ``repro.runtime``: pass a ``mesh`` and the engine shards
the padded node/edge axes over it via ``logical_constraint`` (logical axes
"nodes"/"edges"/"graphs", resolved by ``runtime.gnn_rules``).  Without a
mesh the constraints are no-ops, so CPU tests and single-device serving
are untouched.

Each (bucket, mode) pair owns a ``_CompiledBucket`` record: the jitted
program plus warm-signature bookkeeping, so compilation time never leaks
into a timed region — a fresh signature appearing mid-stream (first chunk
of a new shape, eigvec toggling) is warmed untimed first.

Every mode shares one ``core.layout.GraphLayout`` plan per forward (the
paper's convert-COO-once, §3.4): stream/batched programs build the plan
on device inside the compiled step (exactly one sort, timed honestly as
part of the forward), while ``infer_packed`` accepts the plan the packer
emitted at pack time (``core.batching.pack_layout``) so the packed
program runs with zero on-device sorts.  The plan rides the same bucket
signature as the graph — same padded shapes, same compiled program — so
layout threading adds no compile-cache keys and no recompiles.
``share_layout=False`` reverts every mode to the seed per-call-sort path
(parity tests / A-B benchmarks only).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime as RT
from repro.core import batching as B
from repro.core import graph as G
from repro.core import layout as LY
from repro.gnn import models as M

DEFAULT_BUCKETS: Sequence[tuple] = ((32, 96), (64, 192), (128, 384), (256, 768))


@dataclasses.dataclass
class _CompiledBucket:
    """Per-bucket compile-cache record."""

    fn: Callable
    warm: Set[tuple] = dataclasses.field(default_factory=set)
    compile_s: float = 0.0


class GNNEngine:
    def __init__(
        self,
        cfg: M.GNNConfig,
        params: dict,
        buckets: Sequence[tuple] = DEFAULT_BUCKETS,
        mesh=None,
        rules: Optional[dict] = None,
        precision: str = "fp32",
        calib_graphs: Optional[Sequence[tuple]] = None,
        qconfig=None,
        share_layout: bool = True,
    ):
        """``precision`` selects the serving arithmetic: "fp32" (default),
        "int8" (W8A8 with dynamic per-node activation scales; no
        calibration needed), "int8-static" (calibrated per-tensor
        activation scales; requires ``calib_graphs``, a few raw COO
        tuples), or "fixed" (the paper's ap_fixed<W,I> emulation).
        Quantization happens once here — every mode (stream / batched /
        packed, with or without a mesh) then serves the transformed params
        through the identical bucket/compile machinery.

        ``share_layout`` (default on) threads one ``GraphLayout`` plan per
        forward through every model layer; off = the seed per-call-sort
        path, retained only for parity tests and A/B benchmarks."""
        self.cfg = cfg
        self.precision = precision
        self.share_layout = share_layout
        self.quant_report = None
        if precision != "fp32":
            from repro.quant import apply as QA

            qcfg = qconfig or QA.precision_qconfig(precision)
            if (qcfg.scheme == "int8" and qcfg.act_mode == "static"
                    and not calib_graphs):
                raise ValueError(
                    "static-activation int8 needs calib_graphs (raw COO "
                    "tuples) to calibrate activation ranges"
                )
            params, self.quant_report = QA.quantize_model(
                params, cfg, calib_graphs or (), qcfg
            )
        self.params = params
        self.buckets = sorted(buckets)
        self.mesh = mesh
        if rules is None and mesh is not None:
            rules = RT.gnn_rules(mesh)
        self.rules = rules
        self._compiled: Dict[tuple, _CompiledBucket] = {}

    # ---------------------------------------------------------- plumbing

    @property
    def compile_seconds(self) -> float:
        """Total compile/warm-up time across all buckets (excluded from
        every reported latency)."""
        return sum(cb.compile_s for cb in self._compiled.values())

    def _mesh_scope(self):
        """Context under which programs trace/run: installs the engine's
        mesh + rules so logical_constraint resolves; nullcontext otherwise."""
        if self.mesh is None:
            return contextlib.nullcontext()
        stack = contextlib.ExitStack()
        stack.enter_context(RT.use_mesh(self.mesh))
        stack.enter_context(RT.active_rules(self.rules))
        return stack

    def _constrain_graph(self, g: G.Graph) -> G.Graph:
        """Shard the padded node/edge rows over the engine mesh."""
        lc = RT.logical_constraint
        return dataclasses.replace(
            g,
            node_feat=lc(g.node_feat, ("nodes", None)),
            edge_index=lc(g.edge_index, (None, "edges")),
            edge_feat=lc(g.edge_feat, ("edges", None)),
            node_mask=lc(g.node_mask, ("nodes",)),
            edge_mask=lc(g.edge_mask, ("edges",)),
            graph_id=lc(g.graph_id, ("nodes",)),
        )

    def _constrain_layout(self, layout: LY.GraphLayout) -> LY.GraphLayout:
        """Shard the plan's edge-order arrays like the edge rows they
        index (offsets is (N+1,) and stays replicated)."""
        lc = RT.logical_constraint
        return dataclasses.replace(
            layout,
            perm=lc(layout.perm, ("edges",)),
            ids_sorted=lc(layout.ids_sorted, ("edges",)),
            src_sorted=lc(layout.src_sorted, ("edges",)),
            in_degree=lc(layout.in_degree, ("nodes",)),
        )

    def _bucket_for(self, n: int, e: int) -> tuple:
        for nb, eb in self.buckets:
            if n <= nb and e <= eb:
                return nb, eb
        raise ValueError(f"graph ({n},{e}) exceeds largest bucket {self.buckets[-1]}")

    def _bucket(self, key: tuple, num_graphs: Optional[int] = None) -> _CompiledBucket:
        cb = self._compiled.get(key)
        if cb is None:

            @jax.jit
            def run(params, g: G.Graph, eigvec, layout):
                g = self._constrain_graph(g)
                if eigvec is not None:
                    eigvec = RT.logical_constraint(eigvec, ("nodes",))
                if layout is not None:
                    layout = self._constrain_layout(layout)
                return M.apply(params, g, self.cfg, eigvec=eigvec,
                               num_graphs=num_graphs, layout=layout,
                               share_layout=self.share_layout)

            cb = _CompiledBucket(fn=run)
            self._compiled[key] = cb
        return cb

    def _warm(self, cb: _CompiledBucket, sig: tuple, *args) -> float:
        """Execute once untimed if ``sig`` hasn't run through this bucket
        yet (covers compilation for every distinct trace signature, not
        just the first call).  Returns the time spent warming."""
        if sig in cb.warm:
            return 0.0
        t0 = time.perf_counter()
        jax.block_until_ready(cb.fn(self.params, *args))
        dt = time.perf_counter() - t0
        cb.warm.add(sig)
        cb.compile_s += dt
        return dt

    # ------------------------------------------------------------- modes

    def infer_stream(self, graphs: Iterable[tuple], with_eigvec: bool = False):
        """graphs: iterable of raw (senders, receivers, node_feat, edge_feat
        [, label]) tuples.  Returns (outputs, per-graph latencies seconds,
        compile seconds).  Compilation per bucket is warmed outside the
        timed region and reported separately."""
        outs: List[np.ndarray] = []
        lats: List[float] = []
        compile_time = 0.0
        with self._mesh_scope():
            for graph in graphs:
                s, r, nf, ef = graph[:4]
                nb, eb = self._bucket_for(nf.shape[0], len(s))
                g = G.from_numpy(s, r, nf, ef, n_pad=nb, e_pad=eb)
                eig = self._eigvec(s, r, nf.shape[0], nb) if with_eigvec else None
                cb = self._bucket(("stream", nb, eb), num_graphs=1)
                # layout=None: the compiled step converts COO once on
                # device (the single timed sort of the forward)
                compile_time += self._warm(cb, ("eig", with_eigvec), g, eig, None)
                t0 = time.perf_counter()
                out = jax.block_until_ready(cb.fn(self.params, g, eig, None))
                lats.append(time.perf_counter() - t0)
                outs.append(np.asarray(out[:1]))
        return outs, np.asarray(lats), compile_time

    def infer_batched(self, graphs: Sequence[tuple], batch_size: int,
                      n_pad: int, e_pad: int, with_eigvec: bool = False):
        """Padded-batch mode.  Returns (outputs (n_graphs, out), seconds/graph)."""
        cb = self._bucket(("batched", n_pad, e_pad, batch_size),
                          num_graphs=batch_size)
        outs = []
        total = 0.0
        with self._mesh_scope():
            for i in range(0, len(graphs), batch_size):
                chunk = graphs[i : i + batch_size]
                gs = [(g[0], g[1], g[2], g[3]) for g in chunk]
                g = G.batch_graphs(gs, n_pad=n_pad, e_pad=e_pad)
                eig = None
                if with_eigvec:
                    # per-graph eigenvectors at the packed node offsets
                    # (host-side, built before the timed region)
                    vec = np.zeros((n_pad,), np.float32)
                    off = 0
                    for s, r, nf, _ in gs:
                        n = nf.shape[0]
                        vec[off : off + n] = np.asarray(
                            self._eigvec(s, r, n, n)
                        )
                        off += n
                    eig = jnp.asarray(vec)
                # warm this chunk's exact trace signature untimed: a new
                # signature can show up mid-stream (first chunk, eigvec
                # toggling, a dtype change), not only at i == 0.
                sig = ("eig", with_eigvec) + tuple(
                    (tuple(v.shape), str(v.dtype)) for v in jax.tree.leaves(g)
                )
                self._warm(cb, sig, g, eig, None)
                t0 = time.perf_counter()
                out = jax.block_until_ready(cb.fn(self.params, g, eig, None))
                total += time.perf_counter() - t0
                outs.append(np.asarray(out[: len(chunk)]))
        return np.concatenate(outs), total / len(graphs)

    def infer_packed(self, packed: G.Graph, budget, eigvec=None,
                     warm_only: bool = False, layout=None):
        """Run one already-packed multi-graph batch (``core.batching``).

        ``budget`` is the ``BucketBudget`` the batch was packed against —
        it is the compile-cache key, so every batch packed to the same
        budget reuses one compiled program regardless of how many real
        graphs it carries.  Works identically with and without an engine
        mesh (the packed node/edge rows shard exactly like a single
        graph's).  Returns (outputs (G_pad, out), compute seconds) with
        warm/compile time excluded and tracked in ``compile_seconds``.

        ``layout`` is the batch's ``GraphLayout`` plan, normally emitted
        by the packer (``core.batching.pack_layout``) so the compiled
        program contains zero on-device sorts; when absent (and layout
        sharing is on) the engine builds the host plan here — the plan
        always travels with its batch, never a sort inside the program.
        Plan shapes are functions of the budget, so the compile signature
        per bucket is unchanged.

        ``warm_only`` compiles/warms this batch's signature and returns
        (None, 0.0) without a second timed execution — the scheduler uses
        it to pre-warm budget-ladder rungs.
        """
        key = ("packed", budget.n_pad, budget.e_pad, budget.g_pad)
        cb = self._bucket(key, num_graphs=budget.g_pad)
        if eigvec is not None:
            eigvec = jnp.asarray(eigvec, jnp.float32)
        if layout is None and self.share_layout:
            layout = B.pack_layout(packed)
        with self._mesh_scope():
            sig = ("eig", eigvec is not None, "lay", layout is not None) + tuple(
                (tuple(v.shape), str(v.dtype)) for v in jax.tree.leaves(packed)
            )
            self._warm(cb, sig, packed, eigvec, layout)
            if warm_only:
                return None, 0.0
            t0 = time.perf_counter()
            out = jax.block_until_ready(cb.fn(self.params, packed, eigvec, layout))
            dt = time.perf_counter() - t0
        return np.asarray(out), dt

    def _eigvec(self, s, r, n, n_pad):
        """First non-trivial Laplacian eigenvector — DGN's *input* (the
        paper passes precomputed eigenvectors as a parameter; for synthetic
        streams we compute it on the host as part of data generation)."""
        from repro.data.pipeline import laplacian_eigvec

        return jnp.asarray(laplacian_eigvec(s, r, n, n_pad))
