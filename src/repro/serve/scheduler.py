"""Streaming multi-graph scheduler: request queue + multi-tenant micro-batcher.

The paper's real-time mode serves one graph per program dispatch; under
heavy traffic the dispatch overhead dominates for molecule-sized graphs.
FlowGNN's multi-queue insight applies directly: keep *multiple open
buckets* — one per (tenant, compiled-shape signature) — and greedily pack
arriving graphs into the open bucket for their signature until the
bucket's ``BucketBudget`` is exhausted or a max-wait deadline expires,
then flush the packed batch through the executor.  Every flush of a
signature reuses the same compiled program, so after one warm flush per
signature the stream runs with zero recompiles.

Admission is per-bucket: a request maps to the smallest single-graph
bucket that fits it (``Executor.bucket_for``), and its packed budget is
``capacity`` multiples of that bucket with ``2*capacity`` graph slots —
small graphs pack denser than the worst case, so the node / edge budgets
bind before the slot count does.

Each signature owns a *budget ladder* (rungs 1, 2, 3, 4, 6, 8, 12, ...,
``capacity`` multiples of the base bucket — powers of two and their
1.5x midpoints, bounding padding slack at a flush to ~33%): admission
always targets the top rung, but a flush executes on the smallest rung
that fits what actually accumulated, so a deadline flush carrying one
graph runs a program no bigger than the single-graph mode's.  Ladder
*geometry* is shared across tenants (one ladder per signature, however
many models it serves); warm state is per tenant program, governed by
``prewarm``:

  * ``"eager"`` (single-tenant default, the historical behaviour): every
    rung compiles untimed the first time its signature appears, so a live
    stream never recompiles after warmup no matter how load fluctuates.
  * ``"lazy"`` (multi-tenant default): a rung warms — still strictly
    outside the timed region, tracked in ``compile_seconds`` — on its
    first flush.  One control plane seeing all tenants' traffic only pays
    for the (tenant, rung) programs the load actually exercises, which is
    where the shared executor's warm-time and memory win over N separate
    engines comes from (measured by ``benchmarks/bench_multitenant.py``).

Every flush carries its pack-time payload: ``_execute`` calls
``core.batching.pack_prepared``, which emits the padded graph, the packed
eigenvectors, and the host-built ``GraphLayout`` plan as one
``PreparedBatch`` — the flushed program performs zero on-device sorts
(the paper's COO conversion happens once at pack time and is reused by
every layer, §3.4).

``StreamScheduler.run`` is an event-driven simulation of a live stream on
a single serial executor: arrivals are offered at a configurable rate
(QPS), flushes execute real engine compute (measured wall time), and a
virtual clock folds the two together — so reported per-request latency
includes queueing delay (time waiting for the bucket to fill or the
device to free up), which is what a latency-vs-throughput sweep needs.
Multi-tenant streams tag each request with its model name
(``run(graphs, models=[...])``); packed flushes dispatch per tenant.
"""
from __future__ import annotations

import dataclasses
import math
from collections import Counter
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.batching import (
    BucketBudget,
    graph_sizes,
    pack_prepared,
    unpack_outputs,
)
from repro.serve.executor import Executor


@dataclasses.dataclass
class Request:
    """One in-flight graph: raw COO payload + arrival timestamp + the
    tenant it is routed to (``None`` = the sole registered model)."""

    rid: int
    graph: tuple  # (senders, receivers, node_feat[, edge_feat])
    arrival_s: float
    model: Optional[str] = None
    n: int = 0
    e: int = 0

    def __post_init__(self):
        if len(self.graph) == 3:  # edge-feature-less RawGraph form
            self.graph = (*self.graph, None)
        self.n, self.e = graph_sizes(self.graph)


@dataclasses.dataclass
class StreamReport:
    """Per-request latencies plus stream-level accounting."""

    latencies_s: np.ndarray  # (n_requests,) completion - arrival, rid order
    outputs: List[np.ndarray]  # per-request model outputs, rid order
    batch_sizes: List[int]  # real graphs per flush, flush order
    flush_reasons: Counter  # budget | deadline | drain
    compute_s: float  # total engine compute across flushes
    makespan_s: float  # virtual time from first arrival to last completion
    compile_s: float  # warm/compile time (excluded from latencies)

    @property
    def num_requests(self) -> int:
        return len(self.outputs)

    @property
    def graphs_per_s(self) -> float:
        return self.num_requests / max(self.makespan_s, 1e-12)

    def percentile_ms(self, q: float) -> float:
        return float(np.percentile(self.latencies_s, q) * 1e3)


class _OpenBucket:
    """One (tenant, signature)'s accumulating micro-batch.

    Admission is checked against the *top* rung of the signature's ladder;
    ``rung()`` picks the smallest rung the accumulated batch fits, which
    is the program a flush actually executes.
    """

    __slots__ = ("model", "ladder", "budget", "requests", "n_used", "e_used",
                 "deadline_s")

    def __init__(self, ladder: Sequence[BucketBudget], opened_at_s: float,
                 max_wait_s: float, model: Optional[str] = None):
        self.model = model
        self.ladder = ladder
        self.budget = ladder[-1]
        self.requests: List[Request] = []
        self.n_used = 0
        self.e_used = 0
        self.deadline_s = opened_at_s + max_wait_s

    def rung(self) -> BucketBudget:
        for b in self.ladder:
            if (self.n_used <= b.n_pad and self.e_used <= b.e_pad
                    and len(self.requests) <= b.g_pad):
                return b
        return self.budget

    def admits(self, req: Request) -> bool:
        return self.budget.admits(self.n_used, self.e_used, len(self.requests),
                                  req.n, req.e)

    def add(self, req: Request) -> None:
        self.requests.append(req)
        self.n_used += req.n
        self.e_used += req.e

    @property
    def full(self) -> bool:
        """No further graph could ever be admitted (slot count exhausted)."""
        return len(self.requests) >= self.budget.g_pad


class StreamScheduler:
    """Micro-batching front-end for the serving executor.

    engine:      a single-tenant ``GNNEngine`` facade **or** a multi-tenant
                 ``Executor`` — all compute and warm bookkeeping goes
                 through the executor either way.
    capacity:    packed budgets are ``capacity`` multiples of the base
                 single-graph bucket (with ``2*capacity`` graph slots).
    max_wait_s:  a bucket flushes at latest this long after it opened —
                 the latency ceiling a request pays for batching.
    with_eigvec: compute DGN's Laplacian-eigenvector input per request
                 (host-side, part of data generation, as in the paper);
                 ``"auto"`` resolves per tenant (eigvec iff the tenant's
                 model is DGN) — the multi-tenant setting.
    prewarm:     ``"eager"`` / ``"lazy"`` ladder warm policy (see module
                 docstring); default eager for a single engine (the
                 historical guarantee), lazy for a multi-tenant executor.
    """

    def __init__(
        self,
        engine: Union[Executor, object],
        capacity: int = 4,
        max_wait_s: float = 0.002,
        with_eigvec: Union[bool, str] = False,
        budgets: Optional[Dict[tuple, Sequence[BucketBudget]]] = None,
        prewarm: Optional[str] = None,
    ):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if isinstance(engine, Executor):
            self.engine = None
            self.executor = engine
            self._default_model = None
        else:  # a GNNEngine facade
            self.engine = engine
            self.executor = engine.executor
            self._default_model = engine.name
        if prewarm is None:
            prewarm = "eager" if self.engine is not None else "lazy"
        if prewarm not in ("eager", "lazy"):
            raise ValueError(f"prewarm must be 'eager' or 'lazy', got {prewarm!r}")
        self.prewarm = prewarm
        self.capacity = capacity
        self.max_wait_s = max_wait_s
        self.with_eigvec = with_eigvec
        # signature key -> ascending budget ladder (custom or derived);
        # geometry is shared across tenants
        self._ladders: Dict[tuple, List[BucketBudget]] = {
            k: sorted(v) for k, v in (budgets or {}).items()
        }

    # ------------------------------------------------------------ admission

    def _needs_eigvec(self, model: Optional[str]) -> bool:
        if self.with_eigvec == "auto":
            return self.executor.tenant(model).cfg.model == "dgn"
        return bool(self.with_eigvec)

    def ladder_for(self, req: Request) -> Tuple[tuple, List[BucketBudget]]:
        """Map a request to its signature key and budget ladder.

        Under eager prewarm, the first time a (tenant, signature) pair
        appears every rung is warmed untimed (the executor tracks the cost
        in ``compile_seconds``), so no rung ever compiles inside the
        measured stream; under lazy prewarm, rungs warm on first flush
        instead (still untimed).
        """
        nb, eb = self.executor.bucket_for(req.n, req.e)
        key = (nb, eb)
        ladder = self._ladders.get(key)
        if ladder is None:
            ks, k = set(), 1
            while k < self.capacity:
                ks.add(k)
                if k + k // 2 < self.capacity:
                    ks.add(k + k // 2)  # 1.5x midpoint: 3, 6, 12, ...
                k *= 2
            ks.add(self.capacity)
            ladder = self._ladders[key] = [
                BucketBudget(n_pad=k * nb, e_pad=k * eb, g_pad=2 * k)
                for k in sorted(ks)
            ]
        if self.prewarm == "eager":
            self._warm_ladder(ladder, req)
        return key, ladder

    def _warm_ladder(self, ladder: Sequence[BucketBudget], req: Request) -> None:
        """Compile every rung of a ladder for this request's tenant before
        it can appear in a timed flush.  A minimal dummy graph (1 node,
        1 self-edge, the stream's feature dims) produces the exact padded
        trace signature."""
        model = req.model if req.model is not None else self._default_model
        if all(
            self.executor.has_program(
                ("packed", b.n_pad, b.e_pad, b.g_pad), b.g_pad, model=model
            )
            for b in ladder
        ):
            return
        feat = req.graph[2].shape[1]
        edge = req.graph[3].shape[1] if req.graph[3] is not None else 1
        zero = np.zeros(1, np.int32)
        dummy = (zero, zero, np.zeros((1, feat), np.float32),
                 np.zeros((1, edge), np.float32))
        need_eig = self._needs_eigvec(model)
        tenant = self.executor.tenant(model)
        for budget in ladder:
            prep, _ = pack_prepared(
                [dummy], budget,
                eigvecs=[np.zeros(1, np.float32)] if need_eig else None,
                with_layout=tenant.share_layout,
            )
            self.executor.warm(prep, model=model)

    # -------------------------------------------------------------- serving

    def run(self, graphs: Sequence[tuple], qps: float = 0.0,
            models: Optional[Sequence[Optional[str]]] = None) -> StreamReport:
        """Serve a stream of raw COO graphs and account per-request latency.

        ``qps`` > 0 offers request i at virtual time i/qps; ``qps`` <= 0
        means the whole stream is already queued at t=0 (offline /
        saturation mode).  ``models`` tags request i with a tenant name;
        ``None`` entries (or omitting ``models``) route to the sole
        tenant and are rejected up front when several are registered.
        Compute time is real measured engine time; compile/warm time is
        excluded (tracked in the report).
        """
        if models is not None and len(models) != len(graphs):
            raise ValueError(
                f"models ({len(models)}) must tag every graph ({len(graphs)})"
            )
        if (self._default_model is None and len(self.executor.tenants) > 1
                and (models is None or any(m is None for m in models))):
            raise ValueError(
                "untagged requests are ambiguous on a multi-tenant executor: "
                "pass models=[...] naming a registered tenant per graph "
                f"(registered: {sorted(self.executor.tenants)})"
            )
        requests = [
            Request(rid=i, graph=g[:4],
                    arrival_s=(i / qps if qps > 0 else 0.0),
                    model=(models[i] if models is not None
                           else self._default_model))
            for i, g in enumerate(graphs)
        ]
        compile_before = self.executor.compile_seconds

        open_buckets: Dict[tuple, _OpenBucket] = {}
        outputs: List[Optional[np.ndarray]] = [None] * len(requests)
        latencies = np.zeros(len(requests))
        batch_sizes: List[int] = []
        reasons: Counter = Counter()
        device_free_s = 0.0
        compute_s = 0.0
        last_done_s = 0.0

        def flush(key: tuple, at_s: float, reason: str) -> None:
            nonlocal device_free_s, compute_s, last_done_s
            bucket = open_buckets.pop(key)
            outs, dt = self._execute(bucket)
            start_s = max(at_s, device_free_s)
            done_s = start_s + dt
            device_free_s = done_s
            compute_s += dt
            last_done_s = max(last_done_s, done_s)
            for req, out in zip(bucket.requests, outs):
                outputs[req.rid] = out
                latencies[req.rid] = done_s - req.arrival_s
            batch_sizes.append(len(bucket.requests))
            reasons[reason] += 1

        idx = 0
        while idx < len(requests) or open_buckets:
            next_arrival_s = requests[idx].arrival_s if idx < len(requests) else math.inf
            ddl_key, ddl_s = None, math.inf
            for k, b in open_buckets.items():
                if b.deadline_s < ddl_s:
                    ddl_key, ddl_s = k, b.deadline_s
            # a deadline only matters once the device could actually start
            # the batch: while the executor is backlogged, extra waiting is
            # free, so keep the bucket open and let late arrivals pack in
            # (this is what makes throughput plateau instead of collapse
            # under overload)
            eff_ddl_s = max(ddl_s, device_free_s) if ddl_key is not None else math.inf
            if eff_ddl_s <= next_arrival_s:
                flush(ddl_key, eff_ddl_s,
                      "deadline" if idx < len(requests) else "drain")
                continue
            req = requests[idx]
            idx += 1
            sig, ladder = self.ladder_for(req)
            key = (req.model, sig)
            bucket = open_buckets.get(key)
            if bucket is not None and not bucket.admits(req):
                flush(key, req.arrival_s, "budget")
                bucket = None
            if bucket is None:
                bucket = _OpenBucket(ladder, req.arrival_s, self.max_wait_s,
                                     model=req.model)
                open_buckets[key] = bucket
            bucket.add(req)
            if bucket.full:
                flush(key, req.arrival_s, "budget")

        return StreamReport(
            latencies_s=latencies,
            outputs=[o for o in outputs],
            batch_sizes=batch_sizes,
            flush_reasons=reasons,
            compute_s=compute_s,
            makespan_s=max(last_done_s - (requests[0].arrival_s if requests else 0.0),
                           1e-12),
            compile_s=self.executor.compile_seconds - compile_before,
        )

    # ------------------------------------------------------------- internal

    def _execute(self, bucket: _OpenBucket) -> Tuple[List[np.ndarray], float]:
        """Pack one open bucket on its smallest fitting rung and run it
        through the executor for the bucket's tenant.  The pack-time
        payload (padded graph, packed eigenvectors, host-built layout
        plan) is one ``PreparedBatch`` — zero on-device sorts in the
        flushed program."""
        model = bucket.model
        tenant = self.executor.tenant(model)
        raws = [r.graph for r in bucket.requests]
        rung = bucket.rung()
        vecs = None
        if self._needs_eigvec(model):
            vecs = [
                np.asarray(self.executor._eigvec(s, r, nf.shape[0], nf.shape[0]))
                for s, r, nf, _ in (g[:4] for g in raws)
            ]
        prep, meta = pack_prepared(raws, rung, eigvecs=vecs,
                                   with_layout=tenant.share_layout)
        out, dt = self.executor.run(prep, model=model)
        level = "graph" if tenant.cfg.task == "graph" else "node"
        return unpack_outputs(out, meta, level=level), dt
