"""Streaming multi-graph scheduler: SLO-aware admission + micro-batcher.

The paper's real-time mode serves one graph per program dispatch; under
heavy traffic the dispatch overhead dominates for molecule-sized graphs.
FlowGNN's multi-queue insight applies directly: keep *multiple open
buckets* — one per (tenant, QoS class, compiled-shape signature) — and
greedily pack arriving graphs into the open bucket for their key until
the bucket's ``BucketBudget`` is exhausted or a flush deadline expires,
then flush the packed batch through the executor.  Every flush of a
signature reuses the same compiled program, so after one warm flush per
signature the stream runs with zero recompiles.

**Time.** Nothing here reads a wall clock.  All ``arrival_s`` /
``deadline_s`` / flush timing flows through an injectable
``serve.clock.Clock`` that the event loop advances deterministically —
the default is a fresh ``VirtualClock`` per ``run``, so a scripted
arrival trace reproduces every flush timestamp and shed decision
bitwise (``tests/test_slo_sim.py`` asserts exact float equality).  The
only real-time measurement in the serving stack is the executor's
compute region (``tools/check_engine_singlepath.py`` enforces that
``time`` is untouchable outside ``serve/executor.py`` + ``serve/clock.py``).

**Admission (SLO-aware).**  A request maps to the smallest single-graph
bucket that fits it (``Executor.bucket_for``) and carries a QoS class
(``Request.priority``, lower = more urgent) and an SLO budget
(``slo_s``, resolved per (tenant, class)).  At its arrival instant the
scheduler projects the queueing delay the request would suffer —
``max(0, device_free - now)``, plus one observed service-time estimate
per already-open bucket (admitted work the device has not seen yet),
plus the flush this request would ride — and **sheds** the request with
a typed :class:`Shed` result when the projection exceeds
``admit_margin * slo`` (the guard band absorbs flushes that insert
ahead after admission; see the ``admit_margin`` docstring)
(no executor work, no queue growth) when the projection already exceeds
the SLO; an optional ``admit_limit`` bounds the total admitted-but-
unflushed queue the same way (reason ``"queue_full"``).  Under overload
the queue therefore stays bounded and the p99 of *admitted* requests
holds near the SLO while the shed rate absorbs the excess — goodput
degrades gracefully instead of latency collapsing
(``benchmarks/bench_slo.py`` sweeps 0.5x–2x capacity and asserts this).

**Flush ordering (QoS).**  A bucket's flush deadline is the earliest of
``opened_at + max_wait_s`` and each member's SLO deadline minus the
service estimate.  When several buckets are ready at the same effective
instant (the common case under backlog, where every expired bucket waits
on ``device_free``), the highest-priority class flushes first; ties
break by bucket age — a deterministic total order.

**Budget ladder.**  Each signature owns rungs at 1x, 2x, 3x, 4x, 6x,
8x, ..., ``capacity``x of the base bucket (powers of two plus their 1.5x
midpoints, bounding padding slack at a flush to ~33%): admission always
targets the top rung, but a flush executes on the smallest rung that
fits what actually accumulated.  With ``adapt_ladder=True`` the rung
geometry *re-fits itself* to the observed flush-size histogram every
``refit_every`` flushes per signature: rungs traffic never hits are
closed, rungs the histogram needs are opened (and warm lazily, riding
the ``prewarm="lazy"`` machinery), while the top rung is always kept at
``capacity`` so everything admissible before a refit stays admissible
after it.  Ladder *geometry* is shared across tenants; warm state is per
tenant program, governed by ``prewarm``:

  * ``"eager"`` (single-tenant default, the historical behaviour): every
    rung compiles untimed the first time its signature appears, so a live
    stream never recompiles after warmup no matter how load fluctuates.
  * ``"lazy"`` (multi-tenant default): a rung warms — still strictly
    outside the timed region, tracked in ``compile_seconds`` +
    ``warm_seconds`` — on its first flush.

Every flush carries its pack-time payload: ``_execute`` calls
``core.batching.pack_prepared``, which emits the padded graph, the packed
eigenvectors, and the host-built ``GraphLayout`` plan as one
``PreparedBatch`` — the flushed program performs zero on-device sorts.

``StreamScheduler.run`` is an event-driven simulation of a live stream on
a single serial executor: arrivals are offered at a configurable rate
(QPS) or as an explicit timestamp trace, flushes execute real engine
compute (measured wall time inside the executor), and the virtual clock
folds the two together — so reported per-request latency includes
queueing delay, which is what a latency-vs-throughput sweep needs.

**Telemetry.**  Pass ``tracer=obs.Tracer(clock)`` / ``metrics=obs.
MetricsRegistry()`` to record the full request lifecycle (admit/shed ->
queue -> pack -> flush -> device -> unpack -> respond as spans on the
run's clock timeline) and the serving counter catalog (sheds by reason,
flushes by reason, latency histograms, queue depth, per-signature
service EWMA — ``obs.metrics.CATALOG``).  Both default off; the no-op
sink is provably free — identical flush log, zero extra compile keys,
zero clock reads (``tests/test_obs.py``).  ``StreamReport``'s
aggregates are views over the same flush/shed event records the
registry is fed from, so the two surfaces agree by construction.
"""
from __future__ import annotations

import dataclasses
import math
from collections import Counter, deque
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.batching import (
    BucketBudget,
    graph_sizes,
    pack_prepared,
    unpack_outputs,
)
from repro.obs.metrics import MetricsRegistry, ServingInstruments
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serve.clock import Clock, VirtualClock
from repro.serve.executor import Executor
from repro.serve.pipeline import PipelineConfig, as_pipeline


def _tenant_label(model: Optional[str]) -> str:
    """Metric/trace label for a tenant: ``None`` (the sole tenant on a
    bare executor) renders as ``"default"`` so label values are never
    the string ``"None"``."""
    return model if model is not None else "default"


@dataclasses.dataclass
class Request:
    """One in-flight graph: raw COO payload + arrival timestamp + routing.

    ``model`` names the tenant (``None`` = the sole registered model);
    ``priority`` is the QoS class (lower = more urgent, 0 = default);
    ``slo_s`` is the end-to-end latency budget from arrival (``inf`` =
    best-effort, never shed, never deadline-tightened)."""

    rid: int
    graph: tuple  # (senders, receivers, node_feat[, edge_feat])
    arrival_s: float
    model: Optional[str] = None
    priority: int = 0
    slo_s: float = math.inf
    n: int = 0
    e: int = 0

    def __post_init__(self):
        if len(self.graph) == 3:  # edge-feature-less RawGraph form
            self.graph = (*self.graph, None)
        self.n, self.e = graph_sizes(self.graph)

    @property
    def deadline_s(self) -> float:
        """The SLO deadline: completion after this is a deadline miss."""
        return self.arrival_s + self.slo_s


@dataclasses.dataclass(frozen=True)
class Shed:
    """A typed admission rejection — the backpressure signal a caller can
    retry, downgrade, or route elsewhere on.  ``projected_delay_s`` is
    the queueing-delay estimate that triggered the decision."""

    rid: int
    model: Optional[str]
    priority: int
    reason: str  # "backlog" | "queue_full"
    at_s: float  # virtual admission instant
    projected_delay_s: float
    slo_s: float


@dataclasses.dataclass(frozen=True)
class FlushRecord:
    """One flush event, fully timestamped on the virtual clock — the
    deterministic audit trail the simulation tests assert against, and
    the *primary record* every stream-level tally is a view over
    (``StreamReport.batch_sizes`` / ``flush_reasons`` / ``compute_s`` /
    ``deadline_misses`` are all derived from the flush log, never
    counted in parallel)."""

    model: Optional[str]
    priority: int
    sig: tuple  # base-bucket signature (N_pad, E_pad)
    rids: Tuple[int, ...]
    reason: str  # budget | deadline | drain
    at_s: float  # flush decision instant
    start_s: float  # when the device actually started (>= at_s)
    done_s: float  # start_s + compute
    compute_s: float
    rung_multiple: int  # executed rung, in base-bucket multiples
    misses: int = 0  # members whose done_s exceeded their SLO deadline


@dataclasses.dataclass
class StreamReport:
    """Per-request latencies plus stream-level accounting.

    ``outputs`` / ``latencies_s`` are rid-ordered over every *offered*
    request; shed requests hold ``None`` / ``nan`` there and appear as
    typed :class:`Shed` entries in ``shed``.  Conservation always holds:
    ``num_served + num_shed == num_requests``.

    The report stores only the primary event records — the flush log and
    the shed list.  Every aggregate (``batch_sizes``, ``flush_reasons``,
    ``compute_s``, ``deadline_misses``, the served/shed counts) is a
    *view* derived from those records, never a parallel tally; when a
    metrics registry is attached to the scheduler, the registry's
    counters are fed from the same events, so the two surfaces agree by
    construction (``benchmarks/bench_slo.py`` asserts the equality)."""

    latencies_s: np.ndarray  # (n_offered,) completion - arrival; nan if shed
    outputs: List[Optional[np.ndarray]]  # rid order; None for shed requests
    makespan_s: float  # virtual time from first arrival to last completion
    compile_s: float  # untimed compile + first-run warm (excluded from latencies)
    shed: List[Shed] = dataclasses.field(default_factory=list)
    flush_log: List[FlushRecord] = dataclasses.field(default_factory=list)

    @property
    def batch_sizes(self) -> List[int]:
        """Real graphs per flush, flush order (view over the flush log)."""
        return [len(f.rids) for f in self.flush_log]

    @property
    def flush_reasons(self) -> Counter:
        """budget | deadline | drain counts (view over the flush log)."""
        return Counter(f.reason for f in self.flush_log)

    @property
    def compute_s(self) -> float:
        """Total engine compute across flushes (view over the flush log)."""
        return sum((f.compute_s for f in self.flush_log), 0.0)

    @property
    def deadline_misses(self) -> int:
        """Admitted requests that finished past their SLO (view over the
        flush log's per-flush miss counts)."""
        return sum(f.misses for f in self.flush_log)

    @property
    def num_requests(self) -> int:
        """Offered requests (served + shed)."""
        return len(self.outputs)

    @property
    def num_shed(self) -> int:
        return len(self.shed)

    @property
    def num_served(self) -> int:
        return self.num_requests - self.num_shed

    @property
    def shed_rate(self) -> float:
        return self.num_shed / max(self.num_requests, 1)

    @property
    def graphs_per_s(self) -> float:
        """Goodput: *served* graphs per second of makespan."""
        return self.num_served / max(self.makespan_s, 1e-12)

    def percentile_ms(self, q: float) -> float:
        """Latency percentile over the requests that were actually served.

        ``nan`` when nothing was served (empty stream, or everything
        shed) — an empty report must be representable, not a crash."""
        served = self.latencies_s[np.isfinite(self.latencies_s)]
        if served.size == 0:
            return float("nan")
        return float(np.percentile(served, q) * 1e3)


class _OpenBucket:
    """One (tenant, QoS class, signature)'s accumulating micro-batch.

    Admission is checked against the *top* rung of the signature's ladder;
    ``rung()`` picks the smallest rung the accumulated batch fits, which
    is the program a flush actually executes.  The flush deadline starts
    at ``opened_at + max_wait_s`` and tightens as SLO-carrying members
    join (their deadline minus the service estimate, clamped at their
    arrival), so a bucket never idles a member into a deadline miss the
    scheduler could have avoided.
    """

    __slots__ = ("model", "priority", "seq", "ladder", "budget", "requests",
                 "n_used", "e_used", "deadline_s")

    def __init__(self, ladder: Sequence[BucketBudget], opened_at_s: float,
                 max_wait_s: float, model: Optional[str] = None,
                 priority: int = 0, seq: int = 0):
        self.model = model
        self.priority = priority
        self.seq = seq  # open order: the deterministic final tie-break
        self.ladder = ladder
        self.budget = ladder[-1]
        self.requests: List[Request] = []
        self.n_used = 0
        self.e_used = 0
        self.deadline_s = opened_at_s + max_wait_s

    def rung(self) -> BucketBudget:
        for b in self.ladder:
            if (self.n_used <= b.n_pad and self.e_used <= b.e_pad
                    and len(self.requests) <= b.g_pad):
                return b
        return self.budget

    def admits(self, req: Request) -> bool:
        return self.budget.admits(self.n_used, self.e_used, len(self.requests),
                                  req.n, req.e)

    def add(self, req: Request, service_est_s: float = 0.0) -> None:
        self.requests.append(req)
        self.n_used += req.n
        self.e_used += req.e
        if math.isfinite(req.slo_s):
            self.deadline_s = min(
                self.deadline_s,
                max(req.arrival_s, req.deadline_s - service_est_s),
            )

    @property
    def full(self) -> bool:
        """No further graph could ever be admitted (slot count exhausted)."""
        return len(self.requests) >= self.budget.g_pad


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unharvested flush in the pipelined in-flight
    window.  Every field is fixed at dispatch (the device is serial, so
    the modeled completion instant is known then); the harvest step only
    finalizes — response order, flush-log append, trace/metric emission —
    strictly FIFO off the window front."""

    key: tuple  # (model, priority, sig)
    bucket: _OpenBucket
    rung: BucketBudget
    outs: List[np.ndarray]
    reason: str
    at_s: float  # flush decision instant
    start_s: float  # dispatch instant (host pack done, run_async issued)
    begin_s: float  # device actually starts (>= start_s under backlog)
    done_s: float  # begin_s + compute: the completion/harvest instant
    compute_s: float


class StreamScheduler:
    """SLO-aware micro-batching front-end for the serving executor.

    engine:       a single-tenant ``GNNEngine`` facade **or** a
                  multi-tenant ``Executor`` — all compute and warm
                  bookkeeping goes through the executor either way.
    capacity:     packed budgets are ``capacity`` multiples of the base
                  single-graph bucket (with ``2*capacity`` graph slots).
    max_wait_s:   the batching latency ceiling: a bucket flushes at latest
                  this long after it opened (SLO deadlines can tighten
                  an individual bucket further, never loosen it).
    with_eigvec:  compute DGN's Laplacian-eigenvector input per request;
                  ``"auto"`` resolves per tenant (eigvec iff DGN).
    budgets:      explicit per-signature ladders (overrides derivation).
    prewarm:      ``"eager"`` / ``"lazy"`` ladder warm policy (see module
                  docstring); default eager for a single engine, lazy for
                  a multi-tenant executor.
    slo_s:        default SLO budget (seconds from arrival) for every
                  request; ``None`` = best-effort (no shedding, no
                  deadline accounting) — the historical behaviour.
    slo_by_class: ``{(model|None, priority): slo_s}`` overrides — the
                  per-(tenant, QoS class) SLO table; ``None`` model keys
                  apply to every tenant.
    admit_limit:  bound on admitted-but-unflushed requests; arrivals
                  beyond it shed with reason ``"queue_full"``.
    admit_margin: fraction of the SLO the admission projection may use
                  (0 < margin <= 1, default 1.0).  Under sustained
                  overload, flushes of buckets *filled after* a request
                  was admitted legitimately run before its own
                  deadline-flush, so projecting against the full SLO
                  leaves the tail no headroom; a guard band (e.g. 0.7)
                  sheds at ``projected > margin * slo`` and keeps the
                  p99 of served requests inside the advertised SLO.
                  Deadline accounting still uses the full SLO.
    adapt_ladder: re-fit each signature's rung geometry to the observed
                  flush-size histogram every ``refit_every`` flushes
                  (top rung pinned at ``capacity``; at most ``max_rungs``
                  rungs survive a refit).
    service_s:    initial per-signature service-time estimate used by
                  admission / deadline tightening before the first flush
                  is observed (then an EWMA of measured flush compute).
    svc_alpha:    EWMA coefficient of the per-signature service-time
                  estimate: ``ewma = (1 - svc_alpha) * ewma + svc_alpha
                  * observed`` per flush.  Default 0.5 (the historical
                  half-life-of-one-flush behaviour); smaller = smoother
                  admission projections under noisy compute, larger =
                  faster tracking after a workload shift.  The live
                  per-signature EWMA is exported as the
                  ``serve_service_ewma_seconds{sig=...}`` gauge when a
                  registry is attached.
    tracer:       an ``obs.trace.Tracer`` recording the request
                  lifecycle (admit/shed -> queue -> pack -> flush ->
                  device -> unpack -> respond; docs/OBSERVABILITY.md).
                  Default ``None`` = the shared no-op ``NULL_TRACER``
                  (provably free: identical flush log, zero clock
                  reads).  ``run`` rebinds the tracer's clock to the
                  run's clock so span timestamps share the timeline.
    metrics:      an ``obs.metrics.MetricsRegistry`` receiving the
                  serving counters/gauges/histograms (the catalog in
                  ``obs.metrics.CATALOG``).  Default ``None`` = off.
                  Both sinks are also attached to the executor (if it
                  has none yet) so compile/warm/device accounting lands
                  in the same trace and registry.
    clock:        the time authority; ``None`` = a fresh deterministic
                  ``VirtualClock`` per ``run``.  Inject a shared clock to
                  chain runs on one timeline, or a ``RealClock`` to stamp
                  live arrivals.
    pipeline:     pipelined (dispatch-ahead) execution mode.  ``None`` /
                  ``False`` = the serial event loop (historical
                  behaviour, bitwise-unchanged); ``True`` = defaults
                  (in-flight depth 2); an int = that depth; a
                  ``serve.pipeline.PipelineConfig`` = full control,
                  including the modeled per-flush host-pack cost.  In
                  pipelined mode a bucket dispatches at its deadline
                  whenever the bounded in-flight window has room — the
                  device need not be free — and completions are
                  harvested strictly FIFO, so per-request response order
                  is preserved while host pack for flush k+1 overlaps
                  device compute for flush k on the (virtual) timeline.
                  ``FlushRecord.start_s`` is then the *dispatch* instant
                  (host pack done, ``run_async`` issued), not the device
                  start; ``done_s`` stays the completion instant.
                  Admission projection adds a per-signature host-pack
                  EWMA on top of the serial device-backlog model (with
                  the default free host cost it reduces exactly to the
                  serial projection).  Deterministic under
                  ``VirtualClock``: the loop stays single-threaded and
                  models the overlap; live threading lives only in
                  ``serve.pipeline.PipelinedStream``.
    """

    def __init__(
        self,
        engine: Union[Executor, object],
        capacity: int = 4,
        max_wait_s: float = 0.002,
        with_eigvec: Union[bool, str] = False,
        budgets: Optional[Dict[tuple, Sequence[BucketBudget]]] = None,
        prewarm: Optional[str] = None,
        slo_s: Optional[float] = None,
        slo_by_class: Optional[Dict[Tuple[Optional[str], int], float]] = None,
        admit_limit: Optional[int] = None,
        admit_margin: float = 1.0,
        adapt_ladder: bool = False,
        refit_every: int = 64,
        max_rungs: int = 8,
        service_s: float = 0.0,
        svc_alpha: float = 0.5,
        tracer: Optional[Tracer] = None,
        metrics: Optional[MetricsRegistry] = None,
        clock: Optional[Clock] = None,
        pipeline: Union[None, bool, int, PipelineConfig] = None,
    ):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if isinstance(engine, Executor):
            self.engine = None
            self.executor = engine
            self._default_model = None
        else:  # a GNNEngine facade
            self.engine = engine
            self.executor = engine.executor
            self._default_model = engine.name
        if prewarm is None:
            prewarm = "eager" if self.engine is not None else "lazy"
        if prewarm not in ("eager", "lazy"):
            raise ValueError(f"prewarm must be 'eager' or 'lazy', got {prewarm!r}")
        if admit_limit is not None and admit_limit < 1:
            raise ValueError("admit_limit must be >= 1 (or None for unbounded)")
        if not 0.0 < admit_margin <= 1.0:
            raise ValueError("admit_margin must be in (0, 1]")
        if refit_every < 1:
            raise ValueError("refit_every must be >= 1")
        if max_rungs < 2:
            raise ValueError("max_rungs must be >= 2 (base + top)")
        if not 0.0 < svc_alpha <= 1.0:
            raise ValueError("svc_alpha must be in (0, 1]")
        self.prewarm = prewarm
        self.capacity = capacity
        self.max_wait_s = max_wait_s
        self.with_eigvec = with_eigvec
        self.slo_s = slo_s
        self.slo_by_class = dict(slo_by_class or {})
        self.admit_limit = admit_limit
        self.admit_margin = admit_margin
        self.adapt_ladder = adapt_ladder
        self.refit_every = refit_every
        self.max_rungs = max_rungs
        self.service_s = service_s
        self.svc_alpha = svc_alpha
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        self._mi = ServingInstruments(metrics) if metrics is not None else None
        if (tracer is not None or metrics is not None):
            # compile/warm/device accounting lands in the same sinks; an
            # executor that already carries its own telemetry keeps it
            self.executor.attach_telemetry(tracer=tracer, metrics=metrics)
        self.clock = clock
        # signature key -> ascending budget ladder (custom or derived);
        # geometry is shared across tenants
        self._ladders: Dict[tuple, List[BucketBudget]] = {
            k: sorted(v) for k, v in (budgets or {}).items()
        }
        self._pipeline = as_pipeline(pipeline)
        # per-signature service-time EWMA (measured flush compute) and the
        # observed ideal-rung-multiple window the adaptive refit consumes
        self._svc_s: Dict[tuple, float] = {}
        self._obs_multiples: Dict[tuple, List[int]] = {}
        # per-signature host-pack EWMA (pipelined admission projection)
        self._pack_s: Dict[tuple, float] = {}

    # ------------------------------------------------------------ admission

    def _needs_eigvec(self, model: Optional[str]) -> bool:
        if self.with_eigvec == "auto":
            return self.executor.tenant(model).cfg.model == "dgn"
        return bool(self.with_eigvec)

    def resolve_slo_s(self, model: Optional[str], priority: int) -> float:
        """The SLO budget for one (tenant, QoS class): the class table
        first (tenant-specific beats wildcard), then the default."""
        for key in ((model, priority), (None, priority)):
            if key in self.slo_by_class:
                return float(self.slo_by_class[key])
        return float(self.slo_s) if self.slo_s is not None else math.inf

    def service_estimate_s(self, sig: tuple) -> float:
        """The signature's observed service-time EWMA (initially
        ``service_s``) — the deterministic input to shed decisions and
        deadline tightening."""
        return self._svc_s.get(sig, self.service_s)

    def pack_estimate_s(self, sig: tuple) -> float:
        """The signature's host-pack EWMA (pipelined mode only; 0.0
        before the first flush, and identically 0.0 under the default
        free modeled host cost — which is what makes the pipelined
        admission projection reduce to the serial one)."""
        return self._pack_s.get(sig, 0.0)

    def _observe_pack(self, sig: tuple, pack_s: float) -> None:
        """Fold one flush's host-pack seconds (modeled or measured) into
        the signature's pack EWMA — same ``svc_alpha`` coefficient as
        the service estimate."""
        prev = self._pack_s.get(sig)
        a = self.svc_alpha
        self._pack_s[sig] = (pack_s if prev is None
                             else (1.0 - a) * prev + a * pack_s)
        if self._mi is not None:
            self._mi.pack_ewma.set(self._pack_s[sig], sig=f"{sig[0]}x{sig[1]}")

    def ladder_multiples(self, sig: tuple) -> List[int]:
        """Current rung geometry of one signature, in base-bucket
        multiples (bench/test introspection)."""
        nb, _ = sig
        return [b.n_pad // nb for b in self._ladders.get(sig, [])]

    def ladder_for(self, req: Request) -> Tuple[tuple, List[BucketBudget]]:
        """Map a request to its signature key and budget ladder.

        Under eager prewarm, the first time a (tenant, signature) pair
        appears every rung is warmed untimed (the executor tracks the cost
        in ``compile_seconds``), so no rung ever compiles inside the
        measured stream; under lazy prewarm, rungs warm on first flush
        instead (still untimed).
        """
        nb, eb = self.executor.bucket_for(req.n, req.e)
        key = (nb, eb)
        ladder = self._ladders.get(key)
        if ladder is None:
            ks, k = set(), 1
            while k < self.capacity:
                ks.add(k)
                if k + k // 2 < self.capacity:
                    ks.add(k + k // 2)  # 1.5x midpoint: 3, 6, 12, ...
                k *= 2
            ks.add(self.capacity)
            ladder = self._ladders[key] = [
                BucketBudget(n_pad=k * nb, e_pad=k * eb, g_pad=2 * k)
                for k in sorted(ks)
            ]
        if self.prewarm == "eager":
            self._warm_ladder(ladder, req)
        return key, ladder

    def _refit_ladder(self, sig: tuple) -> None:
        """Re-fit one signature's rung geometry to its observed flush-size
        histogram: keep the rung multiples traffic actually needed, open
        ones it asked for between old rungs, close the rest.  Invariants
        (property-tested): the top rung stays exactly ``capacity`` (so
        admission capacity never shrinks), geometry stays sorted, every
        multiple stays in ``[1, capacity]``, and at most ``max_rungs``
        survive.  Open buckets keep their captured ladder object, so a
        refit never strands an in-flight batch."""
        obs = self._obs_multiples.get(sig)
        if not obs:
            return
        nb, eb = sig
        ks = sorted({min(max(int(k), 1), self.capacity) for k in obs})
        if self.capacity not in ks:
            ks.append(self.capacity)
        if len(ks) > self.max_rungs:
            # evenly-spaced quantiles of the observed set, endpoints pinned
            idx = np.linspace(0, len(ks) - 1, self.max_rungs).round().astype(int)
            ks = sorted({ks[i] for i in idx})
        self._ladders[sig] = [
            BucketBudget(n_pad=k * nb, e_pad=k * eb, g_pad=2 * k) for k in ks
        ]
        self._obs_multiples[sig] = []
        if self._mi is not None:
            self._mi.ladder_refits.inc(sig=f"{nb}x{eb}")

    def _observe_flush(self, sig: tuple, bucket: _OpenBucket, dt: float) -> None:
        """Fold one flush into the signature's service-time EWMA (the
        ``svc_alpha`` knob) and (when adaptive) its rung-demand
        histogram, refitting on a full window."""
        prev = self._svc_s.get(sig)
        a = self.svc_alpha
        self._svc_s[sig] = dt if prev is None else (1.0 - a) * prev + a * dt
        if self._mi is not None:
            self._mi.service_ewma.set(self._svc_s[sig],
                                      sig=f"{sig[0]}x{sig[1]}")
        if not self.adapt_ladder:
            return
        nb, eb = sig
        ideal = max(
            -(-bucket.n_used // nb),  # ceil div
            -(-bucket.e_used // eb),
            -(-len(bucket.requests) // 2),
            1,
        )
        window = self._obs_multiples.setdefault(sig, [])
        window.append(min(ideal, self.capacity))
        if len(window) >= self.refit_every:
            self._refit_ladder(sig)

    def _warm_ladder(self, ladder: Sequence[BucketBudget], req: Request) -> None:
        """Compile every rung of a ladder for this request's tenant before
        it can appear in a timed flush.  A minimal dummy graph (1 node,
        1 self-edge, the stream's feature dims) produces the exact padded
        trace signature."""
        model = req.model if req.model is not None else self._default_model
        if all(
            self.executor.has_program(
                ("packed", b.n_pad, b.e_pad, b.g_pad), b.g_pad, model=model
            )
            for b in ladder
        ):
            return
        feat = req.graph[2].shape[1]
        edge = req.graph[3].shape[1] if req.graph[3] is not None else 1
        zero = np.zeros(1, np.int32)
        dummy = (zero, zero, np.zeros((1, feat), np.float32),
                 np.zeros((1, edge), np.float32))
        need_eig = self._needs_eigvec(model)
        tenant = self.executor.tenant(model)
        for budget in ladder:
            prep, _ = pack_prepared(
                [dummy], budget,
                eigvecs=[np.zeros(1, np.float32)] if need_eig else None,
                with_layout=tenant.share_layout,
            )
            self.executor.warm(prep, model=model)

    def prewarm_ladders(self, graphs: Sequence[tuple],
                        models: Optional[Sequence[Optional[str]]] = None) -> int:
        """Warm the full bucket ladder for each representative graph,
        regardless of the prewarm mode — the restart-fast entry point.

        When the executor carries an AOT cache every warm either loads
        from disk (milliseconds) or compiles and writes back, so one call
        per tenant with a typical graph populates the whole ladder on
        disk and a restarted server serves its first request without a
        single fresh compile.  Idempotent: already-warm rungs are
        skipped.  Returns the number of (tenant, signature) ladders
        touched."""
        if models is None:
            models = [None] * len(graphs)
        seen = set()
        for g, model in zip(graphs, models):
            req = Request(rid=-1, graph=tuple(g)[:4], arrival_s=0.0,
                          model=model)
            key, ladder = self.ladder_for(req)
            if key in seen:
                continue
            seen.add(key)
            if self.prewarm != "eager":  # ladder_for already warmed eager
                self._warm_ladder(ladder, req)
        return len(seen)

    # -------------------------------------------------------------- serving

    def run(self, graphs: Sequence[tuple], qps: float = 0.0,
            models: Optional[Sequence[Optional[str]]] = None,
            priorities: Optional[Sequence[int]] = None,
            arrivals: Optional[Sequence[float]] = None) -> StreamReport:
        """Serve a stream of raw COO graphs and account per-request latency.

        ``qps`` > 0 offers request i at virtual time i/qps after the
        clock's start; ``qps`` <= 0 means the whole stream is already
        queued at the start (offline / saturation mode); ``arrivals``
        scripts explicit non-decreasing arrival timestamps instead (the
        deterministic-simulation input).  ``models`` tags request i with
        a tenant name; ``priorities`` assigns its QoS class (default 0).
        Compute time is real measured engine time; compile/warm time is
        excluded (tracked in the report).
        """
        if models is not None and len(models) != len(graphs):
            raise ValueError(
                f"models ({len(models)}) must tag every graph ({len(graphs)})"
            )
        if priorities is not None and len(priorities) != len(graphs):
            raise ValueError(
                f"priorities ({len(priorities)}) must tag every graph "
                f"({len(graphs)})"
            )
        if (self._default_model is None and len(self.executor.tenants) > 1
                and (models is None or any(m is None for m in models))):
            raise ValueError(
                "untagged requests are ambiguous on a multi-tenant executor: "
                "pass models=[...] naming a registered tenant per graph "
                f"(registered: {sorted(self.executor.tenants)})"
            )
        clock = self.clock if self.clock is not None else VirtualClock()
        t0 = clock.now()
        if arrivals is not None:
            if len(arrivals) != len(graphs):
                raise ValueError(
                    f"arrivals ({len(arrivals)}) must stamp every graph "
                    f"({len(graphs)})"
                )
            arr = [float(a) for a in arrivals]
            if any(b < a for a, b in zip(arr, arr[1:])):
                raise ValueError("arrivals must be non-decreasing")
            if arr and arr[0] < t0:
                raise ValueError(
                    f"first arrival {arr[0]!r} predates the clock ({t0!r})"
                )
        else:
            arr = [t0 + (i / qps if qps > 0 else 0.0) for i in range(len(graphs))]
        requests = []
        for i, g in enumerate(graphs):
            model = models[i] if models is not None else self._default_model
            priority = int(priorities[i]) if priorities is not None else 0
            requests.append(Request(
                rid=i, graph=g[:4], arrival_s=arr[i], model=model,
                priority=priority,
                slo_s=self.resolve_slo_s(model, priority),
            ))
        compile_before = self.executor.untimed_seconds
        tr = self.tracer
        if tr.enabled:
            # span timestamps must share the run's timeline (the tracer
            # may have been built before this run's clock existed)
            tr.clock = clock
        mi = self._mi
        if self._pipeline is not None:
            return self._run_pipelined(requests, clock, t0, compile_before)

        open_buckets: Dict[tuple, _OpenBucket] = {}
        outputs: List[Optional[np.ndarray]] = [None] * len(requests)
        latencies = np.full(len(requests), np.nan)
        shed_list: List[Shed] = []
        flush_log: List[FlushRecord] = []
        device_free_s = t0
        last_done_s = t0
        queued = 0  # admitted-but-unflushed requests, across open buckets
        bucket_seq = 0

        def flush(key: tuple, at_s: float, reason: str) -> None:
            nonlocal device_free_s, last_done_s, queued
            if at_s > clock.now():
                clock.advance_to(at_s)
            bucket = open_buckets.pop(key)
            queued -= len(bucket.requests)
            rung = bucket.rung()
            outs, dt = self._execute(bucket, rung)
            start_s = max(at_s, device_free_s)
            done_s = start_s + dt
            device_free_s = done_s
            last_done_s = max(last_done_s, done_s)
            misses = 0
            for req, out in zip(bucket.requests, outs):
                outputs[req.rid] = out
                latencies[req.rid] = done_s - req.arrival_s
                if done_s > req.deadline_s:
                    misses += 1
            model, priority, sig = key
            flush_log.append(FlushRecord(
                model=model, priority=priority, sig=sig,
                rids=tuple(r.rid for r in bucket.requests), reason=reason,
                at_s=at_s, start_s=start_s, done_s=done_s, compute_s=dt,
                rung_multiple=rung.g_pad // 2, misses=misses,
            ))
            self._observe_flush(sig, bucket, dt)
            if tr.enabled:
                tenant = _tenant_label(model)
                for req in bucket.requests:
                    tr.record("queue", req.arrival_s, at_s, track="scheduler",
                              rid=req.rid, tenant=tenant, priority=priority)
                tr.record("flush", at_s, done_s, track="scheduler",
                          tenant=tenant, priority=priority, reason=reason,
                          graphs=len(bucket.requests), sig=str(sig),
                          rung=rung.g_pad // 2)
                tr.record("device", start_s, done_s, track="device",
                          tenant=tenant, graphs=len(bucket.requests),
                          compute_s=dt)
                for req in bucket.requests:
                    tr.event("respond", t_s=done_s, track="scheduler",
                             rid=req.rid, latency_s=done_s - req.arrival_s,
                             miss=bool(done_s > req.deadline_s))
            if mi is not None:
                tenant = _tenant_label(model)
                pr = str(priority)
                mi.flushes.inc(reason=reason)
                mi.flush_graphs.observe(len(bucket.requests))
                mi.served.inc(len(bucket.requests), tenant=tenant, priority=pr)
                if misses:
                    mi.deadline_misses.inc(misses, tenant=tenant, priority=pr)
                for req in bucket.requests:
                    mi.latency.observe(done_s - req.arrival_s,
                                       tenant=tenant, priority=pr)
                mi.queue_depth.set(queued)
                mi.open_buckets.set(len(open_buckets))

        idx = 0
        while idx < len(requests) or open_buckets:
            next_arrival_s = requests[idx].arrival_s if idx < len(requests) else math.inf
            # a deadline only matters once the device could actually start
            # the batch: while the executor is backlogged, extra waiting is
            # free, so the bucket stays open and late arrivals pack in.
            # Among buckets ready at the same effective instant, the
            # highest-priority class wins the device (then bucket age) —
            # a deterministic total order.
            best_key, best_eff, best_rank = None, math.inf, None
            for k, b in open_buckets.items():
                eff = max(b.deadline_s, device_free_s)
                rank = (eff, b.priority, b.seq)
                if best_rank is None or rank < best_rank:
                    best_key, best_eff, best_rank = k, eff, rank
            if best_key is not None and best_eff <= next_arrival_s:
                # "deadline" while arrivals remain — including one landing
                # at exactly this instant (the expiry wins the tie and the
                # arrival opens a fresh bucket) — "drain" once the offered
                # stream is exhausted.
                flush(best_key, best_eff,
                      "deadline" if idx < len(requests) else "drain")
                continue
            req = requests[idx]
            idx += 1
            clock.advance_to(req.arrival_s)
            now = req.arrival_s
            # ---- SLO-aware admission: shed rather than queue hopelessly.
            # Projected delay = device backlog, plus one service estimate
            # per already-open bucket (admitted work not in device_free_s
            # yet, but each open bucket is one future flush that will
            # occupy the device first), plus the flush this request would
            # ride — already counted when its own bucket is open.
            sig = self.executor.bucket_for(req.n, req.e)
            svc_est = self.service_estimate_s(sig)
            pending = sum(self.service_estimate_s(k[2]) for k in open_buckets)
            own_open = (req.model, req.priority, sig) in open_buckets
            projected = (max(0.0, device_free_s - now) + pending
                         + (0.0 if own_open else svc_est))
            if mi is not None:
                mi.requests.inc(tenant=_tenant_label(req.model),
                                priority=str(req.priority))
            shed_reason = None
            if (math.isfinite(req.slo_s)
                    and projected > req.slo_s * self.admit_margin):
                shed_reason = "backlog"
            elif self.admit_limit is not None and queued >= self.admit_limit:
                shed_reason = "queue_full"
            if shed_reason is not None:
                shed_list.append(Shed(
                    rid=req.rid, model=req.model, priority=req.priority,
                    reason=shed_reason, at_s=now,
                    projected_delay_s=projected, slo_s=req.slo_s,
                ))
                if tr.enabled:
                    tr.event("shed", t_s=now, track="scheduler", rid=req.rid,
                             tenant=_tenant_label(req.model),
                             priority=req.priority, reason=shed_reason,
                             projected_delay_s=projected)
                if mi is not None:
                    mi.shed.inc(tenant=_tenant_label(req.model),
                                priority=str(req.priority),
                                reason=shed_reason)
                continue
            sig, ladder = self.ladder_for(req)
            key = (req.model, req.priority, sig)
            bucket = open_buckets.get(key)
            if bucket is not None and not bucket.admits(req):
                flush(key, now, "budget")
                bucket = None
            if bucket is None:
                bucket = _OpenBucket(ladder, now, self.max_wait_s,
                                     model=req.model, priority=req.priority,
                                     seq=bucket_seq)
                bucket_seq += 1
                open_buckets[key] = bucket
            bucket.add(req, service_est_s=svc_est)
            queued += 1
            if tr.enabled:
                tr.event("admit", t_s=now, track="scheduler", rid=req.rid,
                         tenant=_tenant_label(req.model),
                         priority=req.priority, bucket=str(sig),
                         projected_delay_s=projected)
            if mi is not None:
                mi.admitted.inc(tenant=_tenant_label(req.model),
                                priority=str(req.priority))
                mi.queue_depth.set(queued)
                mi.open_buckets.set(len(open_buckets))
            if bucket.full:
                flush(key, now, "budget")

        if last_done_s > clock.now():
            clock.advance_to(last_done_s)
        if mi is not None:
            mi.queue_depth.set(0)
            mi.open_buckets.set(0)
        return StreamReport(
            latencies_s=latencies,
            outputs=outputs,
            makespan_s=max(last_done_s - (requests[0].arrival_s if requests else t0),
                           1e-12),
            compile_s=self.executor.untimed_seconds - compile_before,
            shed=shed_list,
            flush_log=flush_log,
        )

    # ------------------------------------------------------------- internal

    def _execute(self, bucket: _OpenBucket,
                 rung: Optional[BucketBudget] = None) -> Tuple[List[np.ndarray], float]:
        """Pack one open bucket on its smallest fitting rung and run it
        through the executor for the bucket's tenant.  The pack-time
        payload (padded graph, packed eigenvectors, host-built layout
        plan) is one ``PreparedBatch`` — zero on-device sorts in the
        flushed program."""
        model = bucket.model
        tenant = self.executor.tenant(model)
        raws = [r.graph for r in bucket.requests]
        if rung is None:
            rung = bucket.rung()
        vecs = None
        if self._needs_eigvec(model):
            vecs = [
                np.asarray(self.executor._eigvec(s, r, nf.shape[0], nf.shape[0]))
                for s, r, nf, _ in (g[:4] for g in raws)
            ]
        tr = self.tracer
        with tr.span("pack", track="host", tenant=_tenant_label(model),
                     graphs=len(raws), rung=rung.g_pad // 2):
            prep, meta = pack_prepared(raws, rung, eigvecs=vecs,
                                       with_layout=tenant.share_layout)
        out, dt = self.executor.run(prep, model=model)
        level = "graph" if tenant.cfg.task == "graph" else "node"
        with tr.span("unpack", track="host", tenant=_tenant_label(model),
                     graphs=len(raws)):
            outs = unpack_outputs(out, meta, level=level)
        return outs, dt

    def _execute_pipelined(self, bucket: _OpenBucket, rung: BucketBudget,
                           measure_host: bool) -> Tuple[List[np.ndarray], float, float]:
        """Pack + run + unpack one bucket for the pipelined loop.

        Unlike the serial ``_execute``, pack/unpack are *not* wrapped in
        live tracer spans: the pipelined loop records them with modeled
        timeline intervals instead (the pack span genuinely overlaps the
        device span there).  With ``measure_host`` the real host-side
        pack seconds (eigvec + ``pack_prepared``) are measured through
        the executor's clock — the only real-time source the serving
        stack may read — and returned for timeline folding; otherwise
        the returned pack seconds are 0.0 and the caller's modeled
        ``host_cost`` governs."""
        model = bucket.model
        tenant = self.executor.tenant(model)
        raws = [r.graph for r in bucket.requests]
        t_pack0 = self.executor.clock.now() if measure_host else 0.0
        vecs = None
        if self._needs_eigvec(model):
            vecs = [
                np.asarray(self.executor._eigvec(s, r, nf.shape[0], nf.shape[0]))
                for s, r, nf, _ in (g[:4] for g in raws)
            ]
        prep, meta = pack_prepared(raws, rung, eigvecs=vecs,
                                   with_layout=tenant.share_layout)
        pack_wall_s = (self.executor.clock.now() - t_pack0
                       if measure_host else 0.0)
        out, dt = self.executor.run(prep, model=model)
        level = "graph" if tenant.cfg.task == "graph" else "node"
        outs = unpack_outputs(out, meta, level=level)
        return outs, dt, pack_wall_s

    def _run_pipelined(self, requests: List[Request], clock: Clock,
                       t0: float, compile_before: float) -> StreamReport:
        """Dispatch-ahead event loop (``pipeline=`` mode).

        Differences from the serial loop, and nothing else:

        * the flush gate replaces ``device_free_s`` with the in-flight
          window: ``eff = max(deadline, slot_free)`` where ``slot_free``
          is the front completion when the window is full and ``-inf``
          while it has room — so a bucket dispatches at its deadline even
          while the device is busy (that is the overlap);
        * each dispatch threads three modeled resources: the single host
          prepare worker (``host_free_s`` — packs serialize), the serial
          device (``device_free_s``), and the window slot.  ``start_s``
          is the dispatch instant (pack done), ``done_s`` the device
          completion;
        * completions are harvested strictly FIFO off the window front —
          the device executes dispatches in order, so front-first harvest
          preserves per-request response order by construction.  Harvest
          finalizes outputs/records/telemetry and never advances the
          clock;
        * admission projects host-pack EWMAs on top of the serial
          device-backlog model (free host cost → bitwise the serial
          projection).

        Single-threaded and deterministic under ``VirtualClock``: the
        engine compute runs synchronously at dispatch (clean per-flush
        ``compute_s``), only its *placement* on the timeline models the
        pipeline.  Live threaded overlap is ``serve.pipeline``'s job.
        """
        cfg = self._pipeline
        inflight = cfg.inflight
        cost_fn = cfg.host_cost_fn()  # None => measure real pack seconds
        tr = self.tracer
        mi = self._mi

        open_buckets: Dict[tuple, _OpenBucket] = {}
        outputs: List[Optional[np.ndarray]] = [None] * len(requests)
        latencies = np.full(len(requests), np.nan)
        shed_list: List[Shed] = []
        flush_log: List[FlushRecord] = []
        window: "deque[_InFlight]" = deque()  # dispatch == completion order
        device_free_s = t0
        host_free_s = t0
        last_done_s = t0
        queued = 0
        bucket_seq = 0
        flush_idx = 0

        def harvest_one() -> None:
            f = window.popleft()
            bucket = f.bucket
            misses = 0
            for req, out in zip(bucket.requests, f.outs):
                outputs[req.rid] = out
                latencies[req.rid] = f.done_s - req.arrival_s
                if f.done_s > req.deadline_s:
                    misses += 1
            model, priority, sig = f.key
            flush_log.append(FlushRecord(
                model=model, priority=priority, sig=sig,
                rids=tuple(r.rid for r in bucket.requests), reason=f.reason,
                at_s=f.at_s, start_s=f.start_s, done_s=f.done_s,
                compute_s=f.compute_s, rung_multiple=f.rung.g_pad // 2,
                misses=misses,
            ))
            if tr.enabled:
                tenant = _tenant_label(model)
                for req in bucket.requests:
                    tr.record("queue", req.arrival_s, f.at_s, track="scheduler",
                              rid=req.rid, tenant=tenant, priority=priority)
                tr.record("flush", f.at_s, f.done_s, track="scheduler",
                          tenant=tenant, priority=priority, reason=f.reason,
                          graphs=len(bucket.requests), sig=str(sig),
                          rung=f.rung.g_pad // 2)
                tr.record("unpack", f.done_s, f.done_s, track="host",
                          tenant=tenant, graphs=len(bucket.requests))
                for req in bucket.requests:
                    tr.event("respond", t_s=f.done_s, track="scheduler",
                             rid=req.rid, latency_s=f.done_s - req.arrival_s,
                             miss=bool(f.done_s > req.deadline_s))
            if mi is not None:
                tenant = _tenant_label(model)
                pr = str(priority)
                mi.flushes.inc(reason=f.reason)
                mi.flush_graphs.observe(len(bucket.requests))
                mi.served.inc(len(bucket.requests), tenant=tenant, priority=pr)
                if misses:
                    mi.deadline_misses.inc(misses, tenant=tenant, priority=pr)
                for req in bucket.requests:
                    mi.latency.observe(f.done_s - req.arrival_s,
                                       tenant=tenant, priority=pr)
                mi.inflight_depth.set(len(window))

        def harvest_due(now_s: float) -> None:
            # completions whose modeled finish predates the instant being
            # processed; harvesting never advances the clock
            while window and window[0].done_s <= now_s:
                harvest_one()

        def dispatch(key: tuple, at_s: float, reason: str) -> None:
            nonlocal device_free_s, host_free_s, last_done_s, queued, flush_idx
            if at_s > clock.now():
                clock.advance_to(at_s)
            harvest_due(at_s)
            bucket = open_buckets.pop(key)
            queued -= len(bucket.requests)
            rung = bucket.rung()
            outs, dt, pack_wall = self._execute_pipelined(
                bucket, rung, measure_host=cost_fn is None)
            pack_s = pack_wall if cost_fn is None else cost_fn(flush_idx)
            flush_idx += 1
            # one prepare worker: packs serialize behind host_free_s;
            # without overlap the pack also waits for the device to go
            # idle (the serial loop's inline-blocking host, the modeled
            # baseline for speedup claims)
            pack_begin = max(at_s, host_free_s)
            if not cfg.overlap:
                pack_begin = max(pack_begin, device_free_s)
            start_s = pack_begin + pack_s  # dispatch instant
            host_free_s = start_s
            if len(window) >= inflight:
                # a budget flush can land on a full window: the dispatch
                # stalls until the front completion frees its slot
                start_s = max(start_s, window[0].done_s)
                harvest_one()
            begin_s = max(start_s, device_free_s)  # the device is serial
            done_s = begin_s + dt
            device_free_s = done_s
            last_done_s = max(last_done_s, done_s)
            model, priority, sig = key
            self._observe_flush(sig, bucket, dt)
            self._observe_pack(sig, pack_s)
            window.append(_InFlight(
                key=key, bucket=bucket, rung=rung, outs=outs, reason=reason,
                at_s=at_s, start_s=start_s, begin_s=begin_s, done_s=done_s,
                compute_s=dt,
            ))
            if tr.enabled:
                tenant = _tenant_label(model)
                tr.event("dispatch", t_s=start_s, track="scheduler",
                         tenant=tenant, priority=priority, reason=reason,
                         graphs=len(bucket.requests), sig=str(sig),
                         inflight=len(window))
                tr.record("pack", pack_begin, start_s, track="host",
                          tenant=tenant, graphs=len(bucket.requests),
                          rung=rung.g_pad // 2)
                tr.record("device", begin_s, done_s, track="device",
                          tenant=tenant, graphs=len(bucket.requests),
                          compute_s=dt)
            if mi is not None:
                mi.queue_depth.set(queued)
                mi.open_buckets.set(len(open_buckets))
                mi.inflight_depth.set(len(window))

        idx = 0
        while idx < len(requests) or open_buckets:
            next_arrival_s = (requests[idx].arrival_s if idx < len(requests)
                              else math.inf)
            # the dispatch gate: with window room a bucket's deadline
            # alone governs (dispatch-ahead — the device need not be
            # free); a full window makes the front completion the
            # earliest instant a new flush could enter it.  Priority then
            # bucket age break effective-instant ties, same total order
            # as the serial loop.
            slot_free_s = (window[0].done_s if len(window) >= inflight
                           else -math.inf)
            best_key, best_eff, best_rank = None, math.inf, None
            for k, b in open_buckets.items():
                eff = max(b.deadline_s, slot_free_s)
                rank = (eff, b.priority, b.seq)
                if best_rank is None or rank < best_rank:
                    best_key, best_eff, best_rank = k, eff, rank
            if best_key is not None and best_eff <= next_arrival_s:
                dispatch(best_key, best_eff,
                         "deadline" if idx < len(requests) else "drain")
                continue
            req = requests[idx]
            idx += 1
            clock.advance_to(req.arrival_s)
            now = req.arrival_s
            harvest_due(now)
            # ---- admission: the serial projection plus host-pack EWMAs
            # (each open bucket's future flush passes through the single
            # prepare worker before it can occupy the device).  With the
            # default free modeled host cost every pack estimate is 0.0
            # and this is bitwise the serial projection; device_free_s
            # already carries dispatched-ahead flushes.
            sig = self.executor.bucket_for(req.n, req.e)
            svc_est = self.service_estimate_s(sig)
            pending = sum(
                self.service_estimate_s(k[2]) + self.pack_estimate_s(k[2])
                for k in open_buckets)
            own_open = (req.model, req.priority, sig) in open_buckets
            projected = (max(0.0, device_free_s - now) + pending
                         + (0.0 if own_open
                            else svc_est + self.pack_estimate_s(sig)))
            if mi is not None:
                mi.requests.inc(tenant=_tenant_label(req.model),
                                priority=str(req.priority))
            shed_reason = None
            if (math.isfinite(req.slo_s)
                    and projected > req.slo_s * self.admit_margin):
                shed_reason = "backlog"
            elif self.admit_limit is not None and queued >= self.admit_limit:
                shed_reason = "queue_full"
            if shed_reason is not None:
                shed_list.append(Shed(
                    rid=req.rid, model=req.model, priority=req.priority,
                    reason=shed_reason, at_s=now,
                    projected_delay_s=projected, slo_s=req.slo_s,
                ))
                if tr.enabled:
                    tr.event("shed", t_s=now, track="scheduler", rid=req.rid,
                             tenant=_tenant_label(req.model),
                             priority=req.priority, reason=shed_reason,
                             projected_delay_s=projected)
                if mi is not None:
                    mi.shed.inc(tenant=_tenant_label(req.model),
                                priority=str(req.priority),
                                reason=shed_reason)
                continue
            sig, ladder = self.ladder_for(req)
            key = (req.model, req.priority, sig)
            bucket = open_buckets.get(key)
            if bucket is not None and not bucket.admits(req):
                dispatch(key, now, "budget")
                bucket = None
            if bucket is None:
                bucket = _OpenBucket(ladder, now, self.max_wait_s,
                                     model=req.model, priority=req.priority,
                                     seq=bucket_seq)
                bucket_seq += 1
                open_buckets[key] = bucket
            bucket.add(req, service_est_s=svc_est)
            queued += 1
            if tr.enabled:
                tr.event("admit", t_s=now, track="scheduler", rid=req.rid,
                         tenant=_tenant_label(req.model),
                         priority=req.priority, bucket=str(sig),
                         projected_delay_s=projected)
            if mi is not None:
                mi.admitted.inc(tenant=_tenant_label(req.model),
                                priority=str(req.priority))
                mi.queue_depth.set(queued)
                mi.open_buckets.set(len(open_buckets))
            if bucket.full:
                dispatch(key, now, "budget")

        while window:
            harvest_one()
        if last_done_s > clock.now():
            clock.advance_to(last_done_s)
        if mi is not None:
            mi.queue_depth.set(0)
            mi.open_buckets.set(0)
            mi.inflight_depth.set(0)
        return StreamReport(
            latencies_s=latencies,
            outputs=outputs,
            makespan_s=max(last_done_s - (requests[0].arrival_s if requests else t0),
                           1e-12),
            compile_s=self.executor.untimed_seconds - compile_before,
            shed=shed_list,
            flush_log=flush_log,
        )
