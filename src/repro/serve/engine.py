"""LM serving engine: batched prefill + decode with a static KV cache.

Request flow: requests accumulate into fixed-size batches (padding short
prompts left-aligned), one compiled ``prefill`` builds the cache, then the
compiled ``decode_step`` runs autoregressively (greedy).  Static shapes
throughout — the serving analogue of the GNN engine's bucketed padding.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig
from repro.serve.clock import Clock, RealClock


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    prompt_len: int = 64  # padded prompt length
    cache_len: int = 256
    max_new_tokens: int = 32


class LMServer:
    def __init__(self, params, cfg: ModelConfig, serve_cfg: ServeConfig,
                 clock: Optional[Clock] = None):
        self.params = params
        self.cfg = cfg
        self.scfg = serve_cfg
        # All wall-time reads go through the injectable Clock — same rule
        # as the GNN Executor, enforced by tools/check_engine_singlepath.py
        # (this module is compile-exempt, not timing-exempt).
        self.clock: Clock = clock if clock is not None else RealClock()
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(p, b, cfg, serve_cfg.cache_len)
        )
        self._decode = jax.jit(
            lambda p, c, tok, t: lm.decode_step(p, c, tok, t, cfg),
            donate_argnums=(1,),
        )

    def generate(self, prompts: List[np.ndarray], extras: Optional[dict] = None):
        """prompts: list of int32 arrays (<= prompt_len).  Greedy decode.
        Returns (generated (B, max_new), stats)."""
        scfg = self.scfg
        b = len(prompts)
        assert b <= scfg.max_batch
        toks = np.zeros((scfg.max_batch, scfg.prompt_len), np.int32)
        for i, pr in enumerate(prompts):
            toks[i, -len(pr) :] = pr  # left-pad with 0 (simplification)
        batch = {"tokens": jnp.asarray(toks)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        t0 = self.clock.now()
        cache, last_logits, t = self._prefill(self.params, batch)
        last_logits.block_until_ready()
        prefill_s = self.clock.now() - t0
        out = np.zeros((scfg.max_batch, scfg.max_new_tokens), np.int32)
        tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
        t0 = self.clock.now()
        for i in range(scfg.max_new_tokens):
            out[:, i] = np.asarray(tok[:, 0])
            logits, cache = self._decode(self.params, cache, tok, t)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            t = t + 1
        jax.block_until_ready(cache)
        decode_s = self.clock.now() - t0
        return out[:b], {
            "prefill_s": prefill_s,
            "decode_s_per_token": decode_s / scfg.max_new_tokens,
        }
