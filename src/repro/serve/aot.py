"""Persistent AOT compile cache + XLA flag configuration — kill the warm-up.

GenGNN/FlowGNN amortize program construction by burning the message-passing
dataflow into the bitstream once; every later request runs against finished
hardware.  The TPU/XLA analogue of "the bitstream already exists" is an
**ahead-of-time compiled executable persisted across process restarts**:
the first process pays trace + lower + compile exactly once per
``(program, bucket, signature)`` and serializes the finished executable to
disk; a restarted server deserializes it in milliseconds and is serving
before a single ``jax.jit`` trace has happened.  This module owns that
disk format; ``serve/executor.py`` is the only consumer (its
``_warm`` consults the cache before compiling and writes back on miss).

Three pieces:

* :func:`environment_fingerprint` — the invalidation key.  A serialized
  executable is machine code for one exact (jax, jaxlib, backend,
  device kind, topology, XLA flag set); loading it anywhere else is at
  best a crash and at worst silent wrong numerics.  Every cache entry
  embeds the fingerprint of the environment that produced it, and a
  mismatched load is reported as ``stale`` (distinct from ``miss``) and
  recompiled + overwritten in place — flag-set changes from the
  autotuner self-invalidate the same way.
* :class:`AOTCache` — one file per entry under a root directory, named
  by the SHA-256 of the *logical* key (program key, bucket key, slot
  count, trace signature), each holding a pickled record of
  ``{fingerprint, key, payload, in_tree, out_tree}``.  Writes are
  atomic (tempfile + rename) so a crashed writer can never leave a
  half-entry; corrupted or unreadable entries degrade to a plain miss
  (fresh compile, overwrite) — never an exception on the serving path.
* :class:`XlaFlagConfig` — the checked-in flag table
  (``src/repro/configs/xla_flags.json``) that ``tools/autotune_xla.py``
  writes: per-model (and per-bucket) XLA compiler options, applied by
  the executor at program-build time via ``Lowered.compile(
  compiler_options=...)`` — the saxml ``llm_xla_flags.py`` pattern of
  sweeping latency-relevant flags offline and committing the winners.
  The resolved flag set's hash folds into the fingerprint, so retuning
  invalidates exactly the entries whose flags changed.

When the pinned JAX has no executable-serialization API
(``runtime.compat.HAS_SERIALIZE_EXECUTABLE`` false), the executor falls
back to pointing JAX's own on-disk compilation cache at the same root
(``runtime.compat.enable_compilation_cache``): restarts then still skip
XLA compilation, paying only the (much smaller) retrace cost.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import tempfile
from typing import Dict, Optional

import jax

from repro import runtime as RT

__all__ = [
    "AOTCache",
    "XlaFlagConfig",
    "default_flags_path",
    "environment_fingerprint",
    "flags_hash",
    "model_label",
]

_SCHEMA = "repro-aot/v1"
_FLAGS_SCHEMA = "repro-xla-flags/v1"
ENTRY_SUFFIX = ".aotx"


# ---------------------------------------------------------------------------
# fingerprinting
# ---------------------------------------------------------------------------


def flags_hash(flags: Optional[Dict[str, object]]) -> str:
    """Canonical short hash of one XLA flag set (sorted-key JSON), the
    fingerprint component the autotuner moves when it commits winners."""
    blob = json.dumps(flags or {}, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def environment_fingerprint(flags: Optional[Dict[str, object]] = None) -> dict:
    """Everything a serialized executable is only valid under: jax/jaxlib
    versions, backend platform, device kind, device/process topology, and
    the XLA flag set the program was compiled with.  Deterministic and
    JSON-able; equality is the cache's validity test."""
    import jaxlib

    devices = jax.devices()
    return {
        "schema": _SCHEMA,
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        "device_kind": devices[0].device_kind if devices else "none",
        "num_devices": len(devices),
        "process_count": jax.process_count(),
        "flags": flags_hash(flags),
    }


def model_label(cfg) -> str:
    """The flag-table name of one model config — ``gin_vn`` is a distinct
    program from ``gin`` (``cfg.model`` alone would conflate them)."""
    return cfg.model + ("_vn" if getattr(cfg, "virtual_node", False) else "")


# ---------------------------------------------------------------------------
# the XLA flag table
# ---------------------------------------------------------------------------


def default_flags_path() -> str:
    """The checked-in flag table the autotuner maintains."""
    return os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "configs", "xla_flags.json")


def _bucket_str(bucket_key: tuple) -> str:
    return "|".join(str(x) for x in bucket_key)


@dataclasses.dataclass(frozen=True)
class XlaFlagConfig:
    """Resolved view of ``xla_flags.json``: a global default flag set,
    per-model overrides, and per-(model, bucket) overrides, merged in
    that order by :meth:`resolve`.  Values are XLA ``compiler_options``
    entries (string/bool/int, validated at autotune time — an option the
    backend rejects never reaches this table)."""

    default: Dict[str, object] = dataclasses.field(default_factory=dict)
    models: Dict[str, dict] = dataclasses.field(default_factory=dict)
    source: str = ""

    def resolve(self, model: str, bucket_key: tuple) -> Dict[str, object]:
        """The flag set for one (model, bucket) program: global default,
        overlaid with the model's default, overlaid with the exact
        bucket's entry."""
        flags = dict(self.default)
        spec = self.models.get(model)
        if spec:
            flags.update(spec.get("default", {}))
            flags.update(spec.get("buckets", {}).get(_bucket_str(bucket_key), {}))
        return flags

    @classmethod
    def load(cls, path: Optional[str] = None) -> "XlaFlagConfig":
        """Load a flag table; ``None`` means the checked-in default (an
        absent default file is an empty config, an absent *explicit*
        path is an error)."""
        explicit = path is not None
        path = path or default_flags_path()
        if not os.path.exists(path):
            if explicit:
                raise FileNotFoundError(f"XLA flag table not found: {path}")
            return cls(source=path)
        with open(path) as f:
            doc = json.load(f)
        if doc.get("schema") != _FLAGS_SCHEMA:
            raise ValueError(
                f"{path}: not a {_FLAGS_SCHEMA} document "
                f"(schema={doc.get('schema')!r})"
            )
        return cls(default=dict(doc.get("default", {})),
                   models=dict(doc.get("models", {})), source=path)

    def save(self, path: str, env: Optional[dict] = None,
             provenance: Optional[dict] = None) -> None:
        """Write the commit-the-winners document (sorted keys, stable
        across reruns on identical measurements)."""
        doc = {
            "schema": _FLAGS_SCHEMA,
            "env": env or environment_fingerprint(),
            "provenance": provenance or {},
            "default": self.default,
            "models": self.models,
        }
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")


# ---------------------------------------------------------------------------
# the persistent executable cache
# ---------------------------------------------------------------------------


class AOTCache:
    """Disk cache of serialized compiled executables, keyed by logical
    program identity and guarded by the environment fingerprint.

    ``stats`` tallies ``hit`` (deserialized and serving), ``miss``
    (absent / unreadable / corrupt — fresh compile, write-back), and
    ``stale`` (present but fingerprint-mismatched — fresh compile,
    overwrite).  The executor mirrors these into the
    ``serve_aot_cache_total{result=...}`` metric when a registry is
    attached.
    """

    def __init__(self, root: str):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        self.stats: Dict[str, int] = {"hit": 0, "miss": 0, "stale": 0}
        #: outcome of the most recent :meth:`load` — the executor mirrors
        #: it into the ``serve_aot_cache_total{result=...}`` counter
        self.last_result: str = ""

    # ------------------------------------------------------------ paths

    def entry_path(self, key: tuple) -> str:
        digest = hashlib.sha256(repr(key).encode()).hexdigest()
        return os.path.join(self.root, digest + ENTRY_SUFFIX)

    def entries(self) -> list:
        """Entry files currently on disk (maintenance/introspection)."""
        return sorted(
            f for f in os.listdir(self.root) if f.endswith(ENTRY_SUFFIX)
        )

    # ------------------------------------------------------------- load

    def load(self, key: tuple, fingerprint: dict):
        """The deserialized executable for ``key`` under ``fingerprint``,
        or ``None`` (recorded as miss/stale).  Never raises on the
        serving path: an unreadable, corrupt, colliding, or
        undeserializable entry is a miss — the caller compiles fresh and
        the write-back replaces the bad entry."""
        path = self.entry_path(key)
        if not os.path.exists(path):
            return self._outcome("miss")
        try:
            with open(path, "rb") as f:
                rec = pickle.load(f)
            if not isinstance(rec, dict) or rec.get("schema") != _SCHEMA:
                raise ValueError("bad record schema")
        except Exception:  # noqa: BLE001 - corrupt/truncated file: miss
            return self._outcome("miss")
        if rec.get("key") != repr(key):  # hash collision or tamper
            return self._outcome("miss")
        if rec.get("fingerprint") != fingerprint:
            return self._outcome("stale")
        try:
            exe = RT.deserialize_compiled(
                rec["payload"], rec["in_tree"], rec["out_tree"]
            )
        except Exception:  # noqa: BLE001 - backend refused the payload
            return self._outcome("miss")
        self._outcome("hit")
        return exe

    def _outcome(self, result: str):
        self.stats[result] += 1
        self.last_result = result
        return None

    # ------------------------------------------------------------ store

    def store(self, key: tuple, fingerprint: dict, compiled) -> bool:
        """Serialize ``compiled`` under ``key``; atomic (tempfile +
        rename) so readers never observe a partial entry.  Returns False
        (and stores nothing) when the executable refuses serialization —
        serving continues uncached."""
        try:
            payload, in_tree, out_tree = RT.serialize_compiled(compiled)
        except Exception:  # noqa: BLE001 - unserializable executable
            return False
        rec = {
            "schema": _SCHEMA,
            "key": repr(key),
            "fingerprint": fingerprint,
            "payload": payload,
            "in_tree": in_tree,
            "out_tree": out_tree,
        }
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(rec, f)
            os.replace(tmp, self.entry_path(key))
        except Exception:  # noqa: BLE001 - disk full etc: serve uncached
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return False
        return True
