"""The serving executor: one composable pipeline under every mode and tenant.

GenGNN's thesis is one generic message-passing structure serving a diverse
and growing set of models.  The serving stack had drifted the other way:
every new axis (mesh, packing, precision, layout) was hand-threaded through
``infer_stream`` / ``infer_batched`` / ``infer_packed`` separately, so the
cost of the next axis grew with the number of modes.  This module collapses
that mode x axis matrix into a pipeline of small stages:

    prepare  ->  constrain  ->  warm  ->  run
    (pad / eigvec /  (shard rows    (compile un-   (the one timed
     layout / sig)    over mesh)     timed, once     execution)
                                     per signature)

* **prepare** — the ``prepare_stream`` / ``prepare_batched`` /
  ``prepare_packed`` family turns raw input into a ``PreparedBatch``:
  padded graph + optional eigenvector + optional layout plan + the static
  bucket key and warm signature.  All host-side; one family subsumes the
  per-mode padding/eigvec/layout/signature code the engine used to
  duplicate.
* **constrain** — logical-axis sharding of the padded node/edge rows over
  the executor mesh (no-op without one), applied inside the compiled step.
* **warm** — every distinct trace signature executes once untimed before
  it may be timed; compilation never leaks into a reported latency.  One
  signature function (:func:`trace_signature`, keyed on every input leaf's
  shape+dtype) covers all modes — the stream mode's old two-field
  signature missed mid-stream dtype changes.  The warm stage is split in
  two accounted halves: **compile** (trace + lower + XLA compile — or an
  AOT disk-cache load, see below) and **warm** (the one untimed device
  execution), tracked separately as ``compile_seconds`` /
  ``warm_seconds`` so the AOT cache's effect is measurable — a disk hit
  eliminates the compile half, never the warm half.
* **run** — the single timed region in the serving stack.  Durations are
  read through the executor's injected ``serve.clock.Clock`` (default
  ``RealClock``, i.e. ``time.perf_counter``); substituting a stepping
  clock makes even compute durations deterministic under test.
  ``tools/check_engine_singlepath.py`` keeps this the only place real
  time is measured: every reference to the ``time`` module outside this
  file and ``serve/clock.py`` fails the guard.

On top of the pipeline the executor is **multi-tenant**:
``register(name, cfg, params, precision=...)`` admits several GNN models —
each with its own precision and layout settings — into one bucket ladder
and one compile cache.  Programs are keyed by ``(program_key, bucket_key,
num_graphs)`` where ``program_key = (cfg, precision, share_layout)``:
tenants that share an architecture share compiled programs (params are
runtime arguments, never baked in), while warm signatures carry each
tenant's parameter-tree signature so one tenant's warmth is never
mistaken for another's.  ``serve.gnn_engine.GNNEngine`` remains the
single-tenant facade; ``serve.scheduler.StreamScheduler`` routes tagged
requests to tenants and dispatches packed flushes per tenant.

**AOT persistence.**  With ``aot_cache=`` (a ``serve.aot.AOTCache``),
every signature's compiled executable is consulted on disk before
compiling — keyed by ``(program_key, bucket_key, num_graphs, signature)``
plus the environment fingerprint (jax/jaxlib version, backend, device
kind, topology, XLA flag set) — and written back on miss, so a restarted
process deserializes finished machine code instead of retracing and
recompiling ~10s of programs.  ``xla_flags=`` (a ``serve.aot.
XlaFlagConfig``, normally the checked-in autotuner table) supplies
per-(model, bucket) XLA ``compiler_options`` applied at program build;
the resolved set folds into the fingerprint so retuned flags
self-invalidate exactly the entries they affect.  When the pinned JAX
cannot serialize executables, the cache directory instead hosts JAX's
own compilation cache (``runtime.compat.enable_compilation_cache``) —
restarts then skip XLA compilation but still pay the retrace.

**Telemetry.**  The executor accepts ``tracer=`` / ``metrics=`` sinks
(``repro.obs``; the scheduler attaches its own via
:meth:`Executor.attach_telemetry`) and reports program builds, warm
executions (with their untimed cost), and timed device seconds — the
compile/warm events of the request lifecycle in docs/OBSERVABILITY.md.
Both default off; disabled telemetry adds no compile keys and no time
reads (the instrumentation stamps the *tracer's* clock, never a second
real-time source — this module's injected ``clock`` remains the single
place real time is measured).
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from collections import OrderedDict
from typing import Callable, Dict, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime as RT
from repro.serve.aot import (
    AOTCache, XlaFlagConfig, environment_fingerprint, model_label,
)
from repro.obs.metrics import MetricsRegistry, ServingInstruments
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serve.clock import Clock, RealClock
from repro.core import batching as B
from repro.core import graph as G
from repro.core import layout as LY
from repro.gnn import models as M

DEFAULT_BUCKETS: Sequence[tuple] = ((32, 96), (64, 192), (128, 384), (256, 768))


# ---------------------------------------------------------------------------
# the prepared-batch pytree and the one warm-signature function
# ---------------------------------------------------------------------------


def trace_signature(graph: G.Graph, eigvec=None, layout=None) -> tuple:
    """The warm/compile signature of one prepared input: presence flags for
    the optional operands plus (shape, dtype) of **every** leaf.

    This is the single signature function for every mode.  The stream mode
    used to key warmth on ``("eig", with_eigvec)`` alone, so a mid-stream
    dtype change (int edge features after float ones in the same bucket)
    recompiled inside the timed region; keying on the leaves closes that.
    """
    leaves = jax.tree.leaves((graph, eigvec, layout))
    return (("eig", eigvec is not None), ("lay", layout is not None)) + tuple(
        (tuple(v.shape), str(v.dtype)) for v in leaves
    )


def params_signature(params) -> tuple:
    """Structural signature of a parameter tree (treedef + leaf
    shapes/dtypes).  Part of every warm signature so tenants sharing a
    compiled program never inherit each other's warmth across a parameter
    structure change (e.g. differently-calibrated int8-static trees)."""
    leaves, treedef = jax.tree.flatten(params)
    return (str(treedef),) + tuple(
        (tuple(getattr(v, "shape", ())), str(getattr(v, "dtype", type(v).__name__)))
        for v in leaves
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PreparedBatch:
    """One batch, fully staged for the executor: the padded (possibly
    packed) graph, its optional eigenvector input and layout plan, plus the
    static routing facts — bucket key, graph-slot count, warm signature.

    Produced by the ``prepare_*`` family (and by
    ``core.batching.pack_prepared`` at pack time); consumed by
    :meth:`Executor.warm` / :meth:`Executor.run`.  A pytree: the graph /
    eigvec / layout leaves are data, the routing facts are static metadata.
    """

    graph: G.Graph
    eigvec: Optional[jax.Array]
    layout: Optional[LY.GraphLayout]
    bucket_key: tuple = dataclasses.field(metadata=dict(static=True))
    num_graphs: int = dataclasses.field(metadata=dict(static=True))
    signature: tuple = dataclasses.field(metadata=dict(static=True))


def prepared(graph: G.Graph, eigvec, layout, bucket_key: tuple,
             num_graphs: int) -> PreparedBatch:
    """Assemble a ``PreparedBatch``, computing its warm signature."""
    return PreparedBatch(
        graph=graph, eigvec=eigvec, layout=layout, bucket_key=bucket_key,
        num_graphs=num_graphs,
        signature=trace_signature(graph, eigvec, layout),
    )


# ---------------------------------------------------------------------------
# compile-cache record + tenant registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _CompiledBucket:
    """Per-program compile-cache record: the jitted program plus
    warm-signature bookkeeping.  ``num_graphs`` is recorded (and part of
    the cache key) — the old engine's ``_bucket(key, num_graphs=...)``
    silently kept the first call's value on a cache hit.

    ``executables`` maps each warmed trace signature to its AOT
    executable (freshly ``lower().compile()``-d or deserialized from the
    disk cache); execution dispatches through it, with ``fn`` (the jit
    wrapper) kept as the lowering source and the fallback path.  The old
    single ``compile_s`` is split: ``compile_s`` is trace+lower+compile
    (or disk-load) seconds, ``warm_s`` the first-run device warm —
    separately visible so the AOT cache's effect (it eliminates only the
    first half) is measurable."""

    fn: Callable
    num_graphs: Optional[int]
    warm: Set[tuple] = dataclasses.field(default_factory=set)
    executables: Dict[tuple, Callable] = dataclasses.field(default_factory=dict)
    compile_s: float = 0.0
    warm_s: float = 0.0
    lowered_count: int = 0  # fresh trace+lower+compiles (0 on pure AOT hits)


@dataclasses.dataclass
class Tenant:
    """One registered model: its config, (possibly quantized) params, and
    the derived signatures that route it through the shared machinery."""

    name: str
    cfg: M.GNNConfig
    params: dict
    precision: str = "fp32"
    share_layout: bool = True
    fused: bool = False
    quant_report: Optional[object] = None
    params_sig: tuple = ()

    @property
    def program_key(self) -> tuple:
        """Compiled programs are shared between tenants with equal keys:
        the computation depends on (cfg, precision-structure, layout
        sharing, megakernel fusion), never on the parameter *values*."""
        return (self.cfg, self.precision, self.share_layout, self.fused)


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------


class Executor:
    """The single compile-cache / warm / timing / mesh-scope path that every
    serving mode and every tenant runs through.

    One executor owns one bucket ladder (``buckets``), one optional mesh,
    one compile cache, and any number of registered tenants.  The
    single-tenant ``GNNEngine`` facade registers exactly one; multi-model
    serving registers several and routes by name.
    """

    def __init__(
        self,
        buckets: Sequence[tuple] = DEFAULT_BUCKETS,
        mesh=None,
        rules: Optional[dict] = None,
        clock: Optional[Clock] = None,
        tracer: Optional[Tracer] = None,
        metrics: Optional[MetricsRegistry] = None,
        aot_cache: Optional[AOTCache] = None,
        xla_flags: Optional[XlaFlagConfig] = None,
    ):
        self.buckets = sorted(buckets)
        self.mesh = mesh
        # the one place real time is measured in the serving stack; a test
        # can inject a stepping clock for deterministic compute durations
        self.clock = clock if clock is not None else RealClock()
        if rules is None and mesh is not None:
            rules = RT.gnn_rules(mesh)
        self.rules = rules
        # persistent AOT compile cache + per-program XLA flag table; when
        # the pinned JAX cannot serialize executables the cache root hosts
        # JAX's own compilation cache instead (compile skipped on restart,
        # retrace still paid) — feature-detected, never an error
        self.aot = aot_cache
        self.xla_flags = xla_flags
        self._aot_serialize = aot_cache is not None and RT.HAS_SERIALIZE_EXECUTABLE
        if aot_cache is not None and not self._aot_serialize:
            RT.enable_compilation_cache(aot_cache.root)
        self._env_fp_base: Optional[dict] = None  # lazy (touches devices)
        self._flags_cache: Dict[tuple, Dict[str, object]] = {}
        self.tenants: Dict[str, Tenant] = {}
        self._compiled: Dict[tuple, _CompiledBucket] = {}
        # host eigvec memo: (edge bytes, n, n_pad) -> computed vector
        self._eigvec_lru: "OrderedDict[tuple, jax.Array]" = OrderedDict()
        # telemetry sinks: dark by default (the no-op tracer / no registry
        # costs nothing and adds no compile keys); the scheduler attaches
        # its own sinks here so compile/warm/device events share them
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        self._mi = ServingInstruments(metrics) if metrics is not None else None

    def attach_telemetry(self, tracer: Optional[Tracer] = None,
                         metrics: Optional[MetricsRegistry] = None) -> None:
        """Adopt telemetry sinks after construction (the scheduler passes
        its own through here).  Sinks this executor already carries are
        kept — first attachment wins, so two schedulers sharing one
        executor never silently split its compile/warm accounting."""
        if tracer is not None and not self.tracer.enabled:
            self.tracer = tracer
        if metrics is not None and self.metrics is None:
            self.metrics = metrics
            self._mi = ServingInstruments(metrics)

    # ---------------------------------------------------------- tenants

    def register(
        self,
        name: str,
        cfg: M.GNNConfig,
        params: dict,
        precision: str = "fp32",
        calib_graphs: Optional[Sequence[tuple]] = None,
        qconfig=None,
        share_layout: bool = True,
        fused: bool = False,
    ) -> Tenant:
        """Admit a model into the shared machinery.  ``precision`` selects
        the serving arithmetic ("fp32", "int8", "int8-static", "fixed");
        quantization happens once here and every mode then serves the
        transformed tree.  ``fused`` lowers eligible layers through the
        ``kernels.ops.fused_mp`` megakernel (requires a layout plan —
        layers without one, and opt-outs like GAT, keep the unfused path).
        Like ``share_layout`` it is program-level static: part of
        ``program_key``, never of the bucket/warm signatures, so flipping
        it adds programs but never recompiles inside a timed region.
        Tenants with an equal ``program_key`` share compiled programs;
        params and warm state never cross tenants."""
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        quant_report = None
        if precision != "fp32":
            from repro.quant import apply as QA

            qcfg = qconfig or QA.precision_qconfig(precision)
            if (qcfg.scheme == "int8" and qcfg.act_mode == "static"
                    and not calib_graphs):
                raise ValueError(
                    "static-activation int8 needs calib_graphs (raw COO "
                    "tuples) to calibrate activation ranges"
                )
            params, quant_report = QA.quantize_model(
                params, cfg, calib_graphs or (), qcfg
            )
        tenant = Tenant(
            name=name, cfg=cfg, params=params, precision=precision,
            share_layout=share_layout, fused=fused,
            quant_report=quant_report,
            params_sig=params_signature(params),
        )
        self.tenants[name] = tenant
        return tenant

    def tenant(self, model: Optional[str] = None) -> Tenant:
        """Resolve a tenant by name; ``None`` means the sole tenant."""
        if model is not None:
            try:
                return self.tenants[model]
            except KeyError:
                raise KeyError(
                    f"no tenant {model!r}; registered: {sorted(self.tenants)}"
                ) from None
        if len(self.tenants) == 1:
            return next(iter(self.tenants.values()))
        raise KeyError(
            f"model name required: {len(self.tenants)} tenants registered "
            f"({sorted(self.tenants)})"
        )

    # --------------------------------------------------------- plumbing

    @property
    def compile_seconds(self) -> float:
        """Total trace+lower+compile (or AOT disk-load) time across all
        programs — the half of the historical "warm-up" the AOT cache
        eliminates.  Excluded from every reported latency."""
        return sum(cb.compile_s for cb in self._compiled.values())

    @property
    def warm_seconds(self) -> float:
        """Total first-run device-warm time across all programs — the
        one untimed execution per signature, paid even on an AOT cache
        hit.  Excluded from every reported latency."""
        return sum(cb.warm_s for cb in self._compiled.values())

    @property
    def untimed_seconds(self) -> float:
        """compile + warm: the historical single "compile_seconds"
        total (everything excluded from reported latencies)."""
        return self.compile_seconds + self.warm_seconds

    @property
    def lowered_count(self) -> int:
        """Fresh trace+lower+compile constructions across all programs —
        exactly 0 in a process that served every signature from the AOT
        disk cache (the restart-safe fast path)."""
        return sum(cb.lowered_count for cb in self._compiled.values())

    # ------------------------------------------------------ AOT plumbing

    def _fingerprint(self, flags: Dict[str, object]) -> dict:
        """Environment fingerprint with this program's resolved flag set
        folded in (base part computed once — it touches jax.devices())."""
        if self._env_fp_base is None:
            self._env_fp_base = environment_fingerprint()
        from repro.serve.aot import flags_hash

        fp = dict(self._env_fp_base)
        fp["flags"] = flags_hash(flags)
        return fp

    def _compiler_options(self, tenant: Tenant, bucket_key: tuple) -> dict:
        """The XLA compiler options for one (model, bucket) program,
        resolved once and memoized — also the mutation point when a flag
        set turns out invalid for this backend (we fall back to defaults
        *and* remember that, so the store-side fingerprint matches what
        was actually compiled)."""
        if self.xla_flags is None:
            return {}
        key = (model_label(tenant.cfg), bucket_key)
        flags = self._flags_cache.get(key)
        if flags is None:
            flags = self._flags_cache[key] = self.xla_flags.resolve(*key)
        return flags

    def aot_stats(self) -> Dict[str, int]:
        """Disk-cache outcome tally (zeros when no cache is attached)."""
        return dict(self.aot.stats) if self.aot is not None \
            else {"hit": 0, "miss": 0, "stale": 0}

    def _mesh_scope(self):
        """Context under which programs trace/run: installs the executor's
        mesh + rules so logical_constraint resolves; nullcontext otherwise."""
        if self.mesh is None:
            return contextlib.nullcontext()
        stack = contextlib.ExitStack()
        stack.enter_context(RT.use_mesh(self.mesh))
        stack.enter_context(RT.active_rules(self.rules))
        return stack

    def _constrain_graph(self, g: G.Graph) -> G.Graph:
        """Shard the padded node/edge rows over the executor mesh."""
        lc = RT.logical_constraint
        return dataclasses.replace(
            g,
            node_feat=lc(g.node_feat, ("nodes", None)),
            edge_index=lc(g.edge_index, (None, "edges")),
            edge_feat=lc(g.edge_feat, ("edges", None)),
            node_mask=lc(g.node_mask, ("nodes",)),
            edge_mask=lc(g.edge_mask, ("edges",)),
            graph_id=lc(g.graph_id, ("nodes",)),
        )

    def _constrain_layout(self, layout: LY.GraphLayout) -> LY.GraphLayout:
        """Shard the plan's edge-order arrays like the edge rows they
        index (offsets is (N+1,) and stays replicated)."""
        lc = RT.logical_constraint
        return dataclasses.replace(
            layout,
            perm=lc(layout.perm, ("edges",)),
            ids_sorted=lc(layout.ids_sorted, ("edges",)),
            src_sorted=lc(layout.src_sorted, ("edges",)),
            in_degree=lc(layout.in_degree, ("nodes",)),
        )

    def bucket_for(self, n: int, e: int) -> tuple:
        """Smallest configured (N_pad, E_pad) bucket holding (n, e)."""
        for nb, eb in self.buckets:
            if n <= nb and e <= eb:
                return nb, eb
        raise ValueError(
            f"graph ({n},{e}) exceeds largest bucket {self.buckets[-1]}"
        )

    def _program(self, tenant: Tenant, bucket_key: tuple,
                 num_graphs: Optional[int]) -> _CompiledBucket:
        """The compiled program for (tenant-architecture, bucket, slots).

        ``num_graphs`` is part of the cache key — two calls that share a
        bucket but size their pooled buffers differently must never share
        a program (the old engine's closure captured the first call's
        value).  The forward itself comes from the one program builder,
        ``gnn.models.forward_program``; this is the only place in the
        serving stack that constructs a jitted program.
        """
        key = (tenant.program_key, bucket_key, num_graphs)
        cb = self._compiled.get(key)
        if cb is None:
            program = M.forward_program(
                tenant.cfg, num_graphs=num_graphs,
                share_layout=tenant.share_layout, fused=tenant.fused,
            )

            @jax.jit
            def run(params, g: G.Graph, eigvec, layout):
                g = self._constrain_graph(g)
                if eigvec is not None:
                    eigvec = RT.logical_constraint(eigvec, ("nodes",))
                if layout is not None:
                    layout = self._constrain_layout(layout)
                return program(params, g, eigvec, layout)

            cb = _CompiledBucket(fn=run, num_graphs=num_graphs)
            self._compiled[key] = cb
            if self._mi is not None:
                self._mi.programs_built.inc()
            if self.tracer.enabled:
                self.tracer.event("program_build", track="executor",
                                  tenant=tenant.name, bucket=str(bucket_key),
                                  num_graphs=num_graphs)
        if cb.num_graphs != num_graphs:  # pragma: no cover - key carries it
            raise AssertionError(
                f"compile-cache record for {key} carries num_graphs="
                f"{cb.num_graphs}, requested {num_graphs}"
            )
        return cb

    def _compile(self, cb: _CompiledBucket, tenant: Tenant,
                 p: PreparedBatch, flags: dict) -> Callable:
        """Fresh trace + lower + XLA compile of one signature's program,
        with the resolved XLA compiler options applied.  A flag set the
        backend rejects falls back to a default compile — and the
        resolved-flags memo is amended so the AOT write-back fingerprint
        matches what was actually built."""
        lowered = cb.fn.lower(tenant.params, p.graph, p.eigvec, p.layout)
        cb.lowered_count += 1
        if flags:
            try:
                return lowered.compile(compiler_options=dict(flags))
            except Exception as err:  # noqa: BLE001 - backend rejected a flag
                key = (model_label(tenant.cfg), p.bucket_key)
                self._flags_cache[key] = {}
                warnings.warn(
                    f"XLA flag set for {key} rejected by the backend "
                    f"({err}); compiled with default options", stacklevel=2
                )
        return lowered.compile()

    def _executable(self, cb: _CompiledBucket, sig: tuple, tenant: Tenant,
                    p: PreparedBatch) -> Callable:
        """The ready-to-run executable for one signature: the AOT disk
        cache first (fingerprint-checked; hit/miss/stale accounted), a
        fresh compile with write-back otherwise."""
        flags = self._compiler_options(tenant, p.bucket_key)
        exe = None
        if self._aot_serialize:
            key = (repr(tenant.program_key), p.bucket_key, p.num_graphs, sig)
            exe = self.aot.load(key, self._fingerprint(flags))
            if self._mi is not None:
                self._mi.aot_cache.inc(result=self.aot.last_result or "hit")
            if self.tracer.enabled:
                self.tracer.event("aot_load", track="executor",
                                  tenant=tenant.name, bucket=str(p.bucket_key),
                                  result=self.aot.last_result or "hit")
        if exe is None:
            exe = self._compile(cb, tenant, p, flags)
            if self._aot_serialize:
                # store under the *effective* flags (compile may have
                # fallen back to defaults and amended the memo)
                fp = self._fingerprint(self._compiler_options(tenant, p.bucket_key))
                self.aot.store(key, fp, exe)
        return exe

    def _warm(self, cb: _CompiledBucket, sig: tuple, tenant: Tenant,
              p: PreparedBatch) -> float:
        """Make ``sig`` servable through this program: build (or load
        from the AOT cache) its executable, then execute once untimed —
        so neither compilation nor first-run warm can ever leak into a
        reported latency.  The two halves are accounted separately
        (``compile_s`` / ``warm_s``); returns total seconds spent (0.0
        when already warm)."""
        if sig in cb.warm:
            return 0.0
        t0 = self.clock.now()
        exe = self._executable(cb, sig, tenant, p)
        cb.executables[sig] = exe
        compile_dt = self.clock.now() - t0
        t1 = self.clock.now()
        jax.block_until_ready(exe(tenant.params, p.graph, p.eigvec, p.layout))
        warm_dt = self.clock.now() - t1
        cb.warm.add(sig)
        cb.compile_s += compile_dt
        cb.warm_s += warm_dt
        if self._mi is not None:
            self._mi.warms.inc()
            self._mi.compile_seconds.inc(compile_dt)
            self._mi.warm_seconds.inc(warm_dt)
        if self.tracer.enabled:
            self.tracer.event("warm", track="executor",
                              bucket=str(p.bucket_key), dur_s=warm_dt,
                              compile_s=compile_dt)
        return compile_dt + warm_dt

    # ---------------------------------------------------------- prepare

    def prepare_stream(self, raw: tuple, with_eigvec: bool = False) -> PreparedBatch:
        """Stage one raw COO graph for batch-size-1 streaming: pad into the
        smallest bucket; no layout plan (the compiled step converts COO
        once on device — the single timed sort of the forward)."""
        s, r, nf, ef = raw[:4]
        nb, eb = self.bucket_for(nf.shape[0], len(s))
        g = G.from_numpy(s, r, nf, ef, n_pad=nb, e_pad=eb)
        eig = self._eigvec(s, r, nf.shape[0], nb) if with_eigvec else None
        return prepared(g, eig, None, ("stream", nb, eb), 1)

    def prepare_batched(self, chunk: Sequence[tuple], batch_size: int,
                        n_pad: int, e_pad: int,
                        with_eigvec: bool = False) -> PreparedBatch:
        """Stage one fixed-size padded batch: concatenate the chunk's raw
        graphs, build per-graph eigenvectors at the packed node offsets
        (host-side, before the timed region)."""
        gs = [(g[0], g[1], g[2], g[3]) for g in chunk]
        g = G.batch_graphs(gs, n_pad=n_pad, e_pad=e_pad)
        eig = None
        if with_eigvec:
            vec = np.zeros((n_pad,), np.float32)
            off = 0
            for s, r, nf, _ in gs:
                n = nf.shape[0]
                vec[off : off + n] = np.asarray(self._eigvec(s, r, n, n))
                off += n
            eig = jnp.asarray(vec)
        return prepared(g, eig, None,
                        ("batched", n_pad, e_pad, batch_size), batch_size)

    def prepare_packed(self, packed: G.Graph, budget, eigvec=None,
                       layout=None, model: Optional[str] = None) -> PreparedBatch:
        """Stage one already-packed multi-graph batch (``core.batching``).

        ``layout`` is normally the plan the packer emitted at pack time
        (zero on-device sorts in the flushed program); when absent and the
        tenant shares layouts, the host plan is built here — the plan
        always travels with its batch, never a sort inside the program.
        """
        if eigvec is not None:
            eigvec = jnp.asarray(eigvec, jnp.float32)
        if layout is None and self.tenant(model).share_layout:
            layout = B.pack_layout(packed)
        return prepared(packed, eigvec, layout,
                        ("packed", budget.n_pad, budget.e_pad, budget.g_pad),
                        budget.g_pad)

    def has_program(self, bucket_key: tuple, num_graphs: int,
                    model: Optional[str] = None) -> bool:
        """Whether a compiled program already exists for this tenant's
        architecture at (bucket, slots) — the scheduler's eager-prewarm
        skip check."""
        key = (self.tenant(model).program_key, bucket_key, num_graphs)
        return key in self._compiled

    # --------------------------------------------------------- warm/run

    def _harvest(self, out, tenant: Tenant, p: PreparedBatch,
                 t0: float) -> Tuple[np.ndarray, float]:
        """Complete one dispatched execution: wait for the device, close
        the timed region, then convert the outputs device-to-host under
        the ``unpack_d2h`` accounting (the D2H copy used to hide outside
        every measurement).  The extra clock reads are gated on a live
        sink so the dark path stays free."""
        out = jax.block_until_ready(out)
        dt = self.clock.now() - t0
        accounted = self._mi is not None or self.tracer.enabled
        if accounted:
            t2 = self.clock.now()
        host = np.asarray(out)
        if accounted:
            d2h = self.clock.now() - t2
            if self._mi is not None:
                self._mi.device_seconds.inc(dt)
                self._mi.d2h_seconds.inc(d2h)
            if self.tracer.enabled:
                self.tracer.event("executor_run", track="executor",
                                  tenant=tenant.name, bucket=str(p.bucket_key),
                                  dur_s=dt)
                self.tracer.event("unpack_d2h", track="executor",
                                  tenant=tenant.name, bucket=str(p.bucket_key),
                                  dur_s=d2h)
        return host, dt

    def run_async(self, p: PreparedBatch,
                  model: Optional[str] = None) -> "PendingRun":
        """Dispatch one execution without waiting for it: warm the
        signature (untimed, as ever), open the timed region, hand the
        program to the device, and return a :class:`PendingRun`
        immediately — JAX's async dispatch keeps computing while the
        caller packs the next flush.  ``PendingRun.result()`` harvests
        the outputs and closes the timed region; the in-flight window is
        the *caller's* responsibility (``serve/pipeline.py`` bounds it)."""
        tenant = self.tenant(model)
        cb = self._program(tenant, p.bucket_key, p.num_graphs)
        sig = (tenant.params_sig,) + p.signature
        with self._mesh_scope():
            self._warm(cb, sig, tenant, p)
            # dispatch through the signature's AOT executable (fresh or
            # deserialized); cb.fn remains the lowering source/fallback
            fn = cb.executables.get(sig, cb.fn)
            t0 = self.clock.now()
            out = fn(tenant.params, p.graph, p.eigvec, p.layout)
        return PendingRun(self, out, tenant, p, t0)

    def run(self, p: PreparedBatch,
            model: Optional[str] = None) -> Tuple[np.ndarray, float]:
        """The one timed execution path.  Warms the signature first (un-
        timed, recorded in ``compile_seconds``), then runs and returns
        ``(outputs, seconds)`` — dispatch plus an immediate harvest, so
        serial callers see the exact historical contract while the async
        path stays the single implementation."""
        return self.run_async(p, model=model).result()

    def warm(self, p: PreparedBatch, model: Optional[str] = None) -> float:
        """Compile/warm this batch's signature without a timed execution
        (the scheduler pre-warms budget-ladder rungs with this).  Returns
        seconds spent (0.0 when already warm); also tracked in
        ``compile_seconds``."""
        tenant = self.tenant(model)
        cb = self._program(tenant, p.bucket_key, p.num_graphs)
        with self._mesh_scope():
            return self._warm(cb, (tenant.params_sig,) + p.signature,
                              tenant, p)

    # ------------------------------------------------------------- misc

    _EIGVEC_LRU_SIZE = 128

    def _eigvec(self, s, r, n, n_pad):
        """First non-trivial Laplacian eigenvector — DGN's *input* (the
        paper passes precomputed eigenvectors as a parameter; for synthetic
        streams we compute it on the host as part of data generation).

        Memoized: a small LRU keyed by (edge-list bytes, n, n_pad) — a
        live stream revisits graph shapes constantly (molecule streams
        repeat molecules; benchmarks replay the same take), and the host
        eigensolve is the most expensive single prepare stage, so
        repeated shapes must not re-pay it.  Hits/misses land in the
        ``serve_eigvec_cache_total`` counter when a registry is attached.
        """
        s_arr = np.ascontiguousarray(s)
        r_arr = np.ascontiguousarray(r)
        key = (s_arr.tobytes(), r_arr.tobytes(), int(n), int(n_pad))
        cached = self._eigvec_lru.get(key)
        if cached is not None:
            self._eigvec_lru.move_to_end(key)
            if self._mi is not None:
                self._mi.eigvec_cache.inc(result="hit")
            return cached
        from repro.data.pipeline import laplacian_eigvec

        vec = jnp.asarray(laplacian_eigvec(s, r, n, n_pad))
        self._eigvec_lru[key] = vec
        if len(self._eigvec_lru) > self._EIGVEC_LRU_SIZE:
            self._eigvec_lru.popitem(last=False)
        if self._mi is not None:
            self._mi.eigvec_cache.inc(result="miss")
        return vec


class PendingRun:
    """One dispatched-but-unharvested execution: the future
    :meth:`Executor.run_async` hands back.

    ``result()`` blocks until the device finishes, closes the timed
    region (``dt`` spans dispatch to completion-harvest on the
    executor's clock), converts the outputs to host memory under the
    ``unpack_d2h`` accounting, and caches — a second call returns the
    same ``(outputs, seconds)`` without touching the device again.
    ``done`` flips once harvested (the in-flight bookkeeping hook)."""

    __slots__ = ("_executor", "_out", "_tenant", "_prepared", "_t0", "_result")

    def __init__(self, executor: Executor, out, tenant: Tenant,
                 prepared: PreparedBatch, t0: float):
        self._executor = executor
        self._out = out
        self._tenant = tenant
        self._prepared = prepared
        self._t0 = t0
        self._result: Optional[Tuple[np.ndarray, float]] = None

    @property
    def done(self) -> bool:
        return self._result is not None

    def result(self) -> Tuple[np.ndarray, float]:
        if self._result is None:
            self._result = self._executor._harvest(
                self._out, self._tenant, self._prepared, self._t0
            )
            self._out = None  # drop the device buffers once harvested
        return self._result
