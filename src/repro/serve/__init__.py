"""Serving engines: streaming GNN inference (single-graph, batched, and
packed multi-graph via the micro-batching scheduler) + batched LM
prefill/decode."""
from repro.serve.gnn_engine import GNNEngine
from repro.serve.engine import LMServer, ServeConfig
from repro.serve.scheduler import Request, StreamReport, StreamScheduler
