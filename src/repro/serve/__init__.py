"""Serving engines: the composable Executor pipeline (prepare -> constrain
-> warm -> run) with multi-tenant registration, the single-tenant
GNNEngine facade, the SLO-aware streaming micro-batching scheduler on its
deterministic virtual clock, and the batched LM prefill/decode server."""
from repro.serve.clock import Clock, RealClock, VirtualClock
from repro.serve.executor import Executor, PreparedBatch, Tenant, trace_signature
from repro.serve.gnn_engine import GNNEngine
from repro.serve.engine import LMServer, ServeConfig
from repro.serve.scheduler import (
    FlushRecord,
    Request,
    Shed,
    StreamReport,
    StreamScheduler,
)
