"""Serving engines: streaming GNN inference + batched LM prefill/decode."""
from repro.serve.gnn_engine import GNNEngine
from repro.serve.engine import LMServer, ServeConfig
