"""Host/device pipelining: bounded prepare-ahead + dispatch-ahead.

GenGNN's serving claim is that preprocessing-free inference keeps the
accelerator busy on a live stream; FlowGNN gets there by overlapping data
movement with compute across its queues.  Our serial loop was the
opposite: ``Executor.run`` blocked inside the scheduler's event loop, and
every prepare stage (padding, ``pack_layout``, the Laplacian eigensolve)
ran on the host *between* device executions — at capacity the device
idled while the host packed, and the host idled while the device ran.

This module owns the two live halves of the fix, and is the **only**
place in ``serve/`` + ``obs/`` allowed to touch ``threading`` /
``concurrent.futures`` (``tools/check_engine_singlepath.py`` enforces
it, the same way it pins the ``time`` module to the executor + clock):

* :class:`PipelinedStream` — a double-buffered executor-level runner: a
  single worker thread runs the ``prepare_*`` stage (pad + layout +
  eigvec) for request k+1 and stages it onto the device with
  ``jax.device_put`` while the device runs request k; the caller thread
  dispatches via :meth:`Executor.run_async` (no ``block_until_ready``)
  and harvests completions strictly FIFO through a bounded in-flight
  window (default depth 2).
* :class:`PipelineConfig` — the knob object the scheduler's *modeled*
  pipelined mode takes (``StreamScheduler(pipeline=...)``).  Under a
  ``VirtualClock`` the scheduler must stay single-threaded and bitwise
  deterministic, so it never uses the worker thread: it dispatches and
  harvests out of order on the virtual timeline, modeling host-pack
  cost per flush from ``host_cost`` — ``None`` (free host), a scripted
  constant/sequence (exact sims), or ``"measured"`` (real host seconds
  read through the executor's clock, folded into the timeline).

Thread discipline: exactly one worker, and it only *prepares*; dispatch,
harvest, and every executor-cache mutation stay on the caller thread.
Because the device executes dispatches in order, completions are FIFO by
construction — harvesting the window front preserves per-request
response order even though dispatch k+1 happens before k completes.

:func:`overlap_fraction` reports how much host-pack time actually hid
under device execution, computed from a run's trace spans — the number
``benchmarks/bench_pipeline.py`` records.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.serve.executor import Executor, PendingRun, PreparedBatch


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Knobs of the pipelined execution mode.

    inflight:   bound on dispatched-but-unharvested flushes (the
                in-flight window).  1 = serial dispatch order (the
                equivalence baseline); 2 = double buffering (default).
    host_cost:  how the scheduler's modeled pipeline accounts host-pack
                time per flush on the virtual timeline:
                  * ``None`` — host work is free on the timeline (pure
                    dispatch-ahead semantics; the deterministic default);
                  * a float — constant seconds per flush (exact sims);
                  * a sequence — scripted per-flush seconds, the last
                    entry repeating once exhausted (mirrors the
                    ``scripted_executor`` service-time convention);
                  * ``"measured"`` — real host seconds measured around
                    the pack stage through the executor's clock and
                    folded into the timeline (benchmark honesty on a
                    live box; no longer bitwise across runs).
    overlap:    whether the modeled prepare worker packs *ahead* of the
                device (the pipeline; default).  ``False`` gates each
                pack on the device going idle — exactly the serial
                loop's inline-blocking host — which is the baseline a
                modeled speedup must be measured against:
                ``PipelineConfig(inflight=1, host_cost=h, overlap=False)``
                is "the serial path if its host gap were ``h``".
    """

    inflight: int = 2
    host_cost: Union[None, str, float, Sequence[float]] = None
    overlap: bool = True

    def __post_init__(self):
        if self.inflight < 1:
            raise ValueError(f"inflight must be >= 1, got {self.inflight}")
        hc = self.host_cost
        if hc is None or hc == "measured":
            return
        if isinstance(hc, str):
            raise ValueError(
                f"host_cost must be None, 'measured', seconds, or a "
                f"sequence of seconds; got {hc!r}"
            )
        seq = hc if isinstance(hc, (list, tuple)) else (hc,)
        if not seq or any(float(x) < 0 for x in seq):
            raise ValueError(f"host_cost seconds must be >= 0, got {hc!r}")

    @property
    def measured(self) -> bool:
        return self.host_cost == "measured"

    def host_cost_fn(self) -> Optional[Callable[[int], float]]:
        """Per-flush-index modeled host cost; ``None`` for ``"measured"``
        (the scheduler then times the real pack stage instead)."""
        hc = self.host_cost
        if hc == "measured":
            return None
        if hc is None:
            return lambda i: 0.0
        if isinstance(hc, (int, float)):
            const = float(hc)
            return lambda i: const
        seq = [float(x) for x in hc]
        return lambda i: seq[min(i, len(seq) - 1)]


def as_pipeline(value) -> Optional[PipelineConfig]:
    """Normalize the scheduler's ``pipeline=`` argument: ``None``/False
    = serial (off), True = defaults, an int = that in-flight depth, a
    :class:`PipelineConfig` = itself."""
    if value is None or value is False:
        return None
    if value is True:
        return PipelineConfig()
    if isinstance(value, PipelineConfig):
        return value
    if isinstance(value, int):
        return PipelineConfig(inflight=value)
    raise ValueError(
        f"pipeline must be None/bool/int/PipelineConfig, got {value!r}"
    )


class PipelinedStream:
    """Double-buffered streaming through one executor tenant.

    One worker thread prepares (and device-stages) batches ahead of the
    dispatch loop; the caller thread dispatches with
    :meth:`Executor.run_async` and harvests the bounded in-flight window
    strictly FIFO.  ``prepare_ahead`` bounds how many prepared batches
    may wait staged on the device (default: the in-flight depth — one
    buffer filling while one drains is the classic double buffer).

    stage:  ``jax.device_put`` each prepared batch in the worker, so the
            dispatch-time H2D copy is off the critical path.
    """

    def __init__(self, executor: Executor, model: Optional[str] = None,
                 inflight: int = 2, prepare_ahead: Optional[int] = None,
                 stage: bool = True):
        if inflight < 1:
            raise ValueError(f"inflight must be >= 1, got {inflight}")
        if prepare_ahead is not None and prepare_ahead < 1:
            raise ValueError(f"prepare_ahead must be >= 1, got {prepare_ahead}")
        self.executor = executor
        self.model = model
        self.inflight = inflight
        self.prepare_ahead = prepare_ahead if prepare_ahead is not None else inflight
        self.stage = stage

    def _prepare(self, raw, with_eigvec: bool,
                 prepare: Optional[Callable]) -> PreparedBatch:
        p = (prepare(raw) if prepare is not None
             else self.executor.prepare_stream(raw, with_eigvec=with_eigvec))
        return jax.device_put(p) if self.stage else p

    def run(self, raws: Sequence[tuple], with_eigvec: bool = False,
            prepare: Optional[Callable] = None,
            ) -> Tuple[List[np.ndarray], dict]:
        """Stream ``raws`` through the pipeline; returns ``(outputs,
        stats)`` with outputs in request order (FIFO is asserted by
        construction: the window is harvested front-first).

        ``prepare`` overrides the per-item prepare stage (default:
        ``prepare_stream``); it runs on the worker thread, so it must
        not touch executor compile/warm state — the ``prepare_*`` family
        is host-side construction only, which is exactly why it can
        overlap the device.
        """
        clock = self.executor.clock
        t_start = clock.now()
        outputs: List[np.ndarray] = []
        times: List[float] = []
        window: "collections.deque[PendingRun]" = collections.deque()
        peak_inflight = 0

        def harvest_one() -> None:
            out, dt = window.popleft().result()
            outputs.append(out)
            times.append(dt)

        with concurrent.futures.ThreadPoolExecutor(max_workers=1) as pool:
            prepared: "collections.deque" = collections.deque()
            it = iter(raws)

            def top_up() -> None:
                while len(prepared) < self.prepare_ahead:
                    try:
                        raw = next(it)
                    except StopIteration:
                        return
                    prepared.append(
                        pool.submit(self._prepare, raw, with_eigvec, prepare)
                    )

            top_up()
            while prepared:
                p = prepared.popleft().result()
                top_up()  # refill the prepare queue before dispatching
                if len(window) >= self.inflight:
                    harvest_one()
                window.append(self.executor.run_async(p, model=self.model))
                peak_inflight = max(peak_inflight, len(window))
            while window:
                harvest_one()
        wall_s = clock.now() - t_start
        device_s = float(sum(times))
        return outputs, {
            "wall_s": wall_s,
            "device_s": device_s,
            "per_run_s": times,
            "peak_inflight": peak_inflight,
            "graphs_per_s": len(outputs) / max(wall_s, 1e-12),
        }


def overlap_fraction(trace_or_spans) -> float:
    """Fraction of host-pack span time that overlapped device execution,
    from a run's trace: ``pack`` spans (host track) against the union of
    ``device`` spans.  0.0 when no pack time was recorded — a serial run
    on a `VirtualClock` has zero-width pack markers, so a nonzero value
    is itself evidence the timeline modeled (or measured) real overlap."""
    spans = getattr(trace_or_spans, "spans", trace_or_spans)
    packs = [(s.t0_s, s.t1_s) for s in spans
             if s.name == "pack" and s.t1_s is not None and s.t1_s > s.t0_s]
    total = sum(t1 - t0 for t0, t1 in packs)
    if total <= 0.0:
        return 0.0
    devs = sorted((s.t0_s, s.t1_s) for s in spans
                  if s.name == "device" and s.t1_s is not None)
    merged: List[Tuple[float, float]] = []
    for t0, t1 in devs:
        if merged and t0 <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], t1))
        else:
            merged.append((t0, t1))
    ov = 0.0
    for p0, p1 in packs:
        for d0, d1 in merged:
            ov += max(0.0, min(p1, d1) - max(p0, d0))
    return ov / total
