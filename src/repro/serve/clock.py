"""The serving stack's one time authority: an injectable ``Clock``.

Scheduling correctness lives in timing edge cases — deadline expiry vs
arrival ties, backlog-aware flush ordering, shed decisions taken at
admission time — and none of that is testable against a wall clock.  So
the scheduler never reads wall time: every ``arrival_s`` / ``deadline_s``
/ flush timestamp flows through a ``Clock`` object, and the event loop
*advances* that clock to each event it processes.

Two implementations:

* :class:`VirtualClock` — deterministic simulated time.  The scheduler's
  default: time moves only when the event loop says so, so a scripted
  arrival trace produces bitwise-identical flush timestamps, shed
  decisions, and latencies on every run (``tests/test_slo_sim.py``
  asserts exact float equality, no tolerance).
* :class:`RealClock` — ``time.perf_counter`` for live deployment, where
  arrivals are stamped as they happen.  This module and
  ``serve/executor.py`` are the only places in the serving stack allowed
  to touch the ``time`` module (``tools/check_engine_singlepath.py``
  walks every other ``serve/`` module and fails on ``time.time`` /
  ``time.monotonic`` / ``time.perf_counter`` references), so a wall-clock
  read can never sneak back into scheduling logic.

The :class:`Executor` measures its compute durations through its own
injected clock too (``Executor(clock=...)``, default ``RealClock``) —
its timed region stays the single place real time is *measured*, and a
test can substitute a stepping clock to make even compute durations
deterministic.
"""
from __future__ import annotations

import time


class Clock:
    """Minimal time-source protocol: monotone seconds since an arbitrary
    epoch.  Durations are differences of ``now()`` readings; absolute
    values are meaningless across clock instances.

    ``advance_to`` is the event-loop hook: a simulated clock jumps to the
    requested instant; a real clock cannot jump, so it reports where wall
    time actually is — the scheduler's timeline then *stamps* live events
    instead of scripting them."""

    def now(self) -> float:  # pragma: no cover - interface
        raise NotImplementedError

    def advance_to(self, t_s: float) -> float:  # pragma: no cover - interface
        raise NotImplementedError


class RealClock(Clock):
    """Wall time via ``time.perf_counter`` (highest-resolution monotone
    source) — the live-serving and executor-measurement clock."""

    def now(self) -> float:
        return time.perf_counter()

    def advance_to(self, t_s: float) -> float:
        """Live time cannot jump: the event loop's advance is a stamp.
        Returns wall now — by the time the loop processes an event due at
        ``t_s``, the wall clock is already there or past it, so the
        scheduler's monotone-timeline invariant holds without sleeping."""
        return self.now()


class VirtualClock(Clock):
    """Deterministic simulated time, advanced explicitly by its owner.

    Time never moves on its own and never moves backwards: the scheduler
    advances it to each event (arrival, deadline expiry, flush
    completion) in order, so every timestamp in a simulated stream is an
    exact, reproducible function of the input trace.
    """

    __slots__ = ("_now_s",)

    def __init__(self, start_s: float = 0.0):
        self._now_s = float(start_s)

    def now(self) -> float:
        return self._now_s

    def advance_to(self, t_s: float) -> float:
        """Move time forward to ``t_s``; moving backwards is a scheduling
        bug and raises rather than silently reordering events."""
        if t_s < self._now_s:
            raise ValueError(
                f"virtual time cannot go backwards: now={self._now_s!r}, "
                f"requested {t_s!r}"
            )
        self._now_s = float(t_s)
        return self._now_s

    def advance(self, dt_s: float) -> float:
        """Move time forward by a non-negative delta."""
        if dt_s < 0:
            raise ValueError(f"negative advance: {dt_s!r}")
        return self.advance_to(self._now_s + dt_s)
