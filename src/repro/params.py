"""Parameters with logical sharding axes (MaxText-style logical annotations).

Every parameter is created as ``Param(value, axes)`` where ``axes`` names
one logical axis per array dimension (e.g. ("embed", "heads", "head_dim")).
``sharding.resolve_rules`` maps logical names to physical mesh axes with
divisibility-aware fallback, giving per-tensor PartitionSpecs without
scattering mesh knowledge through model code.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


class Param:
    """A parameter value + its logical axis names.

    Registered as a pytree node with ``axes`` as *static* aux data, so
    Param trees pass through jit / vmap / eval_shape: transformations see
    only ``value`` while the axes ride along in the tree structure.
    """

    __slots__ = ("value", "axes")

    def __init__(self, value: Any, axes: Tuple[str, ...]):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Param(shape={shape}, axes={self.axes})"

    def __eq__(self, other):
        return (
            isinstance(other, Param)
            and other.axes == self.axes
            and other.value is self.value
        )


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.axes),
    lambda axes, children: Param(children[0], axes),
)


def is_param(x) -> bool:
    return isinstance(x, Param)


def values(tree):
    """Strip axes: tree of Param -> tree of arrays."""
    return jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)


def axes(tree):
    """Strip values: tree of Param -> tree of axis tuples."""
    return jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)


def merge(value_tree, axes_tree):
    return jax.tree.map(
        lambda v, a: Param(v, a), value_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(s, str) for s in x),
    )


def init_normal(rng, shape, axes_, scale=None, dtype=jnp.float32) -> Param:
    scale = scale if scale is not None else (1.0 / max(shape[0], 1)) ** 0.5
    return Param(jax.random.normal(rng, shape, dtype) * scale, tuple(axes_))


def init_zeros(shape, axes_, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), tuple(axes_))


def init_ones(shape, axes_, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), tuple(axes_))


def abstract(tree, dtype=None):
    """Param tree -> Param tree of ShapeDtypeStructs (for .lower without
    allocating full-scale weights)."""

    def f(p: Param) -> Param:
        v = p.value
        dt = dtype or v.dtype
        return Param(jax.ShapeDtypeStruct(v.shape, dt), p.axes)

    return jax.tree.map(f, tree, is_leaf=is_param)
