"""Process-wide metrics: counters, gauges, histograms, and the catalog.

A :class:`MetricsRegistry` is a plain in-process store — no background
thread, no clock reads (the AST guard walks this package), no external
dependency.  Instruments are created through ``registry.counter(...)`` /
``gauge`` / ``histogram`` with get-or-create semantics (a second
registration with a different type or label set is a bug and raises),
and every instrument holds one value per label-set *series*.

**The catalog is closed.**  :data:`CATALOG` is the single source of
truth for every metric the serving stack may emit — name, type, help
text, and label names.  Registration of a name outside the catalog
raises unless explicitly marked ad-hoc, and the exporters
(``obs/export.py``) plus ``tools/check_telemetry_artifacts.py`` validate
snapshots against it, so a dashboard can rely on the metric surface the
way tests rely on an API: an unregistered name is a CI failure, not a
silently new time series.

Determinism: snapshots sort metric names and label sets, so two
identical simulated runs serialize to identical JSON.  Histograms use
fixed cumulative ``le`` bucket bounds (Prometheus semantics, ``+Inf``
implicit via ``count``).

``default_registry()`` is the process-wide instance: trace-time
instrumentation that has no injection point (the ``kernels/ops``
dispatch counters — one increment per *compiled program*, never per
request) records there; serving components take an explicit
``metrics=`` registry (default ``None`` = off) so library users pay
nothing unless they opt in.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

# latency-shaped seconds buckets: sub-ms to 1s, the serving stack's range
LATENCY_BUCKETS_S = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
                     0.05, 0.1, 0.25, 0.5, 1.0)
# flush batch-size buckets: base bucket to the deepest ladder rung
SIZE_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0)

#: name -> (type, help, label names).  The closed metric surface.
CATALOG: Dict[str, Tuple[str, str, Tuple[str, ...]]] = {
    # ---- scheduler: admission / shedding / flush accounting
    "serve_requests_total": (
        "counter", "Requests offered to the scheduler", ("tenant", "priority")),
    "serve_admitted_total": (
        "counter", "Requests admitted past SLO projection", ("tenant", "priority")),
    "serve_shed_total": (
        "counter", "Requests shed at admission, by reason",
        ("tenant", "priority", "reason")),
    "serve_served_total": (
        "counter", "Requests served to completion (goodput numerator)",
        ("tenant", "priority")),
    "serve_deadline_misses_total": (
        "counter", "Served requests that finished past their SLO deadline",
        ("tenant", "priority")),
    "serve_flushes_total": (
        "counter", "Bucket flushes, by reason (budget|deadline|drain)",
        ("reason",)),
    "serve_flush_graphs": (
        "histogram", "Real graphs per flush (micro-batch fill)", ()),
    "serve_request_latency_seconds": (
        "histogram", "End-to-end latency of served requests (arrival to done)",
        ("tenant", "priority")),
    "serve_queue_depth": (
        "gauge", "Admitted-but-unflushed requests across open buckets", ()),
    "serve_open_buckets": (
        "gauge", "Currently open (accumulating) micro-batch buckets", ()),
    "serve_service_ewma_seconds": (
        "gauge", "Per-signature service-time EWMA feeding admission projection",
        ("sig",)),
    "serve_ladder_refits_total": (
        "counter", "Adaptive-ladder geometry refits, per signature", ("sig",)),
    # ---- executor: compile / warm / device accounting
    "serve_programs_built_total": (
        "counter", "Compiled-program cache misses (jit program constructions)", ()),
    "serve_warms_total": (
        "counter", "Untimed warm executions (new trace signatures)", ()),
    "serve_compile_seconds_total": (
        "counter", "Seconds spent in trace+lower+compile (or AOT disk load), "
        "outside every timed region", ()),
    "serve_warm_seconds_total": (
        "counter", "Seconds spent in first-run device warm executions, "
        "outside every timed region (paid even on an AOT cache hit)", ()),
    "serve_aot_cache_total": (
        "counter", "AOT disk-cache lookups, by result (hit|miss|stale)",
        ("result",)),
    "serve_cold_start_seconds": (
        "gauge", "Process restart to first served response (serving-stack "
        "cost: construct + register + prewarm/AOT-load + first probe)", ()),
    "serve_device_seconds_total": (
        "counter", "Seconds of timed device execution", ()),
    "serve_d2h_seconds_total": (
        "counter", "Seconds spent in device-to-host output transfer "
        "(the unpack_d2h span at result harvest)", ()),
    "serve_eigvec_cache_total": (
        "counter", "Host eigvec-LRU lookups, by result (hit|miss)",
        ("result",)),
    # ---- pipeline: dispatch-ahead execution
    "serve_inflight_depth": (
        "gauge", "Dispatched-but-unharvested flushes in the pipelined "
        "in-flight window", ()),
    "serve_pack_ewma_seconds": (
        "gauge", "Per-signature host-pack EWMA feeding pipelined admission "
        "projection", ("sig",)),
    # ---- kernels: dispatch decisions (one per compiled program, at trace time)
    "kernels_dispatch_total": (
        "counter",
        "Kernel dispatch decisions at trace time, by op and resolved path "
        "(kernel|interpret|reference|vmem_fallback)",
        ("op", "path")),
}

_TYPES = ("counter", "gauge", "histogram")


def _series_key(labelnames: Tuple[str, ...], labels: dict) -> tuple:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"labels {sorted(labels)} do not match declared label names "
            f"{sorted(labelnames)}"
        )
    return tuple(str(labels[k]) for k in labelnames)


class _Instrument:
    """Shared per-metric state: declared labels + one value per series."""

    kind = "abstract"

    def __init__(self, name: str, help: str, labelnames: Sequence[str]):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._series: dict = {}

    def _key(self, labels: dict) -> tuple:
        return _series_key(self.labelnames, labels)

    def series(self) -> dict:
        """``{label-value tuple: value}`` — sorted by the exporters."""
        return dict(self._series)


class Counter(_Instrument):
    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({value})")
        k = self._key(labels)
        self._series[k] = self._series.get(k, 0.0) + float(value)

    def value(self, **labels) -> float:
        return self._series.get(self._key(labels), 0.0)

    def total(self) -> float:
        return float(sum(self._series.values()))


class Gauge(_Instrument):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[self._key(labels)] = float(value)

    def value(self, **labels) -> float:
        return self._series.get(self._key(labels), 0.0)


class Histogram(_Instrument):
    kind = "histogram"

    def __init__(self, name: str, help: str, labelnames: Sequence[str],
                 buckets: Sequence[float] = LATENCY_BUCKETS_S):
        super().__init__(name, help, labelnames)
        bs = tuple(float(b) for b in buckets)
        if list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(f"histogram {name} buckets must strictly increase")
        if bs and math.isinf(bs[-1]):
            bs = bs[:-1]  # +Inf is implicit (== count)
        self.buckets = bs

    def observe(self, value: float, **labels) -> None:
        k = self._key(labels)
        s = self._series.get(k)
        if s is None:
            s = self._series[k] = {
                "buckets": [0] * len(self.buckets), "sum": 0.0, "count": 0,
            }
        v = float(value)
        for i, bound in enumerate(self.buckets):
            if v <= bound:
                s["buckets"][i] += 1
        s["sum"] += v
        s["count"] += 1

    def count(self, **labels) -> int:
        s = self._series.get(self._key(labels))
        return 0 if s is None else s["count"]

    def sum(self, **labels) -> float:
        s = self._series.get(self._key(labels))
        return 0.0 if s is None else s["sum"]


class MetricsRegistry:
    """Get-or-create instrument store, validated against :data:`CATALOG`."""

    def __init__(self):
        self._metrics: Dict[str, _Instrument] = {}

    # ------------------------------------------------------------ create

    def _get_or_create(self, cls, name: str, help: str,
                       labels: Sequence[str], **kw) -> _Instrument:
        spec = CATALOG.get(name)
        if spec is None:
            raise ValueError(
                f"metric {name!r} is not in obs.metrics.CATALOG — the metric "
                f"surface is closed; add it to the catalog (and to "
                f"docs/OBSERVABILITY.md) first"
            )
        kind, cat_help, cat_labels = spec
        if kind != cls.kind:
            raise ValueError(
                f"metric {name!r} is a {kind} in the catalog, not a {cls.kind}"
            )
        labels = tuple(labels) or cat_labels
        help = help or cat_help
        if labels != cat_labels:
            raise ValueError(
                f"metric {name!r} declares labels {labels}, catalog says "
                f"{cat_labels}"
            )
        inst = self._metrics.get(name)
        if inst is None:
            inst = self._metrics[name] = cls(name, help, labels, **kw)
        elif not isinstance(inst, cls):
            raise ValueError(
                f"metric {name!r} already registered as {inst.kind}, "
                f"not {cls.kind}"
            )
        return inst

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", labels: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS_S) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels,
                                   buckets=buckets)

    # ------------------------------------------------------------- read

    def get(self, name: str) -> Optional[_Instrument]:
        return self._metrics.get(name)

    def names(self) -> list:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """Deterministic JSON-able view: sorted names, sorted series, the
        ``repro-metrics/v1`` schema the artifact checker validates."""
        metrics = {}
        for name in sorted(self._metrics):
            inst = self._metrics[name]
            series = []
            for key in sorted(inst._series):
                entry = {"labels": dict(zip(inst.labelnames, key))}
                val = inst._series[key]
                if inst.kind == "histogram":
                    entry.update(
                        buckets=dict(zip((str(b) for b in inst.buckets),
                                         val["buckets"])),
                        sum=val["sum"], count=val["count"],
                    )
                else:
                    entry["value"] = val
                series.append(entry)
            metrics[name] = {
                "type": inst.kind,
                "help": inst.help,
                "labelnames": list(inst.labelnames),
                "series": series,
            }
            if inst.kind == "histogram":
                metrics[name]["bucket_bounds"] = list(inst.buckets)
        return {"schema": "repro-metrics/v1", "metrics": metrics}


_DEFAULT: Optional[MetricsRegistry] = None


def default_registry() -> MetricsRegistry:
    """The process-wide registry — the sink for trace-time instrumentation
    with no injection point (kernel dispatch decisions).  Serving
    components never reach for this implicitly; they take ``metrics=``."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = MetricsRegistry()
    return _DEFAULT


class ServingInstruments:
    """All catalog instruments of one registry, pre-registered and bound
    to attributes — the scheduler/executor grab these once at attach
    time so the hot path is one method call per emission, and an
    exported snapshot always carries the full declared surface (a
    metric that never fired still appears, with zero series)."""

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        self.requests = registry.counter("serve_requests_total")
        self.admitted = registry.counter("serve_admitted_total")
        self.shed = registry.counter("serve_shed_total")
        self.served = registry.counter("serve_served_total")
        self.deadline_misses = registry.counter("serve_deadline_misses_total")
        self.flushes = registry.counter("serve_flushes_total")
        self.flush_graphs = registry.histogram("serve_flush_graphs",
                                               buckets=SIZE_BUCKETS)
        self.latency = registry.histogram("serve_request_latency_seconds")
        self.queue_depth = registry.gauge("serve_queue_depth")
        self.open_buckets = registry.gauge("serve_open_buckets")
        self.service_ewma = registry.gauge("serve_service_ewma_seconds")
        self.ladder_refits = registry.counter("serve_ladder_refits_total")
        self.programs_built = registry.counter("serve_programs_built_total")
        self.warms = registry.counter("serve_warms_total")
        self.compile_seconds = registry.counter("serve_compile_seconds_total")
        self.warm_seconds = registry.counter("serve_warm_seconds_total")
        self.aot_cache = registry.counter("serve_aot_cache_total")
        self.cold_start = registry.gauge("serve_cold_start_seconds")
        self.device_seconds = registry.counter("serve_device_seconds_total")
        self.d2h_seconds = registry.counter("serve_d2h_seconds_total")
        self.eigvec_cache = registry.counter("serve_eigvec_cache_total")
        self.inflight_depth = registry.gauge("serve_inflight_depth")
        self.pack_ewma = registry.gauge("serve_pack_ewma_seconds")
