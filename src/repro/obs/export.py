"""Exporters: Prometheus text, JSON snapshots, Chrome/Perfetto traces.

Three render targets over the one telemetry store:

* :func:`prometheus_text` — the Prometheus text exposition format
  (``# HELP`` / ``# TYPE`` headers, one sample line per series,
  histograms as cumulative ``_bucket{le=...}`` + ``_sum`` + ``_count``).
  Scrape-ready; also the human-printable face of the admission ledger.
* :func:`metrics_snapshot` / :func:`write_metrics_json` — the
  ``repro-metrics/v1`` JSON document (deterministically ordered) that
  ``--metrics-json`` writes and ``tools/check_telemetry_artifacts.py``
  validates against the closed catalog.
* :func:`trace_events` / :func:`trace_json` / :func:`write_trace` — the
  Chrome trace-event JSON (``chrome://tracing`` / https://ui.perfetto.dev)
  of a :class:`~repro.obs.trace.Tracer`'s spans: one thread row per
  track (scheduler / device / host / executor), complete events for
  closed spans, instants for events.  Under a ``VirtualClock``
  simulation :func:`trace_json` is bitwise-identical across runs of the
  same scripted stream (sorted keys, canonical separators, timestamps
  that are exact functions of the trace).

The validators (:func:`validate_metrics_snapshot`,
:func:`validate_trace_events`) raise ``ValueError`` with a per-defect
message; CI runs them over the artifacts a real serve run wrote, so the
exporter formats are regression-pinned, not aspirational.
"""
from __future__ import annotations

import json
from typing import Optional

from repro.obs.metrics import CATALOG, MetricsRegistry

_SCHEMA = "repro-metrics/v1"


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------


def _fmt(v) -> str:
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


def _esc(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _label_str(labelnames, key, extra=()) -> str:
    pairs = list(zip(labelnames, key)) + list(extra)
    if not pairs:
        return ""
    body = ",".join(f'{k}="{_esc(v)}"' for k, v in pairs)
    return "{" + body + "}"


def prometheus_text(registry: MetricsRegistry) -> str:
    """The registry in Prometheus text exposition format (sorted names
    and series — deterministic)."""
    out = []
    for name in registry.names():
        inst = registry.get(name)
        out.append(f"# HELP {name} {_esc(inst.help)}")
        out.append(f"# TYPE {name} {inst.kind}")
        for key in sorted(inst._series):
            val = inst._series[key]
            if inst.kind == "histogram":
                for bound, n in zip(inst.buckets, val["buckets"]):
                    out.append(
                        f"{name}_bucket"
                        f"{_label_str(inst.labelnames, key, [('le', _fmt(bound))])}"
                        f" {n}"
                    )
                out.append(
                    f"{name}_bucket"
                    f"{_label_str(inst.labelnames, key, [('le', '+Inf')])}"
                    f" {val['count']}"
                )
                out.append(f"{name}_sum{_label_str(inst.labelnames, key)} "
                           f"{_fmt(val['sum'])}")
                out.append(f"{name}_count{_label_str(inst.labelnames, key)} "
                           f"{val['count']}")
            else:
                out.append(f"{name}{_label_str(inst.labelnames, key)} "
                           f"{_fmt(val)}")
    return "\n".join(out) + ("\n" if out else "")


# ---------------------------------------------------------------------------
# JSON snapshot
# ---------------------------------------------------------------------------


def metrics_snapshot(registry: MetricsRegistry) -> dict:
    return registry.snapshot()


def write_metrics_json(registry: MetricsRegistry, path: str) -> None:
    with open(path, "w") as f:
        json.dump(registry.snapshot(), f, indent=2, sort_keys=True)
        f.write("\n")


def validate_metrics_snapshot(doc: dict, catalog: dict = CATALOG) -> int:
    """Schema-check one ``repro-metrics/v1`` document; every metric name
    must be in the closed catalog with a matching type and label set.
    Returns the number of metrics validated; raises ``ValueError``."""
    if not isinstance(doc, dict) or doc.get("schema") != _SCHEMA:
        raise ValueError(f"not a {_SCHEMA} document: schema={doc.get('schema')!r}"
                         if isinstance(doc, dict) else "metrics doc is not an object")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict):
        raise ValueError("metrics document missing 'metrics' object")
    for name, m in metrics.items():
        spec = catalog.get(name)
        if spec is None:
            raise ValueError(f"unregistered metric name {name!r} — not in "
                             f"obs.metrics.CATALOG (the surface is closed)")
        kind, _, labelnames = spec
        if m.get("type") != kind:
            raise ValueError(f"{name}: type {m.get('type')!r} != catalog {kind!r}")
        if tuple(m.get("labelnames", ())) != labelnames:
            raise ValueError(f"{name}: labelnames {m.get('labelnames')} != "
                             f"catalog {list(labelnames)}")
        for s in m.get("series", ()):
            if set(s.get("labels", {})) != set(labelnames):
                raise ValueError(f"{name}: series labels {sorted(s.get('labels', {}))} "
                                 f"!= declared {sorted(labelnames)}")
            if kind == "histogram":
                if not {"buckets", "sum", "count"} <= set(s):
                    raise ValueError(f"{name}: histogram series missing "
                                     f"buckets/sum/count: {sorted(s)}")
            elif "value" not in s:
                raise ValueError(f"{name}: series missing 'value'")
    return len(metrics)


# ---------------------------------------------------------------------------
# Chrome / Perfetto trace events
# ---------------------------------------------------------------------------

_PID = 1
_PROCESS = "repro-serve"


def _arg(v):
    return v if isinstance(v, (str, int, float, bool)) or v is None else str(v)


def trace_events(tracer) -> dict:
    """Spans as a Chrome trace-event document: thread-name metadata first
    (one Perfetto row per track, in sorted track order), then events in
    recorded order.  Timestamps are microseconds on the tracer's clock
    timeline, rounded to 1ns so float formatting is stable."""
    tracks = sorted({s.track for s in tracer.spans})
    tid = {t: i + 1 for i, t in enumerate(tracks)}
    events = [{
        "name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
        "args": {"name": _PROCESS},
    }]
    for t in tracks:
        events.append({"name": "thread_name", "ph": "M", "pid": _PID,
                       "tid": tid[t], "args": {"name": t}})
    for s in tracer.spans:
        ev = {
            "name": s.name,
            "cat": s.track,
            "pid": _PID,
            "tid": tid[s.track],
            "ts": round(s.t0_s * 1e6, 3),
            "args": {k: _arg(v) for k, v in s.attrs},
        }
        if s.t1_s is None:
            ev["ph"] = "i"
            ev["s"] = "t"
        else:
            ev["ph"] = "X"
            ev["dur"] = round(s.dur_s * 1e6, 3)
        events.append(ev)
    return {"displayTimeUnit": "ms", "traceEvents": events}


def trace_json(tracer) -> str:
    """Canonical serialization (sorted keys, fixed separators) — bitwise
    identical for two ``VirtualClock`` runs of the same scripted trace."""
    return json.dumps(trace_events(tracer), sort_keys=True,
                      separators=(",", ":"))


def write_trace(tracer, path: str) -> None:
    with open(path, "w") as f:
        f.write(trace_json(tracer))
        f.write("\n")


def validate_trace_events(doc: dict) -> int:
    """Schema-check one trace-event document.  Returns the number of
    non-metadata events; raises ``ValueError`` on any defect."""
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        raise ValueError("trace document missing 'traceEvents' list")
    n = 0
    for i, ev in enumerate(doc["traceEvents"]):
        ph = ev.get("ph")
        if ph not in ("M", "X", "i"):
            raise ValueError(f"traceEvents[{i}]: unsupported ph {ph!r}")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(f"traceEvents[{i}]: missing name")
        if not isinstance(ev.get("pid"), int) or not isinstance(ev.get("tid"), int):
            raise ValueError(f"traceEvents[{i}]: pid/tid must be ints")
        if ph == "M":
            continue
        n += 1
        if not isinstance(ev.get("ts"), (int, float)):
            raise ValueError(f"traceEvents[{i}]: ts must be numeric")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"traceEvents[{i}]: X event needs dur >= 0")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"traceEvents[{i}]: args must be an object")
    return n


# ---------------------------------------------------------------------------
# the admission ledger, rendered for humans
# ---------------------------------------------------------------------------


def admission_line(registry: MetricsRegistry) -> str:
    """The structured admission ledger as one human-readable line —
    rendered *from the registry* (the machine-readable record), so the
    printout and the exported counters can never disagree."""
    def total(name: str) -> int:
        inst = registry.get(name)
        return int(inst.total()) if inst is not None else 0

    by_reason: dict = {}
    shed = registry.get("serve_shed_total")
    if shed is not None:
        ri = shed.labelnames.index("reason")
        for key, v in sorted(shed.series().items()):
            by_reason[key[ri]] = by_reason.get(key[ri], 0) + int(v)
    line = (f"admission: served {total('serve_served_total')}  "
            f"shed {total('serve_shed_total')} ({by_reason}); "
            f"deadline misses {total('serve_deadline_misses_total')}")

    def seconds(name: str) -> float:
        inst = registry.get(name)
        return float(inst.total()) if inst is not None else 0.0

    # the untimed warm-up, split into the half the AOT cache eliminates
    # (compile) and the half it cannot (first-run warm); omitted entirely
    # when neither was paid so scripted simulations render unchanged
    compile_s = seconds("serve_compile_seconds_total")
    warm_s = seconds("serve_warm_seconds_total")
    if compile_s or warm_s:
        line += f"; untimed compile {compile_s:.2f}s + warm {warm_s:.2f}s"
    aot = registry.get("serve_aot_cache_total")
    if aot is not None and aot.total():
        ri = aot.labelnames.index("result")
        tally = {k: 0 for k in ("hit", "miss", "stale")}
        for key, v in aot.series().items():
            tally[key[ri]] = tally.get(key[ri], 0) + int(v)
        line += (f"; aot hit {tally['hit']} miss {tally['miss']} "
                 f"stale {tally['stale']}")
    return line
