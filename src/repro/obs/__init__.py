"""Serving telemetry: clock-driven tracing, a closed metrics catalog, and
Prometheus / JSON / Perfetto exporters.

The subsystem is dark by default — ``NULL_TRACER`` and ``metrics=None``
are the defaults everywhere, provably free (no compile keys, no clock
reads, identical flush logs; ``tests/test_obs.py``).  Attach a
``Tracer`` (bound to the same injectable ``serve.clock.Clock`` the
scheduler runs on) and a ``MetricsRegistry`` to light it up; see
docs/OBSERVABILITY.md for the span taxonomy and metric catalog.
"""
from repro.obs.metrics import (
    CATALOG,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    ServingInstruments,
    default_registry,
)
from repro.obs.trace import NULL_TRACER, NullTracer, Span, Tracer
from repro.obs import export

__all__ = [
    "CATALOG",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ServingInstruments",
    "default_registry",
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "export",
]
