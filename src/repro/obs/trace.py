"""Span tracer for the serving stack — time only through an injected Clock.

Telemetry in a scheduler whose correctness is *defined* by deterministic
timestamps must not introduce a second time source: a ``time.time()``
inside a span would make two runs of the same scripted trace differ, and
``tools/check_engine_singlepath.py`` would rightly fail the build.  So a
:class:`Tracer` is constructed around the same injectable
``serve.clock.Clock`` the scheduler runs on, and every implicit
timestamp (``span`` enter/exit, ``event`` with no explicit instant) is a
``clock.now()`` read.  Under a ``VirtualClock`` simulation the emitted
spans are therefore a bitwise-deterministic function of the input trace
— ``tests/test_obs.py`` asserts two invocations of the same scripted
stream serialize to *identical* Chrome trace-event JSON.

Two recording styles, matching the two kinds of serving time:

* **Host stages** (pack, unpack, calibration) happen *now*: wrap them in
  ``with tracer.span("pack", tenant=..., graphs=...)``.  On a live
  ``RealClock`` the span measures real host time; on a ``VirtualClock``
  time does not move during host work, so the span is an exact
  zero-duration marker at the virtual instant — still deterministic.
* **Timeline stages** (queue wait, device occupancy) are *computed* by
  the event loop (``start_s = max(at_s, device_free)``), possibly in the
  future relative to ``clock.now()``: record them with explicit
  boundaries via :meth:`Tracer.record`.

The default sink everywhere is :data:`NULL_TRACER`, a shared no-op whose
every method is a constant-return stub — no list append, no clock read,
no attribute dict built (call sites guard attr construction on
``tracer.enabled``).  Telemetry disabled is provably free: the scheduler
emits the identical flush log and the executor builds the identical
compile-key set with and without a live tracer attached
(``tests/test_obs.py`` pins both).

Spans carry a ``track`` (one Perfetto thread row per track:
``scheduler`` / ``device`` / ``host`` / ``executor``) and sorted
``attrs`` tuples so serialization order never depends on dict insertion
order.  Export lives in ``obs/export.py``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


def _freeze_attrs(attrs: dict) -> Tuple[tuple, ...]:
    """Attrs as a sorted, hashable tuple — deterministic serialization
    order regardless of keyword order at the call site."""
    return tuple(sorted(attrs.items()))


@dataclasses.dataclass(frozen=True)
class Span:
    """One closed span: ``[t0_s, t1_s]`` on the tracer's clock timeline.

    Instant events are spans with ``t1_s is None`` (Perfetto ``ph: "i"``);
    closed spans export as complete events (``ph: "X"``)."""

    name: str
    t0_s: float
    t1_s: Optional[float]
    track: str = "scheduler"
    attrs: Tuple[tuple, ...] = ()

    @property
    def dur_s(self) -> float:
        return 0.0 if self.t1_s is None else self.t1_s - self.t0_s


class _LiveSpan:
    """Context manager recording one span on exit (exceptions included —
    a failed stage still shows up in the trace, with its real duration)."""

    __slots__ = ("_tracer", "_name", "_track", "_attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, track: str, attrs: dict):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._attrs = attrs
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = self._tracer.clock.now()
        return self

    def __exit__(self, *exc):
        self._tracer.record(self._name, self._t0, self._tracer.clock.now(),
                            track=self._track, **self._attrs)
        return False


class Tracer:
    """Collects spans/events; all implicit time reads go through the one
    injected ``clock`` (``serve.clock.Clock`` protocol — only ``now()``
    is required)."""

    enabled = True

    def __init__(self, clock):
        self.clock = clock
        self.spans: List[Span] = []

    def span(self, name: str, track: str = "host", **attrs) -> _LiveSpan:
        """Measure a host stage happening *now*:
        ``with tracer.span("pack", tenant=..., bucket=...)``."""
        return _LiveSpan(self, name, track, attrs)

    def record(self, name: str, t0_s: float, t1_s: float,
               track: str = "scheduler", **attrs) -> None:
        """Record a closed span with explicit boundaries (the event loop's
        computed timeline stages: queue wait, device occupancy)."""
        self.spans.append(Span(name=name, t0_s=float(t0_s), t1_s=float(t1_s),
                               track=track, attrs=_freeze_attrs(attrs)))

    def event(self, name: str, t_s: Optional[float] = None,
              track: str = "scheduler", **attrs) -> None:
        """Record an instant event at ``t_s`` (default: the clock's now)."""
        at = self.clock.now() if t_s is None else float(t_s)
        self.spans.append(Span(name=name, t0_s=at, t1_s=None, track=track,
                               attrs=_freeze_attrs(attrs)))

    def clear(self) -> None:
        self.spans.clear()


class _NullSpan:
    """The shared no-op context manager ``NullTracer.span`` returns."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The default sink: every method is a no-op, ``span`` hands back one
    shared context manager, and nothing ever reads a clock.  Call sites
    gate any attr-building work on ``tracer.enabled`` so the disabled
    path allocates nothing."""

    enabled = False
    spans: Tuple[()] = ()

    def span(self, name: str, track: str = "host", **attrs) -> _NullSpan:
        return _NULL_SPAN

    def record(self, name: str, t0_s: float, t1_s: float,
               track: str = "scheduler", **attrs) -> None:
        pass

    def event(self, name: str, t_s: Optional[float] = None,
              track: str = "scheduler", **attrs) -> None:
        pass

    def clear(self) -> None:
        pass


NULL_TRACER = NullTracer()
