"""Logical-axis -> mesh-axis resolution, sharding rules, and the sharded
message-passing collectives (absorbs the old ``repro.sharding`` and the
collective helpers of ``repro.core.distributed``).

Model code annotates every parameter/cache dimension with a *logical* axis
name (params.Param).  This module turns those names into physical
PartitionSpecs for a given mesh via a rules table, enforcing:

  * a mesh axis is used at most once per tensor,
  * a dim is only sharded if its size divides evenly,
  * multi-axis rules (("pod","data") for batch) use the largest prefix
    that divides.

This is how e.g. Mixtral's 8 experts on a 16-way model axis fall back
gracefully: "experts" fails the divisibility check, and the d_ff dim picks
up the model axis instead (classic TP-within-expert) with no per-model
special cases.  The same machinery shards the GNN serving path: padded
node/edge rows carry the logical axes "nodes"/"edges" and resolve onto the
data axis of whatever mesh the engine runs under.
"""
from __future__ import annotations

import contextlib
import contextvars
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro import params as P
from repro.runtime import compat

# Candidate mesh axes per logical axis, in priority order.  A tuple value
# means "use jointly" (e.g. batch over pod x data); a list means
# "try alternatives in order".
DEFAULT_RULES: Dict[Optional[str], tuple] = {
    "batch": ("pod", "data"),
    "seq": (),
    "kv_seq": (),  # overridden to ("data",) for seq-sharded long decode
    "vocab": ("model",),
    "embed": (),
    "embed_out": (),
    "heads": ("model",),
    "heads_flat": ("model",),
    "kv_heads": ("model",),
    # head_dim stays unsharded: when kv_heads < TP width the KV projection
    # is REPLICATED (Megatron convention).  Sharding head_dim instead
    # measurably triggers involuntary GSPMD rematerialization at the
    # repeat_kv boundary (full replication + 650 GB/dev temps).
    "head_dim": (),
    "mlp": ("model",),
    "experts": ("model",),
    # MoE slot tensors: batch-rows axis used by the expert-GEMM constraint;
    # defaults to the batch mapping, overridden by hybrid FSDP+EP rules
    "moe_batch": ("pod", "data"),
    "inner": ("model",),  # mamba d_inner
    "state": (),
    "q_lora": (),
    "kv_lora": (),
    "layers": (),
    # GNN serving: padded node/edge/graph rows (see gnn_rules)
    "nodes": (),
    "edges": (),
    "graphs": (),
    None: (),
}


def resolve_spec(
    axes: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    mesh: Mesh,
    rules: Dict[Optional[str], tuple] | None = None,
) -> PartitionSpec:
    """Map one tensor's logical axes to a PartitionSpec under ``mesh``."""
    rules = rules or DEFAULT_RULES
    used: set = set()
    spec = []
    for dim, name in zip(shape, axes):
        cands = rules.get(name, ())
        chosen: list = []
        prod = 1
        for ax in cands:
            if ax not in mesh.shape or ax in used:
                continue
            nx = mesh.shape[ax]
            if dim % (prod * nx) == 0:
                chosen.append(ax)
                prod *= nx
        if chosen:
            used.update(chosen)
            spec.append(tuple(chosen) if len(chosen) > 1 else chosen[0])
        else:
            spec.append(None)
    return PartitionSpec(*spec)


def tree_shardings(param_tree, mesh: Mesh, rules=None):
    """Param tree -> matching tree of NamedShardings."""

    def f(p: P.Param):
        shape = p.value.shape
        return NamedSharding(mesh, resolve_spec(p.axes, shape, mesh, rules))

    return jax.tree.map(f, param_tree, is_leaf=P.is_param)


def tree_specs(param_tree, mesh: Mesh, rules=None):
    def f(p: P.Param):
        return resolve_spec(p.axes, p.value.shape, mesh, rules)

    return jax.tree.map(f, param_tree, is_leaf=P.is_param)


def batch_rules(mesh: Mesh, batch: int, seq_shard: bool = False) -> dict:
    """Shape-aware rules for activations/caches.

    When the global batch cannot cover the data axis (long-context decode,
    batch=1), shard the KV-cache *sequence* dimension over data instead —
    sequence parallelism for the cache (DESIGN.md §8).
    """
    rules = dict(DEFAULT_RULES)
    dp = int(np.prod([mesh.shape.get(a, 1) for a in ("pod", "data")]))
    if batch % dp != 0 or seq_shard:
        rules["batch"] = ()
        rules["kv_seq"] = ("data",)
    return rules


def fsdp_rules(mesh: Mesh, batch: int) -> dict:
    """FSDP-style preset: data parallelism over BOTH mesh axes, parameters
    sharded over the model axis (GSPMD all-gathers each layer's weights at
    use — ZeRO-3 semantics).

    Napkin math vs Megatron-TP at global batch 256 on 16x16 (per device):
      TP:   ~6 activation all-reduces/layer x (B/dp x S x D) — O(10 s)
      FSDP: param all-gather 3x params_bytes/model_axis + grad
            reduce-scatter — O(1-4 s) for 4-30B dense models
    and the replicated-attention memory problem (MLA, 40 heads) vanishes
    because attention is sequence-local at batch-per-device <= 1.
    """
    rules = dict(DEFAULT_RULES)
    rules["batch"] = ("pod", "data", "model")
    rules["moe_batch"] = ("pod", "data", "model")  # pure FSDP: forcing EP
    # inside this layout was measured at 469 s of resharding (H2, refuted)
    rules["embed"] = ("model",)  # weight matrices: shard the embed dim
    rules["kv_seq"] = ()
    return rules


def gnn_rules(mesh: Mesh | None = None, axis: str = "data") -> dict:
    """GNN serving preset: padded node/edge rows (and the per-graph pool
    axis) shard over ``axis``.  Divisibility-aware resolution means buckets
    whose padded sizes don't divide the axis simply stay replicated.
    ``mesh`` (optional) validates that ``axis`` actually exists on it."""
    if mesh is not None and axis not in mesh.shape:
        raise ValueError(
            f"axis {axis!r} not on mesh (axes: {tuple(mesh.shape)})"
        )
    rules = dict(DEFAULT_RULES)
    rules["nodes"] = (axis,)
    rules["edges"] = (axis,)
    rules["graphs"] = (axis,)
    return rules


def zero1_spec(spec: PartitionSpec, shape, mesh: Mesh, axis: str = "data") -> PartitionSpec:
    """ZeRO-1: shard an optimizer-moment tensor over ``axis`` on its first
    dim that is unsharded and divisible — on top of whatever sharding the
    parameter already has.  Moments are only touched by the (local)
    optimizer update, so this costs one reduce-scatter/all-gather pair of
    the *gradients*, which GSPMD inserts at the update boundary."""
    if axis not in mesh.shape:
        return spec
    used = set()
    for s in spec:
        if s is None:
            continue
        used.update(s if isinstance(s, tuple) else (s,))
    if axis in used:
        return spec
    n = mesh.shape[axis]
    out = list(spec)
    for i, (dim, s) in enumerate(zip(shape, spec)):
        if s is None and dim % n == 0:
            out[i] = axis
            return PartitionSpec(*out)
    return spec


def zero1_rules(base_rules: dict) -> dict:
    """ZeRO-1-style optimizer-state sharding: moments additionally shard
    their first unsharded dim over the data axis (applied to m/v only)."""
    rules = dict(base_rules)
    for name in ("embed", "layers"):
        if not rules.get(name):
            rules[name] = ("data",)
    return rules


_ACTIVE_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_rules", default=None
)


@contextlib.contextmanager
def active_rules(rules: dict):
    """Install shape-aware rules for logical_constraint (set by launchers
    together with ``compat.use_mesh``)."""
    token = _ACTIVE_RULES.set(rules)
    try:
        yield
    finally:
        _ACTIVE_RULES.reset(token)


def logical_constraint(x, axes: Tuple[Optional[str], ...]):
    """with_sharding_constraint via logical axes.

    No-op unless a mesh is installed with ``compat.use_mesh`` (so CPU tests
    and single-device runs are untouched).  Used at activation boundaries
    where GSPMD's propagation otherwise *replicates compute* instead of
    inserting a collective — measured 8-16x per-device FLOPs inflation on
    the MoE expert GEMM (EXPERIMENTS.md §Perf).
    """
    mesh = compat.get_active_mesh()
    if mesh is None or mesh.empty or mesh.size == 1:
        return x
    rules = _ACTIVE_RULES.get() or DEFAULT_RULES
    spec = resolve_spec(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Multi-chip sharded message passing — the large-graph extension (§4.6) at
# scale.  The paper stores node/message buffers in DRAM and hides latency
# with a prefetcher when a graph exceeds on-chip memory; at pod scale the
# analogous limit is a graph exceeding one chip's HBM, and the answer is
# *node sharding* over a mesh axis with collective message exchange.
#
# Two exchange strategies (both built on core.scatter_gather):
#   * allgather_mp — all-gather node embeddings, compute local edges'
#     messages locally, reduce into local destinations.  Comm = O(N*F) per
#     layer; simple and bandwidth-optimal for dense-ish graphs.
#   * alltoall_mp — GenGNN's merged scatter-gather lifted to chip level:
#     each shard packs messages into per-destination capacity slots,
#     exchanges with a single all-to-all, and folds received messages into
#     its local O(N/P) aggregate buffer.  Comm = O(E/P * F).
# ---------------------------------------------------------------------------


def _resolve_num_shards(num_shards: int | None, axis_name: str) -> int:
    """Static shard count for a mapped axis.  ``jax.lax.axis_size`` only
    exists on newer JAX, so callers on 0.4.x must pass num_shards (which
    make_sharded_mp always does, from the mesh)."""
    if num_shards is not None:
        return int(num_shards)
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis_name))
    raise TypeError(
        "num_shards is required on JAX versions without jax.lax.axis_size; "
        "pass it explicitly or build via make_sharded_mp"
    )


def allgather_mp_local(
    x_local: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    edge_mask: jax.Array,
    phi: Callable[[jax.Array], jax.Array],
    axis_name: str,
    num_shards: int | None = None,
) -> jax.Array:
    """Per-shard body: all-gather x, aggregate messages for local dst rows.

    x_local: (N/P, F). src/dst: (E/P,) *global* node ids of local edges.
    num_shards is threaded statically from the mesh by make_sharded_mp;
    direct callers on new JAX may omit it (``jax.lax.axis_size``).
    Returns (N/P, F') aggregated messages for this shard's nodes.
    """
    from repro.core import scatter_gather as sg

    num_shards = _resolve_num_shards(num_shards, axis_name)
    n_local = x_local.shape[0]
    x_global = jax.lax.all_gather(x_local, axis_name, axis=0, tiled=True)
    msgs = phi(jnp.take(x_global, src, axis=0))
    msgs = jnp.where(edge_mask[:, None], msgs, 0.0)
    # Each edge is owned by exactly one shard, but its destination may be
    # remote: segment-reduce into the *global* frame and reduce-scatter rows
    # back to their owners.
    agg_global = sg.segment_reduce(msgs, dst, n_local * num_shards, "sum")
    return jax.lax.psum_scatter(agg_global, axis_name, scatter_dimension=0, tiled=True)


def alltoall_mp_local(
    x_local: jax.Array,
    src_local: jax.Array,
    dst: jax.Array,
    edge_mask: jax.Array,
    phi: Callable[[jax.Array], jax.Array],
    axis_name: str,
    capacity: int,
    num_shards: int | None = None,
) -> jax.Array:
    """Per-shard body for the all-to-all exchange.

    Assumes edges live on the shard that owns their *source* (CSR ownership,
    which is free: the producer of a message owns it — exactly the paper's
    scatter side).  src_local: (E/P,) local row ids; dst: (E/P,) global ids.

    capacity: max messages any (src-shard -> dst-shard) pair may carry per
    layer; overflow drops (GShard semantics) — sized by the caller from the
    degree distribution, and asserted in tests.
    """
    from repro.core import scatter_gather as sg

    p = _resolve_num_shards(num_shards, axis_name)
    n_local = x_local.shape[0]
    msgs = phi(jnp.take(x_local, src_local, axis=0))
    msgs = jnp.where(edge_mask[:, None], msgs, 0.0)
    dst_shard = dst // n_local
    # carry destination-local row id alongside the payload so the receiver
    # can fold messages into its O(N/P) buffer (merged scatter-gather).
    payload = jnp.concatenate(
        [msgs, (dst % n_local).astype(msgs.dtype)[:, None]], axis=-1
    )
    slots, _, _ = sg.dispatch_to_slots(
        payload, dst_shard, p, capacity, valid=edge_mask
    )  # (P, capacity, F+1)
    received = jax.lax.all_to_all(
        slots, axis_name, split_axis=0, concat_axis=0, tiled=True
    )
    rmsg = received[..., :-1].reshape(p * capacity, -1)
    rdst = received[..., -1].reshape(p * capacity).astype(jnp.int32)
    # zero-payload slots reduce harmlessly into row 0
    return sg.segment_reduce(rmsg, rdst, n_local, "sum")


def make_sharded_mp(
    mesh, axis: str, phi: Callable, strategy: str = "allgather", capacity: int = 0
):
    """Build a shard_map-wrapped message-passing aggregate step.

    Returns fn(x, src, dst, edge_mask) -> (N, F') with x sharded on axis 0
    and edges sharded on axis 0 (ownership: 'allgather' -> any shard,
    'alltoall' -> source shard, src given shard-locally).
    """
    num_shards = int(mesh.shape[axis])
    if strategy == "allgather":
        body = partial(
            allgather_mp_local, phi=phi, axis_name=axis, num_shards=num_shards
        )
    elif strategy == "alltoall":
        if capacity <= 0:
            raise ValueError("alltoall strategy requires capacity > 0")
        body = partial(
            alltoall_mp_local, phi=phi, axis_name=axis, capacity=capacity,
            num_shards=num_shards,
        )
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    in_specs = (
        PartitionSpec(axis, None),
        PartitionSpec(axis),
        PartitionSpec(axis),
        PartitionSpec(axis),
    )
    return compat.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=PartitionSpec(axis, None)
    )
