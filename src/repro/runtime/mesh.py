"""Mesh construction (subsumes the old ``repro.launch.mesh``).

Functions, not module-level constants, so importing this module never
touches jax device state (device count locks on first jax init).

Single pod: 16x16 = 256 chips (data x model) — TPU v5e pod slice.
Multi-pod:  2x16x16 = 512 chips (pod x data x model); the ``pod`` axis
carries cross-pod data parallelism over DCI.
"""
from __future__ import annotations

import jax

from repro.runtime import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh for the 8-device distributed tests."""
    return compat.make_mesh((data, model), ("data", "model"))


def make_flat_mesh(n: int | None = None, axis: str = "data") -> jax.sharding.Mesh:
    """One-axis mesh over ``n`` devices (default: all) — the shape used by
    the sharded GNN serving/dry-run paths, where a single graph axis spans
    every chip."""
    devices = jax.devices()
    n = len(devices) if n is None else n
    return compat.make_mesh((n,), (axis,), devices=devices[:n])


def flatten_mesh(mesh: jax.sharding.Mesh, axis: str = "graph") -> jax.sharding.Mesh:
    """Collapse a multi-axis mesh into a single named axis over the same
    devices (e.g. production (data, model) -> one 'graph' axis)."""
    return compat.mesh_from_devices(mesh.devices.reshape(-1), (axis,))
