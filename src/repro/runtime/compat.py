"""Version-portable facade over JAX's moving mesh/sharding API surface.

The mesh API has churned across JAX releases:

  * ``jax.make_mesh`` grew an ``axis_types`` kwarg (with
    ``jax.sharding.AxisType``) after 0.4.x,
  * ``jax.shard_map`` moved out of ``jax.experimental.shard_map`` and
    renamed ``check_rep`` to ``check_vma``,
  * the "current mesh" moved from the thread-local ``with mesh:`` resource
    env to ``jax.set_mesh`` / ``jax.sharding.get_abstract_mesh()``.

Everything in ``repro`` that needs a mesh goes through this module, so the
same code runs on JAX 0.4.x and newer.  Feature flags are module-level so
tests can monkeypatch each detection path.

Beyond the mesh surface, this module also probes the **executable
serialization** API that the persistent AOT compile cache
(``serve/aot.py``) builds on: ``jax.experimental.serialize_executable``
round-trips a ``Lowered(...).compile()`` product to bytes and back
without retracing or recompiling.  Where that API is absent on the
pinned JAX, :func:`enable_compilation_cache` is the feature-detected
fallback — it turns on JAX's own on-disk compilation cache, which still
kills the *compile* half of a restart's warm-up (the trace half stays).
"""
from __future__ import annotations

import contextlib
import contextvars
import inspect
from typing import Callable, Optional

import jax

# --------------------------------------------------------------- detection

HAS_GET_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")
HAS_SET_MESH = hasattr(jax, "set_mesh")
HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")
HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")

try:  # executable (AOT) serialization — the serve/aot.py fast path
    from jax.experimental import serialize_executable as _sx

    HAS_SERIALIZE_EXECUTABLE = (
        hasattr(_sx, "serialize") and hasattr(_sx, "deserialize_and_load")
    )
except ImportError:  # pragma: no cover - depends on pinned jax
    _sx = None
    HAS_SERIALIZE_EXECUTABLE = False


# ------------------------------------------------- executable serialization


def serialize_compiled(compiled) -> tuple:
    """Serialize one ``jax.stages.Compiled`` to ``(payload_bytes,
    in_tree, out_tree)`` — everything :func:`deserialize_compiled` needs
    to rebuild a callable executable in another process.  Raises
    ``RuntimeError`` when the pinned JAX has no serialization API
    (callers feature-gate on ``HAS_SERIALIZE_EXECUTABLE``)."""
    if not HAS_SERIALIZE_EXECUTABLE:
        raise RuntimeError(
            "jax.experimental.serialize_executable is unavailable on this "
            "JAX version; gate on runtime.compat.HAS_SERIALIZE_EXECUTABLE"
        )
    return _sx.serialize(compiled)


def deserialize_compiled(payload: bytes, in_tree, out_tree):
    """Rebuild a callable ``Compiled`` from :func:`serialize_compiled`'s
    triple.  The executable binds to this process's backend: the caller
    (``serve/aot.py``) is responsible for fingerprinting the environment
    so a payload is never loaded onto a different jax/jaxlib/backend/
    topology than it was compiled for."""
    if not HAS_SERIALIZE_EXECUTABLE:
        raise RuntimeError(
            "jax.experimental.serialize_executable is unavailable on this "
            "JAX version; gate on runtime.compat.HAS_SERIALIZE_EXECUTABLE"
        )
    return _sx.deserialize_and_load(payload, in_tree, out_tree)


def enable_compilation_cache(path: str) -> bool:
    """Fallback persistence when executable serialization is absent:
    point JAX's own on-disk compilation cache at ``path`` (with the
    min-compile-time/min-entry-size knobs opened so every serving
    program qualifies).  Returns True when the cache engaged, False when
    this JAX has no usable compilation-cache config (the caller then
    runs uncached, exactly as before)."""
    try:
        jax.config.update("jax_compilation_cache_dir", path)
    except Exception:  # noqa: BLE001 - option absent on this version
        return False
    for knob, value in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                        ("jax_persistent_cache_min_entry_size_bytes", 0)):
        try:
            jax.config.update(knob, value)
        except Exception:  # noqa: BLE001 - knob absent; cache still works
            pass
    return True


# ------------------------------------------------------- mesh construction


def make_mesh(axis_shapes, axis_names, *, devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the concept exists."""
    kw = {"devices": devices} if devices is not None else {}
    if HAS_AXIS_TYPES:
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def mesh_from_devices(devices, axis_names) -> jax.sharding.Mesh:
    """Build a Mesh from an explicit device array (e.g. a flattened view of
    another mesh's devices)."""
    kw = {}
    if HAS_AXIS_TYPES:
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.sharding.Mesh(devices, tuple(axis_names), **kw)


# ------------------------------------------------------------- shard_map


def shard_map(
    f: Callable, mesh, in_specs, out_specs, check_replication: bool = False
):
    """Portable ``shard_map``: resolves the public-vs-experimental location
    and the ``check_vma``/``check_rep`` kwarg rename."""
    if HAS_JAX_SHARD_MAP:
        sm = jax.shard_map
        params = inspect.signature(sm).parameters
        kw = "check_vma" if "check_vma" in params else "check_rep"
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **{kw: check_replication})
    from jax.experimental.shard_map import shard_map as sm

    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=check_replication)


# ------------------------------------------------------------ active mesh

# Our own fallback context: always maintained by use_mesh() so that
# get_active_mesh() works even where JAX has no queryable mesh state.
_ACTIVE_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_runtime_mesh", default=None
)


@contextlib.contextmanager
def use_mesh(mesh):
    """Install ``mesh`` as the active mesh for the dynamic extent.

    On new JAX this is ``jax.set_mesh``; on 0.4.x it is the thread-local
    ``with mesh:`` resource env.  Either way our contextvar mirrors it so
    ``get_active_mesh()`` has a uniform answer.
    """
    token = _ACTIVE_MESH.set(mesh)
    try:
        if HAS_SET_MESH:
            with jax.set_mesh(mesh):
                yield mesh
        else:
            with mesh:
                yield mesh
    finally:
        _ACTIVE_MESH.reset(token)


def _native_abstract_mesh():
    """The new-API answer, or None where absent/empty (split out so tests
    can exercise both detection branches)."""
    if not HAS_GET_ABSTRACT_MESH:
        return None
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return None
    return mesh


def _thread_resources_mesh():
    """The 0.4.x answer: the ``with mesh:`` thread-local physical mesh."""
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        return None if mesh.empty else mesh
    except Exception:  # noqa: BLE001 — internal layout changed; fall through
        return None


def get_active_mesh() -> Optional[object]:
    """Return the active (abstract or physical) mesh, or None.

    Resolution order: native get_abstract_mesh -> our use_mesh contextvar
    -> the 0.4.x thread-resources env.  Never raises on any JAX version.
    """
    mesh = _native_abstract_mesh()
    if mesh is not None:
        return mesh
    mesh = _ACTIVE_MESH.get()
    if mesh is not None:
        return mesh
    return _thread_resources_mesh()
