"""repro.runtime — the version-portable execution substrate.

One import surface for everything mesh/sharding related:

  * ``compat``       — feature-detected JAX mesh API (make_mesh, shard_map,
                       use_mesh, get_active_mesh)
  * ``mesh``         — production / debug / flat mesh builders
  * ``partitioning`` — logical-axis rules, PartitionSpec resolution,
                       logical_constraint, sharded message passing

This package is the sole home of mesh/sharding logic; the pre-runtime
import paths (``repro.sharding``, ``repro.launch.mesh``,
``repro.core.distributed``) are gone.
"""
from repro.runtime import compat, mesh, partitioning
from repro.runtime.compat import (
    HAS_SERIALIZE_EXECUTABLE,
    deserialize_compiled,
    enable_compilation_cache,
    get_active_mesh,
    make_mesh,
    serialize_compiled,
    shard_map,
    use_mesh,
)
from repro.runtime.mesh import (
    flatten_mesh,
    make_debug_mesh,
    make_flat_mesh,
    make_production_mesh,
)
from repro.runtime.partitioning import (
    DEFAULT_RULES,
    active_rules,
    allgather_mp_local,
    alltoall_mp_local,
    batch_rules,
    fsdp_rules,
    gnn_rules,
    logical_constraint,
    make_sharded_mp,
    resolve_spec,
    tree_shardings,
    tree_specs,
    zero1_rules,
    zero1_spec,
)

__all__ = [
    "compat",
    "mesh",
    "partitioning",
    "HAS_SERIALIZE_EXECUTABLE",
    "deserialize_compiled",
    "enable_compilation_cache",
    "get_active_mesh",
    "make_mesh",
    "serialize_compiled",
    "shard_map",
    "use_mesh",
    "flatten_mesh",
    "make_debug_mesh",
    "make_flat_mesh",
    "make_production_mesh",
    "DEFAULT_RULES",
    "active_rules",
    "allgather_mp_local",
    "alltoall_mp_local",
    "batch_rules",
    "fsdp_rules",
    "gnn_rules",
    "logical_constraint",
    "make_sharded_mp",
    "resolve_spec",
    "tree_shardings",
    "tree_specs",
    "zero1_rules",
    "zero1_spec",
]
