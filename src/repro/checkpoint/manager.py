"""Fault-tolerant checkpointing: atomic, asynchronous, elastic.

Requirements at 1000-node scale (DESIGN.md §8):

  * **atomic** — a checkpoint is either fully present or absent: writes go
    to ``<dir>/tmp.step_N`` and are ``os.rename``d to ``step_N`` only
    after an fsync'd manifest lands (rename is atomic on POSIX).
  * **async** — serialization happens on a background thread off the
    training loop; ``wait()`` joins before the next save or at exit.
  * **keep-N** — bounded disk usage with retention of every k-th step.
  * **elastic restore** — arrays are saved with their *logical axes*; on
    restore they are re-laid-out for whatever mesh the job restarts with
    (different data-axis size after excluding failed hosts), via
    ``runtime.partitioning.tree_shardings`` + ``jax.device_put``.

Format: one ``.npy`` per leaf (portable, partial-read friendly) plus a
json manifest holding the tree structure, dtypes, logical axes and step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro import params as P

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, axes_tree: Any = None, blocking: bool = False):
        """Save a pytree of arrays.  ``axes_tree`` (same structure, leaves =
        logical-axes tuples) enables elastic restore."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            tmp = os.path.join(self.dir, f"tmp.step_{step:08d}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            leaves = _flatten_with_paths(host_tree)
            dtypes = {}
            for key, leaf in leaves.items():
                fn = os.path.join(tmp, key.replace("/", "__") + ".npy")
                arr = np.asarray(leaf)
                dtypes[key] = str(arr.dtype)
                if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
                    arr = arr.view(np.uint16)  # bf16: store bit pattern
                    dtypes[key] = "bfloat16"
                np.save(fn, arr)
            manifest = {
                "step": step,
                "keys": list(leaves.keys()),
                "dtypes": dtypes,
                "treedef": jax.tree_util.tree_structure(host_tree).serialize_using_proto().hex(),
                "axes": _axes_manifest(axes_tree) if axes_tree is not None else None,
            }
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._retain()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, _MANIFEST)
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, template: Any = None,
                mesh=None, rules=None) -> tuple:
        """Returns (step, tree).  With ``template`` (a pytree of like-typed
        leaves) the result matches its structure; with ``mesh`` + logical
        axes in the manifest the arrays are placed with resharding (elastic
        restart on a different mesh)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)
        arrays = {}
        dtypes = manifest.get("dtypes", {})
        for key in manifest["keys"]:
            arr = np.load(os.path.join(d, key.replace("/", "__") + ".npy"))
            if dtypes.get(key) == "bfloat16":
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            arrays[key] = arr
        if template is None:
            raise ValueError("restore requires a template tree")
        flat_template = _flatten_with_paths(template)
        assert set(flat_template) == set(arrays), (
            sorted(set(flat_template) ^ set(arrays))[:5]
        )
        leaves = [arrays[k] for k in flat_template]
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves
        )
        if mesh is not None and manifest.get("axes"):
            from repro.runtime import partitioning as SH

            axes = manifest["axes"]
            flat_axes = {k: tuple(v) if v is not None else None for k, v in axes.items()}

            def place(path_key, arr):
                ax = flat_axes.get(path_key)
                if ax is None:
                    return jax.device_put(arr)
                spec = SH.resolve_spec(ax, arr.shape, mesh, rules)
                return jax.device_put(arr, jax.sharding.NamedSharding(mesh, spec))

            flat = _flatten_with_paths(tree)
            placed = {k: place(k, v) for k, v in flat.items()}
            leaves = [placed[k] for k in flat]
            tree = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(template), leaves
            )
        return step, tree


def _axes_manifest(axes_tree):
    flat = jax.tree_util.tree_flatten_with_path(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = list(leaf) if leaf is not None else None
    return out
