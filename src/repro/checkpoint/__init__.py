"""Atomic async checkpointing with elastic (re-mesh) restore."""
from repro.checkpoint.manager import CheckpointManager
