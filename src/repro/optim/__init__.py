"""Optimizers + distributed-optimization tricks (AdamW, int8 grad compression)."""
from repro.optim import adamw, compression
