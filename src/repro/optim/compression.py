"""Gradient compression: int8 quantization with error feedback.

Large-scale data parallelism spends its collective budget on gradient
all-reduce.  This module provides:

  * ``quantize / dequantize`` — per-tensor symmetric int8 with a f32
    scale (4x byte reduction vs f32, 2x vs bf16);
  * ``ef_compress`` — error-feedback wrapper: the quantization residual is
    carried to the next step, which keeps SGD/Adam convergence (Karimireddy
    et al., 2019);
  * ``compressed_psum`` — a shard_map-compatible all-reduce that sums int8
    payloads in int32 and dequantizes once, for pure-DP meshes where the
    gradient exchange is explicit (train/loop.py wires it when mesh has
    only data axes).  Under GSPMD meshes the all-reduce is compiler-
    inserted, so compression there is future work (documented limitation).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (f32/bf16) -> (int8 payload, f32 scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(grads, error_buf):
    """Error-feedback int8 compression of a gradient pytree.

    Returns (compressed-then-decompressed grads, new error buffer).  The
    returned grads are what the *receiver* would see after the compressed
    exchange; error_buf carries the per-tensor residual.
    """

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize(corrected)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_buf)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in outs]), tdef.unflatten([o[1] for o in outs])


def init_error_buf(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed all-reduce inside shard_map: each shard quantizes,
    the sum runs in int32 (no overflow for <= 2^23 shards), and the max
    scale is shared so dequantization is consistent."""
    q, scale = quantize(x)
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the shared scale so the integer sum is coherent
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale_max), -127, 127
    ).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale_max
