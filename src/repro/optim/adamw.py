"""AdamW with global-norm clipping and warmup-cosine schedule.

Pure-pytree implementation (no optax dependency): state is {"m", "v",
"step"} with m/v mirroring the parameter tree, so parameter shardings
propagate to moments automatically (or via zero1 rules, see sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def update(cfg: AdamWConfig, grads, state: dict, params) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
