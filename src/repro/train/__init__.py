"""Fault-tolerant training loop."""
from repro.train.loop import train, make_train_step, LoopConfig
