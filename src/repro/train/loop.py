"""Fault-tolerant training loop.

Failure model at 1000-node scale: transient step failures (preemption,
flaky host, data corruption) and permanent node loss.  The loop provides:

  * restore-latest-and-retry on step exceptions (bounded retries),
  * async atomic checkpoints every ``ckpt_every`` steps,
  * a step-time watchdog that flags stragglers (> factor x running
    median); on real deployments the runner re-forms the mesh from the
    last checkpoint excluding the slow host — elastic restore onto a
    different mesh is exercised by tests/test_checkpoint.py,
  * optional int8+error-feedback gradient compression (pure-DP meshes).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Iterable, Optional

import jax
import numpy as np

from repro import params as P
from repro import runtime as RT
from repro.checkpoint.manager import CheckpointManager
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.optim import compression as comp


@dataclasses.dataclass(frozen=True)
class LoopConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_retries: int = 3
    straggler_factor: float = 3.0
    grad_compression: bool = False


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    grad_compression: bool = False) -> Callable:
    """Builds the jit-able train step: (params, opt_state, ef, batch) ->
    (params, opt_state, ef, metrics)."""

    def step(params, opt_state, ef, batch):
        (loss, aux), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
            params, batch, cfg
        )
        if grad_compression:
            grads, ef = comp.ef_compress(grads, ef)
        new_params, new_opt, om = adamw.update(opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, **aux, **om}
        return new_params, new_opt, ef, metrics

    return step


def train(
    cfg: ModelConfig,
    opt_cfg: adamw.AdamWConfig,
    loop_cfg: LoopConfig,
    data: Iterable[dict],
    rng: Optional[jax.Array] = None,
    params: Any = None,
    mesh: Any = None,
    rules: Optional[dict] = None,
    inject_failure_at: Optional[int] = None,  # test hook
) -> dict:
    """Single-host reference driver (the multi-pod path goes through
    launch/train.py which builds the mesh + shardings around the same step
    fn).  With ``mesh`` the loop runs under ``runtime.use_mesh`` +
    ``active_rules`` so logical_constraint() is live during tracing.
    Returns {"params", "opt_state", "history", "events"}."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    ptree = lm.init_params(rng, cfg) if params is None else params
    pvals = P.values(ptree)
    paxes = P.axes(ptree)
    if mesh is not None:
        pvals = jax.device_put(pvals, RT.tree_shardings(ptree, mesh, rules))
    opt_state = adamw.init(pvals)
    ef = comp.init_error_buf(pvals) if loop_cfg.grad_compression else None
    mgr = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep)
    step_fn = jax.jit(
        make_train_step(cfg, opt_cfg, loop_cfg.grad_compression),
        donate_argnums=(0, 1, 2),
    )

    start = 0
    if mgr.latest_step() is not None:
        start, state = mgr.restore(template={"params": pvals, "opt": opt_state})
        pvals, opt_state = state["params"], state["opt"]

    it = iter(data)
    mesh_ctx = contextlib.ExitStack()
    if mesh is not None:
        mesh_ctx.enter_context(RT.use_mesh(mesh))
        mesh_ctx.enter_context(
            RT.active_rules(rules if rules is not None else RT.DEFAULT_RULES)
        )
    with mesh_ctx:
        pvals, opt_state, ef, history, events = _run_loop(
            loop_cfg, step_fn, mgr, it, pvals, opt_state, ef,
            start, paxes, inject_failure_at,
        )
    mgr.wait()
    return {"params": pvals, "opt_state": opt_state, "history": history,
            "events": events, "axes": paxes}


def _run_loop(loop_cfg, step_fn, mgr, it, pvals, opt_state, ef, step,
              paxes, inject_failure_at):
    history, events = [], []
    durations: list = []
    retries = 0
    injected = False
    while step < loop_cfg.steps:
        batch = _device_batch(next(it))
        t0 = time.perf_counter()
        try:
            if inject_failure_at is not None and step == inject_failure_at and not injected:
                injected = True
                raise RuntimeError("injected node failure")
            pvals, opt_state, ef, metrics = step_fn(pvals, opt_state, ef, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
        except Exception as e:  # noqa: BLE001 — any step failure triggers recovery
            retries += 1
            events.append({"step": step, "event": "failure", "error": str(e)})
            if retries > loop_cfg.max_retries:
                raise
            if mgr.latest_step() is not None:
                step, state = mgr.restore(
                    template={"params": pvals, "opt": opt_state}
                )
                pvals, opt_state = state["params"], state["opt"]
            else:  # no checkpoint yet: re-init optimizer, keep params
                opt_state = adamw.init(pvals)
                step = 0
            ef = comp.init_error_buf(pvals) if loop_cfg.grad_compression else None
            continue
        dt = time.perf_counter() - t0
        durations.append(dt)
        med = float(np.median(durations[-20:]))
        if len(durations) > 5 and dt > loop_cfg.straggler_factor * med:
            events.append({"step": step, "event": "straggler", "dt": dt, "median": med})
        step += 1
        if step % loop_cfg.log_every == 0 or step == loop_cfg.steps:
            history.append({"step": step, **metrics, "dt": dt})
        if step % loop_cfg.ckpt_every == 0 or step == loop_cfg.steps:
            mgr.save(step, {"params": pvals, "opt": opt_state},
                     axes_tree={"params": paxes, "opt": None}, blocking=False)
    return pvals, opt_state, ef, history, events


def _device_batch(batch: dict) -> dict:
    import jax.numpy as jnp

    return {k: jnp.asarray(v) for k, v in batch.items()}


def _has_params(tree) -> bool:
    leaves = jax.tree.leaves(tree, is_leaf=P.is_param)
    return any(P.is_param(l) for l in leaves)
