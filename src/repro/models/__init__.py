"""LM substrate: configs, layers, MoE, SSM mixers, stack, entry points."""
from repro.models.config import ModelConfig, ShapeConfig, SHAPES
