"""Top-level language model: embeddings, stack, losses, step functions.

Families:
  dense/moe/hybrid/ssm — decoder-only LM over tokens.
  vlm   — decoder-only over [patch embeddings ; token embeddings]; the
          vision frontend is a STUB per the brief: ``input_specs`` provides
          precomputed ViT patch embeddings.
  audio — encoder-decoder (whisper): encoder over precomputed log-mel
          frame embeddings (conv frontend STUB), decoder with
          cross-attention.

The cross-entropy loss is *vocab- and sequence-chunked*: logits are
computed per sequence chunk under ``jax.checkpoint`` so the (B,S,V) tensor
is never materialized — required for vocab=262k at 32k context.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import params as P
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(rng, cfg: ModelConfig) -> dict:
    """Returns a Param tree (values + logical axes)."""
    k_emb, k_stack, k_head, k_enc = jax.random.split(rng, 4)
    dt = _dt(cfg)
    p: dict = {
        "embed": P.init_normal(k_emb, (cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
        "final_norm": L.rms_norm_init(cfg.d_model),
        "blocks": T.stack_init(k_stack, cfg, cross_attention=cfg.family == "audio"),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = P.init_normal(
            k_head, (cfg.d_model, cfg.vocab_size), ("embed", "vocab")
        )
    if cfg.family == "audio":
        enc_cfg = encoder_config(cfg)
        p["enc_blocks"] = T.stack_init(k_enc, enc_cfg)
        p["enc_norm"] = L.rms_norm_init(cfg.d_model)
        p["enc_pos"] = P.init_normal(
            k_enc, (cfg.encoder_seq, cfg.d_model), ("kv_seq", "embed"), scale=0.02
        )
    # cast matmul weights to model dtype (norms/scalars stay f32)
    def cast(pr: P.Param):
        v = pr.value
        if v.ndim >= 2:
            return P.Param(v.astype(dt), pr.axes)
        return pr

    return jax.tree.map(cast, p, is_leaf=P.is_param)


def encoder_config(cfg: ModelConfig) -> ModelConfig:
    """Whisper encoder: bidirectional dense attention, same width."""
    import dataclasses

    return dataclasses.replace(
        cfg,
        num_layers=cfg.encoder_layers,
        attn_every=0,
        num_experts=0,
        global_every=0,
        sliding_window=0,
        family="dense",
        causal=False,
        mlp_type="gelu",
    )


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(params, tokens, cfg: ModelConfig):
    e = jnp.take(params["embed"], tokens, axis=0)
    return (e * math.sqrt(cfg.d_model)).astype(_dt(cfg))


def _head_matrix(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def logits_fn(params, hidden, cfg: ModelConfig):
    return jnp.einsum("...d,dv->...v", hidden, _head_matrix(params, cfg))


# ---------------------------------------------------------------------------
# encoder (audio) — bidirectional over precomputed frame embeddings
# ---------------------------------------------------------------------------


def encode_audio(params, frames, cfg: ModelConfig):
    """frames: (B, encoder_seq, d_model) stub embeddings -> encoder output."""
    enc_cfg = encoder_config(cfg)
    x = frames.astype(_dt(cfg)) + params["enc_pos"][None].astype(_dt(cfg))
    # bidirectional: reuse the stack with causal disabled via full-window
    # attention; whisper is small (6L) so always unrolled.
    x, _, _ = T.stack_apply(params["enc_blocks"], x, enc_cfg, remat=False)
    return L.rms_norm(x, params["enc_norm"])


def cross_kv_all(params, enc_out, cfg: ModelConfig):
    """Precompute cross-attention K/V for every decoder block position."""
    out = []
    for pos in range(cfg.group_size):
        cross = params["blocks"][pos]["cross"]
        k = jnp.einsum("bsd,Ldhk->Lbshk", enc_out, cross["wk"])
        v = jnp.einsum("bsd,Ldhk->Lbshk", enc_out, cross["wv"])
        out.append((k, v))
    return out


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def forward_hidden(params, batch: dict, cfg: ModelConfig):
    """Training/prefill forward to final hidden states.

    batch: {"tokens": (B,S)} (+ "patches" (B,P,D) for vlm, "frames" for
    audio).  Returns (hidden (B,S,D), aux_loss).
    """
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    enc_kv = None
    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype)  # (B, P, D) stub
        x = jnp.concatenate([patches, x], axis=1)
    if cfg.family == "audio":
        enc_out = encode_audio(params, batch["frames"], cfg)
        enc_kv = cross_kv_all(params, enc_out, cfg)
    x, _, aux = T.stack_apply(params["blocks"], x, cfg, enc_kv=enc_kv)
    return L.rms_norm(x, params["final_norm"]), aux


def chunked_ce_loss(params, hidden, labels, weights, cfg: ModelConfig):
    """Mean CE over weighted positions; logits chunked over sequence and
    rematerialized in backward."""
    b, s, d = hidden.shape
    c = min(cfg.loss_chunk, s)
    n_chunks = math.ceil(s / c)
    head = _head_matrix(params, cfg)

    def chunk_loss(h, l, w):
        logits = jnp.einsum("bsd,dv->bsv", h, head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * w)

    total = jnp.zeros((), jnp.float32)
    for i in range(n_chunks):
        sl = slice(i * c, min((i + 1) * c, s))
        total = total + jax.checkpoint(chunk_loss)(
            hidden[:, sl], labels[:, sl], weights[:, sl]
        )
    return total / jnp.maximum(jnp.sum(weights), 1.0)


def loss_fn(params, batch: dict, cfg: ModelConfig):
    """batch: tokens (B,S) used as inputs; labels = tokens shifted left."""
    hidden, aux = forward_hidden(params, batch, cfg)
    tokens = batch["tokens"]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    weights = jnp.ones_like(labels, jnp.float32)
    weights = weights.at[:, -1].set(0.0)
    if cfg.family == "vlm":  # hidden includes patch positions: no LM loss there
        hidden = hidden[:, cfg.num_patches :]
    loss = chunked_ce_loss(params, hidden, labels, weights, cfg)
    return loss + cfg.router_aux_coef * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> list:
    return T.stack_cache_init(cfg, batch, seq, _dt(cfg))


_SEQ_CACHE_KEYS = ("k", "v", "ckv", "krope")  # entries indexed by position


def prefill(params, batch: dict, cfg: ModelConfig, cache_len: int):
    """Run the prompt through the stack, building the decode cache.

    The stack runs in ``mode="prefill"``: attention layers return their
    full-sequence K/V (captured during the same forward pass, no
    recomputation) and SSM/RWKV layers return their final recurrent state.
    Sequence-indexed entries are written into a zero cache of length
    ``cache_len``; states are carried as-is.

    Returns (cache, last_logits, t0).
    """
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = embed_tokens(params, tokens, cfg)
    enc_kv = None
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    if cfg.family == "audio":
        enc_out = encode_audio(params, batch["frames"], cfg)
        enc_kv = cross_kv_all(params, enc_out, cfg)
    s = x.shape[1]

    x, captured, _ = T.stack_apply(
        params["blocks"], x, cfg, mode="prefill", enc_kv=enc_kv
    )
    hidden = L.rms_norm(x, params["final_norm"])
    last_logits = logits_fn(params, hidden[:, -1:], cfg)[:, 0]

    cache = P.values(init_cache(cfg, b, cache_len))
    for pos in range(cfg.group_size):
        for key, val in captured[pos].items():
            if key in _SEQ_CACHE_KEYS:  # (G,B,S,...) -> cache[:, :, :S]
                cache[pos][key] = jax.lax.dynamic_update_slice(
                    cache[pos][key],
                    val.astype(cache[pos][key].dtype),
                    (0,) * cache[pos][key].ndim,
                )
            else:
                cache[pos][key] = val.astype(cache[pos][key].dtype)
    return cache, last_logits, jnp.asarray(s, jnp.int32)


def decode_step(params, cache: list, tokens: jax.Array, t: jax.Array, cfg: ModelConfig):
    """One token step.  tokens: (B, 1) int32; t: () int32 position.
    Cross-attention K/V (audio) live in the cache, filled at prefill.

    Returns (logits (B, V), new_cache).
    """
    x = embed_tokens(params, tokens, cfg)
    x, new_cache, _ = T.stack_apply(
        params["blocks"], x, cfg, mode="decode", cache=cache, t=t
    )
    hidden = L.rms_norm(x, params["final_norm"])
    logits = logits_fn(params, hidden[:, 0], cfg)
    return logits, new_cache
