"""State-space mixers: Mamba (Jamba's 7/8 layers) and RWKV-6 "Finch".

Hardware note (DESIGN.md §2): the recurrences are *chain-graph* message
passing — no irregularity for GenGNN's scatter-gather to exploit — so they
use chunked scans instead of the GNN engine.  The elementwise recurrence
is <1% of layer FLOPs (projections dominate); the chunk loop is a
``lax.scan`` whose body HLO is counted once by cost_analysis, and
roofline.py applies the exact analytic trip-count correction (recorded as
``scan_flops_note``).

Mamba: selective SSM with diagonal A; intra-chunk ``associative_scan``
(log-depth, numerically safe), inter-chunk state carried by ``lax.scan``.
RWKV-6: data-dependent-decay linear attention; per-head (hd x hd) wkv
state updated per token inside a time scan.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro import params as P
from repro.models.config import ModelConfig
from repro.runtime import logical_constraint as _lc

# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------


def mamba_init(rng, cfg: ModelConfig) -> dict:
    d, di, ds, dc = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    dtr = max(d // 16, 1)
    ks = jax.random.split(rng, 6)
    a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": P.init_normal(ks[0], (d, 2, di), ("embed", None, "inner")),
        "conv_w": P.init_normal(ks[1], (dc, di), (None, "inner"), scale=0.5),
        "conv_b": P.init_zeros((di,), ("inner",)),
        "x_proj": P.init_normal(ks[2], (di, dtr + 2 * ds), ("inner", None)),
        "dt_proj": P.init_normal(ks[3], (dtr, di), (None, "inner")),
        "dt_bias": P.init_zeros((di,), ("inner",)),
        "a_log": P.Param(jnp.log(a), ("inner", "state")),
        "d_skip": P.init_ones((di,), ("inner",)),
        "out_proj": P.init_normal(ks[4], (di, d), ("inner", "embed")),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv.  x: (B,S,di); w: (dc,di).  state: (B,dc-1,di)
    carries the last dc-1 inputs for decode; returns (y, new_state)."""
    dc = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : dc - 1])
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+dc-1, di)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(dc)) + b
    new_state = xp[:, -(dc - 1) :]
    return y, new_state


def _ssm_params(p, xi, cfg: ModelConfig):
    """xi: (B,S,di) -> (da, db, c) with da/db: (B,S,di,ds), c: (B,S,ds)."""
    ds = cfg.d_state
    dtr = p["dt_proj"].shape[0]
    xdbc = jnp.einsum("bsi,ir->bsr", xi, p["x_proj"])
    dt, b_, c = (
        xdbc[..., :dtr],
        xdbc[..., dtr : dtr + ds],
        xdbc[..., dtr + ds :],
    )
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt, p["dt_proj"]) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])  # (di, ds), negative
    da = jnp.exp(dt[..., None] * a)  # (B,S,di,ds) in (0,1)
    db = (dt * xi)[..., None] * b_[:, :, None, :]
    return da.astype(jnp.float32), db.astype(jnp.float32), c.astype(jnp.float32)


def _chunk_scan(da, db, h0):
    """Associative scan within a chunk.  da/db: (B,C,di,ds); h0: (B,di,ds).
    h_t = da_t * h_{t-1} + db_t.  Returns (h_all (B,C,di,ds), h_last)."""
    db0 = db.at[:, 0].add(da[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    a_c, h_all = jax.lax.associative_scan(combine, (da, db0), axis=1)
    return h_all, h_all[:, -1]


def mamba_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    state: dict | None = None,
    return_state: bool = False,
):
    """x: (B,S,D).  state (decode): {"conv": (B,dc-1,di), "ssm": (B,di,ds)}.
    ``return_state=True`` (prefill) returns the final recurrent state.

    Returns (out (B,S,D), new_state or None).
    """
    b, s, _ = x.shape
    xz = jnp.einsum("bsd,dgi->bsgi", x, p["in_proj"])
    xz = _lc(xz, ("batch", "seq", None, "inner"))  # d_inner stays on model
    xi, z = xz[..., 0, :], xz[..., 1, :]
    conv_state = state["conv"] if state is not None else None
    xi, new_conv = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    xi = jax.nn.silu(xi)
    da, db, c = _ssm_params(p, xi, cfg)

    if state is not None and s == 1:  # decode: one recurrence step
        h = da[:, 0] * state["ssm"] + db[:, 0]  # (B,di,ds)
        y = jnp.einsum("bis,bs->bi", h, c[:, 0])[:, None, :]
        new_state = {"conv": new_conv.astype(state["conv"].dtype), "ssm": h}
    else:  # train/prefill: chunked scan
        ck = min(cfg.ssm_chunk, s)
        n_chunks = math.ceil(s / ck)
        s_pad = n_chunks * ck
        if s_pad != s:  # identity-decay padding keeps the final state exact
            pad = ((0, 0), (0, s_pad - s), (0, 0), (0, 0))
            da = jnp.pad(da, pad, constant_values=1.0)
            db = jnp.pad(db, pad)
        h0 = jnp.zeros((b, da.shape[2], da.shape[3]), jnp.float32)

        def step(h, blk):
            da_c, db_c = blk
            h_all, h_last = _chunk_scan(da_c, db_c, h)
            return h_last, h_all

        da_c = da.reshape(b, n_chunks, ck, *da.shape[2:]).swapaxes(0, 1)
        db_c = db.reshape(b, n_chunks, ck, *db.shape[2:]).swapaxes(0, 1)
        h_last, h_all = jax.lax.scan(step, h0, (da_c, db_c))
        h_all = h_all.swapaxes(0, 1).reshape(b, s_pad, *da.shape[2:])[:, :s]
        y = jnp.einsum("bsin,bsn->bsi", h_all, c)
        new_state = None
        if return_state or state is not None:  # prefill
            new_state = {"conv": new_conv.astype(x.dtype), "ssm": h_last}

    y = y + xi * p["d_skip"]
    # gate in model dtype: f32 state output must not promote z's cotangent
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, new_state


# ---------------------------------------------------------------------------
# RWKV-6 (Finch)
# ---------------------------------------------------------------------------

_RWKV_LORA = 32


def rwkv6_init(rng, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    ks = jax.random.split(rng, 10)
    r = _RWKV_LORA
    decay = -5.0 + 8.0 * (jnp.arange(d) / max(d - 1, 1)) ** 0.7  # rwkv init curve
    return {
        # ddlerp token-shift mixers: 5 targets (w,k,v,r,g) + base mix_x
        "mix_x": P.init_zeros((d,), ("embed",)),
        "mix_wkvrg": P.init_zeros((5, d), (None, "embed")),
        "lora_a": P.init_normal(ks[0], (d, 5, r), ("embed", None, None), scale=0.01),
        "lora_b": P.init_normal(ks[1], (5, r, d), (None, None, "embed"), scale=0.01),
        # projections
        "wr": P.init_normal(ks[2], (d, d), ("embed", "heads_flat")),
        "wk": P.init_normal(ks[3], (d, d), ("embed", "heads_flat")),
        "wv": P.init_normal(ks[4], (d, d), ("embed", "heads_flat")),
        "wg": P.init_normal(ks[5], (d, d), ("embed", "heads_flat")),
        "wo": P.init_normal(ks[6], (d, d), ("heads_flat", "embed")),
        # data-dependent decay
        "w0": P.Param(decay, ("embed",)),
        "wd_a": P.init_normal(ks[7], (d, 2 * r), ("embed", None), scale=0.01),
        "wd_b": P.init_normal(ks[8], (2 * r, d), (None, "embed"), scale=0.01),
        "u": P.init_normal(ks[9], (d,), ("embed",), scale=0.5),
        # per-head groupnorm
        "gn_scale": P.init_ones((d,), ("embed",)),
        "gn_bias": P.init_zeros((d,), ("embed",)),
    }


def _token_shift(x, last=None):
    """x_{t-1} with zero (or carried) boundary.  x: (B,S,D)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last.astype(x.dtype), x[:, :-1]], axis=1)


def rwkv6_time_mix(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    state: dict | None = None,
    return_state: bool = False,
):
    """RWKV-6 time mixing.  x: (B,S,D).
    state (decode): {"shift": (B,1,D), "wkv": (B,H,hd,hd)}."""
    b, s, d = x.shape
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    prev = _token_shift(x, state["shift"] if state is not None else None)
    dx = prev - x
    xxx = x + dx * p["mix_x"]
    lora = jnp.einsum(
        "mbsr,mrd->mbsd",
        jnp.tanh(jnp.einsum("bsd,dmr->mbsr", xxx, p["lora_a"])),
        p["lora_b"],
    )
    mixed = x[None] + dx[None] * (p["mix_wkvrg"][:, None, None, :] + lora)
    xw, xk, xv, xr, xg = mixed[0], mixed[1], mixed[2], mixed[3], mixed[4]
    r = jnp.einsum("bsd,de->bse", xr, p["wr"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"]).reshape(b, s, h, hd)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"]))
    dd = jnp.einsum("bsr,rd->bsd", jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["wd_a"])), p["wd_b"])
    logw = -jnp.exp((p["w0"] + dd).astype(jnp.float32))  # log decay < 0
    w = jnp.exp(logw).reshape(b, s, h, hd)  # (0,1) decay per channel
    u = p["u"].reshape(h, hd)

    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    if state is not None and s == 1:
        st = state["wkv"]  # (B,H,hd_k,hd_v)
        kv = kf[:, 0, :, :, None] * vf[:, 0, :, None, :]  # (B,H,hdk,hdv)
        out = jnp.einsum("bhk,bhkv->bhv", rf[:, 0], st + u[None, :, :, None] * kv)
        new_st = wf[:, 0, :, :, None] * st + kv
        y = out[:, None]  # (B,1,H,hd)
        new_state = {"shift": x[:, -1:].astype(state["shift"].dtype), "wkv": new_st}
    else:

        def step(st, inp):
            rt, kt, vt, wt = inp  # (B,H,hd) each
            kv = kt[:, :, :, None] * vt[:, :, None, :]
            out = jnp.einsum("bhk,bhkv->bhv", rt, st + u[None, :, :, None] * kv)
            st = wt[:, :, :, None] * st + kv
            return st, out

        st0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        xs = tuple(t.swapaxes(0, 1) for t in (rf, kf, vf, wf))
        st_last, outs = jax.lax.scan(step, st0, xs)
        y = outs.swapaxes(0, 1)  # (B,S,H,hd)
        new_state = None
        if return_state or state is not None:
            new_state = {"shift": x[:, -1:].astype(x.dtype), "wkv": st_last}

    # per-head group norm, gate, out-proj
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = ((y - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(b, -1, d)
    yn = (yn * p["gn_scale"] + p["gn_bias"]).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", yn * g, p["wo"])
    return out, new_state


def rwkv_channel_mix(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    state: dict | None = None,
    return_state: bool = False,
):
    """RWKV-6 channel mix with token shift.  state: {"shift": (B,1,D)}."""
    prev = _token_shift(x, state["shift"] if state is not None else None)
    dx = prev - x
    xk = x + dx * p["mix_k"]
    xr = x + dx * p["mix_r"]
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"])
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"])) * kv
    new_state = None
    if return_state or state is not None:
        new_state = {"shift": x[:, -1:].astype(x.dtype)}
    return out, new_state
