"""Model configuration covering all 10 assigned architecture families.

One dataclass describes dense, MoE, hybrid (Mamba+attention), SSM-only,
encoder-decoder (audio) and VLM-backbone models.  Layer heterogeneity
(Jamba's 1:7 attention:Mamba interleave, Gemma-3's 5:1 local:global) is
expressed as a *periodic pattern*: layers are grouped into super-blocks of
``group_size`` layers; the stack scans (or unrolls) over
``num_layers / group_size`` identical groups, which keeps parameters
stackable for ``lax.scan`` and the checkpoint layout mode-independent.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # --- attention ---
    attention: str = "gqa"  # gqa | mla | none
    causal: bool = True  # False = bidirectional (whisper encoder)
    rope_theta: float = 1e4
    rope_fraction: float = 1.0  # chatglm "2d rope": rotate this fraction of dims
    qk_norm: bool = False  # qwen3
    # Replicate KV heads up to this count at apply time (0 = off).  With
    # kv_heads < TP width, plain replication makes the KV-grad reduction an
    # all-reduce of the (B,S,H,hd) f32 expansion (~6 GB/layer measured);
    # repeating the (tiny) KV projection weights to the TP width keeps the
    # expansion device-local.  Training dynamics are IDENTICAL (gradients
    # of tied copies sum), so this is a distribution detail, not a model
    # change.  Set to the production TP width (16) in full-size configs.
    kv_pad_to: int = 0
    sliding_window: int = 0  # 0 = full; >0 = SWA (mixtral, gemma3 local layers)
    global_every: int = 0  # gemma3: layer i is global iff i % global_every == global_offset
    global_offset: int = 0
    logit_softcap: float = 0.0

    # --- MLA (minicpm3 / deepseek-style) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1  # layer i uses MoE iff i % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    norm_topk: bool = False  # qwen3 renormalizes top-k router probs

    # --- hybrid / SSM ---
    attn_every: int = 0  # 0 = attention everywhere; else attn iff i % attn_every == attn_offset
    attn_offset: int = 0
    ssm_type: str = "mamba"  # mamba | rwkv6 (mixer for non-attention layers)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    rwkv_head_dim: int = 64
    ssm_chunk: int = 256

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # e.g. 1500 mel frames (post-conv stub)

    # --- VLM backbone (internvl2) ---
    num_patches: int = 0  # patch-embedding stub length

    # --- MLP / misc ---
    mlp_type: str = "swiglu"  # swiglu | gelu | geglu | relu_sq (rwkv channel mix)
    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    # --- execution knobs (not architecture) ---
    stack_mode: str = "scan"  # scan | unroll (unroll => trip-count-faithful HLO)
    remat: bool = True
    attn_chunk: int = 4096  # q/kv block for the chunked-attention jnp path
    loss_chunk: int = 512  # sequence chunk for the vocab-sharded CE loss
    use_flash_kernel: bool = False  # Pallas path (TPU deployment); jnp otherwise
    moe_impl: str = "dispatch"  # dispatch (scatter-gather, paper technique) | dense

    # ---------------- derived ----------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def kv_heads_effective(self) -> int:
        """KV head count after tied-copy padding (cache layout uses this)."""
        if (
            self.kv_pad_to > self.num_kv_heads
            and self.kv_pad_to % self.num_kv_heads == 0
            and self.num_heads % self.kv_pad_to == 0
        ):
            return self.kv_pad_to
        return self.num_kv_heads

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.expand * self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def mixer_kind(self, i: int) -> str:
        """'attn' | 'mamba' | 'rwkv6' for decoder layer i."""
        if self.attention == "none":
            return self.ssm_type
        if self.attn_every and i % self.attn_every != self.attn_offset:
            return self.ssm_type
        return "attn"

    def ffn_kind(self, i: int) -> str:
        """'moe' | 'mlp' for decoder layer i."""
        if self.num_experts and i % self.moe_every == self.moe_offset:
            return "moe"
        return "mlp"

    def window_for_layer(self, i: int) -> int:
        """Sliding window (0 = full attention) for decoder layer i."""
        if self.global_every:
            is_global = i % self.global_every == self.global_offset
            return 0 if is_global else self.sliding_window
        return self.sliding_window

    @property
    def group_size(self) -> int:
        """Smallest period after which the layer pattern repeats."""
        p = 1
        if self.attn_every:
            p = _lcm(p, self.attn_every)
        if self.num_experts and self.moe_every > 1:
            p = _lcm(p, self.moe_every)
        if self.global_every:
            p = _lcm(p, self.global_every)
        return p

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.group_size == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern period {self.group_size}"
        )
        return self.num_layers // self.group_size

    @property
    def is_sub_quadratic(self) -> bool:
        """True if per-token decode cost is bounded (SSM / hybrid / windowed)."""
        if self.attention == "none":
            return True
        if self.attn_every:  # hybrid: attention layers still O(S) per token,
            return True  # but the 1:7 interleave bounds the constant (jamba)
        if self.sliding_window and not self.global_every:
            return True  # pure SWA (mixtral)
        if self.global_every and self.sliding_window:
            return True  # 5:1 local:global (gemma3) — documented approximation
        return False

    def validate(self) -> "ModelConfig":
        if self.attention == "mla":
            assert self.kv_lora_rank and self.qk_nope_dim and self.qk_rope_dim
        if self.num_experts:
            assert self.experts_per_token > 0
        _ = self.num_groups  # divisibility check
        for i in range(self.group_size):
            for g in range(1, min(self.num_groups, 2)):
                j = g * self.group_size + i
                if j < self.num_layers:
                    assert self.mixer_kind(i) == self.mixer_kind(j), (i, j)
                    assert self.ffn_kind(i) == self.ffn_kind(j), (i, j)
        return self


def _lcm(a: int, b: int) -> int:
    from math import gcd

    return a * b // gcd(a, b)


# ---------------------------------------------------------------------------
# Input shape sets (the four assigned shapes)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
