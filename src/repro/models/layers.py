"""Transformer building blocks: norms, RoPE, dense MLPs, GQA/MLA attention.

Attention comes in two executable forms with identical semantics:
  * ``blocked_attention`` — pure-jnp flash-style q/kv-blocked online
    softmax with *static* block skipping (causal + sliding window).  The
    python block loops unroll, so (a) the (S,S) score matrix never
    materializes, and (b) HLO FLOPs are trip-count-faithful for the
    dry-run cost analysis (skipped blocks contribute nothing).
  * the Pallas kernel (kernels/flash_attention.py) for TPU deployment.

Decode-time attention is a separate single-token path over a KV cache.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro import params as P
from repro.models.config import ModelConfig
from repro.runtime import logical_constraint as _lc

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def rms_norm_init(dim: int) -> P.Param:
    return P.init_ones((dim,), ("embed",))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float, fraction: float = 1.0):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S) int32.

    Rotates the first ``fraction * D`` dims (chatglm-style partial rotary).
    """
    d = x.shape[-1]
    rot = int(d * fraction) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    freqs = rope_freqs(rot, theta)  # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    while ang.ndim < xr.ndim:
        ang = ang[..., None, :]  # broadcast over head dim(s)
    # angles in f32, rotation applied in x.dtype: an f32 multiply here
    # promotes the whole backward residual chain to f32 (measured: 2x
    # collective wire on the dry-run) — the standard bf16-rope trade.
    cos = jnp.cos(ang).astype(x.dtype)
    sin = jnp.sin(ang).astype(x.dtype)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1)


# ---------------------------------------------------------------------------
# dense MLPs
# ---------------------------------------------------------------------------


def mlp_init(rng, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "wi": P.init_normal(k1, (d, 2, f), ("embed", None, "mlp")),
            "wo": P.init_normal(k2, (f, d), ("mlp", "embed")),
        }
    if cfg.mlp_type == "relu_sq":  # rwkv6 channel-mix
        return {
            "wk": P.init_normal(k1, (d, f), ("embed", "mlp")),
            "wv": P.init_normal(k2, (f, d), ("mlp", "embed")),
            "wr": P.init_normal(k3, (d, d), ("embed", "embed_out")),
            "mix_k": P.init_zeros((d,), ("embed",)),
            "mix_r": P.init_zeros((d,), ("embed",)),
        }
    return {  # plain gelu/relu (starcoder2, whisper)
        "wi": P.init_normal(k1, (d, f), ("embed", "mlp")),
        "wo": P.init_normal(k2, (f, d), ("mlp", "embed")),
    }


def mlp_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.mlp_type in ("swiglu", "geglu"):
        h = jnp.einsum("...d,dgf->...gf", x, p["wi"])
        gate, up = h[..., 0, :], h[..., 1, :]
        act = jax.nn.silu(gate) if cfg.mlp_type == "swiglu" else jax.nn.gelu(gate)
        return jnp.einsum("...f,fd->...d", act * up, p["wo"])
    if cfg.mlp_type == "relu_sq":
        raise ValueError("rwkv channel-mix is applied via ssm.rwkv_channel_mix")
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["wi"]))
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------------------
# blocked (flash-style) attention — pure jnp, statically pruned
# ---------------------------------------------------------------------------

_NEG = -1e30


def repeat_kv(k: jax.Array, g: int) -> jax.Array:
    """(B,S,Hkv,D) -> (B,S,Hkv*g,D).  GQA under tensor parallelism: expand
    KV to the full (TP-sharded) head count rather than grouping Q — a
    grouped (Hkv, g) reshape breaks GSPMD head sharding whenever Hkv or g
    is not divisible by the model axis (measured: replicated attention,
    ~50x temp memory).  The repeat is cheap: KV is the small tensor."""
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=2)


def _block_attend(q, k, v, qpos, kpos, window: int, softcap: float):
    """One (q-block, kv-block) tile. q: (B,Sq,H,D), k/v: (B,Sk,H,D)."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    mask = kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask, s, _NEG)
    m = jnp.max(s, axis=-1)  # (B,H,Sq)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bhqd", p, v.astype(jnp.float32))
    return m, l, o


def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cfg: ModelConfig,
    window: int = 0,
    chunk: int | None = None,
    softcap: float = 0.0,
) -> jax.Array:
    """Causal GQA attention.  q: (B,S,H,D); k,v: (B,S,Hkv,D) -> (B,S,H,D).

    Python-unrolled q x kv block loops; blocks fully outside the causal /
    window band are skipped *statically* (no HLO emitted, no FLOPs counted,
    no memory touched) — the jnp mirror of the Pallas kernel's pl.when
    pruning and of GenGNN's "only touch real neighbours" principle.
    """
    b, s, h, d = q.shape
    hkv = k.shape[2]
    dv = v.shape[-1]  # may differ from d (MLA prefill)
    g = h // hkv
    c = min(chunk or cfg.attn_chunk, s)
    n_blocks = math.ceil(s / c)
    scale = 1.0 / math.sqrt(d)
    qs = q * scale
    kr = repeat_kv(k, g)
    vr = repeat_kv(v, g)
    pos = jnp.arange(s)

    out_blocks = []
    for i in range(n_blocks):
        q0, q1 = i * c, min((i + 1) * c, s)
        qi = qs[:, q0:q1]
        qpos = pos[q0:q1]
        m_acc = jnp.full((b, h, q1 - q0), _NEG, jnp.float32)
        l_acc = jnp.zeros((b, h, q1 - q0), jnp.float32)
        o_acc = jnp.zeros((b, h, q1 - q0, dv), jnp.float32)
        for j in range(n_blocks):
            k0, k1 = j * c, min((j + 1) * c, s)
            if k0 > q1 - 1:  # entirely above the diagonal
                continue
            if window and k1 - 1 <= q0 - window:  # entirely left of window
                continue
            m, l, o = _block_attend(
                qi, kr[:, k0:k1], vr[:, k0:k1], qpos, pos[k0:k1], window, softcap
            )
            m_new = jnp.maximum(m_acc, m)
            alpha = jnp.exp(m_acc - m_new)
            beta = jnp.exp(m - m_new)
            l_acc = alpha * l_acc + beta * l
            o_acc = alpha[..., None] * o_acc + beta[..., None] * o
            m_acc = m_new
        o = o_acc / jnp.maximum(l_acc, 1e-30)[..., None]
        out_blocks.append(o)
    out = jnp.concatenate(out_blocks, axis=2)  # (B,H,S,Dv)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    t: jax.Array,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    """Single-token attention over a cache.

    q: (B,1,H,D); caches: (B,S,Hkv,D); t: () int32 current position.
    Positions > t (unwritten cache) and outside the window are masked.
    """
    b, _, h, d = q.shape
    s = k_cache.shape[1]
    hkv = k_cache.shape[2]
    g = h // hkv
    qs = (q / math.sqrt(d)).reshape(b, h, d)
    kr = repeat_kv(k_cache, g)
    vr = repeat_kv(v_cache, g)
    logits = jnp.einsum(
        "bhd,bkhd->bhk", qs.astype(jnp.float32), kr.astype(jnp.float32)
    )
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    kpos = jnp.arange(s)
    mask = kpos <= t
    if window:
        mask &= kpos > t - window
    logits = jnp.where(mask[None, None, :], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhk,bkhd->bhd", p, vr.astype(jnp.float32))
    return o[:, None].astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (init + train/prefill/decode apply)
# ---------------------------------------------------------------------------


def gqa_init(rng, cfg: ModelConfig) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    ks = jax.random.split(rng, 4)
    p = {
        "wq": P.init_normal(ks[0], (d, h, hd), ("embed", "heads", "head_dim")),
        "wk": P.init_normal(ks[1], (d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P.init_normal(ks[2], (d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P.init_normal(ks[3], (h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = P.init_ones((hd,), ("head_dim",))
        p["k_norm"] = P.init_ones((hd,), ("head_dim",))
    return p


def gqa_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    window: int,
    positions: jax.Array | None = None,
    kv_cache: tuple | None = None,
    t: jax.Array | None = None,
    causal: bool = True,
):
    """Returns (out, new_kv) where new_kv is (k, v) for cache construction.

    Train/prefill: x (B,S,D), kv_cache None -> full blocked attention.
    Decode: x (B,1,D), kv_cache (k,v) of shape (B,S,Hkv,hd), t = position.
    """
    b, s, _ = x.shape
    wk, wv = p["wk"], p["wv"]
    hkv = wk.shape[1]
    if cfg.kv_heads_effective > hkv:
        rep = cfg.kv_heads_effective // hkv  # tied-copy KV padding to TP width
        wk = jnp.repeat(wk, rep, axis=1)
        wv = jnp.repeat(wv, rep, axis=1)
    q = _lc(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), ("batch", "seq", "heads", None))
    k = _lc(jnp.einsum("bsd,dhk->bshk", x, wk), ("batch", "seq", "kv_heads", None))
    v = _lc(jnp.einsum("bsd,dhk->bshk", x, wv), ("batch", "seq", "kv_heads", None))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if positions is None:
        positions = jnp.arange(s)[None, :] if t is None else jnp.full((b, 1), t)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    if kv_cache is None:
        if causal:
            o = blocked_attention(q, k, v, cfg, window=window, softcap=cfg.logit_softcap)
        else:  # encoder self-attention (whisper): full bidirectional
            o = _bidirectional_attention(q, k, v)
        new_kv = (k, v)
    else:
        kc, vc = kv_cache  # decode: write slot t, attend over the cache
        kc = _cache_update(kc, k, t)
        vc = _cache_update(vc, v, t)
        o = decode_attention(q, kc, vc, t, window=window, softcap=cfg.logit_softcap)
        new_kv = (kc, vc)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, new_kv


def _cache_update(cache: jax.Array, kv: jax.Array, t: jax.Array) -> jax.Array:
    """cache (B,S,Hkv,D) <- kv (B,1,Hkv,D) at position t."""
    return jax.lax.dynamic_update_slice(cache, kv.astype(cache.dtype), (0, t, 0, 0))


def _bidirectional_attention(q, k, v):
    """Full bidirectional GQA attention (encoder / cross-attention)."""
    b, s, h, d = q.shape
    g = h // k.shape[2]
    kr = repeat_kv(k, g)
    vr = repeat_kv(v, g)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) / math.sqrt(d)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)


def cross_attention_apply(p: dict, x: jax.Array, enc_k, enc_v, cfg: ModelConfig):
    """Decoder cross-attention over precomputed encoder K/V (whisper)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    o = _bidirectional_attention(q, enc_k, enc_v)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def cross_kv(p: dict, enc_out: jax.Array):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (minicpm3 / deepseek family)
# ---------------------------------------------------------------------------


def mla_init(rng, cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(rng, 8)
    return {
        "w_dq": P.init_normal(ks[0], (d, qr), ("embed", "q_lora")),
        "q_norm": P.init_ones((qr,), ("q_lora",)),
        "w_uq": P.init_normal(ks[1], (qr, h, dn + dr), ("q_lora", "heads", "head_dim")),
        "w_dkv": P.init_normal(ks[2], (d, kvr), ("embed", "kv_lora")),
        "kv_norm": P.init_ones((kvr,), ("kv_lora",)),
        "w_kr": P.init_normal(ks[3], (d, dr), ("embed", "head_dim")),
        "w_uk": P.init_normal(ks[4], (kvr, h, dn), ("kv_lora", "heads", "head_dim")),
        "w_uv": P.init_normal(ks[5], (kvr, h, dv), ("kv_lora", "heads", "head_dim")),
        "wo": P.init_normal(ks[6], (h, dv, d), ("heads", "head_dim", "embed")),
    }


def mla_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array | None = None,
    cache: tuple | None = None,
    t: jax.Array | None = None,
):
    """MLA attention.  Cache holds only (c_kv, k_rope): (B,S,kvr), (B,S,dr) —
    the latent compression that gives MLA its small-cache property.

    Prefill/train: expand per-head keys/values and run blocked attention.
    Decode: absorbed form — score in the kv_lora latent space, never
    materializing per-head keys (FLOPs ~ H * (dn*kvr) per cached token).
    """
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(s)[None, :] if t is None else jnp.full((b, 1), t)
    q_lat = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["w_uq"])  # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"])
    k_rope = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, p["w_kr"]), positions, cfg.rope_theta
    )  # (B,S,dr) single shared rope key

    if cache is None:
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
        v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))], axis=-1
        )
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = blocked_attention(qq, k, v, cfg, window=0)  # (B,S,H,dv)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        return out, (c_kv, k_rope)

    ckv_cache, krope_cache = cache
    ckv_cache = jax.lax.dynamic_update_slice(
        ckv_cache, c_kv.astype(ckv_cache.dtype), (0, t, 0)
    )
    krope_cache = jax.lax.dynamic_update_slice(
        krope_cache, k_rope.astype(krope_cache.dtype), (0, t, 0)
    )
    # absorbed scores: q_abs (B,H,kvr) = q_nope . W_uk
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])[:, 0]  # (B,H,kvr)
    scale = 1.0 / math.sqrt(dn + dr)
    s_lat = jnp.einsum(
        "bhr,bkr->bhk", q_abs.astype(jnp.float32), ckv_cache.astype(jnp.float32)
    )
    s_rope = jnp.einsum(
        "bhr,bkr->bhk",
        q_rope[:, 0].astype(jnp.float32),
        krope_cache.astype(jnp.float32),
    )
    logits = (s_lat + s_rope) * scale
    kpos = jnp.arange(ckv_cache.shape[1])
    logits = jnp.where(kpos[None, None, :] <= t, logits, _NEG)
    probs = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhk,bkr->bhr", probs, ckv_cache.astype(jnp.float32))
    o = jnp.einsum("bhr,rhk->bhk", o_lat, p["w_uv"].astype(jnp.float32))  # (B,H,dv)
    out = jnp.einsum("bhk,hkd->bd", o.astype(x.dtype), p["wo"])[:, None, :]
    return out, (ckv_cache, krope_cache)
