"""Decoder stack builder: periodic heterogeneous blocks, scan or unroll.

Layers are grouped into super-blocks of ``cfg.group_size`` (the pattern
period: Jamba 1:7 attention:Mamba = 8, Gemma-3 5:1 local:global = 6, dense
models = 1).  Parameters for position ``pos`` in the group are stacked over
the ``num_groups`` axis, so:

  * ``stack_mode="scan"``   — lax.scan over groups: compact HLO, fast
    compile, the runtime path;
  * ``stack_mode="unroll"`` — python loop over groups: trip-count-faithful
    HLO for the dry-run cost analysis (DESIGN.md §7).

Both modes share one parameter/checkpoint layout.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import params as P
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import ModelConfig
from repro.runtime import logical_constraint as _lc

Cache = Any  # list[pos] of dicts with (G, ...) stacked leaves


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------


def block_init(rng, cfg: ModelConfig, pos: int, cross_attention: bool = False) -> dict:
    keys = jax.random.split(rng, 6)
    p: dict = {"ln1": L.rms_norm_init(cfg.d_model), "ln2": L.rms_norm_init(cfg.d_model)}
    kind = cfg.mixer_kind(pos)
    if kind == "attn":
        p["mixer"] = (
            L.mla_init(keys[0], cfg) if cfg.attention == "mla" else L.gqa_init(keys[0], cfg)
        )
    elif kind == "mamba":
        p["mixer"] = SSM.mamba_init(keys[0], cfg)
    elif kind == "rwkv6":
        p["mixer"] = SSM.rwkv6_init(keys[0], cfg)
    else:
        raise ValueError(kind)
    if cross_attention:
        p["ln_cross"] = L.rms_norm_init(cfg.d_model)
        p["cross"] = L.gqa_init(keys[2], cfg)
    if cfg.ffn_kind(pos) == "moe":
        p["ffn"] = MOE.moe_init(keys[1], cfg)
    elif cfg.mlp_type == "relu_sq":
        p["ffn"] = L.mlp_init(keys[1], cfg)
    else:
        p["ffn"] = L.mlp_init(keys[1], cfg)
    return p


def block_cache_init(cfg: ModelConfig, pos: int, batch: int, seq: int, dtype) -> dict:
    """Zero decode cache for one block (un-stacked), as Param leaves so the
    launcher can resolve cache shardings from logical axes."""
    kind = cfg.mixer_kind(pos)
    c: dict = {}
    if cfg.family == "audio":  # cross-attention K/V filled at prefill
        shp = (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim_)
        axes = ("batch", None, "kv_heads", "head_dim")
        c["cross_k"] = P.Param(jnp.zeros(shp, dtype), axes)
        c["cross_v"] = P.Param(jnp.zeros(shp, dtype), axes)
    if kind == "attn":
        if cfg.attention == "mla":
            c["ckv"] = P.Param(
                jnp.zeros((batch, seq, cfg.kv_lora_rank), dtype),
                ("batch", "kv_seq", "kv_lora"),
            )
            c["krope"] = P.Param(
                jnp.zeros((batch, seq, cfg.qk_rope_dim), dtype),
                ("batch", "kv_seq", "head_dim"),
            )
        else:
            shp = (batch, seq, cfg.kv_heads_effective, cfg.head_dim_)
            axes = ("batch", "kv_seq", "kv_heads", "head_dim")
            c["k"] = P.Param(jnp.zeros(shp, dtype), axes)
            c["v"] = P.Param(jnp.zeros(shp, dtype), axes)
    elif kind == "mamba":
        c["conv"] = P.Param(
            jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
            ("batch", None, "inner"),
        )
        c["ssm"] = P.Param(
            jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
            ("batch", "inner", "state"),
        )
    elif kind == "rwkv6":
        c["shift"] = P.Param(jnp.zeros((batch, 1, cfg.d_model), dtype), ("batch", None, "embed"))
        c["wkv"] = P.Param(
            jnp.zeros((batch, cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32),
            ("batch", "heads", None, None),
        )
    if cfg.mlp_type == "relu_sq":
        c["cm_shift"] = P.Param(
            jnp.zeros((batch, 1, cfg.d_model), dtype), ("batch", None, "embed")
        )
    return c


def block_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    pos: int,
    mode: str = "train",  # train | prefill | decode
    cache: Optional[dict] = None,
    t: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    enc_kv: Optional[tuple] = None,
):
    """Returns (x, cache_out, aux_loss).

    mode="train":   cache_out = {}.
    mode="prefill": cache_out holds full-sequence K/V (B,S,...) and final
                    recurrent states — the caller packs them into a cache.
    mode="decode":  cache is the packed cache; cache_out is its update.
    """
    kind = cfg.mixer_kind(pos)
    window = cfg.window_for_layer(pos)
    decode = mode == "decode"
    prefill = mode == "prefill"
    cache_out: dict = {}
    x = _lc(x, ("batch", "seq", None))  # residual stream: batch over data
    h = L.rms_norm(x, p["ln1"])
    if kind == "attn":
        if cfg.attention == "mla":
            mla_cache = (cache["ckv"], cache["krope"]) if decode else None
            out, kvc = L.mla_apply(p["mixer"], h, cfg, positions=positions, cache=mla_cache, t=t)
            if decode or prefill:
                cache_out["ckv"], cache_out["krope"] = kvc
        else:
            kv_cache = (cache["k"], cache["v"]) if decode else None
            out, kvc = L.gqa_apply(
                p["mixer"], h, cfg, window, positions=positions,
                kv_cache=kv_cache, t=t, causal=cfg.causal,
            )
            if decode or prefill:
                cache_out["k"], cache_out["v"] = kvc
    elif kind == "mamba":
        st = {"conv": cache["conv"], "ssm": cache["ssm"]} if decode else None
        out, st_new = SSM.mamba_apply(p["mixer"], h, cfg, state=st, return_state=prefill)
        if decode or prefill:
            cache_out.update(st_new)
    else:  # rwkv6
        st = {"shift": cache["shift"], "wkv": cache["wkv"]} if decode else None
        out, st_new = SSM.rwkv6_time_mix(p["mixer"], h, cfg, state=st, return_state=prefill)
        if decode or prefill:
            cache_out.update(st_new)
    x = x + out

    if cfg.family == "audio" and "cross" in p:
        hc = L.rms_norm(x, p["ln_cross"])
        if decode:
            ekv = (cache["cross_k"], cache["cross_v"])
        else:
            ekv = enc_kv
        x = x + L.cross_attention_apply(p["cross"], hc, ekv[0], ekv[1], cfg)
        if prefill:
            cache_out["cross_k"], cache_out["cross_v"] = ekv
        elif decode:
            cache_out["cross_k"], cache_out["cross_v"] = cache["cross_k"], cache["cross_v"]

    h2 = L.rms_norm(x, p["ln2"])
    aux = jnp.zeros((), jnp.float32)
    if cfg.ffn_kind(pos) == "moe":
        out2, aux = MOE.moe_apply(p["ffn"], h2, cfg)
    elif cfg.mlp_type == "relu_sq":
        st = {"shift": cache["cm_shift"]} if decode else None
        out2, st_new = SSM.rwkv_channel_mix(p["ffn"], h2, cfg, state=st, return_state=prefill)
        if decode or prefill:
            cache_out["cm_shift"] = st_new["shift"]
    else:
        out2 = L.mlp_apply(p["ffn"], h2, cfg)
    return x + out2, cache_out, aux


# ---------------------------------------------------------------------------
# stack
# ---------------------------------------------------------------------------


def stack_init(rng, cfg: ModelConfig, cross_attention: bool = False) -> list:
    """list[pos] of Param trees with leaves stacked over num_groups."""
    groups = []
    for pos in range(cfg.group_size):
        rng, sub = jax.random.split(rng)
        proto = block_init(sub, cfg, pos, cross_attention)
        keys = jax.random.split(sub, cfg.num_groups)
        vals = jax.vmap(
            lambda k: P.values(block_init(k, cfg, pos, cross_attention))
        )(keys)
        axs = jax.tree.map(lambda pr: ("layers",) + pr.axes, proto, is_leaf=P.is_param)
        groups.append(P.merge(vals, axs))
    return groups


def stack_cache_init(cfg: ModelConfig, batch: int, seq: int, dtype) -> list:
    """list[pos] of Param trees stacked over num_groups."""
    out = []
    for pos in range(cfg.group_size):
        proto = block_cache_init(cfg, pos, batch, seq, dtype)
        vals = jax.tree.map(
            lambda pr: jnp.broadcast_to(pr.value[None], (cfg.num_groups,) + pr.value.shape),
            proto,
            is_leaf=P.is_param,
        )
        axs = jax.tree.map(lambda pr: ("layers",) + pr.axes, proto, is_leaf=P.is_param)
        out.append(P.merge(vals, axs))
    return out


def stack_apply(
    groups: list,
    x: jax.Array,
    cfg: ModelConfig,
    mode: str = "train",
    cache: Optional[list] = None,
    t: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    enc_kv: Optional[list] = None,
    remat: Optional[bool] = None,
):
    """Run all layers.  groups: value trees (no Param wrappers) stacked over
    num_groups.  Returns (x, cache_out, total_aux); cache_out is a
    list[pos] of dicts with (G, ...) stacked leaves (empty dicts in train
    mode)."""
    remat = (cfg.remat if remat is None else remat) and mode == "train"
    gs = cfg.group_size

    def group_body(x, group_params, group_cache, group_enc_kv):
        aux_total = jnp.zeros((), jnp.float32)
        new_group_cache = []
        for pos in range(gs):
            c = group_cache[pos] if group_cache is not None else None
            ekv = group_enc_kv[pos] if group_enc_kv is not None else None
            x, nc, aux = block_apply(
                group_params[pos], x, cfg, pos, mode=mode,
                cache=c, t=t, positions=positions, enc_kv=ekv,
            )
            aux_total = aux_total + aux
            new_group_cache.append(nc)
        return x, new_group_cache, aux_total

    if cfg.stack_mode == "unroll":
        collect = cache is not None or mode == "prefill"
        aux_total = jnp.zeros((), jnp.float32)
        new_cache = [dict() for _ in range(gs)] if collect else None
        fn = jax.checkpoint(group_body) if remat else group_body
        for g in range(cfg.num_groups):
            gp = [jax.tree.map(lambda a: a[g], groups[pos]) for pos in range(gs)]
            gc = (
                [jax.tree.map(lambda a: a[g], cache[pos]) for pos in range(gs)]
                if cache is not None
                else None
            )
            gekv = (
                [jax.tree.map(lambda a: a[g], enc_kv[pos]) for pos in range(gs)]
                if enc_kv is not None
                else None
            )
            x, ncs, aux = fn(x, gp, gc, gekv)
            aux_total = aux_total + aux
            if collect:
                for pos in range(gs):
                    for k2, v2 in ncs[pos].items():
                        new_cache[pos].setdefault(k2, []).append(v2)
        if collect:
            new_cache = [
                {k2: jnp.stack(v2) for k2, v2 in nc.items()} for nc in new_cache
            ]
        return x, new_cache, aux_total

    # scan mode
    def scan_body(carry, xs):
        x, aux_total = carry
        gp, gc, gekv = xs
        fn = jax.checkpoint(group_body) if remat else group_body
        x, nc, aux = fn(x, gp, gc, gekv)
        return (x, aux_total + aux), nc

    xs = (groups, cache, enc_kv)
    (x, aux_total), new_cache = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_cache, aux_total
