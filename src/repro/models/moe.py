"""Mixture-of-Experts FFN routed through GenGNN's scatter-gather core.

Token -> expert routing *is* message passing on a bipartite graph: tokens
are messages, experts are destination nodes, and the capacity-sliced
dispatch/combine is exactly the paper's merged scatter-gather with an O(E
slots) buffer (DESIGN.md §3).  ``core.scatter_gather.dispatch_to_slots``
(sort by destination + rank-within-segment + dense slot gather) does the
routing, so the FLOPs of the expert GEMMs are ~ capacity_factor x the
active-parameter FLOPs — no dense all-experts waste.

Two implementations, selected by cfg.moe_impl:
  * "dispatch" — the scatter-gather path above (default; the paper's
    technique as a first-class LM feature).
  * "dense"    — every token through every expert, masked combine.  The
    GCN-style "SpMM-only accelerator" baseline: correct, simple, and
    O(num_experts / top_k) wasteful — kept as the comparison baseline the
    paper draws against SpMM-only designs (Fig. 7 analogue for MoE).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import params as P
from repro.core import scatter_gather as sg
from repro.models.config import ModelConfig
from repro.runtime import logical_constraint as _lc


def moe_init(rng, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "router": P.init_normal(k1, (d, e), ("embed", "experts"), scale=0.02),
        "wi": P.init_normal(k2, (e, d, 2, f), ("experts", "embed", None, "mlp")),
        "wo": P.init_normal(k3, (e, f, d), ("experts", "mlp", "embed")),
    }


def _route(p, x2d, cfg: ModelConfig):
    """Top-k routing.  x2d: (T, D) -> weights (T, k), experts (T, k), aux."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    k = cfg.experts_per_token
    top_p, top_e = jax.lax.top_k(probs, k)
    if cfg.norm_topk:  # qwen3: renormalize over selected experts
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Switch-style load-balance auxiliary loss
    e = cfg.num_experts
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    ce = jnp.mean(
        (jax.nn.one_hot(top_e[:, 0], e)), axis=0
    )  # fraction of tokens whose top-1 is e
    aux = e * jnp.sum(me * ce)
    return top_p, top_e, aux


def _expert_ffn(slots, p, cfg: ModelConfig):
    """slots: (E, C, D) -> (E, C, D) through each expert's own SwiGLU."""
    h = jnp.einsum("ecd,edgf->ecgf", slots, p["wi"])
    gate, up = h[..., 0, :], h[..., 1, :]
    act = jax.nn.silu(gate) if cfg.mlp_type != "geglu" else jax.nn.gelu(gate)
    return jnp.einsum("ecf,efd->ecd", act * up, p["wo"])


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar).

    Dispatch is GROUPED by batch row (GShard's group = sequence): each
    row's sort / capacity-ranking / slot gather is row-local, so under
    data parallelism the whole dispatch stays on-device and the expert
    GEMM is cleanly 2D-sharded (rows over data, experts over model) — no
    cross-device scatter.  The ungrouped global formulation was measured
    on the dry-run at 153 s of all-reduce per step (qwen3 train_4k,
    recorded in EXPERIMENTS.md §Perf as the refuted variant).
    Capacity is per-row: C = cf * S * k / E (per-group drops, the GShard
    semantics).
    """
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    top_p, top_e, aux = _route(p, x2d, cfg)
    k = cfg.experts_per_token
    e = cfg.num_experts

    if cfg.moe_impl == "dense":
        # baseline: all tokens through all experts, weighted combine
        y_all = _expert_ffn(
            jnp.broadcast_to(x2d[None], (e, t, d)), p, cfg
        )  # (E, T, D)
        w = jnp.zeros((t, e), x.dtype)
        w = w.at[jnp.arange(t)[:, None], top_e].set(top_p.astype(x.dtype))
        out = jnp.einsum("te,etd->td", w, y_all)
        return out.reshape(b, s, d), aux

    # --- grouped dispatch (the paper's merged scatter-gather, per row) ---
    capacity = max(int(cfg.capacity_factor * s * k / e), 1)
    capacity = -(-capacity // 8) * 8  # pad to VREG sublane multiple
    eids = top_e.reshape(b, s * k)  # (B, S*k) destination "nodes" per row
    xk = jnp.repeat(x.astype(x.dtype), k, axis=1)  # (B, S*k, D) payloads

    def row_dispatch(vals, ids):
        return sg.dispatch_to_slots(vals, ids, e, capacity)

    slots, slot_idx, kept = jax.vmap(row_dispatch)(xk, eids)
    # slots: (B, E, C, D); expert GEMMs batched over rows.  The explicit
    # constraints pin (rows -> data, experts -> model): without them GSPMD
    # keeps the GEMM replicated across the model axis because the combine
    # gather downstream prefers a replicated operand (measured 16x FLOPs).
    slots = _lc(slots.astype(x.dtype), ("moe_batch", "experts", None, None))
    h = jnp.einsum("becd,edgf->becgf", slots, p["wi"])
    gate, up = h[..., 0, :], h[..., 1, :]
    act = jax.nn.silu(gate) if cfg.mlp_type != "geglu" else jax.nn.gelu(gate)
    y = jnp.einsum("becf,efd->becd", act * up, p["wo"])  # (B, E, C, D)
    y = _lc(y, ("moe_batch", "experts", None, None))
    back = jax.vmap(sg.combine_from_slots)(y, slot_idx, kept)  # (B, S*k, D)
    back = _lc(back, ("batch", None, None))
    out = jnp.sum(
        back.reshape(b, s, k, d) * top_p.reshape(b, s, k)[..., None].astype(back.dtype),
        axis=2,
    )
    return out.astype(x.dtype), aux
