"""Sort-based segment scatter-gather — the heart of GenGNN's merged MP step.

The paper merges the scatter and gather phases (§3.4): as each message is
produced it is immediately folded into the receiver's partial aggregate, so
the message buffer is O(N), never O(E).  The merge is legal because the
aggregation A(.) is permutation invariant.

On TPU, per-edge random scatter serializes on the VPU, so the same insight
is expressed as: *sort edges by destination once (on device), then reduce
contiguous segments*.  The segment layout is exactly the paper's CSC/CSR
ordering, and the O(N) buffer is the segment-reduction output.

These primitives are reused by three subsystems (see DESIGN.md §3):
the GNN engine, MoE token routing, and distributed large-graph exchange.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_NEG = -1e30
_POS = 1e30

REDUCTIONS = ("sum", "mean", "max", "min", "var", "std", "sqsum")


def segment_reduce(
    values: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    op: str = "sum",
    indices_are_sorted: bool = False,
) -> jax.Array:
    """Permutation-invariant segment reduction (the A(.) of §3.3).

    values: (E, F); segment_ids: (E,) int; returns (num_segments, F).
    Empty segments yield 0 for every op (matching an FPGA accumulator that
    was never written).
    """
    if op not in REDUCTIONS:
        raise ValueError(f"unknown reduction {op!r}; expected one of {REDUCTIONS}")
    kw = dict(num_segments=num_segments, indices_are_sorted=indices_are_sorted)
    if op == "sum":
        return jax.ops.segment_sum(values, segment_ids, **kw)
    if op == "sqsum":
        return jax.ops.segment_sum(values * values, segment_ids, **kw)
    count = jax.ops.segment_sum(jnp.ones_like(values[..., :1]), segment_ids, **kw)
    if op == "mean":
        total = jax.ops.segment_sum(values, segment_ids, **kw)
        return total / jnp.maximum(count, 1.0)
    if op in ("var", "std"):
        total = jax.ops.segment_sum(values, segment_ids, **kw)
        sq = jax.ops.segment_sum(values * values, segment_ids, **kw)
        c = jnp.maximum(count, 1.0)
        mean = total / c
        var = jnp.maximum(sq / c - mean * mean, 0.0)
        return jnp.sqrt(var) if op == "std" else var
    # max / min: mask empty segments back to 0.
    if op == "max":
        red = jax.ops.segment_max(values, segment_ids, **kw)
        red = jnp.where(jnp.isfinite(red), red, 0.0)
    else:
        red = jax.ops.segment_min(values, segment_ids, **kw)
        red = jnp.where(jnp.isfinite(red), red, 0.0)
    return jnp.where(count > 0, red, 0.0)


def sort_by_segment(
    segment_ids: jax.Array, num_segments: int, valid: jax.Array | None = None
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Stable sort key establishing segment locality (on-device, O(E log E)).

    Returns (perm, ids_sorted, offsets) where offsets is (num_segments+1,).
    Invalid entries sort to the end with id == num_segments.
    """
    ids = segment_ids if valid is None else jnp.where(valid, segment_ids, num_segments)
    perm = jnp.argsort(ids, stable=True).astype(jnp.int32)
    ids_sorted = jnp.take(ids, perm)
    probe = jnp.arange(num_segments + 1, dtype=ids_sorted.dtype)
    offsets = jnp.searchsorted(ids_sorted, probe, side="left").astype(jnp.int32)
    return perm, ids_sorted, offsets


def rank_within_segment(segment_ids: jax.Array, num_segments: int) -> jax.Array:
    """Position of each element within its segment (0-based), via stable sort.

    This is the capacity-slot assignment used by MoE dispatch: element e with
    ``rank r`` in segment s lands in slot (s, r).  O(E log E + E) and fully
    on-device — no host preprocessing, per the paper's real-time constraint.
    """
    e = segment_ids.shape[0]
    perm, _, offsets = sort_by_segment(segment_ids, num_segments)
    # index within the sorted run = sorted position - segment start
    seg_start = jnp.take(offsets, jnp.take(jnp.clip(segment_ids, 0, num_segments), perm))
    rank_sorted = jnp.arange(e, dtype=jnp.int32) - seg_start
    # scatter ranks back to original order
    rank = jnp.zeros((e,), jnp.int32).at[perm].set(rank_sorted)
    return rank


def dispatch_to_slots(
    values: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    capacity: int,
    valid: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Gather ``values`` into a dense (num_segments, capacity, F) slot array.

    The bipartite message-passing primitive: element -> segment with bounded
    fan-in.  Elements beyond ``capacity`` in their segment are dropped (their
    ``kept`` flag is False) — the standard GShard/Switch semantics, and the
    analogue of a bounded FPGA FIFO.

    Returns (slots, slot_index, kept):
      slots:      (num_segments, capacity, F)
      slot_index: (E,) int32 flattened destination slot (capacity*seg + rank)
      kept:       (E,) bool
    """
    e, f = values.shape
    ids = segment_ids if valid is None else jnp.where(valid, segment_ids, num_segments)
    rank = rank_within_segment(ids, num_segments)
    kept = (rank < capacity) & (ids < num_segments)
    slot = jnp.where(kept, ids * capacity + rank, num_segments * capacity)
    slots = jnp.zeros((num_segments * capacity + 1, f), values.dtype)
    slots = slots.at[slot].set(values)  # unique slots -> no collisions
    return slots[:-1].reshape(num_segments, capacity, f), slot.astype(jnp.int32), kept


def combine_from_slots(
    slots: jax.Array, slot_index: jax.Array, kept: jax.Array
) -> jax.Array:
    """Inverse of :func:`dispatch_to_slots`: gather each element's slot row.

    Dropped elements receive zeros (identity under sum-combine).
    """
    num_segments, capacity, f = slots.shape
    flat = slots.reshape(num_segments * capacity, f)
    safe = jnp.minimum(slot_index, num_segments * capacity - 1)
    out = jnp.take(flat, safe, axis=0)
    return jnp.where(kept[:, None], out, 0.0)


def sorted_segment_reduce(
    values: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    op: str = "sum",
) -> jax.Array:
    """segment_reduce after a *private* on-device sort (CSR/CSC layout).

    Functionally identical to :func:`segment_reduce`.  The shared-plan
    path (``core.layout.GraphLayout``) amortizes this sort across every
    aggregation of a forward pass; this per-call form remains as the
    layout-less fallback and the seed-parity reference, and is what
    ``core.layout.segment_reduce`` reduces to when handed a fresh sort.
    (The nested ``@jax.jit`` this wrapper used to carry is gone: callers
    are always inside a jitted program already, and the extra jit level
    only added trace overhead and hid the sort from jaxpr inspection.)
    """
    perm, ids_sorted, _ = sort_by_segment(segment_ids, num_segments)
    vals_sorted = jnp.take(values, perm, axis=0)
    return segment_reduce(vals_sorted, ids_sorted, num_segments, op, indices_are_sorted=True)
