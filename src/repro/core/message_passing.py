"""Generic message-passing layer (paper §3.3, Fig. 2/3).

    x_i^{l+1} = gamma( x_i^l , A_{j in N(i)} ( phi(x_j^l, e_ij^l) ) )

The framework fixes the *dataflow* (gather messages along in-edges, reduce
per destination, transform per node) and models plug in:

  * ``phi``      message transformation, applied edge-parallel,
  * ``aggregate``one or more permutation-invariant reductions,
  * ``gamma``    node transformation (the "Node Embedding PE").

GenGNN's merged scatter-gather is realized over a shared
``core.layout.GraphLayout``: the COO->CSC conversion (the one O(E log E)
sort) happens once per graph, and every aggregation of every layer folds
its messages into the O(N) destination buffer through that single plan —
permutation invariance makes the order irrelevant (§3.4).

Masking contract
----------------
Padding-edge masking is the **layout's job**, not the caller's and not a
value-side multiply here:

  * the plan's sort keys are ``where(edge_mask, dst, N_pad)``, so padding
    edges sort to the end carrying the out-of-range id ``N_pad``;
  * JAX segment ops *drop* out-of-range ids, so padding messages never
    reach a real destination row — whatever garbage they hold;
  * callers therefore pass raw, unmasked per-edge messages, and nothing
    in this module multiplies messages by ``edge_mask`` (the seed did
    both, meaning every aggregate paid a redundant (E, F) select *and*
    several callers pre-masked on top of that).

Node-side masking stays explicit (``mp_layer`` zeroes padded node rows on
the way out) because padded node rows are *read back* by the next layer's
gather, unlike padding edges which are write-only.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import layout as LY
from repro.core import scatter_gather as sg
from repro.core.graph import Graph, in_degree
from repro.kernels import ops as kops

# phi(x_src, x_dst, e) -> message  (edge-parallel)
PhiFn = Callable[[jax.Array, jax.Array, jax.Array], jax.Array]
# gamma(x, aggregated) -> new x    (node-parallel)
GammaFn = Callable[[jax.Array, jax.Array], jax.Array]
# aggregate(graph, messages, layout) -> per-node aggregate (the A of §3.3)
AggregateFn = Callable[[Graph, jax.Array, Optional["LY.GraphLayout"]], jax.Array]

AGGREGATORS = ("sum", "mean", "max", "min", "std", "var")

# the megakernel's aggregator set: the accumulators it materializes in
# VMEM scratch.  mean/std are *derived* in gamma from sum/sqsum and the
# plan's cached in-degree, so they never need their own accumulator.
FUSED_AGGREGATORS = ("sum", "sqsum", "max", "min", "wsum")
FUSED_PHIS = ("copy", "add_relu")
FUSED_GAMMAS = ("gcn", "gin", "pna", "dgn")
FUSED_PRECISIONS = ("fp32", "int8")


@dataclasses.dataclass(frozen=True)
class MPSpec:
    """Declarative (phi, A, gamma) layer contract for the fused megakernel.

    Where the closure form of :func:`mp_layer` *computes* phi and gamma,
    an ``MPSpec`` *names* them — a hashable static the Pallas kernel
    (``kernels/fused_mp.py``) compiles into one VMEM-resident pass:

      phi:        "copy" (message = gathered source operand) or
                  "add_relu" (GIN: relu(x_src + edge operand))
      ops:        accumulator tuple, subset of ``FUSED_AGGREGATORS``;
                  "wsum" weights each message by a per-edge operand
                  (DGN's directional w_e) before summing
      gamma:      node-update kind — "gcn" normalized self-loop add,
                  "gin" 2-layer MLP, "pna" scaler tower + skip,
                  "dgn" directional derivative + skip
      precision:  "fp32", or "int8" to run gamma's first linear as an
                  in-kernel W8A8 boundary (per-row dynamic quantize,
                  int32 accumulate, fused requant — the
                  ``quant.qconfig`` dynamic recipe, never leaving VMEM)

    The runtime operands a spec needs (weights, per-node/per-edge
    values) travel separately — see ``kernels/ref.fused_mp_ref`` for the
    operand contract.  Models that cannot lower to this set (GAT's edge
    softmax) keep the closure form and opt out of fusion.
    """

    phi: str = "copy"
    ops: tuple = ("sum",)
    gamma: str = "gcn"
    precision: str = "fp32"

    def __post_init__(self):
        if self.phi not in FUSED_PHIS:
            raise ValueError(f"unknown phi {self.phi!r}; expected {FUSED_PHIS}")
        bad = [op for op in self.ops if op not in FUSED_AGGREGATORS]
        if bad or not self.ops:
            raise ValueError(
                f"fused aggregators {self.ops!r} must be a non-empty subset "
                f"of {FUSED_AGGREGATORS}"
            )
        if self.gamma not in FUSED_GAMMAS:
            raise ValueError(
                f"unknown gamma {self.gamma!r}; expected {FUSED_GAMMAS}"
            )
        if self.precision not in FUSED_PRECISIONS:
            raise ValueError(
                f"unknown precision {self.precision!r}; "
                f"expected {FUSED_PRECISIONS}"
            )


def gather_scatter(
    graph: Graph,
    messages: jax.Array,
    ops: Sequence[str] = ("sum",),
    layout: Optional[LY.GraphLayout] = None,
    use_sorted: bool = True,
) -> jax.Array:
    """Reduce edge messages into per-destination aggregates.

    messages: (E_pad, F) raw per-edge values in COO order — **unmasked**;
    padding-edge rows are dropped by the plan's out-of-range ids (see the
    module-level masking contract).  Returns (N_pad, len(ops) * F) with
    aggregates concatenated feature-wise (PNA-style layout).

    With ``layout`` the messages are permuted once and every op reduces
    the shared sorted stream (zero sorts).  Without one, each op runs the
    seed per-call sort path — kept for parity tests and A/B benchmarks.
    """
    if layout is not None:
        msg_sorted = jnp.take(messages, layout.perm, axis=0)
        outs = [
            LY.segment_reduce(layout, msg_sorted, op, presorted=True)
            for op in ops
        ]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)
    dst = jnp.where(graph.edge_mask, graph.dst, graph.num_nodes)
    outs = []
    for op in ops:
        if use_sorted:
            outs.append(sg.sorted_segment_reduce(messages, dst, graph.num_nodes, op))
        else:
            outs.append(sg.segment_reduce(messages, dst, graph.num_nodes, op))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)


def mp_layer(
    graph: Graph,
    x: jax.Array,
    phi: Optional[PhiFn] = None,
    gamma: Optional[GammaFn] = None,
    ops: Sequence[str] = ("sum",),
    edge_feat: jax.Array | None = None,
    layout: Optional[LY.GraphLayout] = None,
    aggregate: Optional[AggregateFn] = None,
    spec: Optional[MPSpec] = None,
    operands: Optional[Dict[str, jax.Array]] = None,
    mode: str = "auto",
) -> jax.Array:
    """One full message-passing layer: scatter(phi) -> A -> gamma.

    Two forms share this entry point:

    * **closure form** (``phi``/``gamma`` callables): the unfused oracle
      path — gather, transform, reduce, update as separate XLA ops.
      ``aggregate`` overrides the default multi-op ``gather_scatter``
      when a model's A(.) is richer than a concatenation of standard
      reductions (PNA's scaled tower, DGN's directional derivative); it
      receives the shared ``layout`` so custom aggregators also sort
      zero times.
    * **spec form** (``spec`` + ``operands``): the declarative contract,
      dispatched to the fused megakernel (``kernels/ops.fused_mp``) —
      the whole layer runs as one VMEM-resident pass over the plan.
      Requires a ``layout``; ``operands`` follows
      ``kernels/ref.fused_mp_ref`` (msrc/x_res/nop/eop/ew/w1/b1/...).

    ``x``: (N_pad, F) current node embeddings.  Returns (N_pad, F').
    """
    if spec is not None:
        if layout is None:
            raise ValueError(
                "fused mp_layer (spec=...) requires a GraphLayout plan; "
                "pass layout= or use the closure form"
            )
        return kops.fused_mp(
            spec, layout.ids_sorted, layout.src_sorted, layout.in_degree,
            graph.node_mask, mode=mode, **operands,
        )
    e = graph.edge_feat if edge_feat is None else edge_feat
    x_src = jnp.take(x, graph.src, axis=0)
    x_dst = jnp.take(x, graph.dst, axis=0)
    messages = phi(x_src, x_dst, e)
    if aggregate is not None:
        agg = aggregate(graph, messages, layout)
    else:
        agg = gather_scatter(graph, messages, ops=ops, layout=layout)
    out = gamma(x, agg)
    return jnp.where(graph.node_mask[:, None], out, 0.0)


# ---------------------------------------------------------------------------
# PNA degree scalers (paper §4.3)
# ---------------------------------------------------------------------------


def pna_scalers(
    graph: Optional[Graph],
    avg_degree: float,
    degree: Optional[jax.Array] = None,
) -> jax.Array:
    """(N_pad, 3) scaler matrix [1, amplification, attenuation] of [21].

    ``avg_degree`` is the mean degree seen in training data (a model
    hyperparameter, not graph preprocessing).  ``degree`` takes the
    layout's cached in-degree; without it the count is recomputed from
    ``graph`` (identical integer sums either way).
    """
    if degree is None:
        degree = in_degree(graph)
    deg = degree.astype(jnp.float32)
    logd = jnp.log(deg + 1.0)
    log_davg = jnp.log(jnp.asarray(avg_degree) + 1.0)
    amp = logd / log_davg
    att = log_davg / jnp.maximum(logd, 1e-6)
    att = jnp.where(deg > 0, att, 0.0)
    return jnp.stack([jnp.ones_like(logd), amp, att], axis=-1)


def pna_aggregate(
    graph: Graph,
    messages: jax.Array,
    avg_degree: float,
    layout: Optional[LY.GraphLayout] = None,
) -> jax.Array:
    """Full PNA tower: 4 aggregators x 3 scalers -> (N_pad, 12*F).

    With a shared layout the four reductions consume one permuted message
    stream and the scalers come off the cached degree — zero sorts; the
    seed path re-sorted the same edges four times per layer.
    """
    agg = gather_scatter(
        graph, messages, ops=("mean", "std", "max", "min"), layout=layout
    )
    n, f4 = agg.shape
    if layout is not None and layout.pna_scalers is not None:
        scalers = layout.pna_scalers
    else:
        degree = layout.in_degree if layout is not None else None
        scalers = pna_scalers(graph, avg_degree, degree=degree)
    out = agg[:, None, :] * scalers[:, :, None]  # (N, 3, 4F)
    return out.reshape(n, 3 * f4)


# ---------------------------------------------------------------------------
# GAT attention aggregation (paper §4.2) — the declared fusion opt-out
# ---------------------------------------------------------------------------


def gat_attention(
    graph: Graph,
    logits: jax.Array,
    xp: jax.Array,
    layout: Optional[LY.GraphLayout] = None,
    mode: str = "auto",
) -> jax.Array:
    """GAT's A(.): per-destination softmax + attention-weighted sum.

    ``logits``: (E, H) COO-order attention logits; ``xp``: (N, H, F)
    projected per-head features.  Returns (N, H*F).  The softmax
    normalizer couples every edge of a destination *before* any message
    can be folded in, so this A(.) does not lower to the megakernel's
    accumulator set — GAT is the documented ``MPSpec`` opt-out, and its
    two segment kernels ride the shared plan here instead (zero sorts).
    """
    n = graph.num_nodes
    perm, ids_sorted, src_sorted = LY.edge_plan(layout, graph)
    alpha = kops.edge_softmax(
        logits, ids_sorted, n, mode=mode, perm=perm
    )  # (E, H) sorted
    msg = jnp.take(xp, src_sorted, axis=0) * alpha[:, :, None]
    h_f = xp.shape[1] * xp.shape[2]
    return kops.segment_reduce(
        msg.reshape(-1, h_f), ids_sorted, n, op="sum", mode=mode
    )


# ---------------------------------------------------------------------------
# DGN directional aggregation (paper §4.4)
# ---------------------------------------------------------------------------


def dgn_directional_weights(graph: Graph, eigvec: jax.Array):
    """-> (w_e (E,), wsum (N,)) directional weights from the eigenvector.

    w_ij = (phi_j - phi_i) / sum_k |phi_k - phi_i| per in-edge, plus the
    per-destination sum of weights.  The layout caches these
    (``core.layout.with_dgn_weights``); this is the plan-less fallback,
    bit-identical to the cached values.
    """
    dphi = jnp.take(eigvec, graph.src) - jnp.take(eigvec, graph.dst)  # (E,)
    dphi = jnp.where(graph.edge_mask, dphi, 0.0)
    denom = gather_scatter(graph, jnp.abs(dphi)[:, None], ops=("sum",))[:, 0]
    w_e = dphi / jnp.maximum(jnp.take(denom, graph.dst), 1e-6)
    wsum = gather_scatter(graph, w_e[:, None], ops=("sum",))[:, 0]
    return w_e, wsum


def dgn_aggregate(
    graph: Graph,
    messages: jax.Array,
    w_e: jax.Array,
    layout: Optional[LY.GraphLayout] = None,
) -> jax.Array:
    """DGN's A(.): [mean, w-weighted sum] -> (N, 2*F) concatenated.

    ``w_e`` is the (E,) COO-order directional weight vector; both
    reductions consume the one permuted message stream when a ``layout``
    is threaded (zero sorts).
    """
    mean_agg = gather_scatter(graph, messages, ops=("mean",), layout=layout)
    wx = gather_scatter(
        graph, messages * w_e[:, None], ops=("sum",), layout=layout
    )
    return jnp.concatenate([mean_agg, wx], axis=-1)


# ---------------------------------------------------------------------------
# Global graph pooling (graph-level tasks, paper §3.3)
# ---------------------------------------------------------------------------


def global_pool(
    graph: Graph,
    x: jax.Array,
    op: str = "mean",
    num_graphs: int | None = None,
) -> jax.Array:
    """Pool node embeddings per graph id -> (num_graphs, F).

    Uses the same segment machinery; graphs in a padded batch are segments.
    ``num_graphs`` is the static graph-slot count of the batch (the packed
    bucket's G_pad).  When omitted it falls back to the conservative
    ``num_nodes`` upper bound — every graph has at least one node — which
    keeps single-graph call sites working but makes the pooled buffer
    mostly padding; batch/packed callers should always pass the real count.
    (``graph_id`` is node-indexed and already ordered, so pooling never
    needs the edge plan — no sort here in any path.)
    """
    m = graph.num_nodes if num_graphs is None else num_graphs
    gid = jnp.where(graph.node_mask, graph.graph_id, m)
    xm = jnp.where(graph.node_mask[:, None], x, 0.0)
    return sg.segment_reduce(xm, gid, m, op)
