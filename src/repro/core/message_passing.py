"""Generic message-passing layer (paper §3.3, Fig. 2/3).

    x_i^{l+1} = gamma( x_i^l , A_{j in N(i)} ( phi(x_j^l, e_ij^l) ) )

The framework fixes the *dataflow* (gather messages along in-edges, reduce
per destination, transform per node) and models plug in:

  * ``phi``      message transformation, applied edge-parallel,
  * ``aggregate``one or more permutation-invariant reductions,
  * ``gamma``    node transformation (the "Node Embedding PE").

GenGNN's merged scatter-gather is realized by ``sorted_segment_reduce``:
messages fold into the O(N) destination buffer immediately, in sorted-edge
order — permutation invariance makes the order irrelevant (§3.4).
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import scatter_gather as sg
from repro.core.graph import Graph, in_degree

# phi(x_src, x_dst, e) -> message  (edge-parallel)
PhiFn = Callable[[jax.Array, jax.Array, jax.Array], jax.Array]
# gamma(x, aggregated) -> new x    (node-parallel)
GammaFn = Callable[[jax.Array, jax.Array], jax.Array]

AGGREGATORS = ("sum", "mean", "max", "min", "std", "var")


def gather_scatter(
    graph: Graph,
    messages: jax.Array,
    ops: Sequence[str] = ("sum",),
    use_sorted: bool = True,
) -> jax.Array:
    """Reduce edge messages into per-destination aggregates.

    messages: (E_pad, F) — already masked for padding edges by the caller
    (or rely on padding edges pointing at the sink node).
    Returns (N_pad, len(ops) * F) with aggregates concatenated feature-wise
    (PNA-style multi-aggregator layout).
    """
    msg = jnp.where(graph.edge_mask[:, None], messages, 0.0)
    dst = jnp.where(graph.edge_mask, graph.dst, graph.num_nodes)
    outs = []
    for op in ops:
        if use_sorted:
            outs.append(sg.sorted_segment_reduce(msg, dst, graph.num_nodes, op))
        else:
            outs.append(sg.segment_reduce(msg, dst, graph.num_nodes, op))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)


def mp_layer(
    graph: Graph,
    x: jax.Array,
    phi: PhiFn,
    gamma: GammaFn,
    ops: Sequence[str] = ("sum",),
    edge_feat: jax.Array | None = None,
) -> jax.Array:
    """One full message-passing layer: scatter(phi) -> A -> gamma.

    ``x``: (N_pad, F) current node embeddings.  Returns (N_pad, F').
    """
    e = graph.edge_feat if edge_feat is None else edge_feat
    x_src = jnp.take(x, graph.src, axis=0)
    x_dst = jnp.take(x, graph.dst, axis=0)
    messages = phi(x_src, x_dst, e)
    agg = gather_scatter(graph, messages, ops=ops)
    out = gamma(x, agg)
    return jnp.where(graph.node_mask[:, None], out, 0.0)


# ---------------------------------------------------------------------------
# PNA degree scalers (paper §4.3)
# ---------------------------------------------------------------------------


def pna_scalers(graph: Graph, avg_degree: float) -> jax.Array:
    """(N_pad, 3) scaler matrix [1, amplification, attenuation] of [21].

    ``avg_degree`` is the mean degree seen in training data (a model
    hyperparameter, not graph preprocessing).
    """
    deg = in_degree(graph).astype(jnp.float32)
    logd = jnp.log(deg + 1.0)
    log_davg = jnp.log(jnp.asarray(avg_degree) + 1.0)
    amp = logd / log_davg
    att = log_davg / jnp.maximum(logd, 1e-6)
    att = jnp.where(deg > 0, att, 0.0)
    return jnp.stack([jnp.ones_like(logd), amp, att], axis=-1)


def pna_aggregate(graph: Graph, messages: jax.Array, avg_degree: float) -> jax.Array:
    """Full PNA tower: 4 aggregators x 3 scalers -> (N_pad, 12*F)."""
    agg = gather_scatter(graph, messages, ops=("mean", "std", "max", "min"))
    n, f4 = agg.shape
    scalers = pna_scalers(graph, avg_degree)  # (N, 3)
    out = agg[:, None, :] * scalers[:, :, None]  # (N, 3, 4F)
    return out.reshape(n, 3 * f4)


# ---------------------------------------------------------------------------
# Global graph pooling (graph-level tasks, paper §3.3)
# ---------------------------------------------------------------------------


def global_pool(
    graph: Graph,
    x: jax.Array,
    op: str = "mean",
    num_graphs: int | None = None,
) -> jax.Array:
    """Pool node embeddings per graph id -> (num_graphs, F).

    Uses the same segment machinery; graphs in a padded batch are segments.
    ``num_graphs`` is the static graph-slot count of the batch (the packed
    bucket's G_pad).  When omitted it falls back to the conservative
    ``num_nodes`` upper bound — every graph has at least one node — which
    keeps single-graph call sites working but makes the pooled buffer
    mostly padding; batch/packed callers should always pass the real count.
    """
    m = graph.num_nodes if num_graphs is None else num_graphs
    gid = jnp.where(graph.node_mask, graph.graph_id, m)
    xm = jnp.where(graph.node_mask[:, None], x, 0.0)
    return sg.segment_reduce(xm, gid, m, op)
