"""Discrete-event simulator for the NE/MP pipeline strategies (paper §3.5).

The paper's Fig. 4/9 compares three schedules for the two processing
elements — Node Embedding (NE, fixed per-node cost) and Message Passing
(MP, cost proportional to out-degree):

  1. non-pipelined:  NE_i then MP_i, strictly sequential;
  2. fixed pipeline: depth-2 lockstep — NE_{i+1} overlaps MP_i, but the
     pair advances at the pace of the slower stage;
  3. streaming:      NE runs freely ahead into a bounded FIFO (depth Q);
     MP drains the FIFO — degree imbalance is absorbed until the FIFO
     fills/empties (paper uses Q = 10).

On TPU the *execution* answer is edge-parallel segment reduction (see
scatter_gather.py) — but the *scheduling study* is a contribution of the
paper and is reproduced here exactly, as a cycle-level model.  The same
model also reproduces the virtual-node experiment (Fig. 6): a VN is a node
whose degree is N-1, and the streaming schedule hides it if it is emitted
early.

Costs are in abstract cycles: t_NE = c_ne; t_MP(d) = c_mp0 + d * c_mp_edge.
Defaults are calibrated so NE and mean-MP are comparable, the regime the
paper's U50 implementation sits in (Fig. 9 shows pipelining gains shrink
once MP strictly dominates).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineCosts:
    c_ne: float = 16.0  # node-embedding cycles per node (MLP PE, fixed width)
    c_mp0: float = 2.0  # message-passing fixed overhead per node
    c_mp_edge: float = 4.0  # cycles per outgoing edge
    queue_depth: int = 10  # paper's FIFO depth

    def t_ne(self, n: int) -> np.ndarray:
        return np.full(n, self.c_ne, dtype=np.float64)

    def t_mp(self, degrees: np.ndarray) -> np.ndarray:
        return self.c_mp0 + degrees.astype(np.float64) * self.c_mp_edge


def makespan_non_pipelined(degrees: np.ndarray, costs: PipelineCosts) -> float:
    """Fig. 4(a): Sum_i (t_NE + t_MP(d_i))."""
    return float(np.sum(costs.t_ne(len(degrees)) + costs.t_mp(degrees)))


def makespan_fixed(degrees: np.ndarray, costs: PipelineCosts) -> float:
    """Fig. 4(b): depth-2 lockstep pipeline.

    Stage pair (NE_{i+1} || MP_i) completes in max(t_NE, t_MP(d_i));
    prologue = first NE, epilogue included in the final max term.
    """
    t_ne = costs.t_ne(len(degrees))
    t_mp = costs.t_mp(degrees)
    return float(t_ne[0] + np.sum(np.maximum(t_ne, t_mp)))


def makespan_streaming(degrees: np.ndarray, costs: PipelineCosts) -> float:
    """Fig. 4(c): bounded-FIFO decoupled pipeline (event-driven).

    NE emits node i at time ne_done[i] but stalls when the FIFO holds
    ``queue_depth`` not-yet-consumed nodes.  MP consumes in emission order.
    """
    n = len(degrees)
    t_ne = costs.t_ne(n)
    t_mp = costs.t_mp(degrees)
    q = costs.queue_depth
    ne_done = np.zeros(n)
    mp_done = np.zeros(n)
    ne_free = 0.0  # time NE engine becomes free
    for i in range(n):
        # back-pressure: slot available once node i-q left the FIFO
        gate = mp_done[i - q] if i >= q else 0.0
        start = max(ne_free, gate)
        ne_done[i] = start + t_ne[i]
        ne_free = ne_done[i]
        mp_start = max(ne_done[i], mp_done[i - 1] if i else 0.0)
        mp_done[i] = mp_start + t_mp[i]
    return float(mp_done[-1])


STRATEGIES = {
    "non": makespan_non_pipelined,
    "fixed": makespan_fixed,
    "streaming": makespan_streaming,
}


def simulate(degrees: np.ndarray, costs: PipelineCosts | None = None) -> dict:
    """Makespans + the three paper speed-up ratios for one graph."""
    costs = costs or PipelineCosts()
    ms = {k: fn(np.asarray(degrees), costs) for k, fn in STRATEGIES.items()}
    return {
        **ms,
        "fixed_over_non": ms["non"] / ms["fixed"],
        "streaming_over_fixed": ms["fixed"] / ms["streaming"],
        "streaming_over_non": ms["non"] / ms["streaming"],
    }


def random_degree_graph(
    rng: np.random.Generator,
    n: int,
    avg_degree: float,
    pct_large: float,
    large_factor: float = 8.0,
) -> np.ndarray:
    """Synthetic degree sequences matching the Fig. 9(a) sweep axes:
    average node degree x percentage of large-degree nodes."""
    n_large = int(round(n * pct_large))
    n_small = n - n_large
    # solve small-node mean so the overall mean stays avg_degree
    large_deg = avg_degree * large_factor
    small_mean = max((avg_degree * n - large_deg * n_large) / max(n_small, 1), 0.5)
    small = rng.poisson(small_mean, size=n_small)
    large = rng.poisson(large_deg, size=n_large)
    deg = np.concatenate([small, large])
    rng.shuffle(deg)
    return np.maximum(deg, 0)


def virtual_node_graph(
    rng: np.random.Generator, n: int, avg_degree: float, vn_position: str = "first"
) -> np.ndarray:
    """Degree sequence with one virtual node of degree n-1 (Fig. 6).

    ``vn_position``: "first" (paper's recommendation — emit the VN early so
    streaming hides it) or "last" (worst case).
    """
    deg = rng.poisson(avg_degree, size=n - 1)
    vn = np.array([n - 1])
    if vn_position == "first":
        return np.concatenate([vn, deg])
    return np.concatenate([deg, vn])
