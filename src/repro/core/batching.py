"""Multi-graph packing: many small graphs -> one padded ``Graph``.

GenGNN streams heterogeneous graphs through one generic engine; FlowGNN
(the successor) shows the throughput win comes from keeping that stream
*dense* — variable-size graphs are concatenated into a shared padded
buffer so one compiled program amortizes dispatch over many requests.

A ``BucketBudget`` is the static capacity of one packed program:
``(N_pad, E_pad, G_pad)`` — total node rows, total edge rows, and graph
slots.  ``pack_graphs`` concatenates raw COO graphs against a budget
(node ids shifted per graph, ``graph_id`` recording membership) and
returns the padded ``Graph`` plus a ``PackMeta`` that makes the unpack
side *exact*: per-graph outputs are recovered by slot (graph-level) or by
node-offset slicing (node-level), never by masking heuristics.

Everything here is host-side (numpy) construction — the packed ``Graph``
enters the jit boundary exactly like a single padded graph does, so the
engine's compiled buckets are reused across packed batches.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core import layout as LY

# a raw host graph: (senders, receivers, node_feat[, edge_feat])
RawGraph = tuple


@dataclasses.dataclass(frozen=True, order=True)
class BucketBudget:
    """Static capacity of one packed program (compiled-shape key)."""

    n_pad: int  # total padded node rows
    e_pad: int  # total padded edge rows
    g_pad: int  # graph slots (sizes the pooled / per-graph buffers)

    def admits(self, n_used: int, e_used: int, g_used: int,
               n: int, e: int) -> bool:
        """Would a graph of (n nodes, e edges) still fit?"""
        return (
            g_used + 1 <= self.g_pad
            and n_used + n <= self.n_pad
            and e_used + e <= self.e_pad
        )


@dataclasses.dataclass(frozen=True)
class PackMeta:
    """Exact bookkeeping for unpacking a packed batch.

    ``node_counts[i]`` / ``edge_counts[i]`` are graph i's real sizes;
    ``node_offsets`` are the cumulative starts, so graph i's nodes occupy
    rows [node_offsets[i], node_offsets[i+1]) of the packed arrays.
    """

    budget: BucketBudget
    node_counts: Tuple[int, ...]
    edge_counts: Tuple[int, ...]

    @property
    def num_graphs(self) -> int:
        return len(self.node_counts)

    @property
    def node_offsets(self) -> Tuple[int, ...]:
        return tuple(np.concatenate([[0], np.cumsum(self.node_counts)]))


def graph_sizes(raw: RawGraph) -> Tuple[int, int]:
    """(num_nodes, num_edges) of a raw COO tuple."""
    s, _, nf = raw[0], raw[1], raw[2]
    return nf.shape[0], s.shape[0]


def pack_graphs(graphs: Sequence[RawGraph], budget: BucketBudget) -> Tuple[G.Graph, PackMeta]:
    """Concatenate raw graphs into one padded ``Graph`` against ``budget``.

    Node ids are shifted per graph; padding edges point at the final padded
    node, which belongs to no real graph, so they never contaminate real
    aggregates (same invariant as single-graph padding).
    """
    if not graphs:
        raise ValueError("pack_graphs needs at least one graph")
    sizes = [graph_sizes(g) for g in graphs]
    n_tot = sum(n for n, _ in sizes)
    e_tot = sum(e for _, e in sizes)
    if len(graphs) > budget.g_pad or n_tot > budget.n_pad or e_tot > budget.e_pad:
        raise ValueError(
            f"pack of {len(graphs)} graphs ({n_tot} nodes, {e_tot} edges) "
            f"exceeds budget {budget}"
        )
    gs = [(g[0], g[1], g[2], g[3] if len(g) > 3 else None) for g in graphs]
    packed = G.batch_graphs(gs, n_pad=budget.n_pad, e_pad=budget.e_pad)
    meta = PackMeta(
        budget=budget,
        node_counts=tuple(n for n, _ in sizes),
        edge_counts=tuple(e for _, e in sizes),
    )
    return packed, meta


def pack_layout(packed: G.Graph) -> LY.GraphLayout:
    """Emit the packed batch's ``GraphLayout`` plan at pack time.

    Host-side ``np.argsort(kind="stable")`` over the same masked keys the
    device path uses, so the plan is bit-identical to one built on device
    — but the compiled forward program that receives it contains **zero**
    sort ops (the paper's convert-once-at-ingest, §3.4).  The scheduler
    calls this right after :func:`pack_graphs` and hands the plan through
    ``GNNEngine.infer_packed`` alongside the batch.
    """
    return LY.host_layout(packed)


def pack_prepared(
    graphs: Sequence[RawGraph],
    budget: BucketBudget,
    eigvecs: Optional[Sequence[np.ndarray]] = None,
    with_layout: bool = True,
    stage: bool = False,
):
    """Pack raw graphs and emit the whole pack-time payload as one
    ``serve.executor.PreparedBatch``: padded graph, packed eigenvectors,
    host-built ``GraphLayout`` plan, bucket key and warm signature.

    This is the packed mode's *prepare* stage, run at pack time so the
    compiled flush program receives everything ready-made (zero on-device
    sorts; the paper's convert-once-at-ingest, §3.4).  Returns
    ``(prepared, meta)`` — ``meta`` is the exact unpack bookkeeping.

    ``stage=True`` additionally ``jax.device_put``s the prepared pytree —
    the pipelined prepare worker uses this so the H2D copy for flush k+1
    happens while the device runs flush k, off the dispatch critical
    path (``PreparedBatch`` is a registered pytree; its static metadata
    rides along untouched).
    """
    import jax  # deferred with the executor import below

    from repro.serve import executor as X  # deferred: serve imports core

    packed, meta = pack_graphs(graphs, budget)
    eig = None
    if eigvecs is not None:
        eig = jnp.asarray(pack_eigvecs(eigvecs, meta), jnp.float32)
    layout = pack_layout(packed) if with_layout else None
    prep = X.prepared(
        packed, eig, layout,
        ("packed", budget.n_pad, budget.e_pad, budget.g_pad), budget.g_pad,
    )
    if stage:
        prep = jax.device_put(prep)
    return prep, meta


def pack_eigvecs(eigvecs: Sequence[np.ndarray], meta: PackMeta) -> np.ndarray:
    """Concatenate per-graph node vectors (e.g. DGN's Laplacian eigenvector)
    into the packed (N_pad,) layout; padding rows are zero."""
    out = np.zeros((meta.budget.n_pad,), np.float32)
    off = 0
    for vec, n in zip(eigvecs, meta.node_counts):
        out[off : off + n] = np.asarray(vec, np.float32)[:n]
        off += n
    return out


def unpack_outputs(
    outputs: np.ndarray,
    meta: PackMeta,
    level: str = "graph",
) -> List[np.ndarray]:
    """Exact inverse of packing for model outputs.

    ``level="graph"``: outputs is (G_pad, F) — slot i belongs to graph i.
    ``level="node"``: outputs is (N_pad, F) — slice by node offsets.
    Returns one array per real graph; padding slots/rows are dropped.
    """
    outputs = np.asarray(outputs)
    if level == "graph":
        return [outputs[i : i + 1] for i in range(meta.num_graphs)]
    if level == "node":
        offs = meta.node_offsets
        return [outputs[offs[i] : offs[i + 1]] for i in range(meta.num_graphs)]
    raise ValueError(f"unknown level {level!r}; expected 'graph' or 'node'")
