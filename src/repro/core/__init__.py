"""GenGNN core: graph representation, scatter-gather, message passing.

The paper's primary contribution as composable JAX modules.
"""
from repro.core.graph import (
    Graph,
    CSRGraph,
    coo_to_compressed,
    from_numpy,
    batch_graphs,
    in_degree,
    out_degree,
)
from repro.core.message_passing import (
    mp_layer,
    gather_scatter,
    global_pool,
    pna_aggregate,
    pna_scalers,
    AGGREGATORS,
)
from repro.core.batching import (
    BucketBudget,
    PackMeta,
    pack_graphs,
    pack_layout,
    pack_eigvecs,
    unpack_outputs,
)
from repro.core.layout import (
    GraphLayout,
    build_layout,
    host_layout,
    ensure_layout,
)
from repro.core.scatter_gather import (
    segment_reduce,
    sorted_segment_reduce,
    sort_by_segment,
    rank_within_segment,
    dispatch_to_slots,
    combine_from_slots,
)

__all__ = [
    "Graph",
    "CSRGraph",
    "coo_to_compressed",
    "from_numpy",
    "batch_graphs",
    "in_degree",
    "out_degree",
    "BucketBudget",
    "PackMeta",
    "pack_graphs",
    "pack_layout",
    "pack_eigvecs",
    "unpack_outputs",
    "GraphLayout",
    "build_layout",
    "host_layout",
    "ensure_layout",
    "mp_layer",
    "gather_scatter",
    "global_pool",
    "pna_aggregate",
    "pna_scalers",
    "AGGREGATORS",
    "segment_reduce",
    "sorted_segment_reduce",
    "sort_by_segment",
    "rank_within_segment",
    "dispatch_to_slots",
    "combine_from_slots",
]
