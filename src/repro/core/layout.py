"""The shared graph-layout plan: one sort per graph, reused everywhere.

The paper's central dataflow claim (§3.4) is that the COO edge stream is
converted to a destination-ordered layout **once per graph** and that every
layer of every model then consumes the converted form.  Before this module
the conversion was re-derived inside each aggregation call: GCN/GIN sorted
once per layer, PNA/DGN four-plus times per layer (one per aggregator /
weighted reduce), and GAT ran its own per-layer sort for the edge softmax —
5 to 20+ O(E log E) device sorts per forward pass over a graph whose edge
order never changes.

``GraphLayout`` is that conversion reified as a pytree:

  * ``perm``        (E_pad,) int32 — stable argsort of the masked
                    destination ids (padding edges carry key ``N_pad`` and
                    sort to the end).  This is the CSC permutation.
  * ``ids_sorted``  (E_pad,) int32 — destination ids in sorted order;
                    padding rows hold ``N_pad`` (out of range), which JAX
                    segment ops *drop* — validity is encoded in the ids, so
                    downstream consumers never re-mask message values.
  * ``offsets``     (N_pad+1,) int32 — per-destination row offsets
                    (searchsorted over ``ids_sorted``); the CSC offset
                    array a future blocked Pallas aggregation kernel needs.
  * ``src_sorted``  (E_pad,) int32 — source ids in sorted-edge order
                    (GAT gathers its messages with this directly).
  * ``in_degree``   (N_pad,) int32 — real-edge in-degree (exact integer
                    counts; feeds GCN norms and PNA scalers).

plus lazily-attached **model-static derivatives** — values that depend only
on the graph (and, for DGN, its eigenvector input), not on the layer:

  * ``gcn_inv_sqrt``  GCN's 1/sqrt(d+1) symmetric norm,
  * ``pna_scalers``   PNA's (N, 3) [identity, amplification, attenuation],
  * ``dgn_w_e`` / ``dgn_denom`` / ``dgn_wsum``  DGN's directional weights
    computed once from the eigenvector instead of once per layer.

``build_layout`` is the ONLY place in the repository that runs the
on-device edge sort for the message-passing path (enforced by
``tools/check_no_raw_sort.py``); ``host_layout`` is its bit-identical
numpy twin used by ``core.batching`` so a packed batch's plan is emitted
at pack time and the compiled forward program contains **zero** sorts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core import scatter_gather as sg


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphLayout:
    """Destination-ordered edge plan for one (possibly packed) ``Graph``.

    Core fields are always present; derivative fields default to ``None``
    and are attached by the ``with_*`` helpers (attachment is idempotent,
    so "ensure" calls are free once the value exists).  The whole object
    is a pytree and crosses jit boundaries like any other model input.
    """

    perm: jax.Array  # (E_pad,) int32 CSC permutation into COO arrays
    ids_sorted: jax.Array  # (E_pad,) int32 dst ids, padding == N_pad
    offsets: jax.Array  # (N_pad+1,) int32 per-destination row offsets
    src_sorted: jax.Array  # (E_pad,) int32 src ids in sorted-edge order
    in_degree: jax.Array  # (N_pad,) int32 real-edge in-degree
    # -- model-static derivatives (lazily attached) --
    gcn_inv_sqrt: Optional[jax.Array] = None  # (N_pad,) f32
    pna_scalers: Optional[jax.Array] = None  # (N_pad, 3) f32
    dgn_w_e: Optional[jax.Array] = None  # (E_pad,) f32 directional weights
    dgn_denom: Optional[jax.Array] = None  # (N_pad,) f32 |dphi| in-sums
    dgn_wsum: Optional[jax.Array] = None  # (N_pad,) f32 per-dst sum of w_e

    @property
    def num_nodes(self) -> int:
        return self.in_degree.shape[0]

    @property
    def num_edges(self) -> int:
        return self.perm.shape[0]


# ---------------------------------------------------------------------------
# construction — the one sort
# ---------------------------------------------------------------------------


def build_layout(graph: G.Graph) -> GraphLayout:
    """On-device plan construction: the single O(E log E) sort per forward.

    Equivalent to the per-call ``sort_by_segment(masked_dst, N)`` every
    aggregation used to run privately — same masked keys, same stable
    argsort — so consuming the shared plan is bitwise-identical to the
    seed per-call-sort path (asserted by tests/test_layout_parity.py).
    """
    n = graph.num_nodes
    dst = jnp.where(graph.edge_mask, graph.dst, n)
    perm, ids_sorted, offsets = sg.sort_by_segment(dst, n)
    return GraphLayout(
        perm=perm,
        ids_sorted=ids_sorted,
        offsets=offsets,
        src_sorted=jnp.take(graph.src, perm),
        in_degree=G.in_degree(graph),
    )


def host_layout(graph: G.Graph) -> GraphLayout:
    """Numpy twin of :func:`build_layout` for pack-time plan emission.

    ``np.argsort(kind="stable")`` over the identical int32 keys yields the
    identical permutation to the device path, so a host-built plan drops
    into the compiled program without changing a single bit of output —
    while removing the last on-device sort from the packed forward.
    """
    n = graph.num_nodes
    edge_mask = np.asarray(graph.edge_mask)
    dst = np.where(edge_mask, np.asarray(graph.dst), n).astype(np.int32)
    src = np.asarray(graph.src).astype(np.int32)
    perm = np.argsort(dst, kind="stable").astype(np.int32)
    ids_sorted = dst[perm]
    offsets = np.searchsorted(
        ids_sorted, np.arange(n + 1, dtype=np.int32), side="left"
    ).astype(np.int32)
    deg = np.zeros((n,), np.int32)
    np.add.at(deg, np.asarray(graph.dst)[edge_mask], 1)
    return GraphLayout(
        perm=jnp.asarray(perm),
        ids_sorted=jnp.asarray(ids_sorted),
        offsets=jnp.asarray(offsets),
        src_sorted=jnp.asarray(src[perm]),
        in_degree=jnp.asarray(deg),
    )


def ensure_layout(layout: Optional[GraphLayout], graph: G.Graph) -> GraphLayout:
    """Return ``layout`` if supplied (0 sorts) else build it (1 sort)."""
    return build_layout(graph) if layout is None else layout


# ---------------------------------------------------------------------------
# sorted-plan consumption
# ---------------------------------------------------------------------------


def edge_plan(
    layout: Optional[GraphLayout], graph: G.Graph
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(perm, ids_sorted, src_sorted) — from the plan, or freshly sorted.

    The ``layout is None`` branch reproduces the seed per-call-sort path
    exactly (used by the parity tests and the A/B benchmark); every
    production call site passes a layout and performs zero sorts.
    """
    if layout is not None:
        return layout.perm, layout.ids_sorted, layout.src_sorted
    n = graph.num_nodes
    dst = jnp.where(graph.edge_mask, graph.dst, n)
    perm, ids_sorted, _ = sg.sort_by_segment(dst, n)
    return perm, ids_sorted, jnp.take(graph.src, perm)


def segment_reduce(
    layout: GraphLayout,
    values: jax.Array,
    op: str = "sum",
    presorted: bool = False,
) -> jax.Array:
    """Reduce per-edge ``values`` (COO order) into per-destination rows.

    Gathers through ``perm`` (``presorted=True`` skips the gather when the
    caller already holds sorted values) and reduces with
    ``indices_are_sorted=True``.  Padding edges carry id ``N_pad`` which
    JAX segment ops drop — no value masking happens or is needed here;
    that is the plan's masking contract (see core/message_passing.py).
    """
    vals = values if presorted else jnp.take(values, layout.perm, axis=0)
    return sg.segment_reduce(
        vals, layout.ids_sorted, layout.num_nodes, op, indices_are_sorted=True
    )


# ---------------------------------------------------------------------------
# model-static derivatives (lazy, idempotent, zero sorts)
# ---------------------------------------------------------------------------


def with_gcn_norms(layout: GraphLayout) -> GraphLayout:
    """Attach GCN's symmetric norm 1/sqrt(d_in + 1) (self-loop folded in)."""
    if layout.gcn_inv_sqrt is not None:
        return layout
    deg = layout.in_degree.astype(jnp.float32) + 1.0
    return dataclasses.replace(layout, gcn_inv_sqrt=jax.lax.rsqrt(deg))


def with_pna_scalers(layout: GraphLayout, avg_degree: float) -> GraphLayout:
    """Attach PNA's (N, 3) [identity, amplification, attenuation] scalers."""
    if layout.pna_scalers is not None:
        return layout
    from repro.core import message_passing as mp

    scalers = mp.pna_scalers(None, avg_degree, degree=layout.in_degree)
    return dataclasses.replace(layout, pna_scalers=scalers)


def with_dgn_weights(
    layout: GraphLayout, graph: G.Graph, eigvec: jax.Array
) -> GraphLayout:
    """Attach DGN's directional weights, computed once from the eigenvector.

    w_ij = (phi_j - phi_i) / sum_k |phi_k - phi_i| per in-edge, plus the
    per-destination |dphi| normalizer and sum of weights — all three were
    recomputed by every DGN layer (two extra sorted reduces per layer).
    """
    if layout.dgn_w_e is not None:
        return layout
    dphi = jnp.take(eigvec, graph.src) - jnp.take(eigvec, graph.dst)
    dphi = jnp.where(graph.edge_mask, dphi, 0.0)
    denom = segment_reduce(layout, jnp.abs(dphi)[:, None], op="sum")[:, 0]
    w_e = dphi / jnp.maximum(jnp.take(denom, graph.dst), 1e-6)
    wsum = segment_reduce(layout, w_e[:, None], op="sum")[:, 0]
    return dataclasses.replace(
        layout, dgn_w_e=w_e, dgn_denom=denom, dgn_wsum=wsum
    )


def for_model(
    layout: Optional[GraphLayout],
    graph: G.Graph,
    model: str,
    avg_degree: float = 1.0,
    eigvec: Optional[jax.Array] = None,
) -> GraphLayout:
    """Ensure the plan exists and carries ``model``'s static derivatives.

    At most one sort (zero when ``layout`` was supplied); the derivative
    attachment is pure arithmetic over the cached degree / permutation.
    """
    layout = ensure_layout(layout, graph)
    if model == "gcn":
        layout = with_gcn_norms(layout)
    elif model == "pna":
        layout = with_pna_scalers(layout, avg_degree)
    elif model == "dgn" and eigvec is not None:
        layout = with_dgn_weights(layout, graph, eigvec)
    return layout
