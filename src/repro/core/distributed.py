"""Multi-chip sharded message passing — the large-graph extension (§4.6) at scale.

The paper stores node/message buffers in DRAM and hides latency with a
prefetcher when a graph exceeds on-chip memory.  At TPU-pod scale the
analogous limit is a graph exceeding one chip's HBM, and the answer is
*node sharding*: node rows are partitioned across a mesh axis and messages
whose source and destination live on different shards are exchanged with
collectives.  Zero preprocessing is preserved — edge routing is computed on
device from the raw COO stream.

Two exchange strategies (both built on core.scatter_gather):

  * ``allgather_mp``  — all-gather node embeddings, compute local edges'
    messages locally, reduce into local destinations.  Comm = O(N*F) per
    layer; simple and bandwidth-optimal for dense-ish graphs.
  * ``alltoall_mp``   — GenGNN's merged scatter-gather lifted to chip level:
    each shard sorts its edges by destination shard, packs messages into
    per-destination capacity slots (dispatch_to_slots), exchanges with a
    single all-to-all, and folds received messages into its local O(N/P)
    aggregate buffer.  Comm = O(E/P * F) — wins when E/P << N.

Both run inside ``shard_map`` over one mesh axis and are exercised by the
multi-pod dry-run as well as by an 8-virtual-device integration test.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import scatter_gather as sg


def _local_segment_sum(messages, dst_local, n_local):
    return sg.segment_reduce(messages, dst_local, n_local, "sum")


def allgather_mp_local(
    x_local: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    edge_mask: jax.Array,
    phi: Callable[[jax.Array], jax.Array],
    axis_name: str,
) -> jax.Array:
    """Per-shard body: all-gather x, aggregate messages for local dst rows.

    x_local: (N/P, F). src/dst: (E/P,) *global* node ids of local edges.
    Returns (N/P, F') aggregated messages for this shard's nodes.
    """
    n_local = x_local.shape[0]
    idx = jax.lax.axis_index(axis_name)
    x_global = jax.lax.all_gather(x_local, axis_name, axis=0, tiled=True)
    msgs = phi(jnp.take(x_global, src, axis=0))
    msgs = jnp.where(edge_mask[:, None], msgs, 0.0)
    dst_local = dst - idx * 0  # dst is global; map into local frame below
    # Edges may target any shard; keep only this shard's destinations and
    # psum-scatter the rest?  No: each edge is owned by exactly one shard,
    # but its destination may be remote.  Route by segment-reducing into the
    # *global* frame and reduce-scattering rows back to their owners.
    agg_global = sg.segment_reduce(msgs, dst, n_local * jax.lax.axis_size(axis_name), "sum")
    agg_local = jax.lax.psum_scatter(agg_global, axis_name, scatter_dimension=0, tiled=True)
    del dst_local
    return agg_local


def alltoall_mp_local(
    x_local: jax.Array,
    src_local: jax.Array,
    dst: jax.Array,
    edge_mask: jax.Array,
    phi: Callable[[jax.Array], jax.Array],
    axis_name: str,
    capacity: int,
) -> jax.Array:
    """Per-shard body for the all-to-all exchange.

    Assumes edges live on the shard that owns their *source* (CSR ownership,
    which is free: the producer of a message owns it — exactly the paper's
    scatter side).  src_local: (E/P,) local row ids; dst: (E/P,) global ids.

    capacity: max messages any (src-shard -> dst-shard) pair may carry per
    layer; overflow drops (GShard semantics) — sized by the caller from the
    degree distribution, and asserted in tests.
    """
    p = jax.lax.axis_size(axis_name)
    n_local = x_local.shape[0]
    msgs = phi(jnp.take(x_local, src_local, axis=0))
    msgs = jnp.where(edge_mask[:, None], msgs, 0.0)
    dst_shard = dst // n_local
    # carry destination-local row id alongside the payload so the receiver
    # can fold messages into its O(N/P) buffer (merged scatter-gather).
    payload = jnp.concatenate([msgs, (dst % n_local).astype(msgs.dtype)[:, None]], axis=-1)
    slots, _, _ = sg.dispatch_to_slots(
        payload, dst_shard, p, capacity, valid=edge_mask
    )  # (P, capacity, F+1)
    received = jax.lax.all_to_all(slots, axis_name, split_axis=0, concat_axis=0, tiled=True)
    rmsg = received[..., :-1].reshape(p * capacity, -1)
    rdst = received[..., -1].reshape(p * capacity).astype(jnp.int32)
    # zero-payload slots reduce harmlessly into row 0
    return sg.segment_reduce(rmsg, rdst, n_local, "sum")


def make_sharded_mp(
    mesh, axis: str, phi: Callable, strategy: str = "allgather", capacity: int = 0
):
    """Build a shard_map-wrapped message-passing aggregate step.

    Returns fn(x, src, dst, edge_mask) -> (N, F') with x sharded on axis 0
    and edges sharded on axis 0 (ownership: 'allgather' -> any shard,
    'alltoall' -> source shard, src given shard-locally).
    """
    if strategy == "allgather":
        body = partial(allgather_mp_local, phi=phi, axis_name=axis)
        in_specs = (P(axis, None), P(axis), P(axis), P(axis))
    elif strategy == "alltoall":
        if capacity <= 0:
            raise ValueError("alltoall strategy requires capacity > 0")
        body = partial(
            alltoall_mp_local, phi=phi, axis_name=axis, capacity=capacity
        )
        in_specs = (P(axis, None), P(axis), P(axis), P(axis))
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return jax.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=P(axis, None), check_vma=False
    )
