"""Deprecation shim — the sharded message-passing collectives moved to
``repro.runtime.partitioning`` (same functions, now built on the
version-portable ``repro.runtime.compat.shard_map``)."""
from __future__ import annotations

import warnings

from repro.runtime.partitioning import (  # noqa: F401
    allgather_mp_local,
    alltoall_mp_local,
    make_sharded_mp,
)

warnings.warn(
    "repro.core.distributed is deprecated; import from repro.runtime instead",
    DeprecationWarning,
    stacklevel=2,
)
