"""Graph data representation (paper §3.2).

GenGNN takes *raw COO edge streams* with zero host-side preprocessing and
converts to CSR/CSC *on device*, once per graph, reused across all layers.
This module is the TPU/JAX analogue: every conversion below is pure-jnp,
jit-compatible, and runs on the accelerator.

Static shapes: real-time streams contain graphs of varying size, so graphs
are padded to bucketed (N_pad, E_pad) capacities (recompilation happens per
bucket, not per graph). ``node_mask`` / ``edge_mask`` distinguish real
entries; padding edges point at a dedicated sink node (the last padded row)
so they never contaminate real aggregates.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """A (possibly batched, padded) graph in COO form.

    Attributes:
      node_feat:  (N_pad, F) float node features.
      edge_index: (2, E_pad) int32; row 0 = src, row 1 = dst.
      edge_feat:  (E_pad, D) float edge features (D may be 0).
      node_mask:  (N_pad,) bool, True for real nodes.
      edge_mask:  (E_pad,) bool, True for real edges.
      graph_id:   (N_pad,) int32 graph membership for batched pooling.
      n_graph:    () int32 number of real graphs in the batch.
    """

    node_feat: jax.Array
    edge_index: jax.Array
    edge_feat: jax.Array
    node_mask: jax.Array
    edge_mask: jax.Array
    graph_id: jax.Array
    n_graph: jax.Array

    @property
    def num_nodes(self) -> int:
        return self.node_feat.shape[0]

    @property
    def num_edges(self) -> int:
        return self.edge_index.shape[1]

    @property
    def src(self) -> jax.Array:
        return self.edge_index[0]

    @property
    def dst(self) -> jax.Array:
        return self.edge_index[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Adjacency in compressed form, produced on device from COO.

    ``order="csr"``: edges sorted by src (out-edges contiguous per node),
    the layout required by the paper's merged scatter-gather (§3.4).
    ``order="csc"``: edges sorted by dst (in-edges contiguous), the layout
    for the gather-only variant.  ``perm`` maps sorted-edge position ->
    original COO position so edge features can be gathered lazily.
    """

    offsets: jax.Array  # (N_pad + 1,) int32 row offsets
    perm: jax.Array  # (E_pad,) int32 permutation into COO arrays
    src_sorted: jax.Array  # (E_pad,) int32
    dst_sorted: jax.Array  # (E_pad,) int32
    degree: jax.Array  # (N_pad,) int32 out-degree (csr) / in-degree (csc)


def _segment_starts_to_offsets(ids_sorted: jax.Array, num_segments: int) -> jax.Array:
    """Row offsets from sorted segment ids via searchsorted (O(N log E))."""
    probe = jnp.arange(num_segments + 1, dtype=ids_sorted.dtype)
    return jnp.searchsorted(ids_sorted, probe, side="left").astype(jnp.int32)


@partial(jax.jit, static_argnames=("order",))
def coo_to_compressed(graph: Graph, order: str = "csr") -> CSRGraph:
    """On-device COO -> CSR/CSC conversion (paper's on-chip converter).

    Runs once per streamed graph; the result is reused by every GNN layer.
    Stable sort keeps deterministic edge order for reproducibility.
    Padding edges carry key ``N_pad`` and therefore sort to the end.
    """
    n_pad = graph.num_nodes
    key_row = 0 if order == "csr" else 1
    keys = jnp.where(graph.edge_mask, graph.edge_index[key_row], n_pad)
    perm = jnp.argsort(keys, stable=True).astype(jnp.int32)
    src_sorted = jnp.take(graph.edge_index[0], perm)
    dst_sorted = jnp.take(graph.edge_index[1], perm)
    keys_sorted = jnp.take(keys, perm)
    offsets = _segment_starts_to_offsets(keys_sorted, n_pad)
    degree = (offsets[1:] - offsets[:-1]).astype(jnp.int32)
    return CSRGraph(
        offsets=offsets,
        perm=perm,
        src_sorted=src_sorted,
        dst_sorted=dst_sorted,
        degree=degree,
    )


def in_degree(graph: Graph) -> jax.Array:
    """(N_pad,) in-degree over real edges (on device)."""
    ones = graph.edge_mask.astype(jnp.int32)
    return jax.ops.segment_sum(ones, graph.dst, num_segments=graph.num_nodes)


def out_degree(graph: Graph) -> jax.Array:
    ones = graph.edge_mask.astype(jnp.int32)
    return jax.ops.segment_sum(ones, graph.src, num_segments=graph.num_nodes)


# ---------------------------------------------------------------------------
# Host-side construction helpers (test/data-pipeline use; not in the jit path)
# ---------------------------------------------------------------------------


def from_numpy(
    senders: np.ndarray,
    receivers: np.ndarray,
    node_feat: np.ndarray,
    edge_feat: Optional[np.ndarray] = None,
    n_pad: Optional[int] = None,
    e_pad: Optional[int] = None,
) -> Graph:
    """Build a single padded ``Graph`` from raw COO numpy arrays."""
    n = node_feat.shape[0]
    e = senders.shape[0]
    n_pad = n_pad or n
    e_pad = e_pad or e
    if n_pad < n or e_pad < e:
        raise ValueError(f"padding too small: ({n_pad},{e_pad}) < ({n},{e})")
    f = node_feat.shape[1]
    d = 0 if edge_feat is None else edge_feat.shape[1]
    nf = np.zeros((n_pad, f), dtype=node_feat.dtype)
    nf[:n] = node_feat
    ef = np.zeros((e_pad, max(d, 1)), dtype=np.float32)
    if edge_feat is not None:
        ef[:e, :d] = edge_feat
    ei = np.full((2, e_pad), n_pad - 1 if n_pad > n else 0, dtype=np.int32)
    ei[0, :e] = senders
    ei[1, :e] = receivers
    node_mask = np.arange(n_pad) < n
    edge_mask = np.arange(e_pad) < e
    graph_id = np.where(node_mask, 0, 0).astype(np.int32)
    return Graph(
        node_feat=jnp.asarray(nf),
        edge_index=jnp.asarray(ei),
        edge_feat=jnp.asarray(ef),
        node_mask=jnp.asarray(node_mask),
        edge_mask=jnp.asarray(edge_mask),
        graph_id=jnp.asarray(graph_id),
        n_graph=jnp.asarray(1, dtype=jnp.int32),
    )


def batch_graphs(graphs: list, n_pad: int, e_pad: int) -> Graph:
    """Pack a list of small host graphs into one padded batch (jraph-style).

    Node ids are shifted per graph; padding edges point at the final padded
    node which belongs to no real graph.  This is the TPU-efficient serving
    mode; batch-size-1 streaming (the paper's real-time mode) is the special
    case of a single graph per batch.
    """
    nfs, eis, efs, gids = [], [], [], []
    offset = 0
    for gi, g in enumerate(graphs):
        s, r, nf, ef = g
        nfs.append(nf)
        eis.append(np.stack([s + offset, r + offset]))
        efs.append(ef if ef is not None else np.zeros((len(s), 1), np.float32))
        gids.append(np.full((nf.shape[0],), gi, np.int32))
        offset += nf.shape[0]
    n = offset
    e = sum(x.shape[1] for x in eis)
    if n_pad < n or e_pad < e:
        raise ValueError(f"padding too small: ({n_pad},{e_pad}) < ({n},{e})")
    f = nfs[0].shape[1]
    d = efs[0].shape[1]
    nf = np.zeros((n_pad, f), np.float32)
    nf[:n] = np.concatenate(nfs)
    ei = np.full((2, e_pad), n_pad - 1, np.int32)
    ei[:, :e] = np.concatenate(eis, axis=1)
    ef = np.zeros((e_pad, d), np.float32)
    ef[:e] = np.concatenate(efs)
    gid = np.full((n_pad,), len(graphs), np.int32)  # padding -> out-of-range id
    gid[:n] = np.concatenate(gids)
    return Graph(
        node_feat=jnp.asarray(nf),
        edge_index=jnp.asarray(ei),
        edge_feat=jnp.asarray(ef),
        node_mask=jnp.asarray(np.arange(n_pad) < n),
        edge_mask=jnp.asarray(np.arange(e_pad) < e),
        graph_id=jnp.asarray(gid),
        n_graph=jnp.asarray(len(graphs), np.int32),
    )
