"""Deprecation shim — this module moved to ``repro.runtime.partitioning``.

External imports (``from repro import sharding``, ``from repro.sharding
import logical_constraint``) keep working; new code should import from
``repro.runtime`` instead.
"""
from __future__ import annotations

import warnings

from repro.runtime.partitioning import (  # noqa: F401
    DEFAULT_RULES,
    active_rules,
    batch_rules,
    fsdp_rules,
    gnn_rules,
    logical_constraint,
    resolve_spec,
    tree_shardings,
    tree_specs,
    zero1_rules,
    zero1_spec,
)
from repro.runtime.partitioning import _ACTIVE_RULES  # noqa: F401

warnings.warn(
    "repro.sharding is deprecated; import from repro.runtime instead",
    DeprecationWarning,
    stacklevel=2,
)
