"""Logical-axis -> mesh-axis resolution with divisibility-aware fallback.

Model code annotates every parameter/cache dimension with a *logical* axis
name (params.Param).  This module turns those names into physical
PartitionSpecs for a given mesh via a rules table, enforcing:

  * a mesh axis is used at most once per tensor,
  * a dim is only sharded if its size divides evenly,
  * multi-axis rules (("pod","data") for batch) use the largest prefix
    that divides.

This is how e.g. Mixtral's 8 experts on a 16-way model axis fall back
gracefully: "experts" fails the divisibility check, and the d_ff dim picks
up the model axis instead (classic TP-within-expert) with no per-model
special cases.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro import params as P

# Candidate mesh axes per logical axis, in priority order.  A tuple value
# means "use jointly" (e.g. batch over pod x data); a list means
# "try alternatives in order".
DEFAULT_RULES: Dict[Optional[str], tuple] = {
    "batch": ("pod", "data"),
    "seq": (),
    "kv_seq": (),  # overridden to ("data",) for seq-sharded long decode
    "vocab": ("model",),
    "embed": (),
    "embed_out": (),
    "heads": ("model",),
    "heads_flat": ("model",),
    "kv_heads": ("model",),
    # head_dim stays unsharded: when kv_heads < TP width the KV projection
    # is REPLICATED (Megatron convention).  Sharding head_dim instead
    # measurably triggers involuntary GSPMD rematerialization at the
    # repeat_kv boundary (full replication + 650 GB/dev temps).
    "head_dim": (),
    "mlp": ("model",),
    "experts": ("model",),
    # MoE slot tensors: batch-rows axis used by the expert-GEMM constraint;
    # defaults to the batch mapping, overridden by hybrid FSDP+EP rules
    "moe_batch": ("pod", "data"),
    "inner": ("model",),  # mamba d_inner
    "state": (),
    "q_lora": (),
    "kv_lora": (),
    "layers": (),
    None: (),
}


def resolve_spec(
    axes: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    mesh: Mesh,
    rules: Dict[Optional[str], tuple] | None = None,
) -> PartitionSpec:
    """Map one tensor's logical axes to a PartitionSpec under ``mesh``."""
    rules = rules or DEFAULT_RULES
    used: set = set()
    spec = []
    for dim, name in zip(shape, axes):
        cands = rules.get(name, ())
        chosen: list = []
        prod = 1
        for ax in cands:
            if ax not in mesh.shape or ax in used:
                continue
            nx = mesh.shape[ax]
            if dim % (prod * nx) == 0:
                chosen.append(ax)
                prod *= nx
        if chosen:
            used.update(chosen)
            spec.append(tuple(chosen) if len(chosen) > 1 else chosen[0])
        else:
            spec.append(None)
    return PartitionSpec(*spec)


def tree_shardings(param_tree, mesh: Mesh, rules=None):
    """Param tree -> matching tree of NamedShardings."""

    def f(p: P.Param):
        shape = p.value.shape
        return NamedSharding(mesh, resolve_spec(p.axes, shape, mesh, rules))

    return jax.tree.map(f, param_tree, is_leaf=P.is_param)


def tree_specs(param_tree, mesh: Mesh, rules=None):
    def f(p: P.Param):
        return resolve_spec(p.axes, p.value.shape, mesh, rules)

    return jax.tree.map(f, param_tree, is_leaf=P.is_param)


def batch_rules(mesh: Mesh, batch: int, seq_shard: bool = False) -> dict:
    """Shape-aware rules for activations/caches.

    When the global batch cannot cover the data axis (long-context decode,
    batch=1), shard the KV-cache *sequence* dimension over data instead —
    sequence parallelism for the cache (DESIGN.md §8).
    """
    rules = dict(DEFAULT_RULES)
    dp = int(np.prod([mesh.shape.get(a, 1) for a in ("pod", "data")]))
    if batch % dp != 0 or seq_shard:
        rules["batch"] = ()
        rules["kv_seq"] = ("data",)
    return rules


def fsdp_rules(mesh: Mesh, batch: int) -> dict:
    """FSDP-style preset: data parallelism over BOTH mesh axes, parameters
    sharded over the model axis (GSPMD all-gathers each layer's weights at
    use — ZeRO-3 semantics).

    Napkin math vs Megatron-TP at global batch 256 on 16x16 (per device):
      TP:   ~6 activation all-reduces/layer x (B/dp x S x D) — O(10 s)
      FSDP: param all-gather 3x params_bytes/model_axis + grad
            reduce-scatter — O(1-4 s) for 4-30B dense models
    and the replicated-attention memory problem (MLA, 40 heads) vanishes
    because attention is sequence-local at batch-per-device <= 1.
    """
    rules = dict(DEFAULT_RULES)
    rules["batch"] = ("pod", "data", "model")
    rules["moe_batch"] = ("pod", "data", "model")  # pure FSDP: forcing EP
    # inside this layout was measured at 469 s of resharding (H2, refuted)
    rules["embed"] = ("model",)  # weight matrices: shard the embed dim
    rules["kv_seq"] = ()
    return rules


def zero1_spec(spec: PartitionSpec, shape, mesh: Mesh, axis: str = "data") -> PartitionSpec:
    """ZeRO-1: shard an optimizer-moment tensor over ``axis`` on its first
    dim that is unsharded and divisible — on top of whatever sharding the
    parameter already has.  Moments are only touched by the (local)
    optimizer update, so this costs one reduce-scatter/all-gather pair of
    the *gradients*, which GSPMD inserts at the update boundary."""
    if axis not in mesh.shape:
        return spec
    used = set()
    for s in spec:
        if s is None:
            continue
        used.update(s if isinstance(s, tuple) else (s,))
    if axis in used:
        return spec
    n = mesh.shape[axis]
    out = list(spec)
    for i, (dim, s) in enumerate(zip(shape, spec)):
        if s is None and dim % n == 0:
            out[i] = axis
            return PartitionSpec(*out)
    return spec


def zero1_rules(base_rules: dict) -> dict:
    """ZeRO-1-style optimizer-state sharding: moments additionally shard
    their first unsharded dim over the data axis (applied to m/v only)."""
    rules = dict(base_rules)
    for name in ("embed", "layers"):
        if not rules.get(name):
            rules[name] = ("data",)
    return rules


import contextlib
import contextvars

_ACTIVE_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_rules", default=None
)


@contextlib.contextmanager
def active_rules(rules: dict):
    """Install shape-aware rules for logical_constraint (set by launchers
    together with ``jax.set_mesh``)."""
    token = _ACTIVE_RULES.set(rules)
    try:
        yield
    finally:
        _ACTIVE_RULES.reset(token)


def logical_constraint(x, axes: Tuple[Optional[str], ...]):
    """with_sharding_constraint via logical axes.

    No-op unless a mesh is installed with ``jax.set_mesh`` (so CPU tests
    and single-device runs are untouched).  Used at activation boundaries
    where GSPMD's propagation otherwise *replicates compute* instead of
    inserting a collective — measured 8-16x per-device FLOPs inflation on
    the MoE expert GEMM (EXPERIMENTS.md §Perf).
    """
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty or mesh.size == 1:
        return x
    rules = _ACTIVE_RULES.get() or DEFAULT_RULES
    spec = resolve_spec(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, spec)
