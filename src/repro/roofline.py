"""Three-term roofline analysis from compiled dry-run artifacts.

  compute term    = per-device HLO FLOPs / peak FLOP/s per chip
  memory term     = per-device HLO bytes accessed / HBM bandwidth
  collective term = per-device wire bytes (ring-cost model) / link bandwidth

cost_analysis() on this JAX/XLA build reports **per-device** post-SPMD
flops/bytes (verified empirically in DESIGN.md §7), so no further division
by chip count is applied.  Collective bytes are parsed from the compiled
HLO: per-device result shapes with op-specific ring-cost multipliers

  all-gather       bytes x (g-1)/g          (result = gathered size)
  all-reduce       2 x bytes x (g-1)/g      (reduce-scatter + all-gather)
  reduce-scatter   bytes x (g-1)             (result = shard size)
  all-to-all       bytes x (g-1)/g
  collective-permute  bytes

Hardware model (TPU v5e-like, from the brief): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI; cross-pod (DCI) modeled at 25 GB/s.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCI_BW = 25e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s+(?P<result>[^=]+?)\s+(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> List[dict]:
    """Stream the HLO text; one record per collective op instance."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        if "-done" in line.split("=", 1)[1][:120] and m.group("op") + "-done(" in line:
            continue  # -done returns the buffer already counted at -start
        op = m.group("op")
        dts = [dt for dt, _ in _SHAPE_RE.findall(m.group("result"))]
        rbytes = _shape_bytes(m.group("result"))
        g = None
        mb = _GROUPS_BRACE_RE.search(line)
        if mb:
            g = len(mb.group(1).split(","))
        else:
            mi = _GROUPS_IOTA_RE.search(line)
            if mi:
                g = int(mi.group(2))  # [num_groups, group_size]
        g = g or 1
        if g <= 1:
            wire = 0.0
        elif op == "all-reduce":
            wire = 2.0 * rbytes * (g - 1) / g
        elif op == "all-gather":
            wire = rbytes * (g - 1) / g
        elif op == "reduce-scatter":
            wire = float(rbytes) * (g - 1)
        elif op == "all-to-all":
            wire = rbytes * (g - 1) / g
        else:  # collective-permute
            wire = float(rbytes)
        out.append({
            "op": op, "result_bytes": rbytes, "group_size": g,
            "wire_bytes": wire, "dtype": dts[0] if dts else "?",
        })
    return out


def bf16_normalization_correction(colls: List[dict], model_dtype_bf16: bool) -> List[dict]:
    """The CPU backend's FloatNormalization pass legalizes bf16 by
    computing (and communicating) in f32 — verified on the dry-run HLO:
    even forward bf16 matmul outputs appear as f32.  A TPU build keeps
    these in bf16, so large f32 collectives are halved here.  Small f32
    reductions (loss scalars, norms) are left untouched (<64 MB cutoff);
    genuinely-f32 payloads (optimizer moments are updated locally, not
    communicated) do not appear as large collectives in these programs.
    Both raw and corrected values are recorded in the dry-run JSON."""
    if not model_dtype_bf16:
        return colls
    corrected = []
    for c in colls:
        c2 = dict(c)
        if c["dtype"] == "f32" and c["result_bytes"] > 64e6:
            c2["wire_bytes"] = c["wire_bytes"] / 2
            c2["bf16_corrected"] = True
        corrected.append(c2)
    return corrected


def summarize_collectives(colls: List[dict]) -> dict:
    summary: Dict[str, dict] = {}
    for c in colls:
        s = summary.setdefault(c["op"], {"count": 0, "wire_bytes": 0.0})
        s["count"] += 1
        s["wire_bytes"] += c["wire_bytes"]
    return summary


def collective_seconds(colls: List[dict], pod_group_size: Optional[int] = None) -> float:
    """Ring-cost seconds; groups of ``pod_group_size`` (the pod axis) are
    costed at DCI bandwidth."""
    t = 0.0
    for c in colls:
        bw = DCI_BW if (pod_group_size and c["group_size"] == pod_group_size) else ICI_BW
        t += c["wire_bytes"] / bw
    return t


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE)
# ---------------------------------------------------------------------------


def active_param_count(params_tree) -> float:
    """Non-embedding parameter count with MoE experts scaled by
    activation fraction (top_k / num_experts), derived from logical axes."""
    from repro import params as P

    total = 0.0

    def visit(p):
        nonlocal total
        if "vocab" in p.axes:
            return  # embedding / lm head (excluded by the 6ND convention)
        size = float(np.prod(p.value.shape))
        total += size

    import jax

    jax.tree.map(visit, params_tree, is_leaf=P.is_param)
    return total


def model_flops(cfg, params_tree, tokens: float, kind: str) -> float:
    """6·N·D for training, 2·N·D for inference (per the convention), with
    MoE expert params scaled to the active fraction."""
    from repro import params as P
    import jax

    total = 0.0
    frac = (
        cfg.experts_per_token / cfg.num_experts if cfg.num_experts else 1.0
    )

    def visit(p):
        nonlocal total
        if "vocab" in p.axes:
            return
        size = float(np.prod(p.value.shape))
        if "experts" in p.axes:
            size *= frac
        total += size

    jax.tree.map(visit, params_tree, is_leaf=P.is_param)
    mult = 6.0 if kind == "train" else 2.0
    return mult * total * tokens


# ---------------------------------------------------------------------------
# cell-level roofline
# ---------------------------------------------------------------------------


def cell_roofline(record: dict) -> dict:
    """record: one dry-run JSON record.  Returns the three terms + verdict.

    Two memory estimates are reported (DESIGN.md §7):
      * ``memory_s_hlo`` — cost_analysis "bytes accessed" / HBM_bw.  The
        CPU backend's HLO is barely fused, so every elementwise
        intermediate round-trips; on a TPU build most of that traffic
        fuses away.  This is a loose *upper* bound.
      * ``memory_s`` (used for the verdict) — buffer-assignment estimate:
        (arguments + outputs + 2 x temps) / HBM_bw: every argument read
        once, output written once, each live temporary written + read.
        This tracks fused-TPU HBM traffic far more closely.
    """
    flops = record["flops_per_device"]
    bytes_hlo = record["bytes_per_device"]
    mem = record.get("memory", {})
    bytes_fused = (
        mem.get("argument_bytes", 0)
        + mem.get("output_bytes", 0)
        + 2 * mem.get("temp_bytes", 0)
    )
    colls = record.get("collectives_corrected") or record["collectives"]
    pod_gs = 2 if record.get("multi_pod") else None
    t_c = flops / PEAK_FLOPS
    t_m_hlo = bytes_hlo / HBM_BW
    t_m = (bytes_fused / HBM_BW) if bytes_fused else t_m_hlo
    t_n = collective_seconds(colls, pod_group_size=pod_gs)
    dominant = max((("compute", t_c), ("memory", t_m), ("collective", t_n)), key=lambda kv: kv[1])
    bound = dominant[0]
    step_t = max(t_c, t_m, t_n)  # perfectly-overlapped lower bound
    out = {
        "compute_s": t_c,
        "memory_s": t_m,
        "memory_s_hlo": t_m_hlo,
        "collective_s": t_n,
        "bound": bound,
        "step_lower_bound_s": step_t,
        "roofline_fraction": (t_c / step_t) if step_t > 0 else 0.0,
    }
    if record.get("model_flops_per_device"):
        out["useful_flops_ratio"] = record["model_flops_per_device"] / max(flops, 1.0)
    return out
