"""Quantization schemes and the quantized-parameter representation.

GenGNN's on-FPGA arithmetic is entirely ``ap_fixed`` — the paper's word
length W and integer width I are the precision knob of the whole design.
This module gives the JAX reproduction two reduced-precision schemes:

  * ``"int8"``  — W8A8: per-channel symmetric weights, int8 activations,
    int8 x int8 -> int32 accumulate with one fused f32 requantize tail
    (``kernels/quant_mlp.py`` is the MXU kernel, ``kernels/ref.py`` the
    oracle).  Activations come in two modes: ``act_mode="dynamic"``
    (default) computes a per-row — per-node — scale on device, the
    per-token W8A8 recipe production int8 serving uses, and needs no
    calibration; ``act_mode="static"`` uses one calibrated per-tensor
    affine scale (observers + zero-point folded into the bias +
    SmoothQuant-style migration of hot columns into the weights), the
    FPGA-faithful fixed-scale regime.
  * ``"fixed"`` — ``ap_fixed<W,I>`` *emulation* matching the paper's knob:
    weights and activations are snapped to the 2^(I-W) grid with
    saturation, the matmul runs in f32 (standing in for the paper's wide
    fixed-point accumulator), and the output is snapped again.

A quantized linear layer is a ``QuantizedLinear`` pytree node; the model
library (``gnn/layers.linear_apply``) dispatches on it, so a transformed
param tree runs through all six GNN models and every engine mode with no
model-specific code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

SCHEMES = ("int8", "fixed")


@dataclasses.dataclass(frozen=True)
class QConfig:
    """One quantization recipe (the engine's ``precision`` resolves to one).

    scheme:       "int8" | "fixed"
    act_mode:     int8 activation scales: "dynamic" (per-row, computed on
                  device, no calibration) | "static" (per-tensor, from
                  calibration observers)
    granularity:  weight scale granularity, "per_channel" | "per_tensor"
    observer:     static-mode range estimator, "minmax" | "percentile".
                  minmax is the default: GNN sum-aggregates have heavy
                  tails that carry real signal, and clipping them
                  (percentile) measurably hurts logit error here.
    percentile:   absolute-value percentile for the percentile observer
    asymmetric_acts:  static mode: affine (zero-point) activation
                  quantization for one-sided (post-relu) ranges; the
                  zero-point never reaches the kernel — its correction
                  term is folded into the bias at transform time.
    smooth_alpha: static mode: SmoothQuant migration strength for skewed
                  activation columns (folded into the weights).  0
                  disables.
    word_bits/int_bits:  the ap_fixed<W,I> knob (scheme="fixed")
    skip:         top-level param-tree keys kept in fp32.  The prediction
                  head stays fp32 by default (the classic first/last-layer
                  rule: logits are the most sign-sensitive tensor and the
                  head is a negligible share of FLOPs).
    """

    scheme: str = "int8"
    act_mode: str = "dynamic"
    granularity: str = "per_channel"
    observer: str = "minmax"
    percentile: float = 99.9
    asymmetric_acts: bool = True
    smooth_alpha: float = 0.25
    word_bits: int = 16
    int_bits: int = 6
    skip: Tuple[str, ...] = ("head",)

    def __post_init__(self):
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown scheme {self.scheme!r}; expected {SCHEMES}")
        if self.act_mode not in ("dynamic", "static"):
            raise ValueError(f"unknown act_mode {self.act_mode!r}")
        if self.granularity not in ("per_channel", "per_tensor"):
            raise ValueError(f"unknown granularity {self.granularity!r}")
        if not 1 <= self.int_bits < self.word_bits:
            raise ValueError(
                f"ap_fixed<{self.word_bits},{self.int_bits}> needs "
                f"1 <= int_bits < word_bits"
            )


@dataclasses.dataclass(frozen=True)
class QuantizedLinear:
    """A quantized ``{"w", "b"}`` linear layer (pytree node).

    int8 dynamic: w_q int8 (K, N); w_scale f32 (N,) or (); activation
           scales are computed per row at run time (x_scale/x_zero/
           x_premul unused: 1 / 0 / 1); b f32.
    int8 static:  x_scale f32 () and x_zero f32 () from calibration
           (zero-point; its matmul correction is pre-folded into ``b``);
           x_premul f32 (K,) or () SmoothQuant per-column divisor (1 when
           disabled); b f32 effective bias.
    fixed: w_q f32 (K, N) snapped to the ap_fixed grid; w_scale/x_scale
           hold the grid LSB 2^(I-W); b snapped f32; x_premul/x_zero
           unused (1 / 0).
    """

    w_q: Any
    w_scale: Any
    b: Any
    x_scale: Any
    x_premul: Any = 1.0
    x_zero: Any = 0.0
    scheme: str = "int8"
    act_mode: str = "dynamic"
    word_bits: int = 16
    int_bits: int = 6

    @property
    def shape(self):
        return self.w_q.shape


jax.tree_util.register_pytree_node(
    QuantizedLinear,
    lambda q: ((q.w_q, q.w_scale, q.b, q.x_scale, q.x_premul, q.x_zero),
               (q.scheme, q.act_mode, q.word_bits, q.int_bits)),
    lambda aux, kids: QuantizedLinear(*kids, *aux),
)


# ---------------------------------------------------------------------------
# scheme arithmetic
# ---------------------------------------------------------------------------

# Also the int8-dynamic contract for the fused megakernel: when a
# QuantizedLinear lowers into ``kernels.ops.fused_mp`` (via
# ``gnn.layers.fused_linear_operands``) the kernel re-implements the
# dynamic recipe below — ``rs = max(rowmax|x|, _EPS) / 127`` — inside its
# gamma tail, so ``kernels/ref._ROW_EPS`` and ``kernels/fused_mp._ROW_EPS``
# must equal this constant (tests/test_fused_mp.py pins the three).
_EPS = 1e-8


def symmetric_scale(lo, hi, qmax: int = 127):
    """Symmetric range -> positive quantization step (elementwise-safe)."""
    bound = jnp.maximum(jnp.abs(jnp.asarray(lo)), jnp.abs(jnp.asarray(hi)))
    return jnp.maximum(bound, _EPS) / float(qmax)


def quantize_int8(x: jax.Array, scale, zero=0.0) -> jax.Array:
    """Round-to-nearest affine int8 with saturation (zero=0 -> symmetric)."""
    q = jnp.round(x.astype(jnp.float32) / scale) + zero
    return jnp.clip(q, -128.0, 127.0).astype(jnp.int8)


def affine_act_params(lo, hi, asymmetric: bool):
    """-> (x_scale, x_zero) for the activation quantizer.

    Asymmetric (zero-point) quantization maps [lo, hi] onto the full 256
    levels — but only when the range is mostly one-sided (post-relu
    inputs), where it doubles the resolution.  For roughly symmetric
    ranges it is applied as symmetric: the resolution gain is nil there,
    while the exact-fit range clips harder on under-calibrated tails (the
    symmetric form keeps headroom on the narrow side).
    """
    lo = float(min(lo, 0.0))
    hi = float(max(hi, 0.0))
    one_sided = (-lo <= 0.25 * hi) or (hi <= 0.25 * -lo)
    if asymmetric and one_sided:
        scale = max(hi - lo, _EPS) / 255.0
        zero = -128.0 - round(lo / scale)
        return scale, zero
    return float(symmetric_scale(lo, hi)), 0.0


def dequantize_int8(x_q: jax.Array, scale) -> jax.Array:
    return x_q.astype(jnp.float32) * scale


def fixed_round(x: jax.Array, word_bits: int, int_bits: int) -> jax.Array:
    """Snap to the ap_fixed<W,I> grid: LSB 2^(I-W), saturating range
    [-2^(I-1), 2^(I-1) - LSB] (I includes the sign bit, as in HLS)."""
    lsb = 2.0 ** (int_bits - word_bits)
    qmax = 2.0 ** (word_bits - 1) - 1.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / lsb), -(qmax + 1.0), qmax)
    return q * lsb


def quantize_weight(w: jax.Array, qcfg: QConfig):
    """-> (w_q, w_scale) under ``qcfg`` (weights need no observer: their
    range is known exactly at transform time)."""
    if qcfg.scheme == "fixed":
        lsb = jnp.float32(2.0 ** (qcfg.int_bits - qcfg.word_bits))
        return fixed_round(w, qcfg.word_bits, qcfg.int_bits), lsb
    axis = 0 if qcfg.granularity == "per_channel" else None
    bound = jnp.max(jnp.abs(w), axis=axis)
    scale = jnp.maximum(bound, _EPS) / 127.0
    return quantize_int8(w, scale), scale.astype(jnp.float32)


# ---------------------------------------------------------------------------
# the quantized forward (dispatched from gnn/layers.linear_apply)
# ---------------------------------------------------------------------------


def quantized_linear(q: QuantizedLinear, x: jax.Array,
                     activation: str = "none", mode: str = "auto") -> jax.Array:
    """Forward one quantized linear layer: f32 in, f32 out.

    int8 dynamic: compute the per-row (per-node) scale on device —
    exact-range symmetric quantization per row, requantized by the
    (row_scale x w_scale) outer product in the kernel's fused tail.
    int8 static: apply the SmoothQuant per-column divisor, quantize with
    the calibrated static (scale, zero-point), requantize by
    ``x_scale * w_scale`` — the zero-point correction is already folded
    into ``q.b``, so the kernel never sees it.  fixed: snap input to the
    grid, run the fp32 NE PE (the wide accumulator), snap the output.
    """
    if q.scheme == "fixed":
        x_f = fixed_round(x, q.word_bits, q.int_bits)
        y = ops.node_mlp(x_f, q.w_q, q.b, activation=activation, mode=mode)
        return fixed_round(y, q.word_bits, q.int_bits)
    if q.act_mode == "dynamic":
        rs = jnp.maximum(
            jnp.max(jnp.abs(x), axis=1, keepdims=True), _EPS
        ).astype(jnp.float32) / 127.0
        x_q = quantize_int8(x, rs)
        return ops.quant_node_mlp(
            x_q, q.w_q, q.w_scale.astype(jnp.float32), q.b,
            activation=activation, row_scale=rs, mode=mode,
        )
    x_q = quantize_int8(x * q.x_premul, q.x_scale, q.x_zero)
    scale = (q.x_scale * q.w_scale).astype(jnp.float32)
    return ops.quant_node_mlp(x_q, q.w_q, scale, q.b,
                              activation=activation, mode=mode)
