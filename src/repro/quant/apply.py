"""Model-agnostic quantization transform: calibrate a trained param tree,
then swap every eligible ``{"w", "b"}`` linear for a ``QuantizedLinear``.

The transform operates purely on the parameter pytree — it never looks at
model structure.  Eligibility is structural (a dict with a 2-D ``w`` and a
``b``, exactly what ``gnn/layers.linear_init`` emits), activation ranges
come from the calibration hook (``observers.collecting`` around an eager
forward pass), and the quantized tree drops into the same
``models.apply`` / ``GNNEngine`` code paths because
``gnn/layers.linear_apply`` dispatches on the node type.  That is what
makes one transform cover all six GNN models and every serving mode.

    qparams, report = quantize_model(params, cfg, calib_graphs)
    out = models.apply(qparams, graph, cfg)          # runs int8

The same transformed tree also drives the fused megakernel: under
``models.apply(..., fused=True)`` each layer body probes its
``QuantizedLinear`` nodes through ``gnn.layers.fused_linear_operands`` —
int8-dynamic trees lower their gamma matmul *into*
``kernels.ops.fused_mp`` (quantize -> int8 MXU accumulate -> requant in
the kernel tail), while int8-static and "fixed" trees return ``None``
there and keep the unfused path.  Nothing in this module branches on
fusion: one transform, both lowerings.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.data.pipeline import laplacian_eigvec
from repro.gnn import models as M
from repro.quant import observers as O
from repro.quant import qconfig as Q


@dataclasses.dataclass(frozen=True)
class QuantReport:
    """What the transform did: audit trail for tests/benches."""

    quantized: int  # linears swapped for QuantizedLinear
    kept_fp32: int  # linears left alone (skip-listed or uncalibrated)
    skipped_paths: Tuple[str, ...]
    uncalibrated_paths: Tuple[str, ...]
    scheme: str


def _is_linear(node) -> bool:
    return (
        isinstance(node, dict)
        and "w" in node
        and "b" in node
        and getattr(node["w"], "ndim", 0) == 2
    )


def calibrate(
    params: dict,
    cfg: M.GNNConfig,
    graphs: Sequence[tuple],
    qcfg: Optional[Q.QConfig] = None,
    eigvecs: Optional[Sequence[np.ndarray]] = None,
) -> O.Collector:
    """Run an eager forward pass per calibration graph with the collection
    hook active; returns the filled Collector (weight-id -> observer).

    ``graphs`` are raw COO tuples ``(senders, receivers, node_feat,
    edge_feat)``; DGN's eigenvector inputs are computed here when not
    supplied (host-side, like the data pipeline does).
    """
    qcfg = qcfg or Q.QConfig()
    collector = O.Collector(
        lambda: O.make_observer(qcfg.observer, qcfg.percentile)
    )
    with O.collecting(collector):
        for i, g in enumerate(graphs):
            s, r, nf, ef = g[:4]
            gp = G.from_numpy(s, r, nf, ef)
            eig = None
            if cfg.model == "dgn":
                eig = (np.asarray(eigvecs[i], np.float32)[: nf.shape[0]]
                       if eigvecs is not None
                       else laplacian_eigvec(s, r, nf.shape[0]))
                eig = jax.numpy.asarray(eig)
            M.apply(params, gp, cfg, eigvec=eig, num_graphs=1)
    return collector


def _quantize_dynamic_linear(w, b, qcfg: Q.QConfig) -> Q.QuantizedLinear:
    """One linear -> int8 ``QuantizedLinear`` with dynamic (per-row,
    on-device) activation scales — no calibration statistics needed."""
    w_q, w_scale = Q.quantize_weight(w, qcfg)
    return Q.QuantizedLinear(
        w_q=w_q, w_scale=w_scale, b=b.astype(jnp.float32),
        x_scale=jnp.float32(1.0), scheme="int8", act_mode="dynamic",
    )


def _quantize_int8_linear(w, b, obs, qcfg: Q.QConfig) -> Q.QuantizedLinear:
    """One calibrated linear -> static-activation int8 ``QuantizedLinear``.

    Three standard tricks compose here, all resolved at transform time so
    the runtime kernel stays a pure int8 matmul + one f32 tail:

      1. SmoothQuant-style migration (``smooth_alpha``): activation
         column k is divided by ``s_k = colabs_k^a / wrowmax_k^(1-a)``
         and the factor is multiplied into weight row k before
         quantizing — hot activation columns (GNN sum-aggregates have
         heavy tails) stop dictating the per-tensor activation step.
         Applied only when the columns are genuinely skewed
         (max/median >= ``_SMOOTH_SKEW``): rescaling rows costs
         weight-quantization accuracy (weight scales are per *output*
         channel), a net loss for homogeneous activations.
      2. Asymmetric activations: post-relu inputs use all 256 levels.
      3. Zero-point folding: ``sum_k (x_q - zp) s_x w_q s_w`` expands to
         ``s_x s_w (acc - zp * colsum(w_q))``; the correction is a
         per-output-channel constant folded into the bias.
    """
    w_np = np.asarray(w, np.float32)
    col = obs.col_range() if hasattr(obs, "col_range") else None
    alpha = qcfg.smooth_alpha
    skewed = False
    if alpha > 0.0 and col is not None and col[0].shape[0] == w_np.shape[0]:
        colmin, colmax = col
        colabs = np.maximum(np.maximum(np.abs(colmin), np.abs(colmax)), _EPS)
        skewed = float(colabs.max() / np.median(colabs)) >= _SMOOTH_SKEW
    if skewed:
        wrowmax = np.maximum(np.abs(w_np).max(axis=1), _EPS)
        s = np.maximum(colabs ** alpha / wrowmax ** (1.0 - alpha), _EPS)
        x_premul = jnp.asarray((1.0 / s).astype(np.float32))
        lo = float((colmin / s).min())
        hi = float((colmax / s).max())
        w_eff = jnp.asarray(w_np * s[:, None])
    else:
        x_premul = jnp.float32(1.0)
        lo, hi = obs.range()
        w_eff = w
    w_q, w_scale = Q.quantize_weight(w_eff, qcfg)
    x_scale, x_zero = Q.affine_act_params(lo, hi, qcfg.asymmetric_acts)
    # fold the zero-point matmul correction into the bias
    colsum = jnp.sum(w_q.astype(jnp.int32), axis=0).astype(jnp.float32)
    b_eff = (b.astype(jnp.float32)
             - (x_scale * x_zero) * w_scale.astype(jnp.float32) * colsum)
    return Q.QuantizedLinear(
        w_q=w_q, w_scale=w_scale, b=b_eff,
        x_scale=jnp.float32(x_scale), x_premul=x_premul,
        x_zero=jnp.float32(x_zero), scheme="int8", act_mode="static",
    )


_EPS = 1e-6
_SMOOTH_SKEW = 8.0  # hottest column >= this x median before migration pays


def quantize_params(
    params: dict,
    collector: Optional[O.Collector],
    qcfg: Q.QConfig,
) -> Tuple[dict, QuantReport]:
    """Swap calibrated linears for ``QuantizedLinear`` nodes.

    Top-level keys in ``qcfg.skip`` stay fp32 (default: the prediction
    head).  Static-activation int8 linears that were never exercised
    during calibration also stay fp32 (recorded in the report) —
    correctness first.  The "fixed" scheme and dynamic-activation int8
    need no activation statistics, so they never leave a layer behind.
    """
    skipped: List[str] = []
    uncalibrated: List[str] = []
    counts = {"q": 0, "fp32": 0}

    def transform(node, path):
        if _is_linear(node):
            if path and path[0] in qcfg.skip:
                skipped.append("/".join(path))
                counts["fp32"] += 1
                return node
            w, b = node["w"], node["b"]
            if qcfg.scheme == "fixed":
                w_q, lsb = Q.quantize_weight(w, qcfg)
                counts["q"] += 1
                return Q.QuantizedLinear(
                    w_q=w_q, w_scale=lsb,
                    b=Q.fixed_round(b, qcfg.word_bits, qcfg.int_bits),
                    x_scale=lsb, scheme="fixed",
                    word_bits=qcfg.word_bits, int_bits=qcfg.int_bits,
                )
            if qcfg.act_mode == "dynamic":
                counts["q"] += 1
                return _quantize_dynamic_linear(w, b, qcfg)
            obs = (collector.observers.get(id(w))
                   if collector is not None else None)
            if obs is None or getattr(obs, "count", 0) == 0:
                uncalibrated.append("/".join(path))
                counts["fp32"] += 1
                return node
            counts["q"] += 1
            return _quantize_int8_linear(w, b, obs, qcfg)
        if isinstance(node, dict):
            return {k: transform(v, path + (str(k),)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            seq = [transform(v, path + (str(i),)) for i, v in enumerate(node)]
            return type(node)(seq) if isinstance(node, tuple) else seq
        return node

    qparams = transform(params, ())
    report = QuantReport(
        quantized=counts["q"],
        kept_fp32=counts["fp32"],
        skipped_paths=tuple(skipped),
        uncalibrated_paths=tuple(uncalibrated),
        scheme=qcfg.scheme,
    )
    return qparams, report


def quantize_model(
    params: dict,
    cfg: M.GNNConfig,
    calib_graphs: Sequence[tuple],
    qcfg: Optional[Q.QConfig] = None,
    eigvecs: Optional[Sequence[np.ndarray]] = None,
) -> Tuple[dict, QuantReport]:
    """Calibrate (when the scheme needs it) + transform in one call —
    what ``GNNEngine`` uses."""
    qcfg = qcfg or Q.QConfig()
    collector = None
    if qcfg.scheme == "int8" and qcfg.act_mode == "static":
        collector = calibrate(params, cfg, calib_graphs, qcfg, eigvecs=eigvecs)
    return quantize_params(params, collector, qcfg)


def precision_qconfig(precision: str) -> Q.QConfig:
    """Map an engine/CLI ``precision`` name to its default QConfig."""
    if precision == "int8":
        return Q.QConfig(scheme="int8", act_mode="dynamic")
    if precision == "int8-static":
        return Q.QConfig(scheme="int8", act_mode="static")
    if precision == "fixed":
        return Q.QConfig(scheme="fixed")
    raise ValueError(
        f"unknown precision {precision!r}; expected "
        "fp32|int8|int8-static|fixed"
    )
