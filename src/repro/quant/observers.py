"""Range calibration: observers + the forward-pass collection hook.

Static quantization needs one activation range per linear layer.  The
models route every dense transform through ``gnn/layers.linear_apply``,
which reports each layer's input here whenever a ``Collector`` is active
— so calibration is one eager forward pass per calibration graph, with
zero model-specific code.  Layers are keyed by the identity of their
weight array (stable within one param tree), which is how the transform
in ``quant/apply.py`` finds each layer's observer afterwards.

Observers:
  * ``MinMaxObserver``     — running min/max over every update.
  * ``PercentileObserver`` — symmetric absolute-value percentile over a
    bounded reservoir of samples; clips the outlier tail that would
    otherwise stretch the int8 step (the usual fix when a handful of
    activations dominate the range).
"""
from __future__ import annotations

import contextlib
from typing import Callable, Dict, Optional, Tuple

import jax
import numpy as np


class _ColumnStats:
    """Signed per-feature-column extremes, shared by both observers.

    Columns are the matmul contraction dim, so per-column ranges cannot
    feed per-column activation *scales* (the requantization would not
    factorize) — they feed the SmoothQuant-style scale *migration* in
    quant/apply.py, which divides hot activation columns down and folds
    the factor into the weights.
    """

    def __init__(self):
        self.colmin = None
        self.colmax = None

    def update_cols(self, x: np.ndarray) -> None:
        if x.ndim != 2 or x.shape[0] == 0:
            return
        lo, hi = x.min(axis=0), x.max(axis=0)
        if self.colmin is None or self.colmin.shape != lo.shape:
            self.colmin, self.colmax = lo, hi
        else:
            self.colmin = np.minimum(self.colmin, lo)
            self.colmax = np.maximum(self.colmax, hi)

    def col_range(self):
        """-> (colmin, colmax) signed per-column, or None if unseen."""
        if self.colmin is None:
            return None
        return self.colmin, self.colmax


class MinMaxObserver(_ColumnStats):
    def __init__(self):
        super().__init__()
        self.lo = np.inf
        self.hi = -np.inf
        self.count = 0

    def update(self, x: np.ndarray) -> None:
        x = np.asarray(x, np.float32)
        if x.size == 0:
            return
        self.lo = min(self.lo, float(x.min()))
        self.hi = max(self.hi, float(x.max()))
        self.count += x.size
        self.update_cols(x)

    def range(self) -> Tuple[float, float]:
        if self.count == 0:
            raise ValueError("observer saw no data")
        return self.lo, self.hi


class PercentileObserver(_ColumnStats):
    """Symmetric |x| percentile over a capped sample reservoir (the
    per-tensor range; per-column extremes stay exact min/max)."""

    def __init__(self, percentile: float = 99.9, max_samples: int = 1 << 16,
                 seed: int = 0):
        super().__init__()
        self.percentile = percentile
        self.max_samples = max_samples
        self._rng = np.random.default_rng(seed)
        self._samples: list = []
        self.count = 0

    def update(self, x: np.ndarray) -> None:
        x = np.asarray(x, np.float32)
        self.update_cols(x)
        x = np.abs(x).ravel()
        if x.size == 0:
            return
        if x.size > self.max_samples:
            x = self._rng.choice(x, self.max_samples, replace=False)
        self._samples.append(x)
        self.count += x.size
        # keep the reservoir bounded: re-subsample the concatenation
        total = sum(s.size for s in self._samples)
        if total > 4 * self.max_samples:
            pool = np.concatenate(self._samples)
            self._samples = [self._rng.choice(pool, self.max_samples,
                                              replace=False)]

    def range(self) -> Tuple[float, float]:
        if not self._samples:
            raise ValueError("observer saw no data")
        bound = float(np.percentile(np.concatenate(self._samples),
                                    self.percentile))
        return -bound, bound


def make_observer(kind: str, percentile: float = 99.9):
    if kind == "minmax":
        return MinMaxObserver()
    if kind == "percentile":
        return PercentileObserver(percentile)
    raise ValueError(f"unknown observer {kind!r}; expected minmax|percentile")


# ---------------------------------------------------------------------------
# collection hook (active only during quant/apply.calibrate)
# ---------------------------------------------------------------------------


class Collector:
    """Per-layer observers keyed by ``id(weight array)``."""

    def __init__(self, factory: Callable):
        self.factory = factory
        self.observers: Dict[int, object] = {}

    def record(self, w, x) -> None:
        obs = self.observers.get(id(w))
        if obs is None:
            obs = self.observers[id(w)] = self.factory()
        obs.update(np.asarray(x))


_ACTIVE: Optional[Collector] = None


@contextlib.contextmanager
def collecting(collector: Collector):
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, collector
    try:
        yield collector
    finally:
        _ACTIVE = prev


def observe_linear_input(p, x) -> None:
    """Hook called by ``gnn/layers.linear_apply`` on every fp32 linear.
    No-op unless a Collector is active; calibration runs eagerly, so
    traced values (inside jit) are skipped rather than recorded."""
    if _ACTIVE is None:
        return
    w = p.get("w") if isinstance(p, dict) else None
    if w is None:
        return
    try:
        x_np = np.asarray(x)  # raises on traced (jit-time) values
    except (jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError):
        return
    _ACTIVE.record(w, x_np)
