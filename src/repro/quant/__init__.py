"""Quantized inference subsystem — the paper's ``ap_fixed`` design axis.

Layout (mirrors the calibrate -> transform -> serve flow):
  * ``observers.py`` — activation-range calibration (min/max, percentile)
    plus the forward-pass collection hook;
  * ``qconfig.py``   — schemes (symmetric int8, ap_fixed<W,I> emulation),
    the ``QuantizedLinear`` pytree node, and its forward;
  * ``apply.py``     — the model-agnostic param-tree transform
    (``quantize_model``) that makes all six GNN models run quantized.

``apply`` is imported lazily: it pulls in the model library, which itself
imports this package for the ``linear_apply`` dispatch.
"""
from repro.quant.observers import (  # noqa: F401
    Collector,
    MinMaxObserver,
    PercentileObserver,
    collecting,
    make_observer,
    observe_linear_input,
)
from repro.quant.qconfig import (  # noqa: F401
    QConfig,
    QuantizedLinear,
    affine_act_params,
    dequantize_int8,
    fixed_round,
    quantize_int8,
    quantized_linear,
    quantize_weight,
    symmetric_scale,
)

_LAZY = ("calibrate", "quantize_params", "quantize_model",
         "precision_qconfig", "QuantReport", "apply")


def __getattr__(name):
    if name in _LAZY:
        import importlib

        _apply = importlib.import_module("repro.quant.apply")
        return _apply if name == "apply" else getattr(_apply, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
