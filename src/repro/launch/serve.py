"""Serving launcher: batched prefill+decode for LM archs, or the streaming
GNN engine for the paper's models.

Examples (CPU, reduced configs):
  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --reduced
  PYTHONPATH=src python -m repro.launch.serve --gnn gin --n-graphs 32
  PYTHONPATH=src python -m repro.launch.serve --gnn gin --stream \
      --n-graphs 64 --qps 2000 --max-wait-ms 2
  PYTHONPATH=src python -m repro.launch.serve --gnn gin --stream \
      --n-graphs 64 --qps 8000 --slo-ms 20 --admit-limit 32 --adapt-ladder
  PYTHONPATH=src python -m repro.launch.serve --gnn gin --stream \
      --n-graphs 64 --qps 8000 --priority 0,0,1 --slo-ms 0:10,1:50
  PYTHONPATH=src python -m repro.launch.serve --models gcn:int8,gat:fp32 \
      --n-graphs 32 --qps 1000 --slo-ms 20
  PYTHONPATH=src python -m repro.launch.serve --gnn gin --stream \
      --n-graphs 64 --aot-cache /tmp/aot --prewarm-persist
"""
import argparse
import time

import jax
import numpy as np

from repro import params as P
from repro.configs import ARCHS, get_config, get_reduced
from repro.models import lm


def serve_lm(args):
    cfg = (get_reduced if args.reduced else get_config)(args.arch)
    from repro.serve.engine import LMServer, ServeConfig

    params = P.values(lm.init_params(jax.random.PRNGKey(0), cfg))
    scfg = ServeConfig(max_batch=args.batch, prompt_len=args.prompt_len,
                       cache_len=args.cache_len, max_new_tokens=args.max_new)
    srv = LMServer(params, cfg, scfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, rng.integers(4, args.prompt_len))
               for _ in range(args.batch)]
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = rng.normal(size=(args.batch, cfg.num_patches, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        extras["frames"] = rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    out, stats = srv.generate(prompts, extras=extras or None)
    print("generated:", out[:2])
    print(f"prefill {stats['prefill_s']*1e3:.1f} ms, "
          f"decode {stats['decode_s_per_token']*1e3:.2f} ms/token")


def _slo_kwargs(args):
    """StreamScheduler admission kwargs from the CLI flags.

    ``--slo-ms`` is either one budget for every request ("20") or a
    per-QoS-class table ("0:10,1:50" -> ``slo_by_class``); ``--priority``
    cycles its classes over the stream round-robin."""
    kw = dict(admit_limit=args.admit_limit, admit_margin=args.admit_margin,
              adapt_ladder=args.adapt_ladder)
    if args.pipeline:
        from repro.serve.pipeline import PipelineConfig

        kw["pipeline"] = PipelineConfig(inflight=args.inflight)
    if args.slo_ms:
        if ":" in args.slo_ms:
            kw["slo_by_class"] = {
                (None, int(cls)): float(ms) * 1e-3
                for cls, _, ms in (s.partition(":")
                                   for s in args.slo_ms.split(","))
            }
        else:
            kw["slo_s"] = float(args.slo_ms) * 1e-3
    return kw


def _priorities(args, n):
    cycle = [int(p) for p in args.priority.split(",")]
    return [cycle[i % len(cycle)] for i in range(n)]


def _aot_setup(args):
    """(aot_cache, xla_flags) from the CLI.

    ``--aot-cache DIR`` turns on the persistent executable cache;
    ``--xla-flags-file`` points at an explicit flag table (error if
    absent), otherwise the checked-in ``configs/xla_flags.json`` is used
    whenever either AOT flag is given (an absent default file is an
    empty flag set, not an error)."""
    from repro.serve.aot import AOTCache, XlaFlagConfig

    cache = AOTCache(args.aot_cache) if args.aot_cache else None
    flags = None
    if args.xla_flags_file:
        flags = XlaFlagConfig.load(args.xla_flags_file)
    elif cache is not None:
        flags = XlaFlagConfig.load()
    return cache, flags


def _report_cold_start(args, executor, scheduler, graphs, registry,
                       models=None):
    """The restart-fast probe: prewarm the bucket ladder (populating the
    AOT cache on first run, loading from it on the next), then print one
    machine-parseable line — ``bench_coldstart.py`` and the CI smoke
    step parse it.  ``cold_start_s`` counts from launcher entry to
    ladder-warm (serving-ready); interpreter/JAX import time is excluded
    (orthogonal to the cache — see docs/SERVING.md)."""
    if not args.aot_cache:
        return
    if args.prewarm_persist and scheduler is not None and graphs:
        scheduler.prewarm_ladders(graphs, models=models)
    elapsed = time.perf_counter() - args._t0
    stats = executor.aot_stats()
    print(f"cold_start_s={elapsed:.3f} aot_hit={stats['hit']} "
          f"aot_miss={stats['miss']} aot_stale={stats['stale']} "
          f"lowered={executor.lowered_count}")
    if registry is not None:
        from repro.obs.metrics import ServingInstruments

        ServingInstruments(registry).cold_start.set(elapsed)


def _telemetry(args):
    """(tracer, registry) for the stream paths.

    The registry always exists — the admission ledger is a structured
    record in it, rendered for humans by ``obs.export.admission_line``
    (no more free-floating print tallies).  Span tracing only turns on
    when ``--trace-out`` asks for the artifact."""
    from repro.obs import MetricsRegistry, Tracer
    from repro.serve.clock import VirtualClock

    registry = MetricsRegistry()
    tracer = Tracer(VirtualClock()) if args.trace_out else None
    return tracer, registry


def _emit_telemetry(args, tracer, registry):
    """Print the admission ledger from the registry; write artifacts."""
    from repro.obs import export

    print(f"  {export.admission_line(registry)}")
    if args.metrics_json:
        export.write_metrics_json(registry, args.metrics_json)
        print(f"  metrics-json -> {args.metrics_json}")
    if args.trace_out:
        export.write_trace(tracer, args.trace_out)
        print(f"  trace-out -> {args.trace_out}")


def serve_gnn_multitenant(args):
    """Serve several GNN models through ONE executor + ONE scheduler.

    ``--models gcn:int8,gat:fp32`` registers each ``model[:precision]``
    spec as a tenant on a shared ``Executor`` (shared bucket ladder,
    shared compile cache); the stream round-robins requests across the
    tenants and the scheduler routes each to its model's packed flushes.
    """
    from repro import runtime as RT
    from repro.configs.gengnn_models import get_gnn_config
    from repro.data.pipeline import MOLHIV, MoleculeStream
    from repro.gnn import init
    from repro.serve.executor import Executor
    from repro.serve.scheduler import StreamScheduler

    mesh = None
    if args.gnn_mesh > 1:
        mesh = RT.make_flat_mesh(args.gnn_mesh, axis="data")
    aot_cache, xla_flags = _aot_setup(args)
    ex = Executor(mesh=mesh, aot_cache=aot_cache, xla_flags=xla_flags)
    specs = []
    for i, spec in enumerate(args.models.split(",")):
        model, _, precision = spec.partition(":")
        precision = precision or "fp32"
        cfg = get_gnn_config(model)
        params = init(jax.random.PRNGKey(i), cfg)
        calib = None
        if precision == "int8-static":
            calib = [g[:4] for g in MoleculeStream(MOLHIV, seed=97).take(16)]
        ex.register(spec, cfg, params, precision=precision, calib_graphs=calib,
                    share_layout=not args.no_share_layout, fused=args.fused)
        specs.append(spec)
    tracer, registry = _telemetry(args)
    sched = StreamScheduler(ex, capacity=args.pack,
                            max_wait_s=args.max_wait_ms * 1e-3,
                            with_eigvec="auto", tracer=tracer,
                            metrics=registry, **_slo_kwargs(args))
    graphs = [g[:4] for g in MoleculeStream(MOLHIV, seed=0).take(args.n_graphs)]
    models = [specs[i % len(specs)] for i in range(len(graphs))]
    _report_cold_start(args, ex, sched, graphs, registry, models=models)
    rep = sched.run(graphs, qps=args.qps, models=models,
                    priorities=_priorities(args, len(graphs)))
    counts = {s: models.count(s) for s in specs}
    print(f"multi-tenant stream(qps={args.qps:g}, pack x{args.pack}, "
          f"tenants {counts}): {rep.num_requests} graphs in "
          f"{rep.makespan_s*1e3:.1f} ms virtual "
          f"({rep.graphs_per_s:.0f} graphs/s)")
    print(f"  latency ms: p50 {rep.percentile_ms(50):.2f}  "
          f"p95 {rep.percentile_ms(95):.2f}  p99 {rep.percentile_ms(99):.2f}")
    print(f"  {len(rep.batch_sizes)} flushes (reasons {dict(rep.flush_reasons)}); "
          f"{len(ex._compiled)} compiled programs, "
          f"compile {rep.compile_s:.1f}s excluded")
    _emit_telemetry(args, tracer, registry)


def serve_gnn(args):
    from repro import runtime as RT
    from repro.configs.gengnn_models import get_gnn_config
    from repro.data.pipeline import MOLHIV, MoleculeStream
    from repro.gnn import init
    from repro.serve.gnn_engine import GNNEngine

    cfg = get_gnn_config(args.gnn)
    params = init(jax.random.PRNGKey(0), cfg)
    mesh = None
    if args.gnn_mesh > 1:
        # shard padded node/edge rows over a flat data axis
        mesh = RT.make_flat_mesh(args.gnn_mesh, axis="data")
    calib = None
    if args.precision == "int8-static":
        # calibration stream disjoint from the served one (seed split)
        calib = [g[:4] for g in MoleculeStream(MOLHIV, seed=97).take(16)]
    aot_cache, xla_flags = _aot_setup(args)
    eng = GNNEngine(cfg, params, mesh=mesh, precision=args.precision,
                    calib_graphs=calib,
                    share_layout=not args.no_share_layout,
                    fused=args.fused,
                    aot_cache=aot_cache, xla_flags=xla_flags)
    if eng.quant_report is not None:
        r = eng.quant_report
        print(f"[quant] {args.precision}: {r.quantized} linears quantized, "
              f"{r.kept_fp32} fp32 (skip: {list(r.skipped_paths)})")
    graphs = MoleculeStream(MOLHIV, seed=0).take(args.n_graphs)
    if args.stream:
        from repro.serve.scheduler import StreamScheduler

        tracer, registry = _telemetry(args)
        sched = StreamScheduler(
            eng, capacity=args.pack, max_wait_s=args.max_wait_ms * 1e-3,
            with_eigvec=(args.gnn == "dgn"), tracer=tracer,
            metrics=registry, **_slo_kwargs(args),
        )
        _report_cold_start(args, eng.executor, sched,
                           [g[:4] for g in graphs], registry)
        rep = sched.run(graphs, qps=args.qps,
                        priorities=_priorities(args, len(graphs)))
        if rep.num_requests == 0:
            print(f"{args.gnn} stream: no graphs (--n-graphs {args.n_graphs})")
            return
        sizes = np.asarray(rep.batch_sizes)
        print(f"{args.gnn} stream(qps={args.qps:g}, max-wait {args.max_wait_ms}ms, "
              f"pack x{args.pack}"
              f"{', mesh=' + str(args.gnn_mesh) if mesh is not None else ''}): "
              f"{rep.num_requests} graphs in {rep.makespan_s*1e3:.1f} ms virtual "
              f"({rep.graphs_per_s:.0f} graphs/s)")
        print(f"  latency ms: p50 {rep.percentile_ms(50):.2f}  "
              f"p95 {rep.percentile_ms(95):.2f}  p99 {rep.percentile_ms(99):.2f}")
        print(f"  {len(sizes)} flushes (mean batch {sizes.mean():.1f}, "
              f"reasons {dict(rep.flush_reasons)}); "
              f"compile {rep.compile_s:.1f}s excluded")
        _emit_telemetry(args, tracer, registry)
        return
    if args.batched:
        outs, per_graph_s = eng.infer_batched(
            graphs, batch_size=args.batch, n_pad=args.batch * 32,
            e_pad=args.batch * 96, with_eigvec=(args.gnn == "dgn"),
        )
        print(f"{args.gnn} batched(bs={args.batch}"
              f"{', mesh=' + str(args.gnn_mesh) if mesh is not None else ''}): "
              f"{len(outs)} graphs, {per_graph_s*1e6:.0f} us/graph "
              f"(compile {eng.compile_seconds:.1f}s excluded)")
        return
    outs, lats, compile_s = eng.infer_stream(
        [g[:4] for g in graphs], with_eigvec=(args.gnn == "dgn")
    )
    print(f"{args.gnn}: {len(outs)} graphs, mean {np.mean(lats)*1e6:.0f} us/graph "
          f"(p50 {np.percentile(lats,50)*1e6:.0f}, p99 {np.percentile(lats,99)*1e6:.0f}; "
          f"compile {compile_s:.1f}s excluded)")
    if args.aot_cache:
        stats = eng.executor.aot_stats()
        print(f"  aot: hit {stats['hit']} miss {stats['miss']} "
              f"stale {stats['stale']}; {eng.executor.lowered_count} fresh "
              f"compiles")


def main():
    t0 = time.perf_counter()  # cold-start epoch: launcher entry
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--gnn", choices=("gcn", "gin", "gin_vn", "gat", "pna", "dgn"))
    ap.add_argument("--models",
                    help="multi-tenant GNN serving: comma-separated "
                         "model[:precision] specs (e.g. gcn:int8,gat:fp32) "
                         "registered on one shared executor + scheduler")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--n-graphs", type=int, default=16)
    ap.add_argument("--batched", action="store_true",
                    help="GNN: padded-batch mode instead of streaming")
    ap.add_argument("--stream", action="store_true",
                    help="GNN: micro-batched streaming via serve.scheduler")
    ap.add_argument("--qps", type=float, default=1000.0,
                    help="stream: offered load; <=0 means all queued at t=0")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="stream: flush a bucket at latest this long after it opens")
    ap.add_argument("--pack", type=int, default=4,
                    help="stream: packed budget = this many base buckets")
    ap.add_argument("--slo-ms", default="",
                    help="stream: per-request latency SLO; one budget "
                         "('20') or a class:ms table ('0:10,1:50'); "
                         "enables admission control (empty = best-effort, "
                         "never shed)")
    ap.add_argument("--priority", default="0",
                    help="stream: QoS classes cycled over the stream "
                         "round-robin (lower = more urgent), e.g. '0,0,1'")
    ap.add_argument("--admit-limit", type=int, default=None,
                    help="stream: bound on admitted-but-unflushed requests; "
                         "arrivals beyond it shed with reason queue_full")
    ap.add_argument("--admit-margin", type=float, default=1.0,
                    help="stream: fraction of the SLO the admission "
                         "projection may use (guard band; see "
                         "serve/scheduler.py)")
    ap.add_argument("--metrics-json", default="",
                    help="stream: write the metrics-registry snapshot "
                         "(repro-metrics/v1 JSON) here after the run")
    ap.add_argument("--trace-out", default="",
                    help="stream: write the run's Chrome/Perfetto "
                         "trace-event JSON here (the scheduler's "
                         "virtual-clock timeline; open in "
                         "ui.perfetto.dev)")
    ap.add_argument("--adapt-ladder", action="store_true",
                    help="stream: re-fit each signature's bucket-rung "
                         "geometry to the observed flush-size histogram")
    ap.add_argument("--pipeline", action="store_true",
                    help="stream: pipelined (dispatch-ahead) execution — "
                         "flushes dispatch at their deadline while prior "
                         "flushes are still in flight, host pack overlaps "
                         "device compute (see docs/SERVING.md)")
    ap.add_argument("--inflight", type=int, default=2,
                    help="stream: bound on dispatched-but-unharvested "
                         "flushes in pipelined mode (1 = serial dispatch "
                         "order; default 2 = double buffering)")
    ap.add_argument("--gnn-mesh", type=int, default=1,
                    help="GNN: shard node/edge rows over this many devices")
    ap.add_argument("--fused", action="store_true",
                    help="GNN: lower eligible layers through the fused "
                         "(phi, A, gamma) megakernel — one pass for "
                         "message transform, aggregation, and node update "
                         "(GAT and int8-static/fixed params keep the "
                         "unfused path; see docs/KERNELS.md)")
    ap.add_argument("--no-share-layout", action="store_true",
                    help="GNN: disable the shared GraphLayout plan and "
                         "re-sort edges inside every aggregation (the "
                         "pre-layout behaviour; A/B benchmarking only)")
    ap.add_argument("--aot-cache", default="",
                    help="GNN: persistent AOT compile-cache directory — "
                         "serialized executables survive restarts; a warm "
                         "cache restores the whole bucket ladder without "
                         "one fresh compile (docs/SERVING.md)")
    ap.add_argument("--prewarm-persist", action="store_true",
                    help="GNN stream: warm every (tenant, signature) "
                         "bucket ladder before serving, populating "
                         "--aot-cache so the next restart serves in "
                         "milliseconds")
    ap.add_argument("--xla-flags-file", default="",
                    help="explicit XLA flag table (repro-xla-flags/v1 "
                         "JSON, written by tools/autotune_xla.py); "
                         "default: the checked-in configs/xla_flags.json "
                         "when --aot-cache is on")
    ap.add_argument("--precision",
                    choices=("fp32", "int8", "int8-static", "fixed"),
                    default="fp32",
                    help="GNN serving arithmetic: fp32; int8 (dynamic "
                         "per-node activation scales); int8-static "
                         "(calibrated per-tensor scales); or the paper's "
                         "ap_fixed<W,I> emulation")
    args = ap.parse_args()
    args._t0 = t0
    if args.models:
        serve_gnn_multitenant(args)
    elif args.gnn:
        serve_gnn(args)
    else:
        assert args.arch, "--arch or --gnn or --models required"
        serve_lm(args)


if __name__ == "__main__":
    main()
