"""Serving launcher: batched prefill+decode for LM archs, or the streaming
GNN engine for the paper's models.

Examples (CPU, reduced configs):
  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --reduced
  PYTHONPATH=src python -m repro.launch.serve --gnn gin --n-graphs 32
"""
import argparse

import jax
import numpy as np

from repro import params as P
from repro.configs import ARCHS, get_config, get_reduced
from repro.models import lm


def serve_lm(args):
    cfg = (get_reduced if args.reduced else get_config)(args.arch)
    from repro.serve.engine import LMServer, ServeConfig

    params = P.values(lm.init_params(jax.random.PRNGKey(0), cfg))
    scfg = ServeConfig(max_batch=args.batch, prompt_len=args.prompt_len,
                       cache_len=args.cache_len, max_new_tokens=args.max_new)
    srv = LMServer(params, cfg, scfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, rng.integers(4, args.prompt_len))
               for _ in range(args.batch)]
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = rng.normal(size=(args.batch, cfg.num_patches, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        extras["frames"] = rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    out, stats = srv.generate(prompts, extras=extras or None)
    print("generated:", out[:2])
    print(f"prefill {stats['prefill_s']*1e3:.1f} ms, "
          f"decode {stats['decode_s_per_token']*1e3:.2f} ms/token")


def serve_gnn(args):
    from repro import runtime as RT
    from repro.configs.gengnn_models import get_gnn_config
    from repro.data.pipeline import MOLHIV, MoleculeStream
    from repro.gnn import init
    from repro.serve.gnn_engine import GNNEngine

    cfg = get_gnn_config(args.gnn)
    params = init(jax.random.PRNGKey(0), cfg)
    mesh = None
    if args.gnn_mesh > 1:
        # shard padded node/edge rows over a flat data axis
        mesh = RT.make_flat_mesh(args.gnn_mesh, axis="data")
    eng = GNNEngine(cfg, params, mesh=mesh)
    graphs = MoleculeStream(MOLHIV, seed=0).take(args.n_graphs)
    if args.batched:
        outs, per_graph_s = eng.infer_batched(
            graphs, batch_size=args.batch, n_pad=args.batch * 32,
            e_pad=args.batch * 96, with_eigvec=(args.gnn == "dgn"),
        )
        print(f"{args.gnn} batched(bs={args.batch}"
              f"{', mesh=' + str(args.gnn_mesh) if mesh is not None else ''}): "
              f"{len(outs)} graphs, {per_graph_s*1e6:.0f} us/graph "
              f"(compile {eng.compile_seconds:.1f}s excluded)")
        return
    outs, lats, compile_s = eng.infer_stream(
        [g[:4] for g in graphs], with_eigvec=(args.gnn == "dgn")
    )
    print(f"{args.gnn}: {len(outs)} graphs, mean {np.mean(lats)*1e6:.0f} us/graph "
          f"(p50 {np.percentile(lats,50)*1e6:.0f}, p99 {np.percentile(lats,99)*1e6:.0f}; "
          f"compile {compile_s:.1f}s excluded)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--gnn", choices=("gcn", "gin", "gin_vn", "gat", "pna", "dgn"))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--n-graphs", type=int, default=16)
    ap.add_argument("--batched", action="store_true",
                    help="GNN: padded-batch mode instead of streaming")
    ap.add_argument("--gnn-mesh", type=int, default=1,
                    help="GNN: shard node/edge rows over this many devices")
    args = ap.parse_args()
    if args.gnn:
        serve_gnn(args)
    else:
        assert args.arch, "--arch or --gnn required"
        serve_lm(args)


if __name__ == "__main__":
    main()
