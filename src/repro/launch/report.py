"""Regenerate EXPERIMENTS.md §Dry-run / §Roofline tables from the cached
dry-run JSON records.

  PYTHONPATH=src python -m repro.launch.report
"""
from __future__ import annotations

import glob
import json
import os
import re

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")
EXP_MD = os.path.join(os.path.dirname(__file__), "../../../EXPERIMENTS.md")

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(tag=""):
    recs = []
    for p in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        r = json.load(open(p))
        if r.get("tag", "") == tag:
            recs.append(r)
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9), r.get("mesh", "")))
    return recs


def _fmt_bytes(b):
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b/1e3:.0f}K"


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | compile_s | FLOPs/dev | bytes/dev (args/temp) | collectives (count, wire/dev) | HBM est (fits 16G?) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("error"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} | ERROR | | | {r['error'][:60]} | |")
            continue
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} | skip | | | {r['skipped'][:60]} | |")
            continue
        m = r["memory"]
        cs = r["collective_summary"]
        coll = "; ".join(f"{op}×{v['count']} {_fmt_bytes(v['wire_bytes'])}" for op, v in sorted(cs.items()))
        hbm = r.get("hbm_estimate", {})
        fits = f"{_fmt_bytes(hbm.get('total', 0))} ({'yes' if hbm.get('fits_16gb') else 'NO'})"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} "
            f"| {r['flops_per_device']:.2e} | {_fmt_bytes(m['argument_bytes'])}/{_fmt_bytes(m['temp_bytes'])} "
            f"| {coll or '—'} | {fits} |"
        )
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | bound | step LB (s) | useful-FLOPs ratio | what would move the bound |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("error") or r.get("skipped"):
            continue
        if r.get("mesh") != "16x16":
            continue  # roofline table is single-pod (unrolled) only per brief
        rf = r["roofline"]
        note = _bound_note(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | **{rf['bound']}** | {rf['step_lower_bound_s']:.4f} "
            f"| {rf.get('useful_flops_ratio', 0):.3f} | {note} |"
        )
    return "\n".join(lines)


def _bound_note(r):
    rf = r["roofline"]
    if rf["bound"] == "collective":
        return "reduce TP activation all-reduce (seq-parallel / FSDP-style rules / bf16 grads)"
    if rf["bound"] == "memory" and r["kind"] == "decode":
        return "decode is weight+cache streaming: batch up / quantize cache"
    if rf["bound"] == "memory":
        return "shard the replicated attention or cut remat traffic"
    return "already compute-bound: fuse/overlap remaining collectives"


def main():
    recs = load()
    dr = dryrun_table(recs)
    rf = roofline_table(recs)
    md = open(EXP_MD).read()
    md = re.sub(
        r"<!-- DRYRUN_TABLE -->.*?(?=\n## |\Z)",
        "<!-- DRYRUN_TABLE -->\n\n" + dr + "\n\n",
        md,
        flags=re.S,
    )
    md = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n## |\Z)",
        "<!-- ROOFLINE_TABLE -->\n\n" + rf + "\n\n",
        md,
        flags=re.S,
    )
    open(EXP_MD, "w").write(md)
    print(f"updated {EXP_MD} with {len(recs)} records")


if __name__ == "__main__":
    main()
