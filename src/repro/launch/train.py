"""Multi-device training launcher.

Wraps train/loop.py's step function with the production mesh + sharding
rules.  On this CPU container it runs reduced configs on a debug mesh
(``--debug-mesh``); on a real pod slice the same code path runs the full
mesh (the dry-run proves every full config lowers & compiles).

Example (CPU):
  PYTHONPATH=src python -m repro.launch.train --arch rwkv6-1.6b \
      --reduced --steps 20 --batch 4 --seq 64
"""
import argparse
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import params as P
from repro import runtime as RT
from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS, get_config, get_reduced
from repro.data.pipeline import SyntheticTokens, TokenPipelineConfig
from repro.models import lm
from repro.optim import adamw
from repro.optim import compression as comp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--debug-mesh", default="", help="e.g. 2x2 (data x model)")
    ap.add_argument("--rules", default="default", choices=("default", "fsdp"),
                    help="sharding preset (fsdp = EXPERIMENTS.md §Perf H1 winner)")
    args = ap.parse_args()

    cfg = (get_reduced if args.reduced else get_config)(args.arch)
    mesh = None
    rules = None
    if args.debug_mesh:
        d, m = (int(x) for x in args.debug_mesh.split("x"))
        mesh = RT.make_debug_mesh(d, m)
        rules = (
            RT.fsdp_rules(mesh, args.batch)
            if args.rules == "fsdp"
            else RT.batch_rules(mesh, args.batch)
        )

    data = SyntheticTokens(
        TokenPipelineConfig(vocab_size=cfg.vocab_size, batch=args.batch, seq_len=args.seq)
    )
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                                total_steps=args.steps)
    ptree = lm.init_params(jax.random.PRNGKey(0), cfg)
    pvals, paxes = P.values(ptree), P.axes(ptree)
    if mesh is not None:
        shardings = RT.tree_shardings(ptree, mesh, rules)
        pvals = jax.device_put(pvals, shardings)
    opt_state = adamw.init(pvals)
    ef = comp.init_error_buf(pvals) if args.grad_compression else None
    mgr = CheckpointManager(args.ckpt_dir, keep=3)

    from repro.train.loop import make_train_step

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, args.grad_compression),
                      donate_argnums=(0, 1, 2))

    with contextlib.ExitStack() as mesh_ctx:
        if mesh is not None:
            # make logical_constraint() live during tracing/execution
            mesh_ctx.enter_context(RT.use_mesh(mesh))
            mesh_ctx.enter_context(RT.active_rules(rules))
        _run_steps(args, data, step_fn, pvals, opt_state, ef, mgr, paxes)
    mgr.wait()
    print("done")


def _run_steps(args, data, step_fn, pvals, opt_state, ef, mgr, paxes):
    it = iter(data)
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        t0 = time.perf_counter()
        pvals, opt_state, ef, metrics = step_fn(pvals, opt_state, ef, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)", flush=True)
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            mgr.save(step + 1, {"params": pvals, "opt": opt_state},
                     axes_tree={"params": paxes, "opt": None})


if __name__ == "__main__":
    main()
