import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512"
    " --xla_backend_optimization_level=0"
    " --xla_llvm_disable_expensive_passes=true"
)
"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell with ShapeDtypeStruct inputs (no allocation), record memory analysis,
cost analysis and the collective schedule.

Usage:
  python -m repro.launch.dryrun --arch starcoder2-15b --shape train_4k
  python -m repro.launch.dryrun --all                     # all 40 cells, both meshes
  python -m repro.launch.dryrun --all --mesh single       # roofline table mesh

The first two lines of this file set the 512-device placeholder count and
MUST precede any other import (jax locks the device count on first init).
Results are cached as JSON under experiments/dryrun/.
"""
import argparse
import dataclasses
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro import params as P
from repro import roofline as R
from repro.configs import ARCHS, get_config
from repro.launch import specs as SPECS
from repro.runtime import compat as RTC
from repro.runtime import partitioning as SH
from repro.runtime.mesh import make_production_mesh
from repro.models import lm
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.optim import adamw

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")

# attention chunk per shape keeps the unrolled-HLO size and the transient
# logits footprint bounded (see DESIGN.md §7)
_ATTN_CHUNK = {"train_4k": 2048, "prefill_32k": 8192, "decode_32k": 8192, "long_500k": 8192}
_LOSS_CHUNK = {"train_4k": 512}

# long_500k runs only for sub-quadratic archs (per the brief); whisper's
# decoder context is 448 by design, so a 500k cache is not meaningful.
def cell_skip_reason(arch: str, cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k":
        if arch == "whisper-base":
            return "whisper decoder context is 448; 500k KV cache not meaningful"
        if not cfg.is_sub_quadratic:
            return "pure full-attention arch: long_500k skipped per brief"
    return None


def _cache_len(shape: ShapeConfig) -> int:
    return shape.seq_len


def build_lowerable(cfg: ModelConfig, shape: ShapeConfig, mesh, rules, zero1: bool = False):
    """Returns (jitted_fn, example_args) ready for .lower()."""
    params_struct = jax.eval_shape(partial(lm.init_params, cfg=cfg), jax.random.PRNGKey(0))
    pvals = P.values(params_struct)
    p_sh = SH.tree_shardings(params_struct, mesh, rules)

    batch_struct = SPECS.batch_specs(cfg, shape)
    b_axes = SPECS.batch_axes(cfg)
    b_sh = {
        k: jax.sharding.NamedSharding(
            mesh, SH.resolve_spec(b_axes[k], v.shape, mesh, rules)
        )
        for k, v in batch_struct.items()
    }

    if shape.kind == "train":
        opt_struct = jax.eval_shape(adamw.init, pvals)
        m_sh = p_sh
        if zero1:  # ZeRO-1: moments additionally sharded over data
            m_sh = jax.tree.map(
                lambda s, v: jax.sharding.NamedSharding(
                    mesh, SH.zero1_spec(s.spec, v.shape, mesh, "data")
                ),
                p_sh, pvals,
                is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding),
            )
        o_sh = {
            "m": m_sh,
            "v": m_sh,
            "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        }
        opt_cfg = adamw.AdamWConfig()

        def train_step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
                params, batch, cfg
            )
            new_p, new_o, om = adamw.update(opt_cfg, grads, opt_state, params)
            return new_p, new_o, {"loss": loss, **om}

        fn = jax.jit(
            train_step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )
        return fn, (pvals, opt_struct, batch_struct), params_struct

    if shape.kind == "prefill":

        def prefill_step(params, batch):
            return lm.prefill(params, batch, cfg, _cache_len(shape))

        fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
        return fn, (pvals, batch_struct), params_struct

    # decode
    cache_small = lm.init_cache(cfg, 1, 8)  # tiny: only for axes structure
    cache_axes = P.axes(cache_small)
    cache_struct = jax.eval_shape(
        lambda: P.values(lm.init_cache(cfg, shape.global_batch, _cache_len(shape)))
    )
    c_sh = jax.tree.map(
        lambda v, a: jax.sharding.NamedSharding(
            mesh, SH.resolve_spec(a, v.shape, mesh, rules)
        ),
        cache_struct,
        cache_axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    tok_struct, t_struct = SPECS.decode_token_specs(cfg, shape)
    tok_sh = jax.sharding.NamedSharding(
        mesh, SH.resolve_spec(("batch", None), tok_struct.shape, mesh, rules)
    )
    t_sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    def serve_step(params, cache, tokens, t):
        return lm.decode_step(params, cache, tokens, t, cfg)

    fn = jax.jit(
        serve_step,
        in_shardings=(p_sh, c_sh, tok_sh, t_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    )
    return fn, (pvals, cache_struct, tok_struct, t_struct), params_struct


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    stack_mode: str = "unroll",
    overrides: dict | None = None,
    tag: str = "",
    rules_preset: str = "default",
) -> dict:
    shape = SHAPES[shape_name]
    kw = dict(stack_mode=stack_mode)
    if shape_name in _ATTN_CHUNK:
        kw["attn_chunk"] = _ATTN_CHUNK[shape_name]
    if shape_name in _LOSS_CHUNK:
        kw["loss_chunk"] = _LOSS_CHUNK[shape_name]
    kw.update(overrides or {})
    cfg = get_config(arch, **kw)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "multi_pod": multi_pod,
        "kind": shape.kind,
        "stack_mode": cfg.stack_mode,
        "overrides": overrides or {},
        "tag": tag,
    }
    skip = cell_skip_reason(arch, cfg, shape)
    if skip:
        rec["skipped"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    if rules_preset.startswith("fsdp"):
        rules = SH.fsdp_rules(mesh, shape.global_batch)
    else:
        rules = SH.batch_rules(mesh, shape.global_batch)
    rec["rules"] = rules_preset
    fn, args, params_struct = build_lowerable(
        cfg, shape, mesh, rules, zero1=rules_preset.endswith("+zero1")
    )

    t0 = time.time()
    # use_mesh + active_rules make logical_constraint() live during tracing
    with RTC.use_mesh(mesh), SH.active_rules(rules):
        lowered = fn.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    rec["flops_per_device"] = float(ca.get("flops", 0.0))
    rec["bytes_per_device"] = float(ca.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    rec["hlo_lines"] = hlo.count("\n")
    colls = R.parse_collectives(hlo)
    rec["collectives"] = colls
    rec["collectives_corrected"] = R.bf16_normalization_correction(
        colls, cfg.dtype == "bfloat16"
    )
    rec["collective_summary"] = R.summarize_collectives(rec["collectives_corrected"])
    del hlo

    # analytic MODEL_FLOPS (per device): 6ND train / 2ND inference
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = R.model_flops(cfg, params_struct, tokens, shape.kind)
    rec["model_flops_per_device"] = mf / mesh.size
    rec["hbm_estimate"] = estimate_hbm(cfg, shape, mesh, rec, rules)
    rec["roofline"] = R.cell_roofline(rec)
    return rec


def estimate_hbm(cfg: ModelConfig, shape: ShapeConfig, mesh, rec: dict, rules=None) -> dict:
    """Analytic per-device HBM estimate for the 'fits' argument.

    The CPU backend's buffer assignment reports temp sizes without the
    TPU backend's aggressive reuse (and with bf16 normalized to f32), so
    ``memory.temp_bytes`` is a loose upper bound.  This model counts what
    a TPU build keeps live: arguments (params/opt/cache — measured),
    remat residuals (one residual-stream tensor per layer), gradient
    accumulators, and the largest transient working set.
    """
    # resolve the actual batch sharding under the active rules (FSDP puts
    # batch over the model axis too)
    rules = rules or SH.batch_rules(mesh, shape.global_batch)
    bspec = SH.resolve_spec(("batch",), (shape.global_batch,), mesh, rules)
    axes0 = bspec[0]
    if axes0 is None:
        dp = 1
    elif isinstance(axes0, tuple):
        dp = 1
        for a in axes0:
            dp *= mesh.shape[a]
    else:
        dp = mesh.shape[axes0]
    tp = mesh.shape.get("model", 1)
    b_loc = max(shape.global_batch // dp, 1)
    s = shape.seq_len if shape.kind != "decode" else 1
    dt = 2 if cfg.dtype == "bfloat16" else 4
    resid = b_loc * s * cfg.d_model * dt
    est = {"argument_bytes": rec["memory"]["argument_bytes"]}
    if shape.kind == "train":
        est["remat_residuals"] = cfg.num_layers * resid
        est["grads_f32"] = rec["memory"]["argument_bytes"] // 3  # ~params f32/ (p+m+v)
        chunk = min(cfg.attn_chunk, shape.seq_len)
        h_loc = max(cfg.num_heads // tp, 1)
        est["transient"] = max(
            4 * b_loc * h_loc * chunk * chunk * 4,  # attention logits block (f32)
            4 * b_loc * s * (cfg.d_ff // max(tp, 1) or cfg.d_ff) * dt,  # mlp h
        )
    else:
        est["transient"] = 4 * resid
    est["total"] = int(sum(v for v in est.values()))
    est["fits_16gb"] = bool(est["total"] < 16e9)
    return est


def cell_path(arch, shape_name, multi_pod, tag=""):
    mesh = "multi" if multi_pod else "single"
    suffix = f"__{tag}" if tag else ""
    return os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--stack-mode", default="unroll", choices=("unroll", "scan"))
    ap.add_argument("--tag", default="", help="experiment tag for perf variants")
    ap.add_argument("--rules", default="default", choices=("default", "fsdp", "fsdp+zero1"),
                    help="sharding-rules preset")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (int/float/str)")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    os.makedirs(OUT_DIR, exist_ok=True)
    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                path = cell_path(arch, shape_name, multi_pod, args.tag)
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {path}")
                    continue
                label = f"{arch} x {shape_name} x {'multi' if multi_pod else 'single'}"
                print(f"[lower ] {label} ...", flush=True)
                try:
                    rec = run_cell(
                        arch, shape_name, multi_pod,
                        stack_mode=args.stack_mode, overrides=overrides,
                        tag=args.tag, rules_preset=args.rules,
                    )
                except Exception as e:  # noqa: BLE001 — record + continue the sweep
                    failures += 1
                    rec = {
                        "arch": arch, "shape": shape_name,
                        "multi_pod": multi_pod, "tag": args.tag,
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    print(f"[FAIL  ] {label}: {e}")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if "error" not in rec:
                    if rec.get("skipped"):
                        print(f"[skip  ] {label}: {rec['skipped']}")
                    else:
                        r = rec["roofline"]
                        print(
                            f"[ok    ] {label}: compile={rec['compile_s']}s "
                            f"flops/dev={rec['flops_per_device']:.3e} "
                            f"bound={r['bound']} "
                            f"terms(c/m/n)=({r['compute_s']:.4f},{r['memory_s']:.4f},{r['collective_s']:.4f})s",
                            flush=True,
                        )
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
