"""ShapeDtypeStruct input stand-ins per (architecture x shape) — the
weak-type-correct, shardable, zero-allocation inputs the dry-run lowers
against.  Modality frontends are stubs: vlm provides patch embeddings,
audio provides post-conv frame embeddings, per the brief.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShapeConfig


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Training/prefill batch: tokens + modality extras.

    For vlm the patch stub occupies the first ``num_patches`` positions of
    the sequence budget; for audio, tokens are decoder tokens and frames
    are the fixed-length encoder input.
    """
    b, s = shape.global_batch, shape.seq_len
    specs = {}
    if cfg.family == "vlm":
        s_text = s - cfg.num_patches
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        specs["patches"] = jax.ShapeDtypeStruct((b, cfg.num_patches, cfg.d_model), _dt(cfg))
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), _dt(cfg))
    return specs


def batch_axes(cfg: ModelConfig) -> dict:
    """Logical axes per batch entry (for sharding resolution)."""
    axes = {"tokens": ("batch", "seq")}
    if cfg.family == "vlm":
        axes["patches"] = ("batch", "seq", "embed")
    if cfg.family == "audio":
        axes["frames"] = ("batch", "seq", "embed")
    return axes


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig) -> tuple:
    """(tokens (B,1), t ()) for decode_step."""
    b = shape.global_batch
    return (
        jax.ShapeDtypeStruct((b, 1), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
