import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""GNN large-graph dry-run: the paper's §4.6 extension at pod scale.

Lowers + compiles the multi-chip sharded message-passing step
(core/distributed.py) for a web-scale graph (2^27 nodes, 2^31 edges,
F=256 — ~1000x PubMed) with nodes sharded across all 256/512 chips, on
both production meshes.  This is the "graphs that don't fit on chip"
story taken to its logical end: the graph doesn't fit on a PODFUL of
chips without sharding.

  PYTHONPATH=src python -m repro.launch.gnn_dryrun [--multi-pod]
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import roofline as R
from repro.runtime import make_sharded_mp
from repro.runtime.mesh import flatten_mesh, make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")


def run(multi_pod: bool, log_nodes: int = 27, log_edges: int = 31, feat: int = 256):
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    n, e = 2**log_nodes, 2**log_edges
    # one flat "graph" axis over every chip (nodes and edges sharded)
    flat = flatten_mesh(mesh, "graph")

    def phi(m):  # message transform: one dense layer's worth of work
        return jnp.maximum(m, 0.0)

    fn = make_sharded_mp(flat, "graph", phi, strategy="allgather")
    x = jax.ShapeDtypeStruct((n, feat), jnp.bfloat16)
    src = jax.ShapeDtypeStruct((e,), jnp.int32)
    dst = jax.ShapeDtypeStruct((e,), jnp.int32)
    msk = jax.ShapeDtypeStruct((e,), jnp.bool_)
    sh_n = NamedSharding(flat, P("graph", None))
    sh_e = NamedSharding(flat, P("graph"))
    jf = jax.jit(fn, in_shardings=(sh_n, sh_e, sh_e, sh_e))
    t0 = time.time()
    compiled = jf.lower(x, src, dst, msk).compile()
    compile_s = round(time.time() - t0, 2)
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    colls = R.parse_collectives(compiled.as_text())
    rec = {
        "arch": "gengnn-large-graph",
        "shape": f"n2^{log_nodes}_e2^{log_edges}_f{feat}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "multi_pod": multi_pod,
        "kind": "gnn_mp_layer",
        "tag": "gnn",
        "compile_s": compile_s,
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
        },
        "collectives": colls,
        "collective_summary": R.summarize_collectives(colls),
    }
    rec["roofline"] = {
        "compute_s": rec["flops_per_device"] / R.PEAK_FLOPS,
        "memory_s": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + 2 * ma.temp_size_in_bytes) / R.HBM_BW,
        "collective_s": R.collective_seconds(colls),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)
    rec = run(args.multi_pod)
    path = os.path.join(
        OUT_DIR, f"gengnn-large__{rec['shape']}__{'multi' if args.multi_pod else 'single'}.json"
    )
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    rf = rec["roofline"]
    print(
        f"[ok] gengnn large-graph {rec['mesh']}: compile={rec['compile_s']}s "
        f"args/dev={rec['memory']['argument_bytes']/1e9:.2f}G "
        f"terms(c/m/n)=({rf['compute_s']:.4f},{rf['memory_s']:.4f},{rf['collective_s']:.4f})s "
        f"colls={ {k: v['count'] for k, v in rec['collective_summary'].items()} }"
    )


if __name__ == "__main__":
    main()
