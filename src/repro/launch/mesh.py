"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (device count locks on first jax init).

Single pod: 16x16 = 256 chips (data x model) — TPU v5e pod slice.
Multi-pod:  2x16x16 = 512 chips (pod x data x model); the ``pod`` axis
carries cross-pod data parallelism over DCI.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for the 8-device distributed tests."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
