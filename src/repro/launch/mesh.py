"""Deprecation shim — mesh construction moved to ``repro.runtime.mesh``."""
from __future__ import annotations

import warnings

from repro.runtime.mesh import (  # noqa: F401
    flatten_mesh,
    make_debug_mesh,
    make_flat_mesh,
    make_production_mesh,
)

warnings.warn(
    "repro.launch.mesh is deprecated; import from repro.runtime.mesh instead",
    DeprecationWarning,
    stacklevel=2,
)
