"""Pallas TPU kernels for the two GenGNN processing elements + LM attention.

  segment_reduce.py  MP PE: blocked sorted-segment aggregation (one-hot MXU
                     matmul for sum-family, sequential VPU for max/min)
  node_mlp.py        NE PE: fused tiled linear+bias+activation
  edge_softmax.py    GAT per-destination softmax (built on segment_reduce)
  flash_attention.py blockwise GQA attention for the LM substrate
  ops.py             jit'd dispatching wrappers (kernel / interpret / ref)
  ref.py             pure-jnp oracles (the correctness contract)
"""
from repro.kernels.ops import segment_reduce, node_mlp, edge_softmax, flash_attention

__all__ = ["segment_reduce", "node_mlp", "edge_softmax", "flash_attention"]
