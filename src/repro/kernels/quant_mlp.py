"""Pallas TPU kernel for the quantized Node-Embedding PE: int8 matmul with
int32 accumulation, fused requantize + bias + activation.

The paper's PEs run entirely in ``ap_fixed`` arithmetic — narrow multiplies
feeding a wider accumulator, rescaled once on the way out.  The TPU
translation of the int8 serving path is the same shape:

  * x (M, K) int8 activations, w (K, N) int8 weights feed the MXU with
    ``preferred_element_type=int32`` — the wide accumulator;
  * the K grid dimension accumulates int32 partial products in VMEM
    scratch (exact: no rounding until the final rescale);
  * the last K step applies the requantization in one fused tail:
    ``y = acc * scale + b`` with ``scale = x_scale * w_scale`` (per-output-
    channel), then the activation, writing the f32 output tile once.

Tiling mirrors kernels/node_mlp.py (the fp32 NE PE); int8 tiles want a
(32, 128) minimum so the default 128-blocks stay aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _qmlp_kernel(x_ref, w_ref, scale_ref, rs_ref, b_ref, out_ref, acc_ref, *,
                 n_k: int, activation: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _finalize():
        # (1, N) column scale x (M, 1) row scale broadcast into the tile
        y = (acc_ref[...].astype(jnp.float32) * scale_ref[...] * rs_ref[...]
             + b_ref[...])
        if activation == "relu":
            y = jnp.maximum(y, 0.0)
        elif activation == "gelu":
            y = jax.nn.gelu(y)
        out_ref[...] = y.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k", "interpret"),
)
def quant_node_mlp(
    x_q: jax.Array,
    w_q: jax.Array,
    scale: jax.Array,
    b: jax.Array,
    activation: str = "relu",
    row_scale: jax.Array | None = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y = act((x_q @ w_q) * scale * row_scale + b), int32 accumulation.

    x_q: (M, K) int8; w_q: (K, N) int8; scale: (N,) or () f32 per-output-
    channel requantization factor; row_scale: (M, 1) f32 per-row factor
    (dynamic per-node activation scales; None -> 1); b: (N,) f32.  Zero
    padding to block multiples is exact (int8 zeros contribute nothing).
    """
    if activation not in ("relu", "gelu", "none"):
        raise ValueError(f"unknown activation {activation!r}")
    m, kdim = x_q.shape
    _, n = w_q.shape
    mp = -(-m // block_m) * block_m
    kp = -(-kdim // block_k) * block_k
    np_ = -(-n // block_n) * block_n
    if row_scale is None:
        row_scale = jnp.ones((m, 1), jnp.float32)
    if (mp, kp) != (m, kdim):
        x_q = jnp.pad(x_q, ((0, mp - m), (0, kp - kdim)))
    if mp != m:
        row_scale = jnp.pad(row_scale, ((0, mp - m), (0, 0)))
    if (kp, np_) != (kdim, n):
        w_q = jnp.pad(w_q, ((0, kp - kdim), (0, np_ - n)))
    scale = jnp.broadcast_to(scale.astype(jnp.float32), (n,))
    if np_ != n:
        scale = jnp.pad(scale, (0, np_ - n))
        b = jnp.pad(b, (0, np_ - n))
    scale2d = scale.reshape(1, np_)
    b2d = b.astype(jnp.float32).reshape(1, np_)
    grid = (mp // block_m, np_ // block_n, kp // block_k)
    out = pl.pallas_call(
        functools.partial(_qmlp_kernel, n_k=grid[2], activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, scale2d, row_scale.astype(jnp.float32), b2d)
    return out[:m, :n]
