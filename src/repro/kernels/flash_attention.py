"""Pallas TPU blockwise (flash) attention for the LM substrate.

Online-softmax attention with q/kv tiling so the (S, S) score matrix is
never materialized in HBM — the working set per grid cell is
(TQ, D) + (TK, D) + (TQ, TK), sized for VMEM, MXU-aligned.

Supports causal masking, GQA (Hq % Hkv == 0, the kv head is selected by
the BlockSpec index map so no repeated kv materialization), and sliding
windows (Mistral/Gemma-local layers).  The causal/window structure prunes
whole kv blocks via ``pl.when`` (compute skip) — on real hardware the
block would also be skipped at the DMA level with a scalar-prefetch grid.

The dry-run/costing path uses the pure-jnp chunked equivalent in
models/attention.py for clean HLO; this kernel is the TPU deployment path,
validated against kernels/ref.py in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: int | None, block_q: int, block_k: int, n_k: int,
):
    """Grid = (batch*heads, q_blocks, k_blocks); k innermost (sequential)."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # block-level pruning: causal => skip blocks strictly above the diagonal;
    # window => skip blocks entirely left of the window.
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        needed = jnp.logical_and(needed, k_start + block_k > q_start - window + 1)

    @pl.when(needed)
    def _block():
        q = q_ref[0].astype(jnp.float32)  # (TQ, D)
        k = k_ref[0].astype(jnp.float32)  # (TK, D)
        v = v_ref[0].astype(jnp.float32)  # (TK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (TQ, TK)
        qpos = q_start + jax.lax.iota(jnp.int32, block_q)[:, None]
        kpos = k_start + jax.lax.iota(jnp.int32, block_k)[None, :]
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]  # (TQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D).  Returns (B, Hq, S, D).

    S must be a multiple of the block sizes (the LM substrate pads seq);
    D should be a multiple of 128 for MXU alignment (64 tolerated).
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = 1.0 / (d**0.5)
    qf = q.reshape(b * hq, s, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)
    n_q = s // block_q
    n_k = s // block_k
    grid = (b * hq, n_q, n_k)

    def q_map(h, i, j):
        return (h, i, 0)

    def kv_map(h, i, j):
        # GQA: query head h -> kv head (h % hq) // g within its batch
        bidx = h // hq
        kvh = (h % hq) // g
        return (bidx * hkv + kvh, j, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            causal=causal,
            window=window,
            block_q=block_q,
            block_k=block_k,
            n_k=n_k,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, s, d)
