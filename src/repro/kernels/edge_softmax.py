"""Edge softmax for GAT (§4.2) — composition of blocked segment kernels.

GAT normalizes attention logits over each destination's in-edges.  With
sorted (CSC) edges this is two segment reductions (max, then sum of
shifted exponentials) plus an edge-parallel normalize:

    w_e = exp(l_e - max_{e' in seg(e)} l_{e'}) / sum_{e'} exp(...)

The reductions run on the blocked Pallas segment kernel; the gather of the
per-segment statistics back to edges and the elementwise tail are plain
VPU work that XLA fuses.  A dedicated fused single-pass kernel is possible
(carrying running max/sum like flash attention) but measurement on the
blocked layout showed both reductions are DMA-bound on the same edge
stream, so the two-pass form costs one extra stream of the logits — the
paper makes the same call by reusing its generic MP machinery for GAT.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce import segment_reduce_sorted


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def edge_softmax(
    logits: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    interpret: bool = False,
) -> jax.Array:
    """logits: (E, H) sorted by segment; returns per-segment softmax weights."""
    valid = segment_ids < num_segments
    seg_max = segment_reduce_sorted(
        logits, segment_ids, num_segments, op="max", interpret=interpret
    )
    ids_safe = jnp.minimum(segment_ids, num_segments - 1)
    shifted = logits.astype(jnp.float32) - seg_max[ids_safe]
    z = jnp.where(valid[:, None], jnp.exp(shifted), 0.0)
    seg_sum = segment_reduce_sorted(
        z, segment_ids, num_segments, op="sum", interpret=interpret
    )
    w = z / jnp.maximum(seg_sum[ids_safe], 1e-30)
    return jnp.where(valid[:, None], w, 0.0).astype(logits.dtype)
