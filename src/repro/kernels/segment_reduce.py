"""Pallas TPU kernel for the Message-Passing PE: blocked segment reduction.

GenGNN's MP PE folds each message into its destination's partial aggregate
(merged scatter-gather, O(N) buffer).  The TPU-native expression, given
edges sorted by destination (the CSC layout produced on device by
``core.graph.coo_to_compressed``):

  * grid = (node_blocks, edge_blocks); the output block for node tile i
    stays resident in VMEM while the (sequential) edge-block dimension
    streams message tiles HBM -> VMEM.  Pallas's grid pipeline
    double-buffers the next edge tile during the current tile's compute —
    this is the paper's *prefetcher* (§4.6), expressed structurally.
  * sum/mean/sqsum aggregate via a one-hot (TE, TN) matmul on the MXU:
    partial = onehot^T @ messages — turning irregular scatter into dense
    systolic work (the hardware-adaptation decision recorded in DESIGN.md).
  * max/min aggregate via a sequential per-edge accumulate (VPU), mirroring
    the paper's per-edge MP loop; sum-family ops stay on the matmul path.
  * because ids are sorted, an edge block overlaps a node block only if
    their id ranges intersect; non-overlapping cells skip compute via
    ``pl.when`` (the block-sparse early-out).

Block shapes default to (TE=256/512, TN=128, F tiles of 128) — multiples of
the (8, 128) VREG tile and the 128x128 MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# identity element written to empty rows by the finalizer in ops.py
_FILL = {"max": -1e30, "min": 1e30}


def _kernel_matmul(ids_ref, vals_ref, out_ref, *, tn: int, op: str, num_segments: int):
    """sum/mean/sqsum path: one-hot MXU matmul, accumulated over edge blocks."""
    i = pl.program_id(0)  # node block
    j = pl.program_id(1)  # edge block (sequential, innermost)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...][:, 0]  # (TE,)
    lo = i * tn
    first, last = ids[0], ids[-1]
    overlap = (first < lo + tn) & (last >= lo) & (first < num_segments)

    @pl.when(overlap)
    def _accumulate():
        vals = vals_ref[...].astype(jnp.float32)  # (TE, F)
        if op == "sqsum":
            vals = vals * vals
        local = ids - lo
        onehot = (local[:, None] == jax.lax.iota(jnp.int32, tn)[None, :]) & (
            ids[:, None] < num_segments
        )
        partial = jax.lax.dot_general(
            onehot.astype(jnp.float32),
            vals,
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (TN, F)
        out_ref[...] += partial


def _kernel_extremum(ids_ref, vals_ref, out_ref, *, tn: int, op: str, num_segments: int):
    """max/min path: sequential per-edge accumulate (the paper's MP loop)."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    fill = _FILL[op]

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, fill)

    ids = ids_ref[...][:, 0]
    lo = i * tn
    te = ids.shape[0]
    first, last = ids[0], ids[-1]
    overlap = (first < lo + tn) & (last >= lo) & (first < num_segments)

    @pl.when(overlap)
    def _accumulate():
        vals = vals_ref[...].astype(jnp.float32)

        def body(e, _):
            row = ids[e] - lo
            in_block = (row >= 0) & (row < tn) & (ids[e] < num_segments)
            safe = jnp.clip(row, 0, tn - 1)
            cur = pl.load(out_ref, (pl.ds(safe, 1), slice(None)))
            new = (
                jnp.maximum(cur, vals[e][None, :])
                if op == "max"
                else jnp.minimum(cur, vals[e][None, :])
            )
            pl.store(
                out_ref,
                (pl.ds(safe, 1), slice(None)),
                jnp.where(in_block, new, cur),
            )
            return ()

        jax.lax.fori_loop(0, te, body, ())


@functools.partial(
    jax.jit, static_argnames=("num_segments", "op", "block_e", "block_n", "interpret")
)
def segment_reduce_sorted(
    values: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    op: str = "sum",
    block_e: int = 256,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Blocked segment reduction over sorted ids.  See module docstring.

    values (E, F) with E % block_e == 0 handled by internal padding;
    num_segments padded up to a block_n multiple internally.
    Returns (num_segments, F) f32; empty-segment rows are 0 for sum-family
    and ±FILL for max/min (finalized to 0 by ops.segment_reduce_pallas).
    """
    e, f = values.shape
    e_pad = -(-e // block_e) * block_e
    n_pad = -(-num_segments // block_n) * block_n
    if e_pad != e:
        values = jnp.pad(values, ((0, e_pad - e), (0, 0)))
        segment_ids = jnp.pad(
            segment_ids, (0, e_pad - e), constant_values=num_segments
        )
    ids2d = segment_ids.astype(jnp.int32).reshape(e_pad, 1)
    grid = (n_pad // block_n, e_pad // block_e)
    kernel = _kernel_matmul if op in ("sum", "mean", "sqsum") else _kernel_extremum
    kop = "sum" if op == "mean" else op
    out = pl.pallas_call(
        functools.partial(kernel, tn=block_n, op=kop, num_segments=num_segments),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((block_e, f), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, f), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, f), jnp.float32),
        interpret=interpret,
    )(ids2d, values)
    return out[:num_segments]
