"""Public jit'd wrappers over the Pallas kernels.

Dispatch policy: on TPU backends the compiled kernels run natively; on CPU
(this container) they execute in interpret mode when explicitly requested
(tests/benchmarks) and otherwise fall back to the pure-jnp reference path,
which lowers to identical-semantics XLA ops — so the rest of the framework
is backend-agnostic.  ``mode``:

  * "auto":      kernel on TPU, reference elsewhere
  * "kernel":    force Pallas (interpret=True off-TPU)
  * "reference": force pure-jnp oracle
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.edge_softmax import edge_softmax as _edge_softmax_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.node_mlp import node_mlp as _node_mlp_kernel
from repro.kernels.segment_reduce import segment_reduce_sorted as _segment_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: str):
    """-> (use_kernel, interpret)"""
    if mode == "reference":
        return False, False
    if mode == "kernel":
        return True, not _on_tpu()
    return (True, False) if _on_tpu() else (False, False)


def segment_reduce(
    values: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    op: str = "sum",
    mode: str = "auto",
) -> jax.Array:
    """Sorted-segment reduction (MP PE). values (E,F), ids sorted."""
    use_kernel, interpret = _resolve(mode)
    if not use_kernel:
        return ref.segment_reduce_sorted_ref(values, segment_ids, num_segments, op)
    if op == "mean":
        total = _segment_kernel(values, segment_ids, num_segments, "sum", interpret=interpret)
        ones = jnp.ones((values.shape[0], 1), values.dtype)
        count = _segment_kernel(ones, segment_ids, num_segments, "sum", interpret=interpret)
        return (total / jnp.maximum(count, 1.0)).astype(values.dtype)
    out = _segment_kernel(values, segment_ids, num_segments, op, interpret=interpret)
    if op in ("max", "min"):
        ones = jnp.ones((values.shape[0], 1), values.dtype)
        count = _segment_kernel(ones, segment_ids, num_segments, "sum", interpret=interpret)
        out = jnp.where(count > 0, out, 0.0)
    return out.astype(values.dtype)


def node_mlp(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str = "relu",
    mode: str = "auto",
) -> jax.Array:
    """Fused linear+bias+activation (NE PE)."""
    use_kernel, interpret = _resolve(mode)
    if not use_kernel:
        return ref.node_mlp_ref(x, w, b, activation)
    return _node_mlp_kernel(x, w, b, activation, interpret=interpret)


def edge_softmax(
    logits: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    mode: str = "auto",
) -> jax.Array:
    """Per-destination softmax over sorted edges (GAT)."""
    use_kernel, interpret = _resolve(mode)
    if not use_kernel:
        return ref.edge_softmax_ref(logits, segment_ids, num_segments)
    return _edge_softmax_kernel(logits, segment_ids, num_segments, interpret=interpret)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    mode: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Blockwise GQA attention."""
    use_kernel, interpret = _resolve(mode)
    if not use_kernel:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash_kernel(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
