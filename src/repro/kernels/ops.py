"""Public jit'd wrappers over the Pallas kernels.

Dispatch policy: on TPU backends the compiled kernels run natively; on CPU
(this container) they execute in interpret mode when explicitly requested
(tests/benchmarks) and otherwise fall back to the pure-jnp reference path,
which lowers to identical-semantics XLA ops — so the rest of the framework
is backend-agnostic.  ``mode``:

  * "auto":      kernel on TPU, reference elsewhere
  * "kernel":    force Pallas (interpret=True off-TPU)
  * "reference": force pure-jnp oracle

The ``REPRO_KERNEL_MODE`` environment variable, when set, overrides the
per-call ``mode`` globally — benches/CI force the kernel or reference path
without threading a flag through every config.  It is read at trace time:
set it before building/jitting a program (an already-compiled program does
not retrace when the variable changes).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.obs.metrics import default_registry
from repro.kernels import ref
from repro.kernels.edge_softmax import edge_softmax as _edge_softmax_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.fused_mp import fused_mp as _fused_mp_kernel
from repro.kernels.node_mlp import node_mlp as _node_mlp_kernel
from repro.kernels.quant_mlp import quant_node_mlp as _quant_mlp_kernel
from repro.kernels.segment_reduce import segment_reduce_sorted as _segment_kernel

_MODES = ("auto", "kernel", "reference")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _record_dispatch(op: str, use_kernel: bool, interpret: bool,
                     vmem_fallback: bool = False) -> None:
    """Count one dispatch decision in the process-wide registry
    (``kernels_dispatch_total{op, path}``).

    These wrappers execute at *trace time* — once per compiled program,
    never per served request — so the counter is a census of which path
    each program actually lowered through (Pallas kernel, interpret-mode
    kernel, jnp reference, or the VMEM-budget fallback), the serving-
    side view of docs/KERNELS.md's fallback conditions.  A pure-Python
    dict update at trace time: no new compile keys, nothing staged into
    the program."""
    path = ("vmem_fallback" if vmem_fallback
            else "interpret" if use_kernel and interpret
            else "kernel" if use_kernel
            else "reference")
    default_registry().counter("kernels_dispatch_total").inc(op=op, path=path)


def _resolve(mode: str):
    """-> (use_kernel, interpret)"""
    env = os.environ.get("REPRO_KERNEL_MODE", "")
    if env:
        if env not in _MODES:
            raise ValueError(
                f"REPRO_KERNEL_MODE={env!r} invalid; expected one of {_MODES}"
            )
        mode = env
    if mode == "reference":
        return False, False
    if mode == "kernel":
        return True, not _on_tpu()
    if mode != "auto":
        raise ValueError(f"unknown kernel mode {mode!r}; expected one of {_MODES}")
    return (True, False) if _on_tpu() else (False, False)


def segment_reduce(
    values: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    op: str = "sum",
    mode: str = "auto",
    perm: jax.Array | None = None,
) -> jax.Array:
    """Sorted-segment reduction (MP PE). values (E,F), ids sorted.

    Operands are **pre-sorted**: ``segment_ids`` non-decreasing, coming
    from a shared ``core.layout.GraphLayout`` plan — neither the Pallas
    kernel nor the jnp reference ever sorts.  Pass ``perm`` (the plan's
    CSC permutation) when ``values`` are still in COO order; the gather
    happens here so call sites stay sort-free and plan-agnostic.
    """
    if perm is not None:
        values = jnp.take(values, perm, axis=0)
    use_kernel, interpret = _resolve(mode)
    _record_dispatch("segment_reduce", use_kernel, interpret)
    if not use_kernel:
        return ref.segment_reduce_sorted_ref(values, segment_ids, num_segments, op)
    if op == "mean":
        total = _segment_kernel(values, segment_ids, num_segments, "sum", interpret=interpret)
        ones = jnp.ones((values.shape[0], 1), values.dtype)
        count = _segment_kernel(ones, segment_ids, num_segments, "sum", interpret=interpret)
        return (total / jnp.maximum(count, 1.0)).astype(values.dtype)
    out = _segment_kernel(values, segment_ids, num_segments, op, interpret=interpret)
    if op in ("max", "min"):
        ones = jnp.ones((values.shape[0], 1), values.dtype)
        count = _segment_kernel(ones, segment_ids, num_segments, "sum", interpret=interpret)
        out = jnp.where(count > 0, out, 0.0)
    return out.astype(values.dtype)


# the fused megakernel holds the whole (N, F) source table plus gamma's
# weights resident in VMEM; above this footprint compiled dispatch falls
# back to the reference path rather than overflow on-chip memory
# (interpret mode — the CPU test path — is exempt: no real VMEM there)
_FUSED_VMEM_BUDGET = 12 * 1024 * 1024


def fused_mp(
    spec,
    ids_sorted: jax.Array,
    src_sorted: jax.Array,
    in_degree: jax.Array,
    node_mask: jax.Array,
    msrc: jax.Array,
    x_res: jax.Array,
    nop: jax.Array | None = None,
    eop: jax.Array | None = None,
    ew: jax.Array | None = None,
    w1: jax.Array | None = None,
    b1: jax.Array | None = None,
    w1_scale: jax.Array | None = None,
    w2: jax.Array | None = None,
    b2: jax.Array | None = None,
    mode: str = "auto",
    block_e: int = 256,
    block_n: int = 128,
) -> jax.Array:
    """One fused (phi, A, gamma) message-passing layer — the megakernel.

    ``spec`` is a ``core.message_passing.MPSpec``; array operands follow
    :func:`ref.fused_mp_ref` (the oracle, also the CPU production path:
    its jnp lowering keeps the gather -> phi -> reduce -> gamma chain in
    one jit scope, which is how the fused speedups in BENCH_layout.json
    are realized off-TPU).  Per-edge operands arrive in plan order; the
    plan's out-of-range padding ids do the masking.
    """
    use_kernel, interpret = _resolve(mode)
    vmem_fallback = False
    if use_kernel and not interpret:
        resident = msrc.size * 4
        for wgt in (w1, w2):
            if wgt is not None:
                resident += wgt.size * 4
        if resident > _FUSED_VMEM_BUDGET:
            use_kernel = False  # documented fallback: docs/KERNELS.md
            vmem_fallback = True
    _record_dispatch("fused_mp", use_kernel, interpret,
                     vmem_fallback=vmem_fallback)
    if not use_kernel:
        return ref.fused_mp_ref(
            spec, ids_sorted, src_sorted, in_degree, node_mask, msrc, x_res,
            nop=nop, eop=eop, ew=ew, w1=w1, b1=b1, w1_scale=w1_scale,
            w2=w2, b2=b2,
        )
    return _fused_mp_kernel(
        spec, ids_sorted, src_sorted, in_degree, node_mask, msrc, x_res,
        nop=nop, eop=eop, ew=ew, w1=w1, b1=b1, w1_scale=w1_scale,
        w2=w2, b2=b2, block_e=block_e, block_n=block_n, interpret=interpret,
    )


def node_mlp(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str = "relu",
    mode: str = "auto",
) -> jax.Array:
    """Fused linear+bias+activation (NE PE)."""
    use_kernel, interpret = _resolve(mode)
    _record_dispatch("node_mlp", use_kernel, interpret)
    if not use_kernel:
        return ref.node_mlp_ref(x, w, b, activation)
    return _node_mlp_kernel(x, w, b, activation, interpret=interpret)


def quant_node_mlp(
    x_q: jax.Array,
    w_q: jax.Array,
    scale: jax.Array,
    b: jax.Array,
    activation: str = "relu",
    row_scale: jax.Array | None = None,
    mode: str = "auto",
) -> jax.Array:
    """Quantized fused linear (int8 NE PE): int32 accumulate + requantize.

    x_q (M, K) int8, w_q (K, N) int8, scale (N,)/() f32, row_scale
    (M, 1) f32 or None (dynamic per-node scales), b (N,) f32.
    """
    use_kernel, interpret = _resolve(mode)
    _record_dispatch("quant_node_mlp", use_kernel, interpret)
    if not use_kernel:
        return ref.quant_node_mlp_ref(x_q, w_q, scale, b, activation,
                                      row_scale=row_scale)
    return _quant_mlp_kernel(x_q, w_q, scale, b, activation,
                             row_scale=row_scale, interpret=interpret)


def edge_softmax(
    logits: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    mode: str = "auto",
    perm: jax.Array | None = None,
) -> jax.Array:
    """Per-destination softmax over sorted edges (GAT).

    ``segment_ids`` are pre-sorted (a shared layout plan); ``perm``
    gathers COO-order ``logits`` into plan order first — the sort itself
    never happens here, on either the Pallas or the reference path.
    """
    if perm is not None:
        logits = jnp.take(logits, perm, axis=0)
    use_kernel, interpret = _resolve(mode)
    _record_dispatch("edge_softmax", use_kernel, interpret)
    if not use_kernel:
        return ref.edge_softmax_ref(logits, segment_ids, num_segments)
    return _edge_softmax_kernel(logits, segment_ids, num_segments, interpret=interpret)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    mode: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Blockwise GQA attention."""
    use_kernel, interpret = _resolve(mode)
    _record_dispatch("flash_attention", use_kernel, interpret)
    if not use_kernel:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash_kernel(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
