"""Pallas TPU megakernel: one fused (phi, A, gamma) message-passing pass.

GenGNN's central dataflow claim (paper §3.3–3.4) is that message
transformation, aggregation, and node update run as ONE on-chip pipeline —
intermediate edge/node tensors never spill off-chip.  The unfused
reproduction lowers every layer to gather -> phi -> segment-reduce ->
gamma as separate XLA ops that each round-trip HBM; this kernel is the
paper's pipeline expressed as a single ``pallas_call``:

  * grid = (node_blocks, edge_blocks), edge dimension innermost and
    sequential — the output/aggregate block for node tile ``i`` stays
    resident in VMEM while edge tiles stream HBM -> VMEM (Pallas
    double-buffers the next tile during compute: the §4.6 prefetcher);
  * the source-operand table ``msrc`` (N, F) is held whole in VMEM and
    gathered per edge (the paper's node-feature BRAM) — phi is applied on
    the gathered tile, so messages are *produced and consumed* in VMEM;
  * sum-family aggregators (sum / sqsum / wsum) accumulate through a
    one-hot (TE, TN) MXU matmul; max/min run the paper's per-edge MP loop
    on the VPU — both into per-op VMEM scratch, exactly as
    ``kernels/segment_reduce.py`` does standalone;
  * because ids are sorted (the shared ``core.layout.GraphLayout`` plan),
    an edge block overlaps a node block only if their id ranges intersect
    — non-overlapping grid cells skip all work via ``pl.when``;
  * on the LAST edge block the node update gamma runs in-place on the
    VMEM aggregates: GCN's normalized self-loop add, GIN's 2-layer MLP,
    PNA's scaler tower, DGN's directional derivative — and for
    ``precision="int8"`` the gamma matmul quantizes its input per row,
    accumulates int8 x int8 -> int32 on the MXU, and requantizes in the
    same fused tail (W8A8 with the quantize/requant *inside* the pass).

The layer contract arrives as a declarative ``core.message_passing.MPSpec``
(duck-typed: this module never imports ``core``); the pure-jnp oracle is
``kernels/ref.fused_mp_ref``; dispatch (backend policy, VMEM budget
fallback) lives in ``kernels/ops.fused_mp``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_FILL = {"max": -1e30, "min": 1e30}
# must match kernels/ref._ROW_EPS (== quant.qconfig._EPS)
_ROW_EPS = 1e-8


def _gamma_linear(t, w1_ref, b1_ref, s1_ref, precision: str):
    """gamma's first linear + relu on a resident (TN, K) tile.

    int8: per-row exact-range quantize -> int8 x int8 -> int32 MXU
    accumulate -> fused requant ``acc * (row_scale * w_scale) + b``;
    the same expression as the oracle's, so the integer accumulations
    agree exactly and the f32 tails agree op-for-op.
    """
    if precision == "int8":
        rs = jnp.maximum(
            jnp.max(jnp.abs(t), axis=-1, keepdims=True), _ROW_EPS
        ) / 127.0
        q = jnp.clip(jnp.round(t / rs), -128.0, 127.0)
        acc = jax.lax.dot_general(
            q.astype(jnp.int8),
            w1_ref[...],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        y = acc.astype(jnp.float32) * (rs * s1_ref[...]) + b1_ref[...]
    else:
        y = jax.lax.dot_general(
            t,
            w1_ref[...],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) + b1_ref[...]
    return jnp.maximum(y, 0.0)


def _fused_kernel(
    ids_ref, src_ref, msrc_ref, eop_ref, ew_ref, xres_ref, nop_ref,
    deg_ref, mask_ref, w1_ref, b1_ref, s1_ref, w2_ref, b2_ref,
    out_ref, msg_ref, *acc_refs,
    spec, tn: int, te: int, n_e: int, num_segments: int,
):
    i = pl.program_id(0)  # node block
    j = pl.program_id(1)  # edge block (sequential, innermost)

    @pl.when(j == 0)
    def _init():
        for op, acc in zip(spec.ops, acc_refs):
            if op in _FILL:
                acc[...] = jnp.full_like(acc, _FILL[op])
            else:
                acc[...] = jnp.zeros_like(acc)

    ids = ids_ref[...][:, 0]  # (TE,)
    lo = i * tn
    first, last = ids[0], ids[-1]
    overlap = (first < lo + tn) & (last >= lo) & (first < num_segments)

    @pl.when(overlap)
    def _accumulate():
        # gather + phi: messages are produced into VMEM scratch and never
        # leave the chip — the paper's merged scatter-gather
        src = src_ref[...][:, 0]
        n_rows = msrc_ref.shape[0]

        def gather(e, _):
            s = jnp.clip(src[e], 0, n_rows - 1)
            pl.store(
                msg_ref,
                (pl.ds(e, 1), slice(None)),
                pl.load(msrc_ref, (pl.ds(s, 1), slice(None))),
            )
            return ()

        jax.lax.fori_loop(0, te, gather, ())
        if spec.phi == "add_relu":
            msg_ref[...] = jnp.maximum(msg_ref[...] + eop_ref[...], 0.0)
        msg = msg_ref[...]

        local = ids - lo
        onehot = (
            (local[:, None] == jax.lax.iota(jnp.int32, tn)[None, :])
            & (ids[:, None] < num_segments)
        ).astype(jnp.float32)
        for op, acc in zip(spec.ops, acc_refs):
            if op in ("max", "min"):
                continue
            if op == "sum":
                vals = msg
            elif op == "sqsum":
                vals = msg * msg
            else:  # wsum
                vals = msg * ew_ref[...]
            acc[...] += jax.lax.dot_general(
                onehot, vals, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        for op, acc in zip(spec.ops, acc_refs):
            if op not in ("max", "min"):
                continue

            def extremum(e, _, acc=acc, op=op):
                row = ids[e] - lo
                in_block = (row >= 0) & (row < tn) & (ids[e] < num_segments)
                safe = jnp.clip(row, 0, tn - 1)
                cur = pl.load(acc, (pl.ds(safe, 1), slice(None)))
                val = pl.load(msg_ref, (pl.ds(e, 1), slice(None)))
                new = jnp.maximum(cur, val) if op == "max" else jnp.minimum(cur, val)
                pl.store(acc, (pl.ds(safe, 1), slice(None)),
                         jnp.where(in_block, new, cur))
                return ()

            jax.lax.fori_loop(0, te, extremum, ())

    @pl.when(j == n_e - 1)
    def _finalize():
        deg = deg_ref[...]  # (TN, 1) f32
        c = jnp.maximum(deg, 1.0)
        agg = {}
        for op, acc in zip(spec.ops, acc_refs):
            v = acc[...]
            if op in ("max", "min"):
                v = jnp.where(deg > 0, v, 0.0)
            agg[op] = v
        x_res = xres_ref[...]
        if spec.gamma == "gcn":
            out = (agg["sum"] + x_res) * nop_ref[...]
        elif spec.gamma == "gin":
            h = _gamma_linear(
                x_res + agg["sum"], w1_ref, b1_ref, s1_ref, spec.precision
            )
            out = jax.lax.dot_general(
                h, w2_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) + b2_ref[...]
        elif spec.gamma == "pna":
            nop = nop_ref[...]  # (TN, 3) degree scalers
            mean = agg["sum"] / c
            std = jnp.sqrt(jnp.maximum(agg["sqsum"] / c - mean * mean, 0.0))
            agg4 = jnp.concatenate(
                [mean, std, agg["max"], agg["min"]], axis=-1
            )
            tower = jnp.concatenate(
                [agg4 * nop[:, 0:1], agg4 * nop[:, 1:2], agg4 * nop[:, 2:3]],
                axis=-1,
            )
            out = _gamma_linear(tower, w1_ref, b1_ref, s1_ref, spec.precision)
            out = out + x_res
        else:  # dgn
            mean = agg["sum"] / c
            dx = jnp.abs(agg["wsum"] - x_res * nop_ref[...])
            tower = jnp.concatenate([x_res, mean, dx], axis=-1)
            out = _gamma_linear(tower, w1_ref, b1_ref, s1_ref, spec.precision)
            out = out + x_res
        out_ref[...] = jnp.where(mask_ref[...] > 0, out, 0.0)


def _pad_rows(a, rows):
    return a if a.shape[0] == rows else jnp.pad(
        a, ((0, rows - a.shape[0]), (0, 0))
    )


@functools.partial(
    jax.jit, static_argnames=("spec", "block_e", "block_n", "interpret")
)
def fused_mp(
    spec,
    ids_sorted: jax.Array,
    src_sorted: jax.Array,
    in_degree: jax.Array,
    node_mask: jax.Array,
    msrc: jax.Array,
    x_res: jax.Array,
    nop: jax.Array | None = None,
    eop: jax.Array | None = None,
    ew: jax.Array | None = None,
    w1: jax.Array | None = None,
    b1: jax.Array | None = None,
    w1_scale: jax.Array | None = None,
    w2: jax.Array | None = None,
    b2: jax.Array | None = None,
    block_e: int = 256,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """One fused message-passing layer over the sorted edge plan.

    Operand contract is :func:`kernels.ref.fused_mp_ref`'s (the oracle);
    ``spec`` is a hashable static (``core.message_passing.MPSpec``).
    Edge count pads up to a ``block_e`` multiple (padding ids get the
    out-of-range value N, exactly like the plan's own padding rows) and
    node rows pad up to a ``block_n`` multiple (masked out; sliced off on
    return) — ragged shapes are handled here, not by callers.
    """
    n = in_degree.shape[0]
    e = ids_sorted.shape[0]
    f = msrc.shape[1]
    e_pad = -(-e // block_e) * block_e
    n_pad = -(-n // block_n) * block_n
    if e_pad != e:
        ids_sorted = jnp.pad(ids_sorted, (0, e_pad - e), constant_values=n)
        src_sorted = jnp.pad(src_sorted, (0, e_pad - e))
    ids2d = ids_sorted.astype(jnp.int32).reshape(e_pad, 1)
    src2d = src_sorted.astype(jnp.int32).reshape(e_pad, 1)
    deg2d = _pad_rows(in_degree.astype(jnp.float32).reshape(n, 1), n_pad)
    mask2d = _pad_rows(node_mask.astype(jnp.float32).reshape(n, 1), n_pad)
    msrc = _pad_rows(msrc.astype(jnp.float32), n_pad)
    x_res = _pad_rows(x_res.astype(jnp.float32), n_pad)
    nop = (
        jnp.zeros((n_pad, 1), jnp.float32) if nop is None
        else _pad_rows(nop.astype(jnp.float32), n_pad)
    )
    eop = (
        jnp.zeros((e_pad, 1), jnp.float32) if eop is None
        else _pad_rows(eop.astype(jnp.float32), e_pad)
    )
    ew = (
        jnp.zeros((e_pad, 1), jnp.float32) if ew is None
        else _pad_rows(ew.astype(jnp.float32), e_pad)
    )
    if w1 is None:
        w1 = jnp.zeros((1, 1), jnp.float32)
        b1 = jnp.zeros((1,), jnp.float32)
    if w1_scale is None:
        w1_scale = jnp.ones((w1.shape[1],), jnp.float32)
    if w2 is None:
        w2 = jnp.zeros((1, 1), jnp.float32)
        b2 = jnp.zeros((1,), jnp.float32)
    b1_2d = b1.astype(jnp.float32).reshape(1, -1)
    s1_2d = w1_scale.astype(jnp.float32).reshape(1, -1)
    b2_2d = b2.astype(jnp.float32).reshape(1, -1)
    w2 = w2.astype(jnp.float32)
    if spec.precision != "int8":
        w1 = w1.astype(jnp.float32)

    if spec.gamma == "gcn":
        f_out = x_res.shape[1]
    elif spec.gamma == "gin":
        f_out = w2.shape[1]
    else:  # pna / dgn: lin1 output + residual
        f_out = w1.shape[1]

    grid = (n_pad // block_n, e_pad // block_e)
    kernel = functools.partial(
        _fused_kernel, spec=spec, tn=block_n, te=block_e,
        n_e=grid[1], num_segments=n,
    )
    full = lambda a: pl.BlockSpec(a.shape, lambda i, j: (0, 0))
    by_e = lambda a: pl.BlockSpec((block_e, a.shape[1]), lambda i, j: (j, 0))
    by_n = lambda a: pl.BlockSpec((block_n, a.shape[1]), lambda i, j: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            by_e(ids2d), by_e(src2d), full(msrc), by_e(eop), by_e(ew),
            by_n(x_res), by_n(nop), by_n(deg2d), by_n(mask2d),
            full(w1), full(b1_2d), full(s1_2d), full(w2), full(b2_2d),
        ],
        out_specs=pl.BlockSpec((block_n, f_out), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, f_out), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_e, f), jnp.float32)]
        + [pltpu.VMEM((block_n, f), jnp.float32) for _ in spec.ops],
        interpret=interpret,
    )(ids2d, src2d, msrc, eop, ew, x_res, nop, deg2d, mask2d,
      w1, b1_2d, s1_2d, w2, b2_2d)
    return out[:n]
