"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function defines the exact semantics its kernel must match;
tests sweep shapes/dtypes and assert allclose(kernel(interpret=True), ref).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_reduce_sorted_ref(
    values: jax.Array, segment_ids: jax.Array, num_segments: int, op: str = "sum"
) -> jax.Array:
    """Segment reduction over *sorted* segment ids (CSR/CSC edge order).

    values: (E, F) f32/bf16; segment_ids: (E,) int32 non-decreasing, with
    out-of-range ids (>= num_segments) acting as padding.  Empty segments
    produce 0 for every op.
    """
    valid = segment_ids < num_segments
    ids = jnp.where(valid, segment_ids, num_segments)
    v = jnp.where(valid[:, None], values, 0.0).astype(jnp.float32)
    kw = dict(num_segments=num_segments + 1, indices_are_sorted=True)
    count = jax.ops.segment_sum(valid.astype(jnp.float32), ids, **kw)[:-1, None]
    if op == "sum":
        out = jax.ops.segment_sum(v, ids, **kw)[:-1]
    elif op == "mean":
        out = jax.ops.segment_sum(v, ids, **kw)[:-1] / jnp.maximum(count, 1.0)
    elif op == "sqsum":
        out = jax.ops.segment_sum(v * v, ids, **kw)[:-1]
    elif op in ("max", "min"):
        fill = -jnp.inf if op == "max" else jnp.inf
        vm = jnp.where(valid[:, None], values.astype(jnp.float32), fill)
        fn = jax.ops.segment_max if op == "max" else jax.ops.segment_min
        out = fn(vm, ids, **kw)[:-1]
        out = jnp.where(count > 0, out, 0.0)
    else:
        raise ValueError(f"unknown op {op!r}")
    return out.astype(values.dtype)


def node_mlp_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, activation: str = "relu"
) -> jax.Array:
    """Fused linear + bias + activation (the Node-Embedding 'MLP PE').

    x: (M, K); w: (K, N); b: (N,).  Accumulation in f32.
    """
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "gelu":
        y = jax.nn.gelu(y)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y.astype(x.dtype)


def quant_node_mlp_ref(
    x_q: jax.Array,
    w_q: jax.Array,
    scale: jax.Array,
    b: jax.Array,
    activation: str = "relu",
    row_scale: jax.Array | None = None,
) -> jax.Array:
    """Quantized fused linear (int8 NE PE): int32 accumulate, requantize.

    x_q: (M, K) int8; w_q: (K, N) int8; scale: (N,) or () f32 per-output-
    channel requantization factor; row_scale: (M, 1) f32 per-row factor
    (dynamic per-node scales; None -> 1); b: (N,) f32.  The int32
    accumulation is exact, so kernel and oracle agree bit-for-bit up to
    the f32 rescale tail.
    """
    acc = jax.lax.dot_general(
        x_q,
        w_q,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    y = acc.astype(jnp.float32) * scale.astype(jnp.float32)
    if row_scale is not None:
        y = y * row_scale.astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "gelu":
        y = jax.nn.gelu(y)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


def edge_softmax_ref(
    logits: jax.Array, segment_ids: jax.Array, num_segments: int
) -> jax.Array:
    """Numerically-stable per-destination softmax over sorted edges (GAT).

    logits: (E, H) attention logits per head; returns (E, H) weights that
    sum to 1 within each (segment, head); padding edges get weight 0.
    """
    valid = segment_ids < num_segments
    ids = jnp.where(valid, segment_ids, num_segments)
    kw = dict(num_segments=num_segments + 1, indices_are_sorted=True)
    lm = jnp.where(valid[:, None], logits.astype(jnp.float32), -jnp.inf)
    seg_max = jax.ops.segment_max(lm, ids, **kw)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    z = jnp.exp(lm - seg_max[ids])
    z = jnp.where(valid[:, None], z, 0.0)
    seg_sum = jax.ops.segment_sum(z, ids, **kw)
    return (z / jnp.maximum(seg_sum[ids], 1e-30)).astype(logits.dtype)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Full (quadratic) GQA attention oracle.

    q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hq % Hkv == 0.
    window: sliding-window size (None = full); causal mask always applied
    when ``causal``.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    kq = jnp.repeat(k, g, axis=1)
    vq = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kq.astype(jnp.float32)) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32))
    return out.astype(q.dtype)
