"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function defines the exact semantics its kernel must match;
tests sweep shapes/dtypes and assert allclose(kernel(interpret=True), ref).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_reduce_sorted_ref(
    values: jax.Array, segment_ids: jax.Array, num_segments: int, op: str = "sum"
) -> jax.Array:
    """Segment reduction over *sorted* segment ids (CSR/CSC edge order).

    values: (E, F) f32/bf16; segment_ids: (E,) int32 non-decreasing, with
    out-of-range ids (>= num_segments) acting as padding.  Empty segments
    produce 0 for every op.
    """
    valid = segment_ids < num_segments
    ids = jnp.where(valid, segment_ids, num_segments)
    v = jnp.where(valid[:, None], values, 0.0).astype(jnp.float32)
    kw = dict(num_segments=num_segments + 1, indices_are_sorted=True)
    count = jax.ops.segment_sum(valid.astype(jnp.float32), ids, **kw)[:-1, None]
    if op == "sum":
        out = jax.ops.segment_sum(v, ids, **kw)[:-1]
    elif op == "mean":
        out = jax.ops.segment_sum(v, ids, **kw)[:-1] / jnp.maximum(count, 1.0)
    elif op == "sqsum":
        out = jax.ops.segment_sum(v * v, ids, **kw)[:-1]
    elif op in ("max", "min"):
        fill = -jnp.inf if op == "max" else jnp.inf
        vm = jnp.where(valid[:, None], values.astype(jnp.float32), fill)
        fn = jax.ops.segment_max if op == "max" else jax.ops.segment_min
        out = fn(vm, ids, **kw)[:-1]
        out = jnp.where(count > 0, out, 0.0)
    else:
        raise ValueError(f"unknown op {op!r}")
    return out.astype(values.dtype)


def node_mlp_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, activation: str = "relu"
) -> jax.Array:
    """Fused linear + bias + activation (the Node-Embedding 'MLP PE').

    x: (M, K); w: (K, N); b: (N,).  Accumulation in f32.
    """
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "gelu":
        y = jax.nn.gelu(y)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y.astype(x.dtype)


# int8 x int8 partial products fit an f32 mantissa while
# |x| * |w| * K <= 128 * 127 * K < 2^24, i.e. K <= 1032 — under that bound
# an f32 GEMM over the integer-valued operands is bit-identical to an
# int32 accumulator, and on XLA:CPU (no int8 GEMM lowering) ~3x faster
# than ``dot_general(..., preferred_element_type=int32)``.
_EXACT_EMU_MAX_K = 1024


def _int8_accumulate(x_q: jax.Array, w_q: jax.Array) -> jax.Array:
    """(M, K) x (K, N) int8 matmul with exact accumulation, returned f32."""
    if x_q.shape[-1] <= _EXACT_EMU_MAX_K:
        return jnp.dot(x_q.astype(jnp.float32), w_q.astype(jnp.float32))
    return jax.lax.dot_general(
        x_q,
        w_q,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)


def quant_node_mlp_ref(
    x_q: jax.Array,
    w_q: jax.Array,
    scale: jax.Array,
    b: jax.Array,
    activation: str = "relu",
    row_scale: jax.Array | None = None,
) -> jax.Array:
    """Quantized fused linear (int8 NE PE): int32 accumulate, requantize.

    x_q: (M, K) int8; w_q: (K, N) int8; scale: (N,) or () f32 per-output-
    channel requantization factor; row_scale: (M, 1) f32 per-row factor
    (dynamic per-node scales; None -> 1); b: (N,) f32.  The accumulation
    is exact (int32, or its bit-identical f32 emulation for K <= 1024),
    so kernel and oracle agree bit-for-bit up to the f32 rescale tail.
    """
    y = _int8_accumulate(x_q, w_q) * scale.astype(jnp.float32)
    if row_scale is not None:
        y = y * row_scale.astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "gelu":
        y = jax.nn.gelu(y)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y


# floor for the dynamic per-row activation scale — must match
# ``quant.qconfig._EPS`` so the fused requant tail reproduces the unfused
# ``quantized_linear`` dynamic recipe
_ROW_EPS = 1e-8


def _fused_gamma_linear(x, w1, b1, w1_scale, precision: str) -> jax.Array:
    """gamma's first linear + relu, fp32 or the in-pass W8A8 boundary.

    int8: exact-range symmetric per-row quantization of ``x`` (the
    ``quant.qconfig`` dynamic recipe), exact int8 accumulation
    (:func:`_int8_accumulate`), one fused requantize tail
    ``acc * (row_scale * w_scale) + b``.
    """
    if precision == "int8":
        rs = jnp.maximum(
            jnp.max(jnp.abs(x), axis=-1, keepdims=True), _ROW_EPS
        ) / 127.0
        q = jnp.clip(jnp.round(x / rs), -128.0, 127.0)
        y = _int8_accumulate(q, w1) * (rs * w1_scale.astype(jnp.float32)) + b1
    else:
        y = jnp.dot(x, w1.astype(jnp.float32)) + b1
    return jnp.maximum(y, 0.0)


def fused_mp_ref(
    spec,
    ids_sorted: jax.Array,
    src_sorted: jax.Array,
    in_degree: jax.Array,
    node_mask: jax.Array,
    msrc: jax.Array,
    x_res: jax.Array,
    nop: jax.Array | None = None,
    eop: jax.Array | None = None,
    ew: jax.Array | None = None,
    w1: jax.Array | None = None,
    b1: jax.Array | None = None,
    w1_scale: jax.Array | None = None,
    w2: jax.Array | None = None,
    b2: jax.Array | None = None,
) -> jax.Array:
    """Fused (phi, A, gamma) message-passing pass — the megakernel oracle.

    ``spec`` is a ``core.message_passing.MPSpec`` (duck-typed here to keep
    ``kernels`` import-free of ``core``): phi kind, aggregator tuple,
    gamma kind, precision.  Plan operands come straight off a
    ``core.layout.GraphLayout`` (``ids_sorted`` non-decreasing with
    padding rows holding an out-of-range id); per-edge operands
    (``eop``, ``ew``) are already in plan (sorted-edge) order.

      msrc  (N, F)  per-source message operand, gathered via src_sorted
      x_res (N, Fr) gamma's residual/self operand
      nop           per-node gamma operand: gcn (N,1) 1/sqrt(d+1);
                    pna (N,3) degree scalers; dgn (N,1) sum of w_e
      eop   (E, F)  phi="add_relu" edge operand (GIN's edge embedding)
      ew    (E, 1)  "wsum" edge weights (DGN's directional w_e)
      w1/b1[/w1_scale]  gamma's first linear (int8: w1 int8 + per-channel
                    scale — the in-pass W8A8 boundary)
      w2/b2         gamma="gin" second MLP linear (always f32 weights)

    Matches the unfused ``mp_layer`` path: empty segments contribute 0
    (mean/std divide by max(deg, 1); max/min empty rows forced to 0) and
    padded node rows are zeroed on the way out.
    """
    n = in_degree.shape[0]
    msg = jnp.take(msrc.astype(jnp.float32), src_sorted, axis=0)
    if spec.phi == "add_relu":
        msg = jnp.maximum(msg + eop.astype(jnp.float32), 0.0)
    elif spec.phi != "copy":
        raise ValueError(f"unknown phi {spec.phi!r}")
    valid = ids_sorted < n
    ids = jnp.where(valid, ids_sorted, n)
    kw = dict(num_segments=n + 1, indices_are_sorted=True)
    deg = in_degree.astype(jnp.float32)[:, None]
    c = jnp.maximum(deg, 1.0)
    agg = {}
    for op in spec.ops:
        if op == "sum":
            agg[op] = jax.ops.segment_sum(msg, ids, **kw)[:-1]
        elif op == "sqsum":
            agg[op] = jax.ops.segment_sum(msg * msg, ids, **kw)[:-1]
        elif op == "wsum":
            agg[op] = jax.ops.segment_sum(msg * ew, ids, **kw)[:-1]
        elif op in ("max", "min"):
            fill = -jnp.inf if op == "max" else jnp.inf
            vm = jnp.where(valid[:, None], msg, fill)
            fn = jax.ops.segment_max if op == "max" else jax.ops.segment_min
            agg[op] = jnp.where(deg > 0, fn(vm, ids, **kw)[:-1], 0.0)
        else:
            raise ValueError(f"unknown aggregator {op!r}")
    x_res = x_res.astype(jnp.float32)
    if spec.gamma == "gcn":
        out = (agg["sum"] + x_res) * nop
    elif spec.gamma == "gin":
        h = _fused_gamma_linear(
            x_res + agg["sum"], w1, b1, w1_scale, spec.precision
        )
        out = jnp.dot(h, w2.astype(jnp.float32)) + b2
    elif spec.gamma == "pna":
        mean = agg["sum"] / c
        std = jnp.sqrt(jnp.maximum(agg["sqsum"] / c - mean * mean, 0.0))
        agg4 = jnp.concatenate([mean, std, agg["max"], agg["min"]], axis=-1)
        tower = jnp.concatenate(
            [agg4 * nop[:, 0:1], agg4 * nop[:, 1:2], agg4 * nop[:, 2:3]],
            axis=-1,
        )
        out = _fused_gamma_linear(tower, w1, b1, w1_scale, spec.precision)
        out = out + x_res
    elif spec.gamma == "dgn":
        mean = agg["sum"] / c
        dx = jnp.abs(agg["wsum"] - x_res * nop)
        tower = jnp.concatenate([x_res, mean, dx], axis=-1)
        out = _fused_gamma_linear(tower, w1, b1, w1_scale, spec.precision)
        out = out + x_res
    else:
        raise ValueError(f"unknown gamma {spec.gamma!r}")
    return jnp.where(node_mask[:, None], out, 0.0)


def edge_softmax_ref(
    logits: jax.Array, segment_ids: jax.Array, num_segments: int
) -> jax.Array:
    """Numerically-stable per-destination softmax over sorted edges (GAT).

    logits: (E, H) attention logits per head; returns (E, H) weights that
    sum to 1 within each (segment, head); padding edges get weight 0.
    """
    valid = segment_ids < num_segments
    ids = jnp.where(valid, segment_ids, num_segments)
    kw = dict(num_segments=num_segments + 1, indices_are_sorted=True)
    lm = jnp.where(valid[:, None], logits.astype(jnp.float32), -jnp.inf)
    seg_max = jax.ops.segment_max(lm, ids, **kw)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    z = jnp.exp(lm - seg_max[ids])
    z = jnp.where(valid[:, None], z, 0.0)
    seg_sum = jax.ops.segment_sum(z, ids, **kw)
    return (z / jnp.maximum(seg_sum[ids], 1e-30)).astype(logits.dtype)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Full (quadratic) GQA attention oracle.

    q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hq % Hkv == 0.
    window: sliding-window size (None = full); causal mask always applied
    when ``causal``.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    kq = jnp.repeat(k, g, axis=1)
    vq = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kq.astype(jnp.float32)) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32))
    return out.astype(q.dtype)
