"""Pallas TPU kernel for the Node-Embedding PE: fused tiled linear+bias+act.

The paper's MLP PE (§4.1, Fig. 5) copies one node's embedding into a local
fully-partitioned buffer, overlaps the copy with compute via ping-pong
buffers, and parallelizes the multiplies.  The TPU translation:

  * MXU-aligned (TM, TN, TK) = (128/256, 128, 128-multiple) tiles;
  * the Pallas grid pipeline plays the ping-pong role: the next K tile's
    HBM->VMEM DMA overlaps the current tile's matmul;
  * bias add + activation are fused into the final K step so the output
    tile is written once (no extra HBM round-trip between linear layers'
    elementwise tails).

Used by every GNN whose gamma(.) is an MLP (GIN, PNA, DGN heads) — the
paper explicitly reuses its MLP PE across models the same way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mlp_kernel(x_ref, w_ref, b_ref, out_ref, acc_ref, *, n_k: int, activation: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _finalize():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if activation == "relu":
            y = jnp.maximum(y, 0.0)
        elif activation == "gelu":
            y = jax.nn.gelu(y)
        out_ref[...] = y.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k", "interpret"),
)
def node_mlp(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str = "relu",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y = act(x @ w + b), f32 accumulation, VMEM-tiled.

    x: (M, K); w: (K, N); b: (N,).  All dims padded internally to block
    multiples (the engine pads node counts to 128 already).
    """
    m, kdim = x.shape
    _, n = w.shape
    mp = -(-m // block_m) * block_m
    kp = -(-kdim // block_k) * block_k
    np_ = -(-n // block_n) * block_n
    if (mp, kp) != (m, kdim):
        x = jnp.pad(x, ((0, mp - m), (0, kp - kdim)))
    if (kp, np_) != (kdim, n):
        w = jnp.pad(w, ((0, kp - kdim), (0, np_ - n)))
    if np_ != n:
        b = jnp.pad(b, (0, np_ - n))
    b2d = b.reshape(1, np_)
    grid = (mp // block_m, np_ // block_n, kp // block_k)
    out = pl.pallas_call(
        functools.partial(_mlp_kernel, n_k=grid[2], activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w, b2d)
    return out[:m, :n]
