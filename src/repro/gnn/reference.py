"""Dense-adjacency oracles for every GNN model.

The paper guarantees end-to-end correctness by cross-checking the HLS
implementation against PyTorch.  Here the engine (sparse, sorted-segment,
kernel-backed) is cross-checked against an *independent* dense formulation:
adjacency is materialized as an (N, N) matrix and every aggregation is a
dense matmul / masked reduction.  Sharing only the parameter pytrees, not
the code paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.gnn.models import GNNConfig


def dense_adjacency(g: Graph) -> jax.Array:
    """(N, N) with A[dst, src] = 1 for each real edge (in-edge view)."""
    n = g.num_nodes
    a = jnp.zeros((n, n))
    vals = g.edge_mask.astype(jnp.float32)
    return a.at[g.dst, g.src].add(vals)


def _mlp(ps, x, act="relu", final="none"):
    for i, p in enumerate(ps):
        x = x @ p["w"] + p["b"]
        if i < len(ps) - 1 and act == "relu":
            x = jnp.maximum(x, 0)
        elif (i < len(ps) - 1 and act == "gelu") or (i == len(ps) - 1 and final == "gelu"):
            x = jax.nn.gelu(x)
        elif i == len(ps) - 1 and final == "relu":
            x = jnp.maximum(x, 0)
    return x


def _lin(p, x, act="none"):
    y = x @ p["w"] + p["b"]
    if act == "relu":
        y = jnp.maximum(y, 0)
    elif act == "gelu":
        y = jax.nn.gelu(y)
    return y


def _masked_pool(g: Graph, x, op="mean"):
    n = g.num_nodes
    max_graphs = n
    gid = jnp.where(g.node_mask, g.graph_id, max_graphs)
    onehot = (gid[:, None] == jnp.arange(max_graphs)[None, :]).astype(jnp.float32)
    total = onehot.T @ x
    if op == "sum":
        return total
    count = onehot.sum(0)[:, None]
    return total / jnp.maximum(count, 1.0)


def apply_dense(params, g: Graph, cfg: GNNConfig, eigvec=None) -> jax.Array:
    a = dense_adjacency(g)  # (N,N) in-edges: a[i, j] = j -> i
    nm = g.node_mask[:, None].astype(jnp.float32)
    x = _lin(params["encoder"], g.node_feat) * nm
    vn = None  # (max_graphs, w) per-graph virtual-node state
    if cfg.virtual_node:
        vn = jnp.broadcast_to(params["vn_embed"], (g.num_nodes, x.shape[-1]))

    for li, lp in enumerate(params["layers"]):
        if cfg.virtual_node:
            gid = jnp.clip(g.graph_id, 0, g.num_nodes - 1)
            x = x + jnp.take(vn, gid, axis=0) * nm
        if cfg.model == "gcn":
            deg = a.sum(1) + 1.0
            inv = jax.lax.rsqrt(deg)[:, None]
            xw = _lin(lp["lin"], x)
            xs = xw * inv
            x = (a @ xs + xs) * inv * nm
        elif cfg.model == "gin":
            # recompute per-edge messages densely: for each i, sum_j relu(x_j + e_ij)
            n = g.num_nodes
            e_emb = _lin(lp["edge"], g.edge_feat)
            msg = jax.nn.relu(x[g.src] + e_emb) * g.edge_mask[:, None]
            onehot = (g.dst[:, None] == jnp.arange(n)[None, :]).astype(jnp.float32)
            onehot = onehot * g.edge_mask[:, None]
            agg = onehot.T @ msg
            x = _mlp(lp["mlp"], (1.0 + lp["eps"]) * x + agg) * nm
        elif cfg.model == "gat":
            h, f = cfg.heads, cfg.head_features
            n = g.num_nodes
            xp = _lin(lp["proj"], x).reshape(n, h, f)
            a_src = jnp.einsum("nhf,hf->nh", xp, lp["att_src"])
            a_dst = jnp.einsum("nhf,hf->nh", xp, lp["att_dst"])
            logits = jax.nn.leaky_relu(
                a_src[None, :, :] + a_dst[:, None, :], 0.2
            )  # (dst, src, h)
            mask = (a > 0)[:, :, None]
            # per-edge-INSTANCE softmax (PyG semantics): multi-edges weight
            # the numerator and denominator by their multiplicity a[i,j]
            zmax = jnp.max(jnp.where(mask, logits, -jnp.inf), axis=1, keepdims=True)
            zmax = jnp.where(jnp.isfinite(zmax), zmax, 0.0)
            num = a[:, :, None] * jnp.exp(logits - zmax) * mask
            alpha = num / jnp.maximum(num.sum(axis=1, keepdims=True), 1e-30)
            out = jnp.einsum("ijh,jhf->ihf", alpha, xp).reshape(n, h * f)
            x = jax.nn.elu(out) * nm
        elif cfg.model == "pna":
            n = g.num_nodes
            xp = _lin(lp["pre"], x, act="relu")
            deg = a.sum(1)
            cnt = jnp.maximum(deg, 1.0)[:, None]
            mean = (a @ xp) / cnt
            sq = (a @ (xp * xp)) / cnt
            std = jnp.sqrt(jnp.maximum(sq - mean * mean, 0.0))
            big = jnp.where((a > 0)[:, :, None], xp[None, :, :], -jnp.inf)
            mx = jnp.where(deg[:, None] > 0, jnp.max(big, axis=1), 0.0)
            small = jnp.where((a > 0)[:, :, None], xp[None, :, :], jnp.inf)
            mn = jnp.where(deg[:, None] > 0, jnp.min(small, axis=1), 0.0)
            aggs = jnp.concatenate([mean, std, mx, mn], axis=-1)
            logd = jnp.log(deg + 1.0)
            logdavg = jnp.log(jnp.asarray(cfg.avg_degree) + 1.0)
            amp = (logd / logdavg)[:, None]
            att = jnp.where(deg > 0, logdavg / jnp.maximum(logd, 1e-6), 0.0)[:, None]
            tower = jnp.concatenate([aggs, aggs * amp, aggs * att], axis=-1)
            x = (_lin(lp["post"], tower, act="relu") + x) * nm
        elif cfg.model == "dgn":
            n = g.num_nodes
            # multiplicity-weighted (per-edge-instance) directional weights
            dphi = (eigvec[None, :] - eigvec[:, None]) * a  # [i,j] = phi_j - phi_i
            denom = jnp.abs(dphi).sum(1, keepdims=True)
            w = dphi / jnp.maximum(denom, 1e-6)
            deg = a.sum(1)
            mean = (a @ x) / jnp.maximum(deg, 1.0)[:, None]
            dx = jnp.abs(w @ x - x * w.sum(1, keepdims=True))
            tower = jnp.concatenate([x, mean, dx], axis=-1)
            x = (_lin(lp["post"], tower, act="relu") + x) * nm
        if cfg.virtual_node and li < len(params["layers"]) - 1:
            pooled = _masked_pool(g, x, op="sum")
            vn = _mlp(params["vn_mlp"][li], pooled + vn)

    if cfg.task == "graph":
        pooled = _masked_pool(g, x, op="mean")
        return _mlp(params["head"], pooled)
    return _mlp(params["head"], x)
