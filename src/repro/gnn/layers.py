"""Shared building blocks for the GNN model library (the paper's 'rich
library of model-specific components', §4).

Parameters are plain nested dicts of jnp arrays (pytree-native).  Every
dense transform routes through ``kernels.ops.node_mlp`` so the NE PE
kernel/reference dispatch is uniform across models.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.quant import observers as qobs
from repro.quant import qconfig as qc


def glorot(rng, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(rng, shape, dtype) * scale


def linear_init(rng, d_in: int, d_out: int) -> dict:
    kw, _ = jax.random.split(rng)
    return {"w": glorot(kw, (d_in, d_out)), "b": jnp.zeros((d_out,))}


def linear_apply(p, x: jax.Array, activation: str = "none", mode: str = "auto"):
    """Dense transform through the NE PE.  ``p`` is either a plain
    ``{"w", "b"}`` dict (fp32 path) or a ``quant.QuantizedLinear`` (int8 /
    ap_fixed path) — the quantization transform swaps nodes in the param
    tree and every model picks the right kernel here."""
    if isinstance(p, qc.QuantizedLinear):
        return qc.quantized_linear(p, x, activation=activation, mode=mode)
    qobs.observe_linear_input(p, x)  # no-op outside quant calibration
    return ops.node_mlp(x, p["w"], p["b"], activation=activation, mode=mode)


def fused_linear_operands(p):
    """A linear layer's operand form for the fused megakernel, or ``None``.

    The megakernel's gamma matmul supports exactly two parameterizations:
    plain fp32 weights, and int8 *dynamic* W8A8 (per-row activation
    scales computed inside the kernel — no calibration state).  Returns

      {"kind": "fp32", "w", "b"}                      plain ``{"w","b"}``
      {"kind": "int8", "w_q", "w_scale", "b"}         int8-dynamic

    and ``None`` for everything else (int8-static needs calibrated
    affine activation params, "fixed" needs grid snapping on both sides
    — neither folds into the kernel's requant tail), which tells the
    layer body to fall back to the unfused closure path even when the
    engine asked for fusion.
    """
    if isinstance(p, qc.QuantizedLinear):
        if p.scheme == "int8" and p.act_mode == "dynamic":
            return {
                "kind": "int8",
                "w_q": p.w_q,
                "w_scale": jnp.broadcast_to(
                    jnp.asarray(p.w_scale, jnp.float32), (p.w_q.shape[1],)
                ),
                "b": p.b,
            }
        return None
    return {"kind": "fp32", "w": p["w"], "b": p["b"]}


def fused_dequant_weights(p):
    """f32 ``(w, b)`` view of a linear layer, or ``None`` if not expressible.

    Weight-only dequantization for the fused path's *auxiliary* linears
    (GIN's tiny edge embedding, GIN's second MLP layer): re-quantizing
    their activations inside the fused pass costs more than the matmuls
    themselves, so int8-dynamic weights run as dequantized f32 there.
    int8-static / "fixed" return ``None`` (same opt-out as
    :func:`fused_linear_operands`).
    """
    if isinstance(p, qc.QuantizedLinear):
        if p.scheme == "int8" and p.act_mode == "dynamic":
            return qc.dequantize_int8(p.w_q, p.w_scale), p.b
        return None
    return p["w"], p["b"]


def mlp_init(rng, sizes: Sequence[int]) -> list:
    """sizes = (d_in, h1, ..., d_out)."""
    keys = jax.random.split(rng, len(sizes) - 1)
    return [linear_init(k, a, b) for k, a, b in zip(keys, sizes[:-1], sizes[1:])]


def mlp_apply(ps: list, x: jax.Array, activation: str = "relu", mode: str = "auto",
              final_activation: str = "none"):
    """The paper's MLP PE: pipelined linear->act chain with fused tails."""
    for i, p in enumerate(ps):
        act = activation if i < len(ps) - 1 else final_activation
        x = linear_apply(p, x, activation=act, mode=mode)
    return x


def batch_norm_init(dim: int) -> dict:
    """Inference-mode batch norm (folded scale/shift), as the HLS code bakes
    trained BN constants into the bitstream."""
    return {"scale": jnp.ones((dim,)), "shift": jnp.zeros((dim,))}


def batch_norm_apply(p: dict, x: jax.Array) -> jax.Array:
    return x * p["scale"] + p["shift"]
