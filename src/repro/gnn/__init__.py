"""Model-specific component library (paper §4): GCN, GIN(+VN), GAT, PNA, DGN."""
from repro.gnn.models import GNNConfig, paper_config, init, apply
from repro.gnn.reference import apply_dense

__all__ = ["GNNConfig", "paper_config", "init", "apply", "apply_dense"]
