"""The six representative GNN models (paper Table 2 / §4), on the generic
message-passing core.

Every model is expressed through the same (phi, A, gamma) triple the paper
uses, so the engine (serve/gnn_engine.py) runs all of them unchanged —
the 'generic' claim.  Configurations default to the paper's §5.1 settings:

  GCN / GIN / GIN+VN : 5 layers, dim 100, mean pool, linear head
  PNA                : 4 layers, dim 80,  mean pool, MLP head (40, 20, 1)
  DGN                : 4 layers, dim 100, mean pool, MLP head (50, 25, 1)
  GAT                : 5 layers, 4 heads x 16 features, mean pool, linear head
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import graph as G
from repro.core import layout as LY
from repro.core import message_passing as mp
from repro.gnn import layers as L
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    model: str = "gin"  # gcn | gin | gat | pna | dgn
    num_layers: int = 5
    hidden: int = 100
    feat_dim: int = 9  # OGB mol atom features (as floats)
    edge_dim: int = 3  # OGB mol bond features
    out_dim: int = 1
    heads: int = 4  # GAT
    head_features: int = 16  # GAT per-head features
    avg_degree: float = 2.2  # PNA scaler constant (MolHIV train stat)
    task: str = "graph"  # graph | node
    virtual_node: bool = False
    head_hidden: tuple = ()  # () = single linear head
    kernel_mode: str = "auto"

    @property
    def width(self) -> int:
        return self.heads * self.head_features if self.model == "gat" else self.hidden


def paper_config(model: str, virtual_node: bool = False, **kw) -> GNNConfig:
    base = dict(model=model, virtual_node=virtual_node)
    if model in ("gcn", "gin"):
        base.update(num_layers=5, hidden=100)
    elif model == "gat":
        base.update(num_layers=5, heads=4, head_features=16)
    elif model == "pna":
        base.update(num_layers=4, hidden=80, head_hidden=(40, 20))
    elif model == "dgn":
        base.update(num_layers=4, hidden=100, head_hidden=(50, 25))
    else:
        raise ValueError(model)
    base.update(kw)
    return GNNConfig(**base)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init(rng: jax.Array, cfg: GNNConfig) -> dict:
    keys = iter(jax.random.split(rng, 4 + 4 * cfg.num_layers))
    w = cfg.width
    params: dict = {"encoder": L.linear_init(next(keys), cfg.feat_dim, w), "layers": []}
    for _ in range(cfg.num_layers):
        lp: dict = {}
        if cfg.model == "gcn":
            lp["lin"] = L.linear_init(next(keys), w, w)
        elif cfg.model == "gin":
            lp["edge"] = L.linear_init(next(keys), cfg.edge_dim, w)
            lp["eps"] = jnp.zeros(())
            lp["mlp"] = L.mlp_init(next(keys), (w, 2 * w, w))
        elif cfg.model == "gat":
            h, f = cfg.heads, cfg.head_features
            lp["proj"] = L.linear_init(next(keys), w, h * f)
            lp["att_src"] = L.glorot(next(keys), (h, f))
            lp["att_dst"] = L.glorot(next(keys), (h, f))
        elif cfg.model == "pna":
            lp["pre"] = L.linear_init(next(keys), w, w)
            lp["post"] = L.linear_init(next(keys), 12 * w, w)
        elif cfg.model == "dgn":
            lp["post"] = L.linear_init(next(keys), 3 * w, w)
        params["layers"].append(lp)
    if cfg.virtual_node:
        params["vn_embed"] = jnp.zeros((w,))
        vn_mlps = []
        for _ in range(cfg.num_layers - 1):
            m = L.mlp_init(next(keys), (w, 2 * w, w))
            # zero-init the VN update's output layer: the virtual-node
            # branch starts as a no-op (the sum-pool over ~25 nodes
            # otherwise amplifies magnitudes ~w^0.5 per layer; the OGB
            # reference tames this with BatchNorm, which in inference-mode
            # HLS is folded constants — zero-init is the equivalent here)
            m[-1]["w"] = jnp.zeros_like(m[-1]["w"])
            vn_mlps.append(m)
        params["vn_mlp"] = vn_mlps
    head_sizes = (w,) + tuple(cfg.head_hidden) + (cfg.out_dim,)
    params["head"] = L.mlp_init(next(keys), head_sizes)
    return params


# ---------------------------------------------------------------------------
# per-model layer bodies: each is a (phi, A, gamma) triple over the generic
# ``mp.mp_layer`` dataflow, closed over the shared ``GraphLayout`` plan —
# layer bodies never sort (tools/check_no_raw_sort.py) and never call the
# scatter machinery directly (tools/check_mp_spec.py): graph-static values
# come off ``extras["layout"]`` and every reduction goes through
# ``mp.mp_layer`` / the ``mp.*_aggregate`` helpers.
#
# When the engine asks for fusion (``extras["fused"]``) a body *declares*
# its triple as an ``mp.MPSpec`` + operand dict instead of closures, and
# the whole layer runs as one megakernel pass; bodies whose parameters
# can't lower (int8-static / ap_fixed linears) silently keep the closure
# form — same numerics, unfused — and GAT opts out structurally.
# ---------------------------------------------------------------------------


def _spec_precision(lin1):
    return "int8" if lin1["kind"] == "int8" else "fp32"


def _lin1_operands(lin1):
    """fused_linear_operands dict -> the kernel's w1/b1/w1_scale triple."""
    if lin1["kind"] == "int8":
        return dict(w1=lin1["w_q"], b1=lin1["b"], w1_scale=lin1["w_scale"])
    return dict(w1=lin1["w"], b1=lin1["b"])


def _gcn_layer(g: G.Graph, x, lp, cfg, extras):
    # x' = W^T sum_{j in N(i) U {i}} x_j / sqrt((d_i+1)(d_j+1)) + b
    layout = extras["layout"]
    if layout is not None and layout.gcn_inv_sqrt is not None:
        inv_sqrt = layout.gcn_inv_sqrt
    else:
        inv_sqrt = jax.lax.rsqrt(G.in_degree(g).astype(jnp.float32) + 1.0)
    xw = L.linear_apply(lp["lin"], x, mode=cfg.kernel_mode)
    xs = xw * inv_sqrt[:, None]

    if extras.get("fused") and layout is not None:
        # the linear runs *before* aggregation (W^T and the sum commute),
        # so the fused pass is pure dataflow: gamma rescales and adds the
        # normalized self-loop — any precision of lp["lin"] is eligible
        spec = mp.MPSpec(phi="copy", ops=("sum",), gamma="gcn")
        return mp.mp_layer(
            g, xs, layout=layout, spec=spec, mode=cfg.kernel_mode,
            operands=dict(msrc=xs, x_res=xs, nop=inv_sqrt[:, None]),
        )

    def phi(x_src, x_dst, e):
        return x_src

    def gamma(xs_, agg):
        return (agg + xs_) * inv_sqrt[:, None]  # self loop folded in

    return mp.mp_layer(g, xs, phi, gamma, ops=("sum",), layout=layout)


def _gin_layer(g: G.Graph, x, lp, cfg, extras):
    # phi(x, e) = relu(x_src + edge_embed)   (paper: x + eps*m with edge emb)
    layout = extras["layout"]
    if extras.get("fused") and layout is not None:
        lin1 = L.fused_linear_operands(lp["mlp"][0])
        edge_wb = L.fused_dequant_weights(lp["edge"])
        lin2_wb = L.fused_dequant_weights(lp["mlp"][1])
        if lin1 is not None and edge_wb is not None and lin2_wb is not None:
            # edge features gather into plan order first, so the edge
            # embedding lands pre-sorted as the kernel's phi operand
            ef_sorted = jnp.take(g.edge_feat, layout.perm, axis=0)
            e_emb = kops.node_mlp(
                ef_sorted, edge_wb[0], edge_wb[1], activation="none",
                mode=cfg.kernel_mode,
            )
            spec = mp.MPSpec(
                phi="add_relu", ops=("sum",), gamma="gin",
                precision=_spec_precision(lin1),
            )
            return mp.mp_layer(
                g, x, layout=layout, spec=spec, mode=cfg.kernel_mode,
                operands=dict(
                    msrc=x, x_res=(1.0 + lp["eps"]) * x, eop=e_emb,
                    w2=lin2_wb[0], b2=lin2_wb[1], **_lin1_operands(lin1),
                ),
            )

    e_emb = L.linear_apply(lp["edge"], g.edge_feat, mode=cfg.kernel_mode)

    def phi(x_src, x_dst, e):
        return jax.nn.relu(x_src + e)

    def gamma(x_, agg):
        return L.mlp_apply(
            lp["mlp"], (1.0 + lp["eps"]) * x_ + agg, mode=cfg.kernel_mode
        )

    return mp.mp_layer(
        g, x, phi, gamma, ops=("sum",), edge_feat=e_emb, layout=layout
    )


def _gat_layer(g: G.Graph, x, lp, cfg, extras):
    """GAT's A(.) is an edge softmax, not a plain reduction: the softmax
    normalizer couples all of a destination's edges before any message can
    fold in, so GAT is the declared ``MPSpec`` opt-out (it ignores
    ``extras["fused"]``).  phi produces per-edge logits and messages and
    ``mp.gat_attention`` normalizes + reduces over the shared plan;
    gamma is the elu tail."""
    h, f = cfg.heads, cfg.head_features
    n = g.num_nodes
    xp = L.linear_apply(lp["proj"], x, mode=cfg.kernel_mode).reshape(n, h, f)
    a_src = jnp.einsum("nhf,hf->nh", xp, lp["att_src"])
    a_dst = jnp.einsum("nhf,hf->nh", xp, lp["att_dst"])
    logits = jax.nn.leaky_relu(
        jnp.take(a_src, g.src, axis=0) + jnp.take(a_dst, g.dst, axis=0), 0.2
    )  # (E, H) in COO order
    agg = mp.gat_attention(
        g, logits, xp, layout=extras["layout"], mode=cfg.kernel_mode
    )
    out = jax.nn.elu(agg)
    return jnp.where(g.node_mask[:, None], out, 0.0)


def _pna_layer(g: G.Graph, x, lp, cfg, extras):
    layout = extras["layout"]
    xp = L.linear_apply(lp["pre"], x, activation="relu", mode=cfg.kernel_mode)

    if extras.get("fused") and layout is not None:
        lin1 = L.fused_linear_operands(lp["post"])
        if lin1 is not None:
            if layout.pna_scalers is not None:
                scalers = layout.pna_scalers
            else:
                scalers = mp.pna_scalers(
                    g, cfg.avg_degree, degree=layout.in_degree
                )
            spec = mp.MPSpec(
                phi="copy", ops=("sum", "sqsum", "max", "min"), gamma="pna",
                precision=_spec_precision(lin1),
            )
            return mp.mp_layer(
                g, xp, layout=layout, spec=spec, mode=cfg.kernel_mode,
                operands=dict(
                    msrc=xp, x_res=x, nop=scalers, **_lin1_operands(lin1)
                ),
            )

    def phi(x_src, x_dst, e):
        return x_src

    def aggregate(graph, messages, layout_):
        return mp.pna_aggregate(graph, messages, cfg.avg_degree, layout=layout_)

    def gamma(xp_, tower):
        out = L.linear_apply(
            lp["post"], tower, activation="relu", mode=cfg.kernel_mode
        )
        return out + x  # skip connection (§4.3) from the layer input

    return mp.mp_layer(g, xp, phi, gamma, aggregate=aggregate, layout=layout)


def _dgn_layer(g: G.Graph, x, lp, cfg, extras):
    """mean + directional-derivative aggregation along eigenvector phi1 (§4.4).

    B_dx row i: w_ij = (phi_j - phi_i) / sum_k |phi_k - phi_i|;
    y_dx_i = | sum_j w_ij x_j  -  x_i sum_j w_ij |.

    The directional weights depend only on the graph and its eigenvector,
    so they live on the layout (computed once per forward, not per layer);
    the per-layer work is phi = x_src, A = [mean, w-weighted sum], and
    gamma assembles the |.| derivative and the post-MLP + skip.  Fused,
    the weighted sum is the kernel's "wsum" accumulator over the plan-
    ordered weights and the derivative assembles in the finalize tail.
    """
    layout = extras["layout"]
    if layout is not None and layout.dgn_w_e is not None:
        w_e, wsum = layout.dgn_w_e, layout.dgn_wsum
    else:
        w_e, wsum = mp.dgn_directional_weights(g, extras["eigvec"])

    if extras.get("fused") and layout is not None:
        lin1 = L.fused_linear_operands(lp["post"])
        if lin1 is not None:
            ew_sorted = jnp.take(w_e, layout.perm)[:, None]
            spec = mp.MPSpec(
                phi="copy", ops=("sum", "wsum"), gamma="dgn",
                precision=_spec_precision(lin1),
            )
            return mp.mp_layer(
                g, x, layout=layout, spec=spec, mode=cfg.kernel_mode,
                operands=dict(
                    msrc=x, x_res=x, nop=wsum[:, None], ew=ew_sorted,
                    **_lin1_operands(lin1),
                ),
            )

    def phi(x_src, x_dst, e):
        return x_src

    def aggregate(graph, messages, layout_):
        return mp.dgn_aggregate(graph, messages, w_e, layout=layout_)

    def gamma(x_, agg):
        d = x_.shape[-1]
        mean_agg, wx = agg[:, :d], agg[:, d:]
        dx_agg = jnp.abs(wx - x_ * wsum[:, None])
        tower = jnp.concatenate([x_, mean_agg, dx_agg], axis=-1)
        out = L.linear_apply(
            lp["post"], tower, activation="relu", mode=cfg.kernel_mode
        )
        return out + x_  # skip connection, as in PNA (§4.4)

    return mp.mp_layer(g, x, phi, gamma, aggregate=aggregate, layout=layout)


_LAYERS = {"gcn": _gcn_layer, "gin": _gin_layer, "gat": _gat_layer,
           "pna": _pna_layer, "dgn": _dgn_layer}


# ---------------------------------------------------------------------------
# full forward pass
# ---------------------------------------------------------------------------


def apply(
    params: dict,
    g: G.Graph,
    cfg: GNNConfig,
    eigvec: Optional[jax.Array] = None,
    num_graphs: Optional[int] = None,
    layout: Optional[LY.GraphLayout] = None,
    share_layout: bool = True,
    fused: bool = False,
) -> jax.Array:
    """Forward pass.  Returns (num_graphs, out_dim) for graph tasks or
    (N_pad, out_dim) for node tasks.  ``eigvec`` is DGN's precomputed
    Laplacian eigenvector *input* (a model input, like the paper's).

    ``num_graphs`` is the static graph-slot count (a packed bucket's G_pad
    or the serving batch size); it sizes the pooled / virtual-node buffers.
    When omitted it falls back to the ``num_nodes`` upper bound, which is
    correct but allocates one pooled row per padded node.

    ``layout`` is the shared destination-ordered edge plan (§3.4): pass
    one built at pack/ingest time for a zero-sort forward, or leave it
    ``None`` to build it here (exactly one on-device sort, amortized over
    every layer).  ``share_layout=False`` disables the plan entirely and
    reverts to the seed per-call-sort path — kept for the bitwise parity
    tests and the A/B sort-count benchmark, never for serving.

    ``fused`` lowers each layer body to its declarative ``mp.MPSpec`` and
    runs the whole (phi, A, gamma) pass through the fused megakernel
    (``kernels/fused_mp.py`` / its oracle) instead of separate gather /
    reduce / update ops.  Requires ``share_layout``; GAT and layers whose
    quantized parameters can't lower (int8-static, ap_fixed) keep the
    closure path automatically.  Off by default: the unfused path is the
    parity oracle, exactly as the per-call-sort path is for layouts.
    """
    m = g.num_nodes if num_graphs is None else num_graphs
    layer_fn = _LAYERS[cfg.model]
    if share_layout:
        layout = LY.for_model(
            layout, g, cfg.model, avg_degree=cfg.avg_degree, eigvec=eigvec
        )
    else:
        layout = None
    extras = {"eigvec": eigvec, "layout": layout, "fused": fused}
    x = L.linear_apply(params["encoder"], g.node_feat, mode=cfg.kernel_mode)
    x = jnp.where(g.node_mask[:, None], x, 0.0)
    vn = None  # (m, w) per-graph virtual-node state
    if cfg.virtual_node:
        vn = jnp.broadcast_to(params["vn_embed"], (m, x.shape[-1]))

    for li in range(cfg.num_layers):
        if cfg.virtual_node:
            # virtual node broadcasts its state to every node of its graph
            gid = jnp.clip(g.graph_id, 0, m - 1)
            x = x + jnp.take(vn, gid, axis=0) * g.node_mask[:, None]
        x = layer_fn(g, x, params["layers"][li], cfg, extras)
        if cfg.virtual_node and li < cfg.num_layers - 1:
            # vn_{l+1} = MLP(vn_l + sum-pool of that graph's nodes)
            pooled = mp.global_pool(g, x, op="sum", num_graphs=m)
            vn = L.mlp_apply(
                params["vn_mlp"][li], pooled + vn, mode=cfg.kernel_mode
            )

    if cfg.task == "graph":
        pooled = mp.global_pool(g, x, op="mean", num_graphs=m)
        return L.mlp_apply(params["head"], pooled, mode=cfg.kernel_mode)
    return L.mlp_apply(params["head"], x, mode=cfg.kernel_mode)


def forward_program(
    cfg: GNNConfig,
    num_graphs: Optional[int] = None,
    share_layout: bool = True,
    fused: bool = False,
) -> Callable:
    """The engine-facing program: :func:`apply` with its statics bound.

    Returns a pure ``(params, graph, eigvec, layout) -> logits`` closure —
    the positional shape every compiled serving program shares.  Built
    exactly once per compile-cache entry by ``serve.executor.Executor``
    (the only module that may wrap it in ``jax.jit``; see
    ``tools/check_engine_singlepath.py``).  ``fused`` is a program-level
    static like ``share_layout``: it changes which ops the program lowers
    to, never the positional signature.
    """

    def program(params, g: G.Graph, eigvec, layout):
        return apply(params, g, cfg, eigvec=eigvec, num_graphs=num_graphs,
                     layout=layout, share_layout=share_layout, fused=fused)

    return program
