"""repro: GenGNN (generic GNN acceleration framework) reproduced on TPU/JAX,
plus the multi-pod LM substrate for the assigned architecture pool.

Layers (bottom-up): kernels (Pallas) -> core (message passing) -> gnn /
models -> sharding / optim / checkpoint / data -> train / serve -> launch.
"""
__version__ = "1.0.0"
