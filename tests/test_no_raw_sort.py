"""Sort-ownership hygiene: the no-raw-sort guard passes on the real tree
and actually catches violations (so the CI step can't silently no-op)."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_no_raw_sort as cnrs  # noqa: E402


def test_no_module_outside_core_sorts_edges():
    assert cnrs.main() == 0


def test_guard_flags_raw_sorts(tmp_path):
    bad = tmp_path / "rogue.py"
    bad.write_text(
        "import jax, jax.numpy as jnp\n"
        "from jax.numpy import argsort\n"
        "from repro.core.scatter_gather import sort_by_segment\n"
        "def f(ids, n):\n"
        "    perm, s, o = sort_by_segment(ids, n)\n"
        "    a = argsort(ids)            # bare-name import\n"
        "    b = jnp.argsort(ids)\n"
        "    c = jnp.lexsort((ids,))\n"
        "    d = jnp.sort(ids)\n"
        "    return jax.lax.sort(ids)    # dotted module chain\n"
    )
    errors = cnrs.check_module(bad)
    for needle in ("sort_by_segment", "argsort", "lexsort", "`sort`"):
        assert any(needle in e for e in errors), (needle, errors)
    assert len(errors) == 6


def test_guard_allows_plan_consumers_and_host_sorts(tmp_path):
    ok = tmp_path / "fine.py"
    ok.write_text(
        "from repro.core import layout as LY\n"
        "def f(layout, graph, msgs, recs):\n"
        "    recs.sort(key=len)          # host-side list sort is fine\n"
        "    xs = sorted(recs)\n"
        "    return LY.segment_reduce(layout, msgs), xs\n"
    )
    assert cnrs.check_module(ok) == []


def test_guard_runs_as_script():
    r = subprocess.run(
        [sys.executable, "tools/check_no_raw_sort.py"],
        capture_output=True, text=True, cwd=ROOT,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
