"""Roofline machinery unit tests: HLO collective parsing, ring-cost model,
bf16-normalization correction, and term computation."""
import numpy as np

from repro import roofline as R

HLO_SAMPLE = """
  %ar = f32[16,4096,6144]{2,1,0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag.1 = bf16[512,1024]{1,0} all-gather(%y), replica_groups=[32,16]<=[512]T(1,0), dimensions={0}
  %rs = f32[64,64]{1,0} reduce-scatter(%z), replica_groups={{0,1}}, dimensions={0}
  %a2a = bf16[8,128]{1,0} all-to-all(%w), replica_groups=[64,8]<=[512]
  %cp = f32[256]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(%a, %b)
"""


def test_parse_collectives_ops_and_groups():
    colls = R.parse_collectives(HLO_SAMPLE)
    ops = [c["op"] for c in colls]
    assert ops == ["all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                   "collective-permute"]
    ar, ag, rs, a2a, cp = colls
    assert ar["group_size"] == 4 and ar["dtype"] == "f32"
    assert ar["result_bytes"] == 16 * 4096 * 6144 * 4
    assert ag["group_size"] == 16  # iota format [groups, group_size]
    assert rs["group_size"] == 2
    assert a2a["group_size"] == 8
    assert cp["group_size"] == 1 or cp["wire_bytes"] == cp["result_bytes"]


def test_ring_cost_model():
    colls = R.parse_collectives(HLO_SAMPLE)
    ar = colls[0]
    # all-reduce: 2 * bytes * (g-1)/g
    assert np.isclose(ar["wire_bytes"], 2 * ar["result_bytes"] * 3 / 4)
    ag = colls[1]
    assert np.isclose(ag["wire_bytes"], ag["result_bytes"] * 15 / 16)
    rs = colls[2]
    assert np.isclose(rs["wire_bytes"], rs["result_bytes"] * 1)  # (g-1) = 1


def test_bf16_normalization_correction_halves_large_f32_only():
    colls = [
        {"op": "all-reduce", "result_bytes": int(1e9), "group_size": 4,
         "wire_bytes": 1e9, "dtype": "f32"},
        {"op": "all-reduce", "result_bytes": int(1e3), "group_size": 4,
         "wire_bytes": 1e3, "dtype": "f32"},  # small: loss scalar — untouched
        {"op": "all-gather", "result_bytes": int(1e9), "group_size": 4,
         "wire_bytes": 1e9, "dtype": "bf16"},  # already bf16 — untouched
    ]
    out = R.bf16_normalization_correction(colls, model_dtype_bf16=True)
    assert out[0]["wire_bytes"] == 0.5e9 and out[0].get("bf16_corrected")
    assert out[1]["wire_bytes"] == 1e3
    assert out[2]["wire_bytes"] == 1e9
    noop = R.bf16_normalization_correction(colls, model_dtype_bf16=False)
    assert noop[0]["wire_bytes"] == 1e9


def test_cell_roofline_terms_and_bound():
    rec = {
        "flops_per_device": R.PEAK_FLOPS,  # 1 second of compute
        "bytes_per_device": R.HBM_BW * 10,  # (unfused; not the verdict)
        "memory": {"argument_bytes": int(R.HBM_BW * 0.1), "output_bytes": 0,
                   "temp_bytes": int(R.HBM_BW * 0.1)},
        "collectives": [
            {"op": "all-reduce", "result_bytes": 1, "group_size": 16,
             "wire_bytes": R.ICI_BW * 2.0, "dtype": "bf16"},
        ],
        "model_flops_per_device": R.PEAK_FLOPS * 0.5,
    }
    rf = R.cell_roofline(rec)
    assert np.isclose(rf["compute_s"], 1.0)
    assert np.isclose(rf["memory_s"], 0.3)  # args + 2*temps
    assert np.isclose(rf["collective_s"], 2.0)
    assert rf["bound"] == "collective"
    assert np.isclose(rf["roofline_fraction"], 0.5)
    assert np.isclose(rf["useful_flops_ratio"], 0.5)


def test_pod_axis_collectives_use_dci_bandwidth():
    colls = [{"op": "all-reduce", "result_bytes": 1, "group_size": 2,
              "wire_bytes": R.DCI_BW, "dtype": "bf16"}]
    t_pod = R.collective_seconds(colls, pod_group_size=2)
    t_ici = R.collective_seconds(colls, pod_group_size=None)
    assert np.isclose(t_pod, 1.0)
    assert np.isclose(t_ici, R.DCI_BW / R.ICI_BW)
