"""Contract hygiene: the mp-spec guard passes on the real tree and
actually catches violations (so the CI step can't silently no-op)."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_mp_spec as cms  # noqa: E402


def test_gnn_models_speak_the_contract():
    assert cms.main() == 0


def test_guard_flags_primitive_calls(tmp_path):
    bad = tmp_path / "rogue_model.py"
    bad.write_text(
        "from repro.core.message_passing import gather_scatter\n"
        "from repro.kernels import ops as kops\n"
        "import jax\n"
        "def layer(g, msg, lay):\n"
        "    a = gather_scatter(g, msg)            # bare-name import\n"
        "    b = kops.segment_reduce(msg, lay.ids_sorted, 8)\n"
        "    c = kops.edge_softmax(msg, lay.ids_sorted, 8)\n"
        "    return jax.ops.segment_sum(msg, lay.ids_sorted, 8), a, b, c\n"
    )
    errors = cms.check_module(bad)
    for needle in ("gather_scatter", "segment_reduce", "edge_softmax",
                   "segment_sum"):
        assert any(needle in e for e in errors), (needle, errors)
    assert len(errors) == 4


def test_guard_allows_the_contract_surface(tmp_path):
    ok = tmp_path / "fine_model.py"
    ok.write_text(
        "from repro.core import message_passing as mp\n"
        "def layer(g, x, lay, spec, operands):\n"
        "    h = mp.mp_layer(g, x, spec=spec, operands=operands, layout=lay)\n"
        "    att = mp.gat_attention(g, x, x[:, None, :], layout=lay)\n"
        "    return mp.global_pool(g, h), att\n"
    )
    assert cms.check_module(ok) == []


def test_guard_runs_as_script():
    r = subprocess.run(
        [sys.executable, "tools/check_mp_spec.py"],
        capture_output=True, text=True, cwd=ROOT,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
