"""Single-path hygiene: the engine-singlepath guard passes on the real
tree and actually catches violations (so the CI step can't silently
no-op) — ``time.perf_counter`` timing and ``jax.jit`` program
construction live only in ``serve/executor.py``."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_engine_singlepath as cesp  # noqa: E402


def test_no_serve_module_owns_timing_or_compilation():
    assert cesp.main() == 0


def test_guard_flags_private_timing_and_compile_paths(tmp_path):
    bad = tmp_path / "rogue_mode.py"
    bad.write_text(
        "import time, jax\n"
        "from time import perf_counter\n"
        "from jax import jit\n"
        "def infer_rogue(fn, params, g):\n"
        "    compiled = jax.jit(fn)          # private compile path\n"
        "    handle = jit                    # aliasing counts too\n"
        "    t0 = time.perf_counter()        # private timed region\n"
        "    t1 = perf_counter()\n"
        "    out = compiled(params, g)\n"
        "    return out, perf_counter() - t1, t0, handle\n"
    )
    errors = cesp.check_module(bad)
    for needle in ("jax.jit", "time.perf_counter", "perf_counter timing",
                   "jit program construction"):
        assert any(needle in e for e in errors), (needle, errors)
    assert len(errors) >= 5


def test_guard_resolves_module_and_name_aliases(tmp_path):
    """`import time as t` / `import jax as j` / `from time import monotonic`
    / `as`-renamed from-imports must not slip past the guard."""
    bad = tmp_path / "sneaky_mode.py"
    bad.write_text(
        "import time as t\n"
        "import jax as j\n"
        "from time import monotonic\n"
        "from jax import jit as compile_me\n"
        "def infer_sneaky(fn, params, g):\n"
        "    prog = j.jit(fn)\n"
        "    prog2 = compile_me(fn)\n"
        "    t0 = t.perf_counter()\n"
        "    t1 = monotonic()\n"
        "    return prog(params, g), prog2, t0, t1\n"
    )
    errors = cesp.check_module(bad)
    for needle in ("jax.jit", "time.perf_counter", "monotonic timing",
                   "jit program construction"):
        assert any(needle in e for e in errors), (needle, errors)
    assert len(errors) == 4


def test_guard_allows_executor_consumers(tmp_path):
    ok = tmp_path / "fine_mode.py"
    ok.write_text(
        "from repro.serve.clock import VirtualClock\n"
        "def serve(executor, prepared, model, clock):\n"
        "    opened_at = clock.now()         # injected clock: the one way\n"
        "    out, dt = executor.run(prepared, model=model)\n"
        "    return out, dt, opened_at\n"
    )
    assert cesp.check_module(ok) == []


def test_guard_flags_wall_clock_reads(tmp_path):
    """``time.time`` used to be tolerated as a harmless stamp; since the
    scheduler runs on the injectable Clock it is a determinism leak and
    must be flagged in every form (attribute, from-import, alias)."""
    bad = tmp_path / "wall_clock_mode.py"
    bad.write_text(
        "import time\n"
        "import time as t\n"
        "from time import time as wall\n"
        "def admit(req):\n"
        "    a = time.time()\n"
        "    b = t.time()\n"
        "    c = wall()\n"
        "    return a, b, c\n"
    )
    errors = cesp.check_module(bad)
    assert len(errors) == 3, errors
    assert all("time" in e and "Clock" in e for e in errors)


def test_clock_module_is_timing_exempt_but_compile_checked(tmp_path):
    """serve/clock.py wraps the real clock, so its timing references are
    sanctioned — but a jit path hiding in it must still fail."""
    assert cesp.check_module(cesp.SERVE / "clock.py", allow_timing=True) == []
    # the real clock module does reference time; without the exemption the
    # guard sees it (so the exemption is load-bearing, not vacuous)
    assert cesp.check_module(cesp.SERVE / "clock.py") != []
    sneaky = tmp_path / "clocklike.py"
    sneaky.write_text(
        "import time, jax\n"
        "def now():\n"
        "    return time.monotonic()\n"
        "def compile_here(fn):\n"
        "    return jax.jit(fn)\n"
    )
    errors = cesp.check_module(sneaky, allow_timing=True)
    assert len(errors) == 1 and "jit program construction" in errors[0]


def test_gnn_serving_modules_are_actually_covered():
    """The facade, scheduler, clock, pipeline, LM engine — and since the
    threading rule landed, the executor itself — must be in the guard's
    walk set (a rename must not silently drop them from coverage)."""
    walked = {p.name for p in cesp.SERVE.glob("*.py")}
    assert {"gnn_engine.py", "scheduler.py", "clock.py", "engine.py",
            "pipeline.py", cesp.ALLOWED} <= walked
    # the exemptions are one-sided, never a full skip
    assert "clock.py" not in cesp.COMPILE_EXEMPT
    assert "clock.py" in cesp.TIMING_EXEMPT
    assert "engine.py" in cesp.COMPILE_EXEMPT
    assert "engine.py" not in cesp.TIMING_EXEMPT
    assert cesp.THREADING_EXEMPT == {"pipeline.py"}
    # the executor's timing/compile allowance never extends to threading
    assert cesp.ALLOWED not in cesp.THREADING_EXEMPT
    assert "pipeline.py" not in cesp.TIMING_EXEMPT
    assert "pipeline.py" not in cesp.COMPILE_EXEMPT


def test_guard_flags_threading_outside_pipeline(tmp_path):
    """Worker threads anywhere but serve/pipeline.py are a determinism
    leak: every import form of threading / _thread / concurrent.futures
    must be flagged, and the exemption must be load-bearing."""
    bad = tmp_path / "threaded_mode.py"
    bad.write_text(
        "import threading\n"
        "import threading as th\n"
        "import concurrent.futures\n"
        "from concurrent import futures\n"
        "from concurrent.futures import ThreadPoolExecutor\n"
        "import _thread\n"
        "def spawn(fn):\n"
        "    return threading.Thread(target=fn)\n"
    )
    errors = cesp.check_module(bad)
    assert len(errors) == 6, errors
    assert all("threading surface" in e for e in errors)
    # the exemption clears exactly the threading errors, nothing else
    assert cesp.check_module(bad, allow_threading=True) == []
    # the real pipeline module needs the exemption (it is load-bearing)
    pipeline = cesp.SERVE / "pipeline.py"
    assert cesp.check_module(pipeline) != []
    assert cesp.check_module(pipeline, allow_threading=True) == []
    # allow_threading grants nothing beyond threading
    sneaky = tmp_path / "sneaky_pipeline.py"
    sneaky.write_text(
        "import time, jax\n"
        "from concurrent.futures import ThreadPoolExecutor\n"
        "def prep(fn):\n"
        "    return jax.jit(fn), time.perf_counter()\n"
    )
    errors = cesp.check_module(sneaky, allow_threading=True)
    assert len(errors) == 2, errors


def test_executor_is_threading_checked():
    """The executor keeps its timing/compile sanction but is walked for
    the threading rule — dispatch-ahead must stay thread-free there."""
    assert cesp.check_module(cesp.SERVE / cesp.ALLOWED,
                             allow_timing=True, allow_compile=True) == []


def test_lm_engine_is_compile_exempt_but_timing_checked(tmp_path):
    """serve/engine.py keeps its own jit pair (a separate serving stack)
    but must read wall time only through the injected Clock — the old
    blanket exemption is gone, and a ``time`` read hiding in it fails."""
    assert cesp.check_module(cesp.SERVE / "engine.py", allow_compile=True) == []
    # the real engine does jit; without the exemption the guard sees it
    # (so the exemption is load-bearing, not vacuous)
    assert cesp.check_module(cesp.SERVE / "engine.py") != []
    sneaky = tmp_path / "enginelike.py"
    sneaky.write_text(
        "import time, jax\n"
        "def prefill(fn):\n"
        "    return jax.jit(fn)\n"
        "def stamp():\n"
        "    return time.perf_counter()\n"
    )
    errors = cesp.check_module(sneaky, allow_compile=True)
    assert len(errors) == 1 and "time.perf_counter timing" in errors[0]


def test_obs_package_is_walked_with_full_rules(tmp_path):
    """src/repro/obs/ is part of the guard's walk set with no exemptions:
    the tracer reads time only through its injected Clock, so a rogue
    ``time`` read or jit path in the telemetry layer must fail."""
    obs_files = {p.name for p in cesp.OBS.glob("*.py")}
    assert {"trace.py", "metrics.py", "export.py"} <= obs_files
    for p in sorted(cesp.OBS.glob("*.py")):
        assert cesp.check_module(p) == [], p.name
    bad = tmp_path / "rogue_obs.py"
    bad.write_text(
        "import time\n"
        "def span_now():\n"
        "    return time.perf_counter()\n"
    )
    assert cesp.check_module(bad) != []


def test_guard_flags_rogue_executable_serialization(tmp_path):
    """Every import/reference form of jax.experimental.serialize_executable
    outside serve/aot.py and the executor is a second persistence path
    and must fail; the exemption clears exactly those errors."""
    bad = tmp_path / "rogue_persist.py"
    bad.write_text(
        "import jax\n"
        "import jax.experimental.serialize_executable\n"
        "from jax.experimental import serialize_executable\n"
        "from jax.experimental.serialize_executable import serialize\n"
        "from jax.experimental.serialize_executable import "
        "deserialize_and_load as undump\n"
        "def persist(compiled):\n"
        "    a = jax.experimental.serialize_executable.serialize(compiled)\n"
        "    b = serialize(compiled)\n"
        "    return a, b, undump\n"
    )
    errors = cesp.check_module(bad)
    assert len(errors) >= 6, errors
    assert any("persistence surface" in e for e in errors)
    assert any("executable serialization" in e for e in errors)
    assert cesp.check_module(bad, allow_serialize=True) == []
    # allow_serialize grants nothing beyond serialization
    sneaky = tmp_path / "sneaky_persist.py"
    sneaky.write_text(
        "import time, jax\n"
        "from jax.experimental.serialize_executable import serialize\n"
        "def dump(fn):\n"
        "    return serialize(jax.jit(fn)), time.perf_counter()\n"
    )
    errors = cesp.check_module(sneaky, allow_serialize=True)
    assert len(errors) == 2, errors


def test_aot_module_is_compile_and_serialize_exempt_only():
    """serve/aot.py joins the walk with compile+serialize allowances but
    stays timing- and threading-checked; the exemption sets stay
    one-sided."""
    walked = {p.name for p in cesp.SERVE.glob("*.py")}
    assert "aot.py" in walked
    assert cesp.check_module(cesp.SERVE / "aot.py", allow_compile=True,
                             allow_serialize=True) == []
    assert "aot.py" in cesp.COMPILE_EXEMPT
    assert "aot.py" not in cesp.TIMING_EXEMPT
    assert "aot.py" not in cesp.THREADING_EXEMPT
    assert cesp.SERIALIZE_EXEMPT == {"aot.py", "executor.py"}


def test_guard_runs_as_script():
    r = subprocess.run(
        [sys.executable, "tools/check_engine_singlepath.py"],
        capture_output=True, text=True, cwd=ROOT,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
