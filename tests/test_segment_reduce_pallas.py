"""The Pallas ``segment_reduce_sorted`` kernel in interpret mode against
the pure-jnp oracle (kernels/ref.py) for every op — including empty
segments and edge counts that are not multiples of the block sizes.

Two layers are covered on purpose:
  * the raw kernel contract (sum-family exact; max/min leave ±FILL in
    empty rows; "mean" returns the per-segment *sum*, finalized by ops),
  * the public ``ops.segment_reduce(mode="kernel")`` semantics, which must
    equal the oracle bit-for-contract for all five ops.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import segment_reduce
from repro.kernels.segment_reduce import _FILL, segment_reduce_sorted

RNG = np.random.default_rng(7)
OPS = ["sum", "mean", "sqsum", "max", "min"]


def _case(e, n, f, pad_tail=0, skip_even=False):
    """Sorted ids in [0, n) with optional padding tail (ids == n) and,
    with ``skip_even``, only odd segments populated (evens stay empty)."""
    pool = np.arange(1, n, 2) if skip_even else np.arange(n)
    ids = np.sort(RNG.choice(pool, size=e)).astype(np.int32)
    if pad_tail:
        ids[-pad_tail:] = n
    vals = RNG.normal(size=(e, f)).astype(np.float32)
    return jnp.asarray(vals), jnp.asarray(ids)


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize(
    "e,n,f,be,bn",
    [
        (64, 16, 8, 32, 8),  # clean multiples of both blocks
        (100, 24, 16, 32, 16),  # E not a multiple of block_e
        (37, 19, 4, 16, 8),  # E and N both ragged
        (260, 130, 8, 64, 64),  # N not a multiple of block_n
    ],
)
def test_raw_kernel_matches_oracle(op, e, n, f, be, bn):
    vals, ids = _case(e, n, f, pad_tail=max(e // 10, 1))
    got = segment_reduce_sorted(
        vals, ids, n, op, block_e=be, block_n=bn, interpret=True
    )
    if op == "mean":
        # raw kernel contract: mean is finalized by ops; kernel returns sums
        want = np.asarray(ref.segment_reduce_sorted_ref(vals, ids, n, "sum"))
    else:
        want = np.asarray(ref.segment_reduce_sorted_ref(vals, ids, n, op))
        if op in ("max", "min"):
            # raw kernel leaves ±FILL in empty rows (oracle writes 0)
            count = np.bincount(
                np.asarray(ids)[np.asarray(ids) < n], minlength=n
            )[:, None]
            want = np.where(count > 0, want, _FILL[op])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("op", OPS)
def test_public_op_matches_oracle_with_empty_segments(op):
    # only odd segments populated; evens (incl. segment 0) must come out 0
    vals, ids = _case(96, 20, 6, pad_tail=9, skip_even=True)
    got = segment_reduce(vals, ids, 20, op, mode="kernel")
    want = ref.segment_reduce_sorted_ref(vals, ids, 20, op)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )
    assert float(np.abs(np.asarray(got)[::2]).max()) == 0.0


@pytest.mark.parametrize("op", OPS)
def test_public_op_all_segments_empty(op):
    # every edge is padding: output must be identically zero
    vals = jnp.asarray(RNG.normal(size=(16, 3)), jnp.float32)
    ids = jnp.full((16,), 8, jnp.int32)  # == num_segments -> padding
    got = segment_reduce(vals, ids, 8, op, mode="kernel")
    np.testing.assert_array_equal(np.asarray(got), np.zeros((8, 3), np.float32))


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("e,n,f", [(100, 24, 16), (513, 129, 4)])
def test_public_op_ragged_shapes(op, e, n, f):
    vals, ids = _case(e, n, f, pad_tail=e // 7)
    got = segment_reduce(vals, ids, n, op, mode="kernel")
    want = ref.segment_reduce_sorted_ref(vals, ids, n, op)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )
