"""Shared-GraphLayout parity: one sort per graph must change NOTHING.

The layout plan (core/layout.py) replaces 5-20 per-aggregation edge sorts
with one shared conversion (paper §3.4).  Because the plan's keys and
stable sort are exactly what every aggregation ran privately, the refactor
must be *bitwise* invisible:

  * ``apply`` with the shared plan (built in-forward, prebuilt on device,
    or host-built at pack time) == the seed per-call-sort path
    (``share_layout=False``), for all six models, across padding fuzz;
  * every engine mode (stream / batched / packed) x fp32 / int8 serves
    bitwise-identical outputs with sharing on and off;
  * the jaxpr of a shared forward contains at most ONE ``sort`` op
    (zero when the plan is supplied), while the seed path has many;
  * the masking contract: padding-edge message values are dropped by the
    plan's out-of-range ids, so garbage there never reaches real rows.

The deterministic seeded cases always run; with ``hypothesis`` installed
(requirements-dev.txt) the parity property is additionally fuzzed over
random graphs and padding amounts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layout as LY
from repro.core import message_passing as mp
from repro.core.batching import BucketBudget, pack_eigvecs, pack_graphs, pack_layout
from repro.core.graph import batch_graphs
from repro.gnn import init
from repro.gnn.models import apply, paper_config

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to the seeded cases only
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)
MODELS = [("gcn", False), ("gin", False), ("gin", True), ("gat", False),
          ("pna", False), ("dgn", False)]
# (n_pad, e_pad) padding fuzz: tight, loose, lopsided
PADDINGS = [(48, 120), (80, 160), (50, 300)]


def _random_batch(rng, n_pad, e_pad, n_graphs=3):
    gs = []
    for _ in range(n_graphs):
        n = int(rng.integers(5, 14))
        e = int(rng.integers(n, 2 * n))
        gs.append((
            rng.integers(0, n, e).astype(np.int32),
            rng.integers(0, n, e).astype(np.int32),
            rng.normal(size=(n, 9)).astype(np.float32),
            rng.normal(size=(e, 3)).astype(np.float32),
        ))
    return batch_graphs(gs, n_pad=n_pad, e_pad=e_pad)


def _bitwise(a, b, msg):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=msg)


# ------------------------------------------------------------- direct apply


@pytest.mark.parametrize("model,vn", MODELS)
def test_apply_shared_layout_bitwise_equals_seed_path(model, vn, rng):
    cfg = paper_config(model, virtual_node=vn)
    params = init(KEY, cfg)
    for n_pad, e_pad in PADDINGS:
        g = _random_batch(rng, n_pad, e_pad)
        eig = jnp.asarray(rng.normal(size=(n_pad,)), jnp.float32)
        seed = apply(params, g, cfg, eigvec=eig, share_layout=False)
        for tag, layout in [
            ("in-forward", None),
            ("device-plan", LY.build_layout(g)),
            ("host-plan", LY.host_layout(g)),
        ]:
            got = apply(params, g, cfg, eigvec=eig, layout=layout)
            _bitwise(got, seed, f"{model} vn={vn} pad=({n_pad},{e_pad}) {tag}")


def test_host_layout_bitwise_equals_device_layout(rng):
    for n_pad, e_pad in PADDINGS:
        g = _random_batch(rng, n_pad, e_pad)
        dev, host = LY.build_layout(g), LY.host_layout(g)
        for f in ("perm", "ids_sorted", "offsets", "src_sorted", "in_degree"):
            _bitwise(getattr(host, f), getattr(dev, f), f)


def test_layout_plan_invariants(rng):
    g = _random_batch(rng, 64, 160)
    lay = LY.build_layout(g)
    ids = np.asarray(lay.ids_sorted)
    assert (np.diff(ids) >= 0).all(), "ids_sorted must be non-decreasing"
    n = g.num_nodes
    offs = np.asarray(lay.offsets)
    counts = np.bincount(
        np.asarray(jnp.where(g.edge_mask, g.dst, n)), minlength=n + 1
    )[:n]
    assert (np.diff(offs) == counts).all(), "offsets must delimit dst runs"
    assert (np.asarray(lay.in_degree) == counts).all()
    # padding edges sort to the end with the out-of-range key
    e_real = int(np.asarray(g.edge_mask).sum())
    assert (ids[e_real:] == n).all()


def test_padding_edge_messages_are_dropped_by_plan(rng):
    """Masking is the layout's job: garbage on padding-edge messages must
    not reach any real destination row (ids >= N are dropped)."""
    g = _random_batch(rng, 48, 120)
    lay = LY.build_layout(g)
    e_pad = g.num_edges
    msg = jnp.asarray(rng.normal(size=(e_pad, 7)), jnp.float32)
    garbage = jnp.where(
        jnp.asarray(g.edge_mask)[:, None], msg, 1e30 * jnp.ones_like(msg)
    )
    for ops in [("sum",), ("mean", "std", "max", "min")]:
        clean = mp.gather_scatter(g, msg, ops=ops, layout=lay)
        dirty = mp.gather_scatter(g, garbage, ops=ops, layout=lay)
        _bitwise(dirty, clean, f"padding garbage leaked into {ops}")


def test_shared_forward_has_at_most_one_sort(rng):
    """The tentpole invariant, asserted at trace level (also measured by
    benchmarks/bench_layout.py with latency numbers — the jaxpr walker is
    shared with it so test and bench can never disagree on the count)."""
    from benchmarks.bench_layout import count_jaxpr_sorts as count_sorts

    g = _random_batch(rng, 48, 120)
    for model, vn in MODELS:
        cfg = paper_config(model, virtual_node=vn)
        params = init(KEY, cfg)
        eig = jnp.asarray(rng.normal(size=(g.num_nodes,)), jnp.float32)
        lay = LY.build_layout(g)
        shared = count_sorts(jax.make_jaxpr(
            lambda p, gg, e: apply(p, gg, cfg, eigvec=e))(params, g, eig).jaxpr)
        preplanned = count_sorts(jax.make_jaxpr(
            lambda p, gg, e, l: apply(p, gg, cfg, eigvec=e, layout=l)
        )(params, g, eig, lay).jaxpr)
        seed = count_sorts(jax.make_jaxpr(
            lambda p, gg, e: apply(p, gg, cfg, eigvec=e, share_layout=False)
        )(params, g, eig).jaxpr)
        assert shared == 1, (model, vn, shared)
        assert preplanned == 0, (model, vn, preplanned)
        assert seed > 1, (model, vn, seed)  # what the plan amortizes away


# ------------------------------------------------------------ engine modes


def _reduced_config(model, vn):
    kw = dict(num_layers=2, virtual_node=vn)
    if model == "gat":
        kw.update(heads=2, head_features=8)
    elif model == "pna":
        kw.update(hidden=16, head_hidden=(8,))
    elif model == "dgn":
        kw.update(hidden=16, head_hidden=(8,))
    else:
        kw.update(hidden=16)
    return paper_config(model, **kw)


def _raw_graphs(rng, k=4):
    out = []
    for _ in range(k):
        n = int(rng.integers(5, 14))
        e = int(rng.integers(n, 2 * n))
        out.append((
            rng.integers(0, n, e).astype(np.int32),
            rng.integers(0, n, e).astype(np.int32),
            rng.normal(size=(n, 9)).astype(np.float32),
            rng.normal(size=(e, 3)).astype(np.float32),
        ))
    return out


@pytest.mark.parametrize("model,vn", MODELS)
@pytest.mark.parametrize("precision", ["fp32", "int8"])
def test_engine_modes_bitwise_parity(model, vn, precision, rng):
    """stream / batched / packed x {fp32, int8}: layout sharing on vs off
    serves bitwise-identical outputs (reduced configs keep compiles cheap;
    the structural parity is config-independent)."""
    from repro.serve.gnn_engine import GNNEngine

    cfg = _reduced_config(model, vn)
    params = init(KEY, cfg)
    graphs = _raw_graphs(rng)
    eigvec = model == "dgn"
    shared = GNNEngine(cfg, params, buckets=((16, 32),), precision=precision)
    percall = GNNEngine(cfg, params, buckets=((16, 32),), precision=precision,
                        share_layout=False)
    assert shared.share_layout and not percall.share_layout

    outs_a, _, _ = shared.infer_stream(graphs, with_eigvec=eigvec)
    outs_b, _, _ = percall.infer_stream(graphs, with_eigvec=eigvec)
    for i, (a, b) in enumerate(zip(outs_a, outs_b)):
        _bitwise(a, b, f"stream graph {i}")

    ba, _ = shared.infer_batched(graphs, batch_size=2, n_pad=32, e_pad=64,
                                 with_eigvec=eigvec)
    bb, _ = percall.infer_batched(graphs, batch_size=2, n_pad=32, e_pad=64,
                                  with_eigvec=eigvec)
    _bitwise(ba, bb, "batched")

    budget = BucketBudget(n_pad=64, e_pad=128, g_pad=len(graphs))
    packed, meta = pack_graphs(graphs, budget)
    eig = None
    if eigvec:
        from repro.data.pipeline import laplacian_eigvec

        vecs = [laplacian_eigvec(s, r, nf.shape[0]) for s, r, nf, _ in graphs]
        eig = pack_eigvecs(vecs, meta)
    pa, _ = shared.infer_packed(packed, budget, eigvec=eig,
                                layout=pack_layout(packed))
    pb, _ = percall.infer_packed(packed, budget, eigvec=eig)
    _bitwise(pa, pb, "packed")


# -------------------------------------------------------------- hypothesis


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        data=st.data(),
        model_ix=st.integers(0, len(MODELS) - 1),
        n=st.integers(3, 14),
        e=st.integers(1, 28),
        n_slack=st.integers(0, 20),
        e_slack=st.integers(0, 40),
    )
    def test_fuzz_layout_parity(data, model_ix, n, e, n_slack, e_slack):
        model, vn = MODELS[model_ix]
        cfg = _reduced_config(model, vn)
        params = init(KEY, cfg)
        s = np.asarray(
            data.draw(st.lists(st.integers(0, n - 1), min_size=e, max_size=e)),
            np.int32,
        )
        r = np.asarray(
            data.draw(st.lists(st.integers(0, n - 1), min_size=e, max_size=e)),
            np.int32,
        )
        rng = np.random.default_rng(n * 1000 + e)
        nf = rng.normal(size=(n, 9)).astype(np.float32)
        ef = rng.normal(size=(e, 3)).astype(np.float32)
        g = batch_graphs([(s, r, nf, ef)], n_pad=n + n_slack + 1,
                         e_pad=e + e_slack)
        eig = jnp.asarray(rng.normal(size=(g.num_nodes,)), jnp.float32)
        seed = apply(params, g, cfg, eigvec=eig, share_layout=False)
        shared = apply(params, g, cfg, eigvec=eig)
        host = apply(params, g, cfg, eigvec=eig, layout=LY.host_layout(g))
        np.testing.assert_array_equal(np.asarray(shared), np.asarray(seed))
        np.testing.assert_array_equal(np.asarray(host), np.asarray(seed))
