"""The Fig. 9 scheduling study: simulator invariants + the paper's
measured speed-up bands on the same synthetic sweep, + the virtual-node
experiment (Fig. 6)."""
import numpy as np
import pytest

from repro.core.pipeline_sim import (
    PipelineCosts,
    makespan_fixed,
    makespan_non_pipelined,
    makespan_streaming,
    random_degree_graph,
    simulate,
    virtual_node_graph,
)

RNG = np.random.default_rng(7)


def test_streaming_never_slower_than_fixed_never_slower_than_non():
    for _ in range(20):
        deg = RNG.poisson(RNG.uniform(1, 10), size=200)
        c = PipelineCosts()
        non = makespan_non_pipelined(deg, c)
        fix = makespan_fixed(deg, c)
        stream = makespan_streaming(deg, c)
        assert stream <= fix + 1e-9 <= non + 1e-9


def test_streaming_lower_bound_is_stage_max():
    """Streaming cannot beat max(total NE, total MP) — the busy-stage bound."""
    deg = RNG.poisson(4, size=300)
    c = PipelineCosts()
    stream = makespan_streaming(deg, c)
    lower = max(c.c_ne * len(deg), float(np.sum(c.t_mp(deg))))
    assert stream >= lower - 1e-9
    assert stream <= lower * 1.5  # and should be near it


def test_paper_speedup_bands_on_synthetic_sweep():
    """Fig. 9(a): fixed/non in ~1.2-1.5x, streaming/fixed in ~1.15-1.37x,
    streaming/non in ~1.53-1.92x over the (avg degree x %large) sweep."""
    ratios = {"fn": [], "sf": [], "sn": []}
    for avg_deg in (2, 3, 4):
        for pct in (0.01, 0.05, 0.1):
            deg = random_degree_graph(RNG, 2000, avg_deg, pct)
            r = simulate(deg)
            ratios["fn"].append(r["fixed_over_non"])
            ratios["sf"].append(r["streaming_over_fixed"])
            ratios["sn"].append(r["streaming_over_non"])
    assert 1.15 <= np.mean(ratios["fn"]) <= 1.55, np.mean(ratios["fn"])
    assert 1.10 <= np.mean(ratios["sf"]) <= 1.40, np.mean(ratios["sf"])
    assert 1.45 <= np.mean(ratios["sn"]) <= 2.00, np.mean(ratios["sn"])


def test_virtual_node_hidden_when_early():
    """Fig. 6: the streaming pipeline absorbs the virtual node iff it is
    emitted early; last-position VN leaves an un-hidden tail."""
    c = PipelineCosts()
    deg_first = virtual_node_graph(RNG, 400, avg_degree=3, vn_position="first")
    deg_last = virtual_node_graph(RNG, 400, avg_degree=3, vn_position="last")
    s_first = makespan_streaming(deg_first, c)
    s_last = makespan_streaming(deg_last, c)
    assert s_first < s_last  # early VN overlaps with other nodes' NE
    # and streaming with early VN stays close to the no-VN busy bound
    base = max(c.c_ne * 400, float(np.sum(c.t_mp(deg_first))))
    assert s_first <= base * 1.25


def test_degree_imbalance_helps_streaming():
    """The paper's observed trend: more imbalance (NE ~ MP) => larger
    streaming gain; MP-dominated graphs degrade streaming toward fixed."""
    c = PipelineCosts()
    balanced = random_degree_graph(RNG, 1000, 3, 0.02)  # NE ~ mean MP
    heavy = random_degree_graph(RNG, 1000, 20, 0.3)  # MP dominates
    r_bal = simulate(balanced, c)
    r_heavy = simulate(heavy, c)
    assert r_bal["streaming_over_fixed"] > r_heavy["streaming_over_fixed"]
