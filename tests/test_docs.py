"""Docs hygiene: the link checker passes on the real tree and actually
catches breakage (so the CI step can't silently no-op)."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_docs_links as cdl  # noqa: E402


def test_repo_docs_have_no_dangling_references():
    assert cdl.main() == 0


def test_checker_flags_broken_link_and_dangling_path(tmp_path):
    md = tmp_path / "doc.md"
    md.write_text(
        "see [other](missing.md) and `src/repro/no_such_module.py`\n"
        "but `src/repro/core/batching.py` and [real](REAL.md) are fine\n"
    )
    (tmp_path / "REAL.md").write_text("x")
    errors = cdl.check_file(md, cdl.repo_files())
    assert any("missing.md" in e for e in errors)
    assert any("no_such_module.py" in e for e in errors)
    assert len(errors) == 2


def test_checker_runs_as_script():
    r = subprocess.run(
        [sys.executable, "tools/check_docs_links.py"],
        capture_output=True, text=True, cwd=ROOT,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
