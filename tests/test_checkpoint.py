"""Checkpointing: atomic roundtrip, retention, async save, and ELASTIC
restore onto a different device mesh (the node-failure recovery path)."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": jnp.asarray(3)},
    }


def test_roundtrip_and_retention():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        t = _tree()
        for step in (1, 2, 3, 4):
            mgr.save(step, t, blocking=True)
        assert mgr.all_steps() == [3, 4]  # keep=2
        step, got = mgr.restore(template=t)
        assert step == 4
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(t)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_restore():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        t = _tree()
        mgr.save(7, t, blocking=False)
        mgr.wait()
        step, got = mgr.restore(template=t)
        assert step == 7


def test_no_partial_checkpoint_visible():
    """Interrupted writes (tmp dirs) must not be restorable."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        os.makedirs(os.path.join(d, "tmp.step_00000009"))
        assert mgr.latest_step() is None
        mgr.save(1, _tree(), blocking=True)
        assert mgr.latest_step() == 1


_ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, "src")
from repro.checkpoint.manager import CheckpointManager
from repro.runtime import compat
from jax.sharding import NamedSharding, PartitionSpec as P

d = sys.argv[1]
mgr = CheckpointManager(d)

mesh1 = compat.make_mesh((4, 2), ("data", "model"))
w = jax.device_put(np.arange(64, dtype=np.float32).reshape(8, 8),
                   NamedSharding(mesh1, P("data", "model")))
tree = {"w": w}
axes = {"w": ("batch", "mlp")}
mgr.save(5, tree, axes_tree=axes, blocking=True)

# 'node failure': restart on a SMALLER mesh (2x2) — elastic restore
mesh2 = compat.make_mesh((2, 2), ("data", "model"))
step, got = mgr.restore(template={"w": np.zeros((8, 8), np.float32)},
                        mesh=mesh2)
assert step == 5
w2 = got["w"]
np.testing.assert_array_equal(np.asarray(w2), np.arange(64).reshape(8, 8))
spec = w2.sharding.spec
print("RESHARD_OK", spec)
"""


def test_elastic_restore_on_different_mesh():
    """Save on a 4x2 mesh, restore on 2x2 (simulated node loss) with
    logical-axis-driven resharding — runs in a subprocess so the 8-device
    placeholder count does not leak into this process."""
    with tempfile.TemporaryDirectory() as d:
        r = subprocess.run(
            [sys.executable, "-c", _ELASTIC_SCRIPT, d],
            capture_output=True, text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
        )
        assert r.returncode == 0, r.stderr[-2000:]
        assert "RESHARD_OK" in r.stdout
