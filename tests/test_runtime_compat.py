"""repro.runtime.compat must behave identically whether or not the host
JAX exposes the new mesh APIs (``get_abstract_mesh`` / ``set_mesh`` /
``AxisType`` / public ``jax.shard_map``).  Both detection branches are
exercised by monkeypatching the module-level feature flags."""
import types

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro.runtime import compat
from repro.runtime import partitioning as PT


def test_make_mesh_with_and_without_axis_types(monkeypatch):
    m = compat.make_mesh((1,), ("data",))
    assert dict(m.shape) == {"data": 1}
    monkeypatch.setattr(compat, "HAS_AXIS_TYPES", False)
    m2 = compat.make_mesh((1,), ("data",))
    assert dict(m2.shape) == {"data": 1}


def test_get_active_mesh_absent_api_uses_use_mesh_context(monkeypatch):
    monkeypatch.setattr(compat, "HAS_GET_ABSTRACT_MESH", False)
    assert compat.get_active_mesh() is None
    mesh = compat.make_mesh((1,), ("data",))
    with compat.use_mesh(mesh):
        got = compat.get_active_mesh()
        assert got is not None and dict(got.shape) == {"data": 1}
    assert compat.get_active_mesh() is None


def test_get_active_mesh_present_api_wins(monkeypatch):
    fake = types.SimpleNamespace(empty=False, size=4, shape={"data": 4})
    monkeypatch.setattr(compat, "HAS_GET_ABSTRACT_MESH", True)
    monkeypatch.setattr(
        jax.sharding, "get_abstract_mesh", lambda: fake, raising=False
    )
    assert compat.get_active_mesh() is fake


def test_get_active_mesh_present_but_empty_falls_through(monkeypatch):
    empty = types.SimpleNamespace(empty=True, size=0, shape={})
    monkeypatch.setattr(compat, "HAS_GET_ABSTRACT_MESH", True)
    monkeypatch.setattr(
        jax.sharding, "get_abstract_mesh", lambda: empty, raising=False
    )
    assert compat.get_active_mesh() is None
    mesh = compat.make_mesh((1,), ("data",))
    with compat.use_mesh(mesh):
        got = compat.get_active_mesh()
        assert got is not None and dict(got.shape) == {"data": 1}


def test_shard_map_new_api_kwarg_rename(monkeypatch):
    captured = {}

    def fake_shard_map(f, mesh=None, in_specs=None, out_specs=None,
                       check_vma=True):
        captured["check_vma"] = check_vma
        return f

    monkeypatch.setattr(jax, "shard_map", fake_shard_map, raising=False)
    monkeypatch.setattr(compat, "HAS_JAX_SHARD_MAP", True)
    fn = compat.shard_map(lambda x: x, mesh=None, in_specs=(), out_specs=())
    assert callable(fn)
    assert captured == {"check_vma": False}


def test_shard_map_executes_without_new_api(monkeypatch):
    monkeypatch.setattr(compat, "HAS_JAX_SHARD_MAP", False)
    mesh = compat.make_mesh((1,), ("d",))
    fn = compat.shard_map(
        lambda x: x * 2.0, mesh=mesh,
        in_specs=PartitionSpec("d"), out_specs=PartitionSpec("d"),
    )
    out = fn(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0) * 2)


def test_logical_constraint_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert PT.logical_constraint(x, ("batch", None)) is x


def test_logical_constraint_noop_on_single_device_mesh():
    mesh = compat.make_mesh((1,), ("data",))
    x = jnp.ones((4, 4))
    with compat.use_mesh(mesh):
        assert PT.logical_constraint(x, ("batch", None)) is x


def test_deprecation_shims_are_gone():
    # the PR-1 shim modules were deleted once external callers migrated;
    # their import paths must stay dead (a reintroduction would silently
    # shadow the runtime package as the canonical home)
    import importlib

    import pytest

    for name in ("repro.sharding", "repro.core.distributed",
                 "repro.launch.mesh"):
        with pytest.raises(ModuleNotFoundError):
            importlib.import_module(name)
