"""Quantized inference subsystem: scheme arithmetic, observers, the int8
Pallas kernel vs its jnp oracle, the model-agnostic param transform, and
engine-wide precision plumbing (stream + packed, zero recompiles)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.quant import observers as O
from repro.quant import qconfig as Q

RNG = np.random.default_rng(11)


# ---------------------------------------------------------------- schemes


def test_fixed_round_snaps_to_grid_and_saturates():
    w, i = 8, 3  # ap_fixed<8,3>: lsb 2^-5, range [-4, 4 - 2^-5]
    lsb = 2.0 ** (i - w)
    x = jnp.asarray([0.0, 0.017, -0.017, 3.99, 100.0, -100.0], jnp.float32)
    y = np.asarray(Q.fixed_round(x, w, i))
    assert np.all(np.abs(np.round(y / lsb) - y / lsb) < 1e-6)  # on grid
    assert y[3] <= 4.0 - lsb and y[4] == pytest.approx(4.0 - lsb)
    assert y[5] == pytest.approx(-4.0)
    # idempotent: snapping a snapped value is a no-op
    np.testing.assert_array_equal(np.asarray(Q.fixed_round(jnp.asarray(y), w, i)), y)


def test_int8_roundtrip_error_bounded_by_half_step():
    x = jnp.asarray(RNG.uniform(-2.0, 2.0, size=(64, 32)), jnp.float32)
    scale = Q.symmetric_scale(-2.0, 2.0)
    back = Q.dequantize_int8(Q.quantize_int8(x, scale), scale)
    assert float(jnp.abs(x - back).max()) <= float(scale) / 2 + 1e-7


def test_symmetric_scale_zero_range_is_positive():
    assert float(Q.symmetric_scale(0.0, 0.0)) > 0.0


def test_quantize_weight_per_channel_vs_per_tensor():
    w = jnp.asarray(RNG.normal(size=(16, 8)) * [1, 2, 4, 8, 1, 2, 4, 8],
                    jnp.float32)
    wq_c, sc_c = Q.quantize_weight(w, Q.QConfig(granularity="per_channel"))
    wq_t, sc_t = Q.quantize_weight(w, Q.QConfig(granularity="per_tensor"))
    assert sc_c.shape == (8,) and sc_t.shape == ()
    err_c = float(jnp.abs(Q.dequantize_int8(wq_c, sc_c) - w).max())
    err_t = float(jnp.abs(Q.dequantize_int8(wq_t, sc_t) - w).max())
    assert err_c < err_t  # per-channel adapts to the column scales


def test_affine_act_params_asymmetric_uses_full_range():
    scale, zero = Q.affine_act_params(0.0, 2.55, True)
    assert scale == pytest.approx(2.55 / 255.0)
    assert zero == -128.0
    assert int(Q.quantize_int8(jnp.float32(0.0), scale, zero)) == -128
    assert int(Q.quantize_int8(jnp.float32(2.55), scale, zero)) == 127
    # symmetric keeps zero at 0
    scale_s, zero_s = Q.affine_act_params(-1.0, 1.0, False)
    assert zero_s == 0.0 and int(Q.quantize_int8(jnp.float32(0.0), scale_s)) == 0


def test_zero_point_fold_matches_fp32_on_relu_range():
    from repro.quant.apply import _quantize_int8_linear

    w = jnp.asarray(RNG.normal(size=(24, 12)) * 0.2, jnp.float32)
    b = jnp.asarray(RNG.normal(size=(12,)), jnp.float32)
    x = jnp.asarray(RNG.uniform(0.0, 4.0, size=(16, 24)), jnp.float32)
    obs = O.MinMaxObserver()
    obs.update(np.asarray(x))
    q = _quantize_int8_linear(w, b, obs, Q.QConfig(smooth_alpha=0.0))
    assert float(q.x_zero) != 0.0  # non-negative range -> shifted zero-point
    got = Q.quantized_linear(q, x, activation="none", mode="reference")
    want = ref.node_mlp_ref(x, w, b, "none")
    assert float(jnp.abs(got - want).max()) < 0.05


def test_smoothquant_migration_reduces_error_on_skewed_columns():
    from repro.quant.apply import _quantize_int8_linear

    colscale = np.where(np.arange(32) % 8 == 0, 50.0, 0.5)
    x = jnp.asarray(RNG.normal(size=(64, 32)) * colscale, jnp.float32)
    w = jnp.asarray(RNG.normal(size=(32, 16)) * 0.2, jnp.float32)
    b = jnp.zeros((16,), jnp.float32)
    obs = O.MinMaxObserver()
    obs.update(np.asarray(x))
    want = ref.node_mlp_ref(x, w, b, "none")
    errs = {}
    for alpha in (0.0, 0.5):
        q = _quantize_int8_linear(w, b, obs, Q.QConfig(smooth_alpha=alpha))
        got = Q.quantized_linear(q, x, activation="none", mode="reference")
        errs[alpha] = float(jnp.abs(got - want).mean())
    assert errs[0.5] < 0.5 * errs[0.0]  # migration tames the hot columns
    q = _quantize_int8_linear(w, b, obs, Q.QConfig(smooth_alpha=0.5))
    assert q.x_premul.shape == (32,)


# -------------------------------------------------------------- observers


def test_minmax_observer_tracks_extremes_across_updates():
    obs = O.MinMaxObserver()
    obs.update(np.asarray([1.0, 2.0]))
    obs.update(np.asarray([-3.0, 0.5]))
    assert obs.range() == (-3.0, 2.0)


def test_percentile_observer_clips_outlier_tail():
    obs = O.PercentileObserver(percentile=99.0)
    obs.update(np.concatenate([RNG.uniform(-1, 1, 10_000), [1e6]]))
    lo, hi = obs.range()
    assert hi < 2.0 and lo == -hi


def test_observer_raises_without_data():
    with pytest.raises(ValueError):
        O.MinMaxObserver().range()


def test_collector_hook_records_per_weight(monkeypatch):
    from repro.gnn import layers as L

    p1 = L.linear_init(jax.random.PRNGKey(0), 4, 4)
    p2 = L.linear_init(jax.random.PRNGKey(1), 4, 4)
    coll = O.Collector(O.MinMaxObserver)
    with O.collecting(coll):
        L.linear_apply(p1, jnp.ones((3, 4)))
        L.linear_apply(p2, 2.0 * jnp.ones((3, 4)))
        L.linear_apply(p1, -jnp.ones((3, 4)))
    assert set(coll.observers) == {id(p1["w"]), id(p2["w"])}
    assert coll.observers[id(p1["w"])].range() == (-1.0, 1.0)
    assert coll.observers[id(p2["w"])].range() == (2.0, 2.0)
    # hook is inert outside the context
    L.linear_apply(p1, 5.0 * jnp.ones((3, 4)))
    assert coll.observers[id(p1["w"])].range() == (-1.0, 1.0)


# ------------------------------------------------------------ int8 kernel


@pytest.mark.parametrize("act", ["relu", "gelu", "none"])
@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (37, 130, 50), (128, 256, 384)])
def test_quant_node_mlp_kernel_matches_oracle(act, m, k, n):
    x_q = jnp.asarray(RNG.integers(-127, 128, size=(m, k)), jnp.int8)
    w_q = jnp.asarray(RNG.integers(-127, 128, size=(k, n)), jnp.int8)
    scale = jnp.asarray(RNG.uniform(1e-3, 1e-2, size=(n,)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    got = ops.quant_node_mlp(x_q, w_q, scale, b, act, mode="kernel")
    want = ref.quant_node_mlp_ref(x_q, w_q, scale, b, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (37, 130, 50)])
def test_quant_node_mlp_kernel_row_scales_match_oracle(m, k, n):
    x_q = jnp.asarray(RNG.integers(-127, 128, size=(m, k)), jnp.int8)
    w_q = jnp.asarray(RNG.integers(-127, 128, size=(k, n)), jnp.int8)
    scale = jnp.asarray(RNG.uniform(1e-3, 1e-2, size=(n,)), jnp.float32)
    rs = jnp.asarray(RNG.uniform(1e-3, 1e-1, size=(m, 1)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    got = ops.quant_node_mlp(x_q, w_q, scale, b, "relu",
                             row_scale=rs, mode="kernel")
    want = ref.quant_node_mlp_ref(x_q, w_q, scale, b, "relu", row_scale=rs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_quant_node_mlp_int32_accumulation_is_exact():
    # scale 1, bias 0: output must be the exact integer accumulator
    x_q = jnp.asarray(RNG.integers(-127, 128, size=(40, 96)), jnp.int8)
    w_q = jnp.asarray(RNG.integers(-127, 128, size=(96, 24)), jnp.int8)
    got = ops.quant_node_mlp(
        x_q, w_q, jnp.float32(1.0), jnp.zeros((24,)), "none", mode="kernel"
    )
    want = np.asarray(x_q, np.int64) @ np.asarray(w_q, np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


def test_quantized_linear_static_matches_fp32_within_step():
    rng = np.random.default_rng(3)
    p = {"w": jnp.asarray(rng.normal(size=(32, 16)) * 0.2, jnp.float32),
         "b": jnp.asarray(rng.normal(size=(16,)), jnp.float32)}
    # keep x inside the calibrated range: out-of-range values saturate by
    # design and would dominate the error bound
    x = jnp.asarray(rng.uniform(-3.4, 3.4, size=(8, 32)), jnp.float32)
    qcfg = Q.QConfig()
    w_q, w_scale = Q.quantize_weight(p["w"], qcfg)
    q = Q.QuantizedLinear(w_q=w_q, w_scale=w_scale, b=p["b"],
                          x_scale=Q.symmetric_scale(-3.5, 3.5),
                          act_mode="static")
    got = Q.quantized_linear(q, x, activation="none", mode="reference")
    want = ref.node_mlp_ref(x, p["w"], p["b"], "none")
    assert float(jnp.abs(got - want).max()) < 0.1


def test_quantized_linear_dynamic_beats_static_on_mixed_row_scales():
    # rows with wildly different magnitudes (degree-skewed aggregates):
    # per-row dynamic scales keep small rows accurate
    p = {"w": jnp.asarray(RNG.normal(size=(32, 16)) * 0.2, jnp.float32),
         "b": jnp.zeros((16,), jnp.float32)}
    rowscale = np.where(np.arange(16) % 4 == 0, 30.0, 0.3)[:, None]
    x = jnp.asarray(RNG.normal(size=(16, 32)) * rowscale, jnp.float32)
    w_q, w_scale = Q.quantize_weight(p["w"], Q.QConfig())
    q_dyn = Q.QuantizedLinear(w_q=w_q, w_scale=w_scale, b=p["b"],
                              x_scale=jnp.float32(1.0), act_mode="dynamic")
    q_sta = Q.QuantizedLinear(w_q=w_q, w_scale=w_scale, b=p["b"],
                              x_scale=Q.symmetric_scale(float(x.min()),
                                                        float(x.max())),
                              act_mode="static")
    want = ref.node_mlp_ref(x, p["w"], p["b"], "none")
    err_dyn = float(jnp.abs(
        Q.quantized_linear(q_dyn, x, "none", mode="reference") - want
    )[np.arange(16) % 4 != 0].mean())
    err_sta = float(jnp.abs(
        Q.quantized_linear(q_sta, x, "none", mode="reference") - want
    )[np.arange(16) % 4 != 0].mean())
    assert err_dyn < 0.2 * err_sta


def test_quantized_linear_is_a_pytree_node():
    q = Q.QuantizedLinear(
        w_q=jnp.zeros((4, 4), jnp.int8), w_scale=jnp.ones((4,)),
        b=jnp.zeros((4,)), x_scale=jnp.float32(0.1),
        scheme="int8", word_bits=16, int_bits=6,
    )
    leaves, treedef = jax.tree_util.tree_flatten(q)
    assert len(leaves) == 6
    q2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert q2.scheme == "int8" and q2.word_bits == 16


# ------------------------------------------------------- param transform


def _calib_graphs(n=3, feat=9, edge=3, seed=5):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        nn = int(rng.integers(6, 14))
        e = int(rng.integers(nn, 2 * nn))
        out.append((rng.integers(0, nn, e).astype(np.int32),
                    rng.integers(0, nn, e).astype(np.int32),
                    rng.normal(size=(nn, feat)).astype(np.float32),
                    rng.normal(size=(e, edge)).astype(np.float32)))
    return out


@pytest.mark.parametrize("act_mode", ["dynamic", "static"])
def test_quantize_model_structure_and_report(act_mode):
    from repro.gnn import init
    from repro.gnn.models import paper_config
    from repro.quant.apply import quantize_model

    cfg = paper_config("gin")
    params = init(jax.random.PRNGKey(0), cfg)
    qp, rep = quantize_model(params, cfg, _calib_graphs(),
                             Q.QConfig(act_mode=act_mode))
    assert isinstance(qp["encoder"], Q.QuantizedLinear)
    assert qp["encoder"].act_mode == act_mode
    assert isinstance(qp["layers"][0]["mlp"][0], Q.QuantizedLinear)
    # the head stays fp32 (skip list) and nothing was left uncalibrated
    assert isinstance(qp["head"][0], dict)
    assert rep.uncalibrated_paths == ()
    assert rep.quantized == 16 and rep.kept_fp32 == 1  # enc + 5*(edge+2mlp)
    assert rep.skipped_paths == ("head/0",)
    # original params untouched
    assert isinstance(params["encoder"], dict)


def test_quantized_forward_close_to_fp32_all_models():
    from repro.core import graph as G
    from repro.gnn import init
    from repro.gnn.models import apply, paper_config
    from repro.quant.apply import quantize_model

    graphs = _calib_graphs(n=3)
    s, r, nf, ef = graphs[0]
    gp = G.from_numpy(s, r, nf, ef)
    for name in ("gcn", "gat"):  # fast small-logit models; rest in bench
        cfg = paper_config(name)
        params = init(jax.random.PRNGKey(0), cfg)
        qp, _ = quantize_model(params, cfg, graphs)
        want = np.asarray(apply(params, gp, cfg, num_graphs=1))
        got = np.asarray(apply(qp, gp, cfg, num_graphs=1))
        assert np.isfinite(got).all()
        assert float(np.abs(got - want).max()) < 0.05, name


def test_fixed_scheme_needs_no_calibration_and_tracks_fp32():
    from repro.core import graph as G
    from repro.gnn import init
    from repro.gnn.models import apply, paper_config
    from repro.quant.apply import quantize_params

    cfg = paper_config("gcn")
    params = init(jax.random.PRNGKey(0), cfg)
    qp, rep = quantize_params(params, None, Q.QConfig(scheme="fixed"))
    assert rep.quantized > 0 and rep.uncalibrated_paths == ()
    s, r, nf, ef = _calib_graphs(n=1)[0]
    gp = G.from_numpy(s, r, nf, ef)
    want = np.asarray(apply(params, gp, cfg, num_graphs=1))
    got = np.asarray(apply(qp, gp, cfg, num_graphs=1))
    # ap_fixed<16,6>: lsb 2^-10 — emulation tracks fp32 tightly
    assert float(np.abs(got - want).max()) < 1e-2


# ------------------------------------------------------- engine plumbing


def test_engine_static_int8_requires_calibration_graphs():
    from repro.gnn import init
    from repro.gnn.models import paper_config
    from repro.serve.gnn_engine import GNNEngine

    cfg = paper_config("gcn")
    params = init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="calib_graphs"):
        GNNEngine(cfg, params, precision="int8-static")
    # dynamic int8 needs none
    eng = GNNEngine(cfg, params, precision="int8")
    assert eng.quant_report.quantized > 0


def test_engine_precision_modes_stream_packed_zero_recompiles():
    from repro.core.batching import BucketBudget, pack_graphs
    from repro.gnn import init
    from repro.gnn.models import paper_config
    from repro.serve.gnn_engine import GNNEngine
    from repro.serve.scheduler import StreamScheduler

    cfg = paper_config("gcn")
    params = init(jax.random.PRNGKey(0), cfg)
    graphs = _calib_graphs(n=6, seed=8)
    fp32 = GNNEngine(cfg, params)
    int8 = GNNEngine(cfg, params, precision="int8")
    assert int8.precision == "int8" and int8.quant_report.quantized > 0

    # stream mode: quantized engine matches fp32 closely
    outs_fp, _, _ = fp32.infer_stream(graphs)
    outs_q, _, _ = int8.infer_stream(graphs)
    for a, b in zip(outs_fp, outs_q):
        np.testing.assert_allclose(a, b, atol=0.05)

    # packed mode through the scheduler: warm once, then zero recompiles
    sched = StreamScheduler(int8, capacity=2, max_wait_s=0.001)
    rep1 = sched.run(graphs, qps=0.0)
    warm = int8.compile_seconds
    rep2 = sched.run(graphs, qps=0.0)
    assert int8.compile_seconds == warm, "int8 packed stream recompiled"
    for a, b in zip(rep2.outputs, outs_q):
        np.testing.assert_allclose(a, b, atol=1e-4)

    # direct packed call agrees too
    budget = BucketBudget(n_pad=64, e_pad=128, g_pad=4)
    packed, meta = pack_graphs(graphs[:2], budget)
    out, _ = int8.infer_packed(packed, budget)
    np.testing.assert_allclose(out[:1], outs_q[0], atol=1e-4)


def test_engine_precision_int8_static_stream_close_to_fp32():
    from repro.gnn import init
    from repro.gnn.models import paper_config
    from repro.serve.gnn_engine import GNNEngine

    cfg = paper_config("gcn")
    params = init(jax.random.PRNGKey(0), cfg)
    graphs = _calib_graphs(n=4, seed=8)
    static = GNNEngine(cfg, params, precision="int8-static",
                       calib_graphs=_calib_graphs(n=4, seed=9))
    assert static.quant_report.scheme == "int8"
    outs_fp, _, _ = GNNEngine(cfg, params).infer_stream(graphs)
    outs_q, _, _ = static.infer_stream(graphs)
    for a, b in zip(outs_fp, outs_q):
        np.testing.assert_allclose(a, b, atol=0.1)


def test_engine_precision_fixed_no_calibration():
    from repro.gnn import init
    from repro.gnn.models import paper_config
    from repro.serve.gnn_engine import GNNEngine

    cfg = paper_config("gcn")
    params = init(jax.random.PRNGKey(0), cfg)
    eng = GNNEngine(cfg, params, precision="fixed")
    fp32 = GNNEngine(cfg, params)
    graphs = _calib_graphs(n=3, seed=12)
    outs_fx, _, _ = eng.infer_stream(graphs)
    outs_fp, _, _ = fp32.infer_stream(graphs)
    for a, b in zip(outs_fx, outs_fp):
        np.testing.assert_allclose(a, b, atol=1e-2)
