"""Fused (phi, A, gamma) megakernel: parity, fallbacks, serving statics.

Three layers of guarantees:

  * **kernel vs oracle** — the Pallas megakernel (interpret mode on CPU)
    matches ``kernels/ref.fused_mp_ref`` for every gamma x precision over
    ragged shapes, empty edge blocks, and isolated nodes.  PNA gets a
    documented tolerance: its std derives from ``sqsum/c - mean^2``, and
    XLA may contract the multiply-subtract into an FMA (exact ``mean^2``
    against the *rounded* ``sqsum``), leaving ~1 ulp of variance that
    ``sqrt`` at zero amplifies to ~ value * sqrt(eps) — benign, backend-
    dependent, and orders below the model's quantization noise.
  * **fused vs unfused model forward** — ``models.apply(..., fused=True)``
    is *bitwise* identical to the unfused closure path in fp32 for all six
    models (the CPU fused path is the same jnp arithmetic in one jit
    scope), matches unfused int8 within quantization-noise bounds for
    int8-dynamic, and falls back to bitwise-identical unfused execution
    for the parameterizations that can't lower (GAT, int8-static, fixed).
  * **serving statics** — ``fused`` rides ``program_key`` exactly like
    ``share_layout``: distinct programs, zero recompiles after warm, no
    new bucket/warm keys.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layout as LY
from repro.core import message_passing as mp
from repro.core.graph import batch_graphs
from repro.gnn import init
from repro.gnn.models import apply, paper_config
from repro.kernels import fused_mp as FK
from repro.kernels import ops as kops
from repro.kernels import ref as KR
from repro.quant import qconfig as qc

KEY = jax.random.PRNGKey(0)
MODELS = [("gcn", False), ("gin", False), ("gin", True), ("gat", False),
          ("pna", False), ("dgn", False)]
PADDINGS = [(48, 120), (80, 160), (50, 300)]

# std tolerance: FMA contraction of `sqsum/c - mean^2` (see module doc)
PNA_TOL = 5e-3
# int8 kernel/oracle use the same exact-emulation accumulate; only the
# f32 requant tail can diverge by rounding
INT8_TOL = 2e-5


def _bitwise(a, b, msg):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=msg)


def _random_batch(rng, n_pad, e_pad, n_graphs=3):
    gs = []
    for _ in range(n_graphs):
        n = int(rng.integers(5, 14))
        e = int(rng.integers(n, 2 * n))
        gs.append((
            rng.integers(0, n, e).astype(np.int32),
            rng.integers(0, n, e).astype(np.int32),
            rng.normal(size=(n, 9)).astype(np.float32),
            rng.normal(size=(e, 3)).astype(np.float32),
        ))
    return batch_graphs(gs, n_pad=n_pad, e_pad=e_pad)


def _quant_cols(w):
    """Per-channel symmetric int8 weights, the fused operand form."""
    s = jnp.max(jnp.abs(w), axis=0) / 127.0
    return jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8), s


def _spec_operands(rng, gamma, precision, n, e_pad, f=12):
    """(MPSpec, operand dict) exercising every operand slot of ``gamma``."""
    msrc = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    x_res = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    b1 = jnp.asarray(rng.normal(size=(f,)), jnp.float32)
    if gamma == "gcn":
        spec = mp.MPSpec("copy", ("sum",), "gcn", precision)
        return spec, dict(
            msrc=msrc, x_res=x_res,
            nop=jnp.asarray(rng.normal(size=(n, 1)), jnp.float32),
        )
    if gamma == "gin":
        w1 = jnp.asarray(rng.normal(size=(f, f)) * 0.3, jnp.float32)
        kw = dict(
            msrc=msrc, x_res=x_res,
            eop=jnp.asarray(rng.normal(size=(e_pad, f)), jnp.float32),
            b1=b1,
            w2=jnp.asarray(rng.normal(size=(f, f)) * 0.3, jnp.float32),
            b2=jnp.asarray(rng.normal(size=(f,)), jnp.float32),
        )
    elif gamma == "pna":
        w1 = jnp.asarray(rng.normal(size=(12 * f, f)) * 0.2, jnp.float32)
        kw = dict(
            msrc=msrc, x_res=x_res, b1=b1,
            nop=jnp.asarray(np.abs(rng.normal(size=(n, 3))) + 0.5,
                            jnp.float32),
        )
    else:  # dgn
        w1 = jnp.asarray(rng.normal(size=(3 * f, f)) * 0.2, jnp.float32)
        kw = dict(
            msrc=msrc, x_res=x_res, b1=b1,
            nop=jnp.asarray(np.abs(rng.normal(size=(n, 1))) + 0.1,
                            jnp.float32),
            ew=jnp.asarray(rng.normal(size=(e_pad, 1)), jnp.float32),
        )
    phi = "add_relu" if gamma == "gin" else "copy"
    ops = {"gin": ("sum",), "pna": ("sum", "sqsum", "max", "min"),
           "dgn": ("sum", "wsum")}[gamma]
    if precision == "int8":
        kw["w1"], kw["w1_scale"] = _quant_cols(w1)
    else:
        kw["w1"] = w1
    return mp.MPSpec(phi, ops, gamma, precision), kw


# --------------------------------------------------------------- the spec


def test_mpspec_validation():
    mp.MPSpec("copy", ("sum", "max"), "pna", "int8")  # fine
    with pytest.raises(ValueError):
        mp.MPSpec(phi="exp")
    with pytest.raises(ValueError):
        mp.MPSpec(ops=("mean",))  # derived in gamma, not an accumulator
    with pytest.raises(ValueError):
        mp.MPSpec(ops=())
    with pytest.raises(ValueError):
        mp.MPSpec(gamma="gat")  # the documented opt-out is not a gamma
    with pytest.raises(ValueError):
        mp.MPSpec(precision="int4")


def test_mp_layer_spec_requires_layout(rng):
    g = _random_batch(rng, 48, 120)
    spec, kw = _spec_operands(rng, "gcn", "fp32", 48, 120)
    with pytest.raises(ValueError, match="requires a GraphLayout"):
        mp.mp_layer(g, kw["msrc"], spec=spec, operands=kw)


def test_int8_row_eps_constants_pinned():
    """The kernel re-implements qconfig's dynamic recipe; the epsilon in
    `rs = max(rowmax|x|, eps) / 127` must stay one constant in all three
    homes or fused/unfused int8 silently diverge on near-zero rows."""
    assert KR._ROW_EPS == qc._EPS
    assert FK._ROW_EPS == qc._EPS


# ----------------------------------------------------- kernel vs oracle


@pytest.mark.parametrize("gamma", ["gcn", "gin", "pna", "dgn"])
@pytest.mark.parametrize("precision", ["fp32", "int8"])
def test_kernel_matches_oracle(gamma, precision, rng):
    """Interpret-mode Pallas vs the jnp oracle, small blocks so the grid
    exercises multi-block accumulation, ragged tails, and node blocks
    with no overlapping edges."""
    tol = PNA_TOL if gamma == "pna" else (
        INT8_TOL if precision == "int8" else 1e-5
    )
    for n_pad, e_pad, n_graphs in [(50, 121, 3), (33, 70, 2)]:
        g = _random_batch(rng, n_pad, e_pad, n_graphs=n_graphs)
        lay = LY.build_layout(g)
        spec, kw = _spec_operands(rng, gamma, precision, n_pad, e_pad)
        a = kops.fused_mp(spec, lay.ids_sorted, lay.src_sorted,
                          lay.in_degree, g.node_mask, mode="reference", **kw)
        b = kops.fused_mp(spec, lay.ids_sorted, lay.src_sorted,
                          lay.in_degree, g.node_mask, mode="kernel",
                          block_e=32, block_n=16, **kw)
        d = float(np.abs(np.asarray(a) - np.asarray(b)).max())
        assert d <= tol, (gamma, precision, (n_pad, e_pad), d)


def test_kernel_sparse_and_isolated(rng):
    """One tiny graph in huge padding: most edge blocks are pure padding
    (overlap early-out), most node rows are empty segments, and real
    isolated nodes get zero (not the +/-inf fill) from max/min."""
    g = batch_graphs(
        [(np.asarray([1], np.int32), np.asarray([0], np.int32),
          rng.normal(size=(5, 9)).astype(np.float32),
          rng.normal(size=(1, 3)).astype(np.float32))],
        n_pad=33, e_pad=70,
    )
    lay = LY.build_layout(g)
    spec, kw = _spec_operands(rng, "pna", "fp32", 33, 70)
    a = np.asarray(kops.fused_mp(spec, lay.ids_sorted, lay.src_sorted,
                                 lay.in_degree, g.node_mask,
                                 mode="reference", **kw))
    b = np.asarray(kops.fused_mp(spec, lay.ids_sorted, lay.src_sorted,
                                 lay.in_degree, g.node_mask, mode="kernel",
                                 block_e=32, block_n=16, **kw))
    assert np.isfinite(a).all() and np.isfinite(b).all()
    assert np.abs(a - b).max() <= PNA_TOL
    # padded node rows are masked to exactly zero on both paths
    assert (a[5:] == 0).all() and (b[5:] == 0).all()


# ------------------------------------------- fused vs unfused model paths


@pytest.mark.parametrize("model,vn", MODELS)
def test_fused_apply_bitwise_equals_unfused_fp32(model, vn, rng):
    """The CPU fused path is the same jnp arithmetic fused into one jit
    scope — fp32 must be *bitwise* identical, across padding fuzz (the
    packed-flush shapes included)."""
    cfg = paper_config(model, virtual_node=vn)
    params = init(KEY, cfg)
    for n_pad, e_pad in PADDINGS:
        g = _random_batch(rng, n_pad, e_pad)
        eig = jnp.asarray(rng.normal(size=(n_pad,)), jnp.float32)
        lay = LY.for_model(None, g, model, avg_degree=cfg.avg_degree,
                           eigvec=eig)
        un = apply(params, g, cfg, eigvec=eig, layout=lay)
        fu = apply(params, g, cfg, eigvec=eig, layout=lay, fused=True)
        _bitwise(fu, un, f"{model} vn={vn} pad=({n_pad},{e_pad})")


@pytest.mark.parametrize("model,vn", MODELS)
def test_fused_int8_within_quantization_noise(model, vn, rng):
    """int8-dynamic: the fused lowering re-quantizes at the same boundary
    with the same recipe; GIN's auxiliary linears run weight-only
    dequantized, so fused != unfused bit-for-bit there — the bound is that
    fused int8 stays as close to fp32 as unfused int8 is (same error
    class, no compounding)."""
    from repro.quant import apply as QA

    cfg = paper_config(model, virtual_node=vn)
    params = init(KEY, cfg)
    qparams, _ = QA.quantize_model(params, cfg, (),
                                   QA.precision_qconfig("int8"))
    g = _random_batch(rng, 80, 160)
    eig = jnp.asarray(rng.normal(size=(80,)), jnp.float32)
    lay = LY.for_model(None, g, model, avg_degree=cfg.avg_degree, eigvec=eig)
    fp32 = np.asarray(apply(params, g, cfg, eigvec=eig, layout=lay))
    un = np.asarray(apply(qparams, g, cfg, eigvec=eig, layout=lay))
    fu = np.asarray(apply(qparams, g, cfg, eigvec=eig, layout=lay,
                          fused=True))
    mae_un = np.abs(un - fp32).mean()
    mae_fu = np.abs(fu - fp32).mean()
    # factor 5: GIN trades its auxiliaries' activation quantization for
    # weight-only dequant — a different rounding profile of the same
    # order, not compounding (both MAEs stay ~1e-3 on an O(4) logit span)
    assert mae_fu <= 5.0 * mae_un + 1e-4, (model, vn, mae_fu, mae_un)


@pytest.mark.parametrize("precision", ["int8-static", "fixed"])
def test_unlowerable_precisions_fall_back_bitwise(precision, rng):
    """int8-static / ap_fixed params return None from the operand probes,
    so fused=True must execute the identical unfused computation."""
    from repro.quant import apply as QA

    cfg = paper_config("gin")
    params = init(KEY, cfg)
    calib = []
    for _ in range(3):
        n = int(rng.integers(6, 12))
        e = int(rng.integers(n, 2 * n))
        calib.append((rng.integers(0, n, e).astype(np.int32),
                      rng.integers(0, n, e).astype(np.int32),
                      rng.normal(size=(n, 9)).astype(np.float32),
                      rng.normal(size=(e, 3)).astype(np.float32)))
    qparams, _ = QA.quantize_model(params, cfg, calib,
                                   QA.precision_qconfig(precision))
    g = _random_batch(rng, 48, 120)
    lay = LY.build_layout(g)
    un = apply(qparams, g, cfg, layout=lay)
    fu = apply(qparams, g, cfg, layout=lay, fused=True)
    _bitwise(fu, un, precision)


def test_fused_forward_stays_zero_sort(rng):
    """Fusion must not reintroduce sorts: with a supplied plan the fused
    jaxpr contains zero sort ops (one when built in-forward), matching
    the unfused layout invariant."""
    from benchmarks.bench_layout import count_jaxpr_sorts

    g = _random_batch(rng, 48, 120)
    for model, vn in MODELS:
        cfg = paper_config(model, virtual_node=vn)
        params = init(KEY, cfg)
        eig = jnp.asarray(rng.normal(size=(48,)), jnp.float32)
        lay = LY.for_model(None, g, model, avg_degree=cfg.avg_degree,
                           eigvec=eig)
        pre = count_jaxpr_sorts(jax.make_jaxpr(
            lambda p, gg, e, l: apply(p, gg, cfg, eigvec=e, layout=l,
                                      fused=True)
        )(params, g, eig, lay).jaxpr)
        inf = count_jaxpr_sorts(jax.make_jaxpr(
            lambda p, gg, e: apply(p, gg, cfg, eigvec=e, fused=True)
        )(params, g, eig).jaxpr)
        assert pre == 0, (model, vn, pre)
        assert inf == 1, (model, vn, inf)


# ------------------------------------------------------- serving statics


def _reduced_config(model="gin"):
    return paper_config(model, num_layers=2, hidden=16)


def _raw_graphs(rng, k=4):
    out = []
    for _ in range(k):
        n = int(rng.integers(5, 14))
        e = int(rng.integers(n, 2 * n))
        out.append((rng.integers(0, n, e).astype(np.int32),
                    rng.integers(0, n, e).astype(np.int32),
                    rng.normal(size=(n, 9)).astype(np.float32),
                    rng.normal(size=(e, 3)).astype(np.float32)))
    return out


def test_fused_is_a_program_key_static(rng):
    """fused tenants compile their own programs (no silent sharing with
    unfused same-arch tenants) but share with equal-fused tenants."""
    from repro.serve.executor import Executor

    cfg = _reduced_config()
    params = init(KEY, cfg)
    ex = Executor(buckets=((16, 32),))
    a = ex.register("plain", cfg, params)
    b = ex.register("fused", cfg, params, fused=True)
    c = ex.register("fused2", cfg, params, fused=True)
    assert a.program_key != b.program_key
    assert b.program_key == c.program_key
    g = _raw_graphs(rng, 1)[0]
    pa = ex.prepare_stream(g)
    ex.run(pa, model="plain")
    ex.run(pa, model="fused")
    assert len(ex._compiled) == 2  # one program per distinct key


def test_fused_engine_zero_recompiles_after_warm(rng):
    """Same bucket signatures as unfused: after the first graph warms a
    bucket, further fused traffic through it never compiles again."""
    from repro.serve.gnn_engine import GNNEngine

    cfg = _reduced_config()
    params = init(KEY, cfg)
    eng = GNNEngine(cfg, params, buckets=((16, 32),), fused=True)
    assert eng.fused
    graphs = _raw_graphs(rng)
    eng.infer_stream(graphs[:1])
    warm = eng.compile_seconds
    assert warm > 0.0
    outs, lats, compile_s = eng.infer_stream(graphs)
    assert compile_s == 0.0
    assert eng.compile_seconds == warm
    assert len(outs) == len(graphs)


def test_fused_engine_matches_unfused_engine_bitwise(rng):
    """End-to-end through the serving stack: fp32 fused serving returns
    bit-identical outputs to unfused serving."""
    from repro.serve.gnn_engine import GNNEngine

    cfg = _reduced_config("pna")
    params = init(KEY, cfg)
    graphs = _raw_graphs(rng)
    plain = GNNEngine(cfg, params, buckets=((16, 32),))
    fused = GNNEngine(cfg, params, buckets=((16, 32),), fused=True,
                      name="fused")
    outs_a, _, _ = plain.infer_stream(graphs)
    outs_b, _, _ = fused.infer_stream(graphs)
    for i, (a, b) in enumerate(zip(outs_a, outs_b)):
        _bitwise(a, b, f"stream graph {i}")
