"""End-to-end GNN correctness: every paper model cross-checked against an
independent dense-adjacency oracle (the paper's PyTorch cross-check
analogue), plus engine behaviour (bucketing, batch-vs-stream parity)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import batch_graphs
from repro.gnn import apply, apply_dense, init, paper_config
from tests.conftest import random_molecule_batch

KEY = jax.random.PRNGKey(0)


def _rel_err(a, b):
    return float((jnp.abs(a - b) / (jnp.abs(b) + 1.0)).max())


@pytest.mark.parametrize(
    "model,vn",
    [("gcn", False), ("gin", False), ("gin", True), ("gat", False),
     ("pna", False), ("dgn", False)],
)
def test_model_matches_dense_oracle(model, vn, rng):
    g = random_molecule_batch(rng)
    cfg = paper_config(model, virtual_node=vn)
    params = init(KEY, cfg)
    eig = jnp.asarray(rng.normal(size=(g.num_nodes,)), jnp.float32)
    out = apply(params, g, cfg, eigvec=eig)
    want = apply_dense(params, g, cfg, eigvec=eig)
    # unnormalized GNNs amplify magnitudes across layers; compare relative
    assert _rel_err(out[:4], want[:4]) < 1e-4, (model, vn)


@pytest.mark.parametrize("model", ["gin", "gat"])
def test_model_kernel_mode_matches_reference_mode(model, rng):
    """Pallas (interpret) engine path == pure-jnp path."""
    g = random_molecule_batch(rng)
    cfg_ref = paper_config(model)
    cfg_k = paper_config(model, kernel_mode="kernel")
    params = init(KEY, cfg_ref)
    out_ref = apply(params, g, cfg_ref)
    out_k = apply(params, g, cfg_k)
    assert _rel_err(out_k[:4], out_ref[:4]) < 1e-3


def test_node_level_task_output_shape(rng):
    g = random_molecule_batch(rng)
    cfg = paper_config("gcn", task="node", out_dim=7)
    params = init(KEY, cfg)
    out = apply(params, g, cfg)
    assert out.shape == (g.num_nodes, 7)
    assert not bool(jnp.isnan(out).any())


def test_engine_stream_matches_direct_apply(rng):
    from repro.data.pipeline import MOLHIV, MoleculeStream
    from repro.serve.gnn_engine import GNNEngine

    cfg = paper_config("gin")
    params = init(KEY, cfg)
    eng = GNNEngine(cfg, params)
    graphs = MoleculeStream(MOLHIV, seed=3).take(5)
    outs, lats, _ = eng.infer_stream([g[:4] for g in graphs])
    assert len(outs) == 5 and (lats > 0).all()
    # cross-check graph 0 against direct apply on a fresh padded batch
    s, r, nf, ef, _ = graphs[0]
    g0 = batch_graphs([(s, r, nf, ef)], n_pad=eng._bucket_for(nf.shape[0], len(s))[0],
                      e_pad=eng._bucket_for(nf.shape[0], len(s))[1])
    direct = apply(params, g0, cfg)
    np.testing.assert_allclose(outs[0][0], np.asarray(direct[0]), rtol=1e-4, atol=1e-5)


def test_gnn_permutation_of_graph_nodes_invariance(rng):
    """Graph-level output must be invariant to node relabeling."""
    n, e = 12, 30
    s = rng.integers(0, n, e).astype(np.int32)
    r = rng.integers(0, n, e).astype(np.int32)
    nf = rng.normal(size=(n, 9)).astype(np.float32)
    ef = rng.normal(size=(e, 3)).astype(np.float32)
    cfg = paper_config("gin")
    params = init(KEY, cfg)
    g1 = batch_graphs([(s, r, nf, ef)], n_pad=16, e_pad=40)
    perm = rng.permutation(n).astype(np.int32)
    inv = np.argsort(perm).astype(np.int32)
    g2 = batch_graphs([(inv[s], inv[r], nf[perm], ef)], n_pad=16, e_pad=40)
    o1 = apply(params, g1, cfg)[0]
    o2 = apply(params, g2, cfg)[0]
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-5)
