"""Packing round-trip properties: a packed multi-graph batch must be
indistinguishable from per-graph serving.

Three layers of guarantee, for every model in gnn/models.py:
  * round-trip — packed-batch forward slot i == graph i served alone,
    across two different bucket budgets (padding-amount independence);
  * mask-exact — at fixed shapes, garbage written into every padding
    region (node/edge features, padded edge endpoints, graph ids, eigvec
    tail) leaves outputs BITWISE identical;
  * aggregators — gather_scatter over a packed batch equals per-graph
    gather_scatter for all ops in AGGREGATORS.

The deterministic seeded cases below always run; when ``hypothesis`` is
installed (requirements-dev.txt) the same properties are additionally
fuzzed over randomly drawn graph sets.
"""
import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import message_passing as mp
from repro.core.batching import BucketBudget, pack_eigvecs, pack_graphs, unpack_outputs
from repro.core.graph import batch_graphs
from repro.gnn import init
from repro.gnn.models import apply, paper_config

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to the seeded cases only
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)
MODELS = [("gcn", False), ("gin", False), ("gin", True), ("gat", False),
          ("pna", False), ("dgn", False)]
# singles always fit (16, 40); budgets hold any generated set
SINGLE_N, SINGLE_E = 16, 40
BUDGETS = (BucketBudget(80, 200, 6), BucketBudget(96, 240, 8))
# deterministic graph-set shapes: 1..5 graphs, n<=12 nodes, e<=30 edges
SEED_CASES = [
    ([(8, 20), (11, 26), (4, 7)], 0),
    ([(12, 30)], 1),
    ([(3, 2), (3, 2), (3, 2), (3, 2), (3, 2)], 2),
    ([(12, 30), (12, 30), (12, 30), (12, 30), (12, 30)], 3),
    ([(5, 9), (12, 24)], 4),
]


def _materialize(sizes, seed):
    rng = np.random.default_rng(seed)
    graphs, eigs = [], []
    for n, e in sizes:
        graphs.append((
            rng.integers(0, n, e).astype(np.int32),
            rng.integers(0, n, e).astype(np.int32),
            rng.normal(size=(n, 9)).astype(np.float32),
            rng.normal(size=(e, 3)).astype(np.float32),
        ))
        eigs.append(rng.normal(size=(n,)).astype(np.float32))
    return graphs, eigs


@lru_cache(maxsize=None)
def _model(model, vn):
    """(cfg, params, jitted packed fns per budget, jitted single fn)."""
    cfg = paper_config(model, virtual_node=vn)
    params = init(KEY, cfg)
    packed_fns = {
        b: jax.jit(lambda p, g, eig, b=b: apply(p, g, cfg, eigvec=eig,
                                                num_graphs=b.g_pad))
        for b in BUDGETS
    }
    single_fn = jax.jit(lambda p, g, eig: apply(p, g, cfg, eigvec=eig, num_graphs=1))
    return cfg, params, packed_fns, single_fn


def _single_outputs(graphs, eigs, params, single_fn):
    outs = []
    for g, eig in zip(graphs, eigs):
        single = batch_graphs([g], n_pad=SINGLE_N, e_pad=SINGLE_E)
        ev = np.zeros((SINGLE_N,), np.float32)
        ev[: len(eig)] = eig
        outs.append(np.asarray(single_fn(params, single, jnp.asarray(ev))[0]))
    return outs


def _check_roundtrip(model, vn, sizes, seed):
    graphs, eigs = _materialize(sizes, seed)
    cfg, params, packed_fns, single_fn = _model(model, vn)
    want = _single_outputs(graphs, eigs, params, single_fn)
    for budget in BUDGETS:
        packed, meta = pack_graphs(graphs, budget)
        eig = jnp.asarray(pack_eigvecs(eigs, meta))
        out = np.asarray(packed_fns[budget](params, packed, eig))
        got = unpack_outputs(out, meta, level="graph")
        for i in range(len(graphs)):
            np.testing.assert_allclose(
                got[i][0], want[i], rtol=1e-4, atol=1e-6,
                err_msg=f"{model} vn={vn} budget={budget} graph={i}",
            )


def _check_gather_scatter(sizes, seed, op):
    graphs, _ = _materialize(sizes, seed)
    budget = BUDGETS[0]
    packed, meta = pack_graphs(graphs, budget)
    msgs = jnp.take(packed.node_feat, packed.src, axis=0)
    agg_packed = np.asarray(mp.gather_scatter(packed, msgs, ops=(op,)))
    per_node = unpack_outputs(agg_packed, meta, level="node")
    for i, g in enumerate(graphs):
        single = batch_graphs([g], n_pad=SINGLE_N, e_pad=SINGLE_E)
        m = jnp.take(single.node_feat, single.src, axis=0)
        want = np.asarray(mp.gather_scatter(single, m, ops=(op,)))
        n = meta.node_counts[i]
        np.testing.assert_allclose(
            per_node[i], want[:n], rtol=1e-5, atol=1e-6,
            err_msg=f"op={op} graph={i}",
        )


# ---------------------------------------------------------- deterministic


@pytest.mark.parametrize("model,vn", MODELS)
@pytest.mark.parametrize("sizes,seed", SEED_CASES[:3])
def test_packed_forward_matches_per_graph(model, vn, sizes, seed):
    _check_roundtrip(model, vn, sizes, seed)


@pytest.mark.parametrize("op", mp.AGGREGATORS)
@pytest.mark.parametrize("sizes,seed", SEED_CASES)
def test_packed_gather_scatter_matches_per_graph(op, sizes, seed):
    _check_gather_scatter(sizes, seed, op)


@pytest.mark.parametrize("model,vn", MODELS)
def test_packed_forward_is_mask_exact(model, vn, rng):
    """Garbage in every padding region must not move a single bit."""
    budget = BUDGETS[0]
    graphs, eigs = _materialize([(8, 20), (11, 26), (4, 7)], seed=3)
    cfg, params, packed_fns, _ = _model(model, vn)
    packed, meta = pack_graphs(graphs, budget)
    eig = pack_eigvecs(eigs, meta)
    baseline = np.asarray(packed_fns[budget](params, packed, jnp.asarray(eig)))

    n_real = sum(meta.node_counts)
    e_real = sum(meta.edge_counts)
    nf = np.asarray(packed.node_feat).copy()
    nf[n_real:] = rng.normal(size=nf[n_real:].shape)
    ef = np.asarray(packed.edge_feat).copy()
    ef[e_real:] = rng.normal(size=ef[e_real:].shape)
    ei = np.asarray(packed.edge_index).copy()
    ei[:, e_real:] = rng.integers(0, budget.n_pad, size=ei[:, e_real:].shape)
    gid = np.asarray(packed.graph_id).copy()
    gid[n_real:] = rng.integers(0, budget.g_pad + 1, size=budget.n_pad - n_real)
    eig_fuzz = eig.copy()
    eig_fuzz[n_real:] = rng.normal(size=budget.n_pad - n_real)
    fuzzed = dataclasses.replace(
        packed,
        node_feat=jnp.asarray(nf.astype(np.float32)),
        edge_feat=jnp.asarray(ef.astype(np.float32)),
        edge_index=jnp.asarray(ei.astype(np.int32)),
        graph_id=jnp.asarray(gid.astype(np.int32)),
    )
    out = np.asarray(packed_fns[budget](params, fuzzed, jnp.asarray(eig_fuzz)))
    np.testing.assert_array_equal(
        out[: meta.num_graphs], baseline[: meta.num_graphs],
        err_msg=f"{model} vn={vn}: padding content leaked into outputs",
    )


# -------------------------------------------------------------- hypothesis

if HAVE_HYPOTHESIS:
    graph_set_strategy = st.lists(
        st.tuples(st.integers(3, 12), st.integers(2, 30)), min_size=1, max_size=5
    )

    @pytest.mark.parametrize("model,vn", MODELS)
    @settings(max_examples=5, deadline=None)
    @given(sizes=graph_set_strategy, seed=st.integers(0, 2**16))
    def test_packed_forward_matches_per_graph_fuzzed(model, vn, sizes, seed):
        _check_roundtrip(model, vn, sizes, seed)

    @settings(max_examples=15, deadline=None)
    @given(sizes=graph_set_strategy, seed=st.integers(0, 2**16),
           op=st.sampled_from(mp.AGGREGATORS))
    def test_packed_gather_scatter_matches_per_graph_fuzzed(sizes, seed, op):
        _check_gather_scatter(sizes, seed, op)
