"""Pipelined execution: dispatch-ahead scheduling, the bounded in-flight
window, FIFO harvest, exact virtual-clock overlap sims, the threaded
``PipelinedStream`` runner, eigvec LRU, and D2H accounting.

Same discipline as ``tests/test_slo_sim.py`` / ``tests/test_obs.py``:
scripted arrival traces + scripted service/host-pack times on a
``VirtualClock``, binary-fraction timestamps, assertions by exact float
equality — never tolerances.  Real-engine parity cases assert *bitwise*
output equality between the serial and pipelined paths.
"""
import jax
import numpy as np
import pytest

from conftest import scripted_executor
from repro.gnn import init
from repro.gnn.models import paper_config
from repro.obs import MetricsRegistry, Tracer, export
from repro.serve.clock import RealClock, VirtualClock
from repro.serve.gnn_engine import GNNEngine
from repro.serve.pipeline import (
    PipelineConfig,
    PipelinedStream,
    as_pipeline,
    overlap_fraction,
)
from repro.serve.scheduler import StreamScheduler

KEY = jax.random.PRNGKey(0)
# binary fractions: every modeled timestamp below is exact in float64
MW = 0.0009765625  # max_wait_s = 2**-10
A1 = 0.001953125  # 2**-9
A2 = 0.00390625  # 2**-8
H = 0.0029296875  # scripted host-pack seconds = 3 * 2**-10
SVC = 0.00390625  # scripted flush compute = 2**-8


def graph(n=8, e=12, feat=9, edge=3, seed=0):
    rng = np.random.default_rng(seed)
    return (
        rng.integers(0, n, e).astype(np.int32),
        rng.integers(0, n, e).astype(np.int32),
        rng.normal(size=(n, feat)).astype(np.float32),
        rng.normal(size=(e, edge)).astype(np.float32),
    )


def graphs(k, seed=0, nodes=(5, 14)):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(k):
        n = int(rng.integers(*nodes))
        e = int(rng.integers(n, 2 * n))
        out.append((
            rng.integers(0, n, e).astype(np.int32),
            rng.integers(0, n, e).astype(np.int32),
            rng.normal(size=(n, 9)).astype(np.float32),
            rng.normal(size=(e, 3)).astype(np.float32),
        ))
    return out


def flush_rows(rep, with_start=True):
    return [
        (f.rids, f.reason, f.at_s, f.start_s, f.done_s, f.compute_s)
        if with_start else (f.rids, f.reason, f.at_s, f.done_s, f.compute_s)
        for f in rep.flush_log
    ]


# ------------------------------------------------------------ config surface


def test_pipeline_config_validation():
    assert PipelineConfig().inflight == 2
    with pytest.raises(ValueError, match="inflight"):
        PipelineConfig(inflight=0)
    with pytest.raises(ValueError, match="host_cost"):
        PipelineConfig(host_cost="wall")
    with pytest.raises(ValueError, match="host_cost"):
        PipelineConfig(host_cost=-0.001)
    with pytest.raises(ValueError, match="host_cost"):
        PipelineConfig(host_cost=[0.001, -0.002])
    with pytest.raises(ValueError, match="host_cost"):
        PipelineConfig(host_cost=[])
    assert PipelineConfig(host_cost="measured").measured
    assert not PipelineConfig(host_cost=0.001).measured


def test_as_pipeline_normalization():
    assert as_pipeline(None) is None
    assert as_pipeline(False) is None
    assert as_pipeline(True) == PipelineConfig()
    assert as_pipeline(3) == PipelineConfig(inflight=3)
    cfg = PipelineConfig(inflight=4, host_cost=0.001)
    assert as_pipeline(cfg) is cfg
    with pytest.raises(ValueError, match="pipeline"):
        as_pipeline("deep")


def test_host_cost_fn_forms():
    assert PipelineConfig(host_cost=None).host_cost_fn()(7) == 0.0
    assert PipelineConfig(host_cost=H).host_cost_fn()(3) == H
    seq = PipelineConfig(host_cost=[0.001, 0.002]).host_cost_fn()
    assert [seq(0), seq(1), seq(2), seq(9)] == [0.001, 0.002, 0.002, 0.002]
    assert PipelineConfig(host_cost="measured").host_cost_fn() is None


# -------------------------------------------- serial equivalence at depth 1


def _paced_run(pipeline, slo=None):
    ex = scripted_executor(service_s=[0.004, 0.002, 0.006, 0.003])
    s = StreamScheduler(ex, capacity=2, max_wait_s=0.0015, slo_s=slo,
                        service_s=0.004, pipeline=pipeline)
    gs = graphs(12, seed=3)
    return s.run(gs, arrivals=[0.001 * i for i in range(len(gs))])


def test_depth1_free_host_cost_equals_serial():
    """``pipeline=PipelineConfig(inflight=1)`` with the default free host
    cost reproduces the serial loop exactly — same flush decisions, rids,
    reasons, completion times, latencies, and outputs.  Only ``start_s``
    is allowed to differ: serial records the modeled *device* start,
    pipelined records the *dispatch* instant."""
    ser = _paced_run(None)
    p1 = _paced_run(PipelineConfig(inflight=1))
    assert flush_rows(ser, with_start=False) == flush_rows(p1, with_start=False)
    np.testing.assert_array_equal(ser.latencies_s, p1.latencies_s)
    for a, b in zip(ser.outputs, p1.outputs):
        np.testing.assert_array_equal(a, b)
    assert ser.makespan_s == p1.makespan_s
    # dispatch instant <= modeled device start, always
    for fs, fp in zip(ser.flush_log, p1.flush_log):
        assert fp.start_s <= fs.start_s


def test_depth1_equivalence_with_slo_shedding():
    ser = _paced_run(None, slo=0.006)
    p1 = _paced_run(PipelineConfig(inflight=1), slo=0.006)
    assert [(s.rid, s.reason, s.at_s, s.projected_delay_s) for s in ser.shed] \
        == [(s.rid, s.reason, s.at_s, s.projected_delay_s) for s in p1.shed]
    assert flush_rows(ser, with_start=False) == flush_rows(p1, with_start=False)


# ------------------------------------------------- exact overlap simulation


def _overlap_sim(tracer=None, metrics=None, inflight=2, host_cost=H):
    """Three singleton deadline flushes with scripted host + service
    times — every timestamp below is hand-computed and binary-exact."""
    ex = scripted_executor(service_s=SVC)
    s = StreamScheduler(
        ex, capacity=2, max_wait_s=MW, tracer=tracer, metrics=metrics,
        pipeline=PipelineConfig(inflight=inflight, host_cost=host_cost),
    )
    rep = s.run([graph(seed=0), graph(seed=1), graph(seed=2)],
                arrivals=[0.0, A1, A2])
    return ex, rep


def test_exact_virtual_clock_overlap_sim():
    """The full modeled timeline of the worked example, by exact float
    equality.  Flush 1 *dispatches* (start_s) before flush 0 completes —
    that is the overlap the serial loop cannot express."""
    _, rep = _overlap_sim()
    # f0: deadline at 2**-10; pack H; device free -> runs immediately
    # f1: deadline at A1+MW; pack queues behind f0's pack (host_free),
    #     device queues behind f0 (device_free)
    # f2: window full at its deadline -> dispatch gate waits for f0's
    #     completion (slot), reason "drain" (stream exhausted)
    assert flush_rows(rep) == [
        ((0,), "deadline", MW, MW + H, MW + H + SVC, SVC),
        ((1,), "deadline", A1 + MW, MW + 2 * H,
         MW + H + 2 * SVC, SVC),
        ((2,), "drain", MW + H + SVC, MW + H + SVC + H,
         MW + H + 3 * SVC, SVC),
    ]
    np.testing.assert_array_equal(rep.latencies_s, [
        MW + H + SVC,
        MW + H + 2 * SVC - A1,
        MW + H + 3 * SVC - A2,
    ])
    assert rep.makespan_s == MW + H + 3 * SVC
    # the overlap itself: flush 1 dispatched strictly before flush 0 done
    f0, f1, f2 = rep.flush_log
    assert f1.start_s < f0.done_s
    # FIFO: completion (== flush-log) order is dispatch order
    assert [f.rids for f in rep.flush_log] == [(0,), (1,), (2,)]
    assert f0.done_s <= f1.done_s <= f2.done_s


def test_pipelined_sim_is_bitwise_reproducible():
    tr_a, tr_b = Tracer(VirtualClock()), Tracer(VirtualClock())
    reg_a, reg_b = MetricsRegistry(), MetricsRegistry()
    _, rep_a = _overlap_sim(tracer=tr_a, metrics=reg_a)
    _, rep_b = _overlap_sim(tracer=tr_b, metrics=reg_b)
    assert flush_rows(rep_a) == flush_rows(rep_b)
    np.testing.assert_array_equal(rep_a.latencies_s, rep_b.latencies_s)
    assert export.trace_json(tr_a) == export.trace_json(tr_b)
    assert export.prometheus_text(reg_a) == export.prometheus_text(reg_b)


def test_pipelined_trace_models_overlap():
    """The trace's pack span for flush k+1 genuinely overlaps the device
    span for flush k on the virtual timeline, and ``overlap_fraction``
    reports it; a serial run reports 0.0 (zero-width pack markers)."""
    tr = Tracer(VirtualClock())
    _overlap_sim(tracer=tr)
    packs = [s for s in tr.spans if s.name == "pack"]
    devs = [s for s in tr.spans if s.name == "device"]
    assert len(packs) == 3 and len(devs) == 3
    assert all(s.dur_s == H for s in packs)
    # pack of flush 1 inside device of flush 0
    assert packs[1].t0_s < devs[0].t1_s and packs[1].t1_s > devs[0].t0_s
    frac = overlap_fraction(tr)
    assert 0.0 < frac <= 1.0
    # hand-check: pack0 [MW, MW+H] vs device union starting at MW+H ->
    # pack0 contributes 0; packs 1 and 2 fully covered -> 2/3
    assert frac == pytest.approx(2.0 / 3.0)
    tr_ser = Tracer(VirtualClock())
    ex = scripted_executor(service_s=SVC)
    StreamScheduler(ex, capacity=2, max_wait_s=MW, tracer=tr_ser).run(
        [graph(seed=0)], arrivals=[0.0])
    assert overlap_fraction(tr_ser) == 0.0


def test_dispatch_events_and_inflight_metric():
    tr = Tracer(VirtualClock())
    reg = MetricsRegistry()
    _, rep = _overlap_sim(tracer=tr, metrics=reg)
    dispatches = [s for s in tr.spans if s.name == "dispatch"]
    assert len(dispatches) == len(rep.flush_log)
    by_attr = [dict(s.attrs) for s in dispatches]
    assert all(1 <= a["inflight"] <= 2 for a in by_attr)
    snap = export.metrics_snapshot(reg)
    assert export.validate_metrics_snapshot(snap) == len(snap["metrics"])
    text = export.prometheus_text(reg)
    assert "serve_inflight_depth 0" in text  # drained at end of run
    assert "serve_pack_ewma_seconds" in text


def test_pack_ewma_tracks_scripted_host_costs():
    """Scripted per-flush host costs fold into the per-signature pack
    EWMA with the ``svc_alpha`` coefficient — exact values."""
    ex = scripted_executor(service_s=SVC)
    s = StreamScheduler(
        ex, capacity=2, max_wait_s=MW, svc_alpha=0.5,
        pipeline=PipelineConfig(inflight=2, host_cost=[0.002, 0.004, 0.008]),
    )
    s.run([graph(seed=0), graph(seed=1), graph(seed=2)],
          arrivals=[0.0, A1, A2])
    sig = (32, 96)
    # ewma: 0.002 -> 0.5*0.002+0.5*0.004 = 0.003 -> 0.5*0.003+0.5*0.008
    assert s.pack_estimate_s(sig) == 0.5 * (0.5 * (0.002 + 0.004)) + 0.5 * 0.008
    # a fresh signature projects zero pack cost
    assert s.pack_estimate_s((64, 192)) == 0.0


def test_admission_projection_accounts_host_pack_backlog():
    """With a scripted host-pack cost the admission projection grows by
    the pack EWMA, so a tight-SLO stream sheds more than the free-host
    run at the same depth — and at depth 1 the free-host pipelined run
    sheds exactly like serial (depth 2 may legitimately differ: a bucket
    dispatching at its deadline while the device is busy changes batch
    composition versus serial, which lets late arrivals pack in)."""
    def run(pipeline):
        ex = scripted_executor(service_s=0.004)
        s = StreamScheduler(ex, capacity=1, max_wait_s=0.0005,
                            slo_s=0.0105, service_s=0.004, pipeline=pipeline)
        gs = graphs(10, seed=5)
        return s.run(gs, arrivals=[0.0008 * i for i in range(len(gs))])

    ser = run(None)
    d1 = run(PipelineConfig(inflight=1, host_cost=None))
    free = run(PipelineConfig(inflight=2, host_cost=None))
    costly = run(PipelineConfig(inflight=2, host_cost=0.004))
    assert [(s.rid, s.reason, s.at_s, s.projected_delay_s) for s in ser.shed] \
        == [(s.rid, s.reason, s.at_s, s.projected_delay_s) for s in d1.shed]
    assert len(costly.shed) > len(free.shed)
    # conservation holds in every mode
    for rep in (ser, d1, free, costly):
        assert rep.num_served + rep.num_shed == rep.num_requests


# ------------------------------------------------------ in-flight window


def test_inflight_window_bounds():
    """At depth d, flush k cannot dispatch before flush k-d completed:
    the window is a hard bound on dispatched-but-unharvested flushes."""
    for depth in (1, 2, 4):
        ex = scripted_executor(service_s=SVC)
        s = StreamScheduler(
            ex, capacity=1, max_wait_s=MW,
            pipeline=PipelineConfig(inflight=depth, host_cost=0.0001),
        )
        rep = s.run(graphs(12, seed=7), qps=0.0)  # saturation
        log = rep.flush_log
        assert len(log) >= depth + 2
        for k in range(depth, len(log)):
            assert log[k].start_s >= log[k - depth].done_s
        # ...and depth genuinely allows dispatch-ahead: some flush starts
        # before its predecessor completes whenever the window has room
        if depth >= 2:
            assert any(log[k].start_s < log[k - 1].done_s
                       for k in range(1, len(log)))


def test_fifo_response_order_under_unequal_service_times():
    """A short flush dispatched behind a long one still completes and
    responds after it (serial device + FIFO harvest): response order is
    dispatch order, never compute-time order."""
    ex = scripted_executor(service_s=[0.016, 0.0005, 0.0005])
    tr = Tracer(VirtualClock())
    s = StreamScheduler(
        ex, capacity=1, max_wait_s=MW, tracer=tr,
        pipeline=PipelineConfig(inflight=3, host_cost=None),
    )
    rep = s.run(graphs(6, seed=9), qps=0.0)
    log = rep.flush_log
    assert len(log) >= 3
    assert [f.done_s for f in log] == sorted(f.done_s for f in log)
    # rids respond in dispatch order
    responds = [dict(s.attrs)["rid"] for s in tr.spans if s.name == "respond"]
    flat = [r for f in log for r in f.rids]
    assert responds == flat
    # outputs land at the right request indices regardless
    assert all(o is not None for o in rep.outputs)


# ------------------------------------------------- real-engine parity


MODELS = [("gcn", False), ("gin", False), ("gin", True), ("gat", False),
          ("pna", False), ("dgn", False)]


def _reduced_config(model, vn=False, **kw):
    base = dict(num_layers=2, virtual_node=vn)
    if model == "gat":
        base.update(heads=2, head_features=8)
    elif model in ("pna", "dgn"):
        base.update(hidden=16, head_hidden=(8,))
    else:
        base.update(hidden=16)
    base.update(kw)
    return paper_config(model, **base)


@pytest.mark.parametrize("model,vn", MODELS)
@pytest.mark.parametrize("precision", ["fp32", "int8"])
def test_pipelined_bitwise_parity_all_models(model, vn, precision, rng):
    """Pipelined outputs are bitwise-equal to serial for every model x
    precision, in both serving shapes: the packed scheduler path
    (serial loop vs pipelined loop) and the streaming path
    (``infer_stream`` vs the threaded ``PipelinedStream``)."""
    cfg = _reduced_config(model, vn)
    params = init(KEY, cfg)
    gs = graphs(6, seed=11)
    eig = model == "dgn"
    eng = GNNEngine(cfg, params, buckets=((16, 32),), precision=precision)
    # packed: same engine, serial then pipelined scheduler runs
    ser = StreamScheduler(eng, capacity=2, max_wait_s=0.002,
                          with_eigvec=eig).run(gs)
    pipe = StreamScheduler(eng, capacity=2, max_wait_s=0.002,
                           with_eigvec=eig,
                           pipeline=PipelineConfig(inflight=2)).run(gs)
    assert [f.rids for f in ser.flush_log] == [f.rids for f in pipe.flush_log]
    for a, b in zip(ser.outputs, pipe.outputs):
        np.testing.assert_array_equal(a, b)
    # stream: blocking loop vs threaded double-buffered runner
    base, _, _ = eng.infer_stream(gs, with_eigvec=eig)
    outs, stats = PipelinedStream(eng.executor, model=eng.name,
                                  inflight=2).run(gs, with_eigvec=eig)
    assert len(outs) == len(base) and stats["peak_inflight"] <= 2
    for a, b in zip(base, outs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[:1])


def test_pipelined_stream_validation_and_staging(rng):
    cfg = _reduced_config("gin")
    eng = GNNEngine(cfg, init(KEY, cfg), buckets=((16, 32),))
    with pytest.raises(ValueError, match="inflight"):
        PipelinedStream(eng.executor, inflight=0)
    with pytest.raises(ValueError, match="prepare_ahead"):
        PipelinedStream(eng.executor, inflight=2, prepare_ahead=0)
    gs = graphs(4, seed=13)
    base, _, _ = eng.infer_stream(gs)
    for kwargs in (dict(stage=False), dict(prepare_ahead=3)):
        outs, _ = PipelinedStream(eng.executor, model=eng.name,
                                  inflight=2, **kwargs).run(gs)
        for a, b in zip(base, outs):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[:1])


def test_pack_prepared_stage_is_bitwise_transparent(rng):
    from repro.core.batching import BucketBudget, pack_prepared

    cfg = _reduced_config("gin")
    eng = GNNEngine(cfg, init(KEY, cfg), buckets=((16, 32),))
    gs = graphs(4, seed=17)
    budget = BucketBudget(64, 128, 8)
    prep, _ = pack_prepared(gs, budget, with_layout=eng.share_layout)
    staged, _ = pack_prepared(gs, budget, with_layout=eng.share_layout,
                              stage=True)
    assert staged.bucket_key == prep.bucket_key
    out_a, _ = eng.executor.run(prep, model=eng.name)
    out_b, _ = eng.executor.run(staged, model=eng.name)
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))


# ------------------------------------------- executor satellites (LRU, D2H)


def test_eigvec_lru_hits_and_misses(rng):
    from repro.serve.executor import Executor

    reg = MetricsRegistry()
    ex = Executor(buckets=((16, 32),))
    ex.attach_telemetry(metrics=reg)
    g = graph(seed=21)

    def count(result):
        m = export.metrics_snapshot(reg)["metrics"].get(
            "serve_eigvec_cache_total", {"series": []})
        for s in m["series"]:
            if s["labels"]["result"] == result:
                return s["value"]
        return 0

    v1 = ex._eigvec(g[0], g[1], g[2].shape[0], 16)
    assert count("miss") == 1 and count("hit") == 0
    v2 = ex._eigvec(g[0], g[1], g[2].shape[0], 16)
    assert count("miss") == 1 and count("hit") == 1
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    # distinct edge list (same sizes) is a different key
    g2 = graph(seed=22)
    ex._eigvec(g2[0], g2[1], g2[2].shape[0], 16)
    assert count("miss") == 2
    # same edges, different padding: also a different key
    ex._eigvec(g[0], g[1], g[2].shape[0], 32)
    assert count("miss") == 3


def test_eigvec_lru_evicts_least_recent(monkeypatch):
    from repro.serve.executor import Executor

    ex = Executor(buckets=((16, 32),))
    monkeypatch.setattr(Executor, "_EIGVEC_LRU_SIZE", 2)
    ga, gb, gc = graph(seed=31), graph(seed=32), graph(seed=33)
    for g in (ga, gb, gc):
        ex._eigvec(g[0], g[1], g[2].shape[0], 16)
    assert len(ex._eigvec_lru) == 2  # ga evicted
    ex._eigvec(gb[0], gb[1], gb[2].shape[0], 16)  # hit, refreshes gb
    ex._eigvec(ga[0], ga[1], ga[2].shape[0], 16)  # re-miss, evicts gc
    keys = list(ex._eigvec_lru)
    assert len(keys) == 2


def test_d2h_span_and_counter(rng):
    """Every harvested run converts outputs under the traced
    ``unpack_d2h`` span, and the seconds land in the
    ``serve_d2h_seconds_total`` counter."""
    cfg = _reduced_config("gin")
    tr = Tracer(RealClock())
    reg = MetricsRegistry()
    eng = GNNEngine(cfg, init(KEY, cfg), buckets=((16, 32),))
    eng.executor.attach_telemetry(tracer=tr, metrics=reg)
    gs = graphs(4, seed=41)
    eng.infer_stream(gs)
    d2h = [s for s in tr.spans if s.name == "unpack_d2h"]
    runs = [s for s in tr.spans if s.name == "executor_run"]
    assert len(d2h) == len(runs) == len(gs)
    assert all(dict(s.attrs)["dur_s"] >= 0.0 for s in d2h)
    text = export.prometheus_text(reg)
    assert "serve_d2h_seconds_total" in text
    total = sum(dict(s.attrs)["dur_s"] for s in d2h)
    snap = export.metrics_snapshot(reg)
    val = snap["metrics"]["serve_d2h_seconds_total"]["series"][0]["value"]
    assert val == pytest.approx(total)


def test_run_async_pending_run_contract(rng):
    """``run_async`` returns an unharvested future; ``result()`` closes
    the timed region once and caches; ``run`` is exactly
    ``run_async().result()``."""
    cfg = _reduced_config("gin")
    eng = GNNEngine(cfg, init(KEY, cfg), buckets=((16, 32),))
    ex = eng.executor
    p = ex.prepare_stream(graph(seed=51))
    pr = ex.run_async(p, model=eng.name)
    assert not pr.done
    out, dt = pr.result()
    assert pr.done and dt >= 0.0
    out2, dt2 = pr.result()  # cached: same object, no re-harvest
    assert out2 is out and dt2 == dt
    out3, _ = ex.run(ex.prepare_stream(graph(seed=51)), model=eng.name)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out3))


# ----------------------------------------------------------------- clocks


def test_real_clock_advance_to_stamps():
    c = RealClock()
    t = c.now()
    assert c.advance_to(t + 100.0) >= t  # live time cannot jump


def test_virtual_clock_advance_to_monotone():
    c = VirtualClock(1.0)
    assert c.advance_to(2.5) == 2.5
    with pytest.raises(ValueError, match="backwards"):
        c.advance_to(2.0)
