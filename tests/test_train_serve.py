"""Training-loop + serving integration: loss decreases, failure recovery,
data determinism, MoE dispatch vs dense equivalence, LM server generate."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import params as P
from repro.data.pipeline import (
    MOLHIV,
    MoleculeStream,
    SyntheticTokens,
    TokenPipelineConfig,
)
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, train

TINY = ModelConfig(
    num_layers=2, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
    vocab_size=64, attn_chunk=16, loss_chunk=16, remat=False, dtype="float32",
).validate()


def test_loss_decreases_and_recovers_from_failure():
    data = SyntheticTokens(TokenPipelineConfig(vocab_size=64, batch=4, seq_len=16))
    with tempfile.TemporaryDirectory() as d:
        out = train(
            TINY,
            AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=40),
            LoopConfig(steps=40, log_every=10, ckpt_every=10, ckpt_dir=d, max_retries=2),
            data,
            inject_failure_at=25,
        )
    h = out["history"]
    assert h[-1]["loss"] < h[0]["loss"]
    assert any(e["event"] == "failure" for e in out["events"])
    assert h[-1]["step"] == 40  # completed despite the injected failure


def test_grad_compression_training_matches_uncompressed_closely():
    data = SyntheticTokens(TokenPipelineConfig(vocab_size=64, batch=4, seq_len=16))
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        base = train(TINY, AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=30),
                     LoopConfig(steps=30, ckpt_every=1000, ckpt_dir=d1), data)
        comp = train(TINY, AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=30),
                     LoopConfig(steps=30, ckpt_every=1000, ckpt_dir=d2,
                                grad_compression=True), data)
    l_base = base["history"][-1]["loss"]
    l_comp = comp["history"][-1]["loss"]
    assert abs(l_base - l_comp) < 0.25 * l_base


def test_data_pipeline_determinism_and_sharding():
    cfg = TokenPipelineConfig(vocab_size=100, batch=8, seq_len=32, seed=5)
    a = SyntheticTokens(cfg).batch_at(3)["tokens"]
    b = SyntheticTokens(cfg).batch_at(3)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = SyntheticTokens(cfg).batch_at(4)["tokens"]
    assert not np.array_equal(a, c)
    s0 = TokenPipelineConfig(vocab_size=100, batch=8, seq_len=32, seed=5, shard_index=0, shard_count=2)
    s1 = TokenPipelineConfig(vocab_size=100, batch=8, seq_len=32, seed=5, shard_index=1, shard_count=2)
    assert not np.array_equal(
        SyntheticTokens(s0).batch_at(0)["tokens"], SyntheticTokens(s1).batch_at(0)["tokens"]
    )


def test_molecule_stream_determinism():
    g1 = MoleculeStream(MOLHIV, seed=1).graph_at(10)
    g2 = MoleculeStream(MOLHIV, seed=1).graph_at(10)
    for a, b in zip(g1, g2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_dispatch_matches_dense_baseline():
    """The scatter-gather MoE (paper technique) == dense all-experts
    baseline when capacity is ample."""
    cfg_d = ModelConfig(
        num_layers=2, d_model=32, num_heads=2, num_kv_heads=2, d_ff=48,
        vocab_size=64, num_experts=4, experts_per_token=2, family="moe",
        capacity_factor=4.0, moe_impl="dispatch", attn_chunk=16, loss_chunk=16,
        remat=False, dtype="float32",
    ).validate()
    import dataclasses

    cfg_dense = dataclasses.replace(cfg_d, moe_impl="dense")
    params = P.values(lm.init_params(jax.random.PRNGKey(1), cfg_d))
    batch = {"tokens": jnp.asarray(np.random.default_rng(0).integers(0, 64, (2, 16)), jnp.int32)}
    h1, _ = lm.forward_hidden(params, batch, cfg_d)
    h2, _ = lm.forward_hidden(params, batch, cfg_dense)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_are_bounded():
    from repro.core import scatter_gather as sg

    rng = np.random.default_rng(0)
    ids = rng.integers(0, 4, 128).astype(np.int32)
    vals = rng.normal(size=(128, 8)).astype(np.float32)
    _, _, kept = sg.dispatch_to_slots(jnp.asarray(vals), jnp.asarray(ids), 4, capacity=16)
    # perfectly balanced would keep 64; capacity 16*4=64 slots
    assert int(kept.sum()) <= 64


def test_lm_server_generates():
    from repro.serve.engine import LMServer, ServeConfig

    params = P.values(lm.init_params(jax.random.PRNGKey(0), TINY))
    srv = LMServer(params, TINY, ServeConfig(max_batch=2, prompt_len=8, cache_len=48, max_new_tokens=4))
    out, stats = srv.generate([np.array([1, 2, 3]), np.array([4, 5])])
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < TINY.vocab_size).all()
    assert stats["prefill_s"] > 0 and stats["decode_s_per_token"] > 0
