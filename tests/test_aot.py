"""The persistent AOT compile cache (serve/aot.py) + XLA flag table.

* **Round-trip parity** — an executable serialized to disk and
  deserialized by a fresh Executor must produce *bitwise* the outputs of
  the fresh compile, across models x precision x fused, with every load
  a hit and zero fresh lowerings in the second executor.
* **Fingerprint invalidation** — a cache entry from a different flag
  set, jax version, or device topology reports ``stale`` (distinct from
  ``miss``), recompiles, and overwrites in place.
* **Corruption** — truncated/garbage/colliding entries degrade to a
  plain miss (never an exception on the serving path) and are healed by
  the write-back.
* **Restart** — a subprocess given only the cache directory and the
  saved params serves bitwise-identical outputs with ``lowered_count ==
  0``: not one ``jax.jit`` trace in the whole process (the kill-the-
  warm-up contract).
"""
import json
import os
import pickle
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import runtime as RT
from repro.gnn import init
from repro.gnn.models import paper_config
from repro.serve.aot import (AOTCache, XlaFlagConfig, default_flags_path,
                             environment_fingerprint, flags_hash, model_label)
from repro.serve.executor import Executor
from repro.serve.gnn_engine import GNNEngine
from repro.serve.scheduler import StreamScheduler

KEY = jax.random.PRNGKey(0)

pytestmark = pytest.mark.skipif(
    not RT.HAS_SERIALIZE_EXECUTABLE,
    reason="pinned jax lacks jax.experimental.serialize_executable",
)


def _reduced_config(model, vn=False, **kw):
    base = dict(num_layers=2, virtual_node=vn)
    if model == "gat":
        base.update(heads=2, head_features=8)
    else:
        base.update(hidden=16)
    base.update(kw)
    return paper_config(model, **base)


def _raw_graphs(rng, k=3, feat=9, edge=3):
    out = []
    for _ in range(k):
        n = int(rng.integers(5, 14))
        e = int(rng.integers(n, 2 * n))
        out.append((
            rng.integers(0, n, e).astype(np.int32),
            rng.integers(0, n, e).astype(np.int32),
            rng.normal(size=(n, feat)).astype(np.float32),
            rng.normal(size=(e, edge)).astype(np.float32),
        ))
    return out


def _serve(cache_dir, cfg, params, graphs, precision="fp32", fused=False,
           xla_flags=None):
    """(outputs, engine) — one fresh engine over ``cache_dir`` serving
    ``graphs`` through the stream path."""
    eng = GNNEngine(cfg, params, buckets=((16, 32),), precision=precision,
                    fused=fused, aot_cache=AOTCache(str(cache_dir)),
                    xla_flags=xla_flags)
    outs, _, _ = eng.infer_stream(graphs)
    return np.concatenate(outs), eng


# ------------------------------------------------------------ round trip


@pytest.mark.parametrize("model,precision,fused", [
    ("gcn", "fp32", False),
    ("gin", "fp32", True),
    ("gin", "int8", False),
    ("gat", "fp32", False),
])
def test_aot_round_trip_is_bitwise_and_trace_free(model, precision, fused,
                                                  rng, tmp_path):
    cfg = _reduced_config(model)
    params = init(KEY, cfg)
    graphs = _raw_graphs(rng)
    out_fresh, eng1 = _serve(tmp_path, cfg, params, graphs,
                             precision=precision, fused=fused)
    ex1 = eng1.executor
    assert ex1.lowered_count > 0
    assert ex1.aot_stats()["miss"] == ex1.lowered_count
    assert ex1.aot_stats()["hit"] == 0

    out_disk, eng2 = _serve(tmp_path, cfg, params, graphs,
                            precision=precision, fused=fused)
    ex2 = eng2.executor
    assert ex2.lowered_count == 0, "warm restart must not trace once"
    assert ex2.aot_stats() == {"hit": ex1.lowered_count, "miss": 0,
                               "stale": 0}
    np.testing.assert_array_equal(
        out_fresh, out_disk,
        err_msg=f"{model}/{precision}/fused={fused}: cache-hit outputs "
                f"differ from the fresh compile",
    )


def test_compile_warm_split_accounts_both_halves(rng, tmp_path):
    """Fresh run pays compile+warm; the disk-hit run still pays warm
    (one untimed execution) but compile collapses to the deserialize."""
    cfg = _reduced_config("gcn")
    params = init(KEY, cfg)
    graphs = _raw_graphs(rng)
    _, eng1 = _serve(tmp_path, cfg, params, graphs)
    assert eng1.compile_seconds > 0 and eng1.warm_seconds > 0
    _, eng2 = _serve(tmp_path, cfg, params, graphs)
    assert eng2.warm_seconds > 0, "first-run warm is paid even on a hit"
    assert eng2.compile_seconds < eng1.compile_seconds, (
        "disk load must be cheaper than the fresh compile it replaces"
    )


# ---------------------------------------------------------- invalidation


def test_stale_fingerprint_is_not_a_miss_and_heals(tmp_path):
    cache = AOTCache(str(tmp_path))
    fn = jax.jit(lambda x: x * 2.0 + 1.0)
    compiled = fn.lower(np.ones((4,), np.float32)).compile()
    key = ("prog", ("stream", 16, 32), 1, ("sig",))
    fp = environment_fingerprint()
    assert cache.store(key, fp, compiled)

    # same key, moved flag hash -> stale (the autotuner-retune case)
    moved = dict(fp, flags=flags_hash({"xla_whatever": 1}))
    assert cache.load(key, moved) is None
    assert cache.stats["stale"] == 1 and cache.stats["miss"] == 0

    # jax version / topology drift -> stale too
    for field, value in [("jax", "9.9.9"), ("num_devices", 1024),
                         ("backend", "tpu")]:
        assert cache.load(key, dict(fp, **{field: value})) is None
    assert cache.stats["stale"] == 4

    # overwrite under the new fingerprint heals it in place
    assert cache.store(key, moved, compiled)
    assert cache.load(key, moved) is not None
    assert cache.stats["hit"] == 1
    assert len(cache.entries()) == 1


def test_executor_recompiles_and_overwrites_stale_entries(rng, tmp_path):
    """End to end: retuned flags must invalidate exactly the cached
    programs whose flags changed — served outputs stay available
    throughout (numerics-neutral flags)."""
    cfg = _reduced_config("gcn")
    params = init(KEY, cfg)
    graphs = _raw_graphs(rng)
    out1, eng1 = _serve(tmp_path, cfg, params, graphs)
    # "retune": a different (valid) flag set -> every entry stale
    flags = XlaFlagConfig(default={"xla_embed_ir_in_executable": True})
    out2, eng2 = _serve(tmp_path, cfg, params, graphs, xla_flags=flags)
    stats = eng2.executor.aot_stats()
    assert stats["stale"] > 0 and stats["hit"] == 0
    assert eng2.executor.lowered_count == stats["stale"]
    np.testing.assert_array_equal(out1, out2)
    # third run under the retuned flags: all hits again
    _, eng3 = _serve(tmp_path, cfg, params, graphs, xla_flags=flags)
    assert eng3.executor.lowered_count == 0
    assert eng3.executor.aot_stats()["stale"] == 0


# ------------------------------------------------------------ corruption


def test_corrupt_entries_degrade_to_miss_and_heal(tmp_path):
    cache = AOTCache(str(tmp_path))
    fn = jax.jit(lambda x: x - 3.0)
    compiled = fn.lower(np.ones((2,), np.float32)).compile()
    key = ("p", ("stream", 16, 32), 1, ("s",))
    fp = environment_fingerprint()
    assert cache.store(key, fp, compiled)
    path = Path(cache.entry_path(key))

    path.write_bytes(b"\x00garbage")  # not a pickle
    assert cache.load(key, fp) is None and cache.stats["miss"] == 1

    path.write_bytes(pickle.dumps({"schema": "wrong/v0"}))
    assert cache.load(key, fp) is None and cache.stats["miss"] == 2

    # right schema, wrong logical key (hash collision / tamper)
    path.write_bytes(pickle.dumps({
        "schema": "repro-aot/v1", "key": repr(("other",)), "fingerprint": fp,
        "payload": b"", "in_tree": None, "out_tree": None,
    }))
    assert cache.load(key, fp) is None and cache.stats["miss"] == 3

    path.write_bytes(path.read_bytes()[:10])  # truncated
    assert cache.load(key, fp) is None and cache.stats["miss"] == 4

    assert cache.store(key, fp, compiled)  # heal
    exe = cache.load(key, fp)
    assert exe is not None
    np.testing.assert_array_equal(
        np.asarray(exe(np.ones((2,), np.float32))), -2.0 * np.ones(2)
    )


def test_executor_serves_through_a_poisoned_cache(rng, tmp_path):
    """A corrupt entry on the serving path is a fresh compile plus an
    overwrite — never an exception, and the next process hits."""
    cfg = _reduced_config("gcn")
    params = init(KEY, cfg)
    graphs = _raw_graphs(rng)
    out1, eng1 = _serve(tmp_path, cfg, params, graphs)
    for f in Path(tmp_path).glob("*.aotx"):
        f.write_bytes(b"poison")
    out2, eng2 = _serve(tmp_path, cfg, params, graphs)
    assert eng2.executor.aot_stats()["miss"] == eng2.executor.lowered_count > 0
    np.testing.assert_array_equal(out1, out2)
    _, eng3 = _serve(tmp_path, cfg, params, graphs)
    assert eng3.executor.lowered_count == 0


# -------------------------------------------------------- the flag table


def test_flag_config_merge_order_and_io(tmp_path):
    flags = XlaFlagConfig(
        default={"a": 1, "b": 1},
        models={"gin": {"default": {"b": 2, "c": 2},
                        "buckets": {"packed|64|192|4": {"c": 3}}}},
    )
    assert flags.resolve("gcn", ("stream", 16, 32)) == {"a": 1, "b": 1}
    assert flags.resolve("gin", ("stream", 16, 32)) == {"a": 1, "b": 2,
                                                        "c": 2}
    assert flags.resolve("gin", ("packed", 64, 192, 4)) == {"a": 1, "b": 2,
                                                            "c": 3}
    path = tmp_path / "flags.json"
    flags.save(str(path), provenance={"tool": "test"})
    loaded = XlaFlagConfig.load(str(path))
    assert loaded.default == flags.default and loaded.models == flags.models
    with pytest.raises(FileNotFoundError):
        XlaFlagConfig.load(str(tmp_path / "absent.json"))
    (tmp_path / "bad.json").write_text(json.dumps({"schema": "nope"}))
    with pytest.raises(ValueError, match="repro-xla-flags/v1"):
        XlaFlagConfig.load(str(tmp_path / "bad.json"))


def test_checked_in_flag_table_loads_and_is_validated():
    """The committed configs/xla_flags.json parses, and every flag in it
    is accepted by this backend (the autotuner's try-compile contract)."""
    assert os.path.exists(default_flags_path())
    table = XlaFlagConfig.load()
    probe = jax.jit(lambda x: x + 1.0).lower(np.ones((2,), np.float32))
    seen = 0
    for model, spec in table.models.items():
        for flags in [spec.get("default", {})] + \
                list(spec.get("buckets", {}).values()):
            if flags:
                probe.compile(compiler_options=dict(flags))  # must not raise
                seen += 1
    assert seen > 0, "the committed table should carry measured winners"


def test_rejected_flag_set_falls_back_and_fingerprints_honestly(rng,
                                                                tmp_path):
    """A flag XLA rejects compiles with defaults (warning, not crash) and
    the write-back is fingerprinted as default-flags — so the next
    default-flags process *hits* instead of going stale."""
    cfg = _reduced_config("gcn")
    params = init(KEY, cfg)
    graphs = _raw_graphs(rng)
    bad = XlaFlagConfig(default={"xla_no_such_option_exists": True})
    with pytest.warns(UserWarning, match="rejected by the backend"):
        out1, eng1 = _serve(tmp_path, cfg, params, graphs, xla_flags=bad)
    assert eng1.executor.lowered_count > 0
    # a plain process with no flag table finds the entries valid
    out2, eng2 = _serve(tmp_path, cfg, params, graphs)
    assert eng2.executor.lowered_count == 0
    np.testing.assert_array_equal(out1, out2)


def test_model_label_distinguishes_virtual_node():
    assert model_label(_reduced_config("gin")) == "gin"
    assert model_label(_reduced_config("gin", vn=True)) == "gin_vn"
    assert flags_hash(None) == flags_hash({})
    assert flags_hash({"a": 1}) != flags_hash({"a": 2})


# -------------------------------------------------------- restart process


def test_restarted_process_serves_with_zero_traces(rng, tmp_path):
    """The whole point: process A populates the cache through the
    scheduler's ladder prewarm; process B (given only the cache dir and
    the saved params) serves bitwise-identical outputs with
    ``lowered_count == 0`` and every load a hit."""
    cfg = _reduced_config("gin")
    params = init(KEY, cfg)
    graphs = _raw_graphs(rng, k=4)
    cache_dir = tmp_path / "aot"
    eng = GNNEngine(cfg, params, buckets=((16, 32),),
                    aot_cache=AOTCache(str(cache_dir)))
    sched = StreamScheduler(eng, capacity=2, max_wait_s=0.001)
    sched.prewarm_ladders(graphs)
    rep = sched.run(graphs)
    assert eng.executor.lowered_count > 0

    blob = tmp_path / "state.pkl"
    with open(blob, "wb") as f:
        pickle.dump({
            "params": jax.tree_util.tree_map(np.asarray, params),
            "graphs": graphs,
            "outputs": [np.asarray(o) for o in rep.outputs],
        }, f)

    child = textwrap.dedent(f"""
        import pickle, sys
        import numpy as np
        from repro.gnn.models import paper_config
        from repro.serve.aot import AOTCache
        from repro.serve.gnn_engine import GNNEngine
        from repro.serve.scheduler import StreamScheduler

        state = pickle.load(open({str(blob)!r}, "rb"))
        cfg = paper_config("gin", num_layers=2, hidden=16)
        eng = GNNEngine(cfg, state["params"], buckets=((16, 32),),
                        aot_cache=AOTCache({str(cache_dir)!r}))
        sched = StreamScheduler(eng, capacity=2, max_wait_s=0.001)
        sched.prewarm_ladders(state["graphs"])
        rep = sched.run(state["graphs"])
        stats = eng.executor.aot_stats()
        assert eng.executor.lowered_count == 0, (
            "restarted process traced", eng.executor.lowered_count)
        assert stats["miss"] == 0 and stats["stale"] == 0, stats
        assert stats["hit"] > 0, stats
        for mine, theirs in zip(rep.outputs, state["outputs"]):
            np.testing.assert_array_equal(np.asarray(mine), theirs)
        print("RESTART_OK hits=%d" % stats["hit"])
    """)
    env = dict(os.environ, PYTHONPATH=str(
        Path(__file__).resolve().parent.parent / "src"))
    r = subprocess.run([sys.executable, "-c", child], capture_output=True,
                       text=True, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "RESTART_OK" in r.stdout
