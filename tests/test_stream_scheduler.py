"""Streaming scheduler: greedy pack-to-budget micro-batching, deadline
flushing, budget-ladder rung selection, compiled-bucket reuse (zero
recompiles after warmup), and mesh-sharded packed parity (subprocess)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.batching import BucketBudget, pack_graphs, unpack_outputs

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _graphs(n_graphs=10, nodes=(6, 16), feat=9, edge=3, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_graphs):
        n = int(rng.integers(*nodes))
        e = int(rng.integers(n, 2 * n))
        out.append(
            (
                rng.integers(0, n, e).astype(np.int32),
                rng.integers(0, n, e).astype(np.int32),
                rng.normal(size=(n, feat)).astype(np.float32),
                rng.normal(size=(e, edge)).astype(np.float32),
            )
        )
    return out


@pytest.fixture(scope="module")
def engine():
    from repro.gnn import init
    from repro.gnn.models import paper_config
    from repro.serve.gnn_engine import GNNEngine

    cfg = paper_config("gin")
    return GNNEngine(cfg, init(jax.random.PRNGKey(0), cfg))


@pytest.fixture(scope="module")
def scheduler(engine):
    from repro.serve.scheduler import StreamScheduler

    return StreamScheduler(engine, capacity=2, max_wait_s=0.002)


# ------------------------------------------------------------------- packing


def test_pack_unpack_node_level_roundtrip():
    graphs = _graphs(3)
    budget = BucketBudget(64, 128, 4)
    packed, meta = pack_graphs(graphs, budget)
    node_feat = np.asarray(packed.node_feat)
    per_graph = unpack_outputs(node_feat, meta, level="node")
    for i, g in enumerate(graphs):
        np.testing.assert_array_equal(per_graph[i], g[2])


def test_pack_rejects_over_budget():
    graphs = _graphs(3, nodes=(30, 31))
    with pytest.raises(ValueError, match="exceeds budget"):
        pack_graphs(graphs, BucketBudget(32, 96, 8))
    with pytest.raises(ValueError, match="exceeds budget"):
        pack_graphs(_graphs(3), BucketBudget(64, 128, 2))


# ----------------------------------------------------------------- scheduler


def test_scheduler_outputs_match_per_graph_stream(engine, scheduler):
    graphs = _graphs(10)
    outs, _, _ = engine.infer_stream(graphs)
    rep = scheduler.run(graphs, qps=0.0)
    assert rep.num_requests == 10
    for i in range(10):
        np.testing.assert_allclose(rep.outputs[i], outs[i], rtol=1e-4, atol=1e-5)
    # saturation mode packs multiple graphs per flush
    assert max(rep.batch_sizes) > 1
    assert sum(rep.batch_sizes) == 10


def test_scheduler_zero_recompiles_after_warmup(engine, scheduler):
    graphs = _graphs(10, seed=1)
    scheduler.run(graphs, qps=0.0)  # warm (signatures already hot from above)
    warm_s = engine.compile_seconds
    n_buckets = len(engine._compiled)
    for qps in (0.0, 500.0, 5000.0):
        rep = scheduler.run(graphs, qps=qps)
        assert rep.compile_s == 0.0
    assert engine.compile_seconds == warm_s
    assert len(engine._compiled) == n_buckets


def test_scheduler_deadline_flushes_singletons_at_low_load(engine, scheduler):
    graphs = _graphs(5)
    # 10 qps: arrivals 100ms apart >> 2ms max-wait -> every flush is a
    # singleton driven by its deadline (CPU compute ~ms << 100ms gap)
    rep = scheduler.run(graphs, qps=10.0)
    assert rep.batch_sizes == [1] * 5
    assert rep.flush_reasons["deadline"] + rep.flush_reasons["drain"] == 5
    # each request waited out max_wait before computing
    assert float(rep.latencies_s.min()) >= scheduler.max_wait_s


def test_scheduler_budget_flush_on_overflow(engine):
    from repro.serve.scheduler import StreamScheduler

    sched = StreamScheduler(engine, capacity=2, max_wait_s=10.0)
    # 30-node graphs hit bucket (32, 96); budget (64, 192, 4) fits only two
    graphs = _graphs(5, nodes=(28, 31), seed=2)
    rep = sched.run(graphs, qps=0.0)
    assert rep.flush_reasons["budget"] >= 2
    assert max(rep.batch_sizes) == 2


def test_rung_selection_prefers_smallest_fit(engine):
    from repro.serve.scheduler import StreamScheduler, _OpenBucket, Request

    sched = StreamScheduler(engine, capacity=4)
    req = Request(rid=0, graph=_graphs(1)[0], arrival_s=0.0)
    key, ladder = sched.ladder_for(req)
    # powers of two plus 1.5x midpoints, capped at capacity
    assert [b.n_pad for b in ladder] == [k * key[0] for k in (1, 2, 3, 4)]
    bucket = _OpenBucket(ladder, 0.0, 1.0)
    bucket.add(req)
    assert bucket.rung() == ladder[0]  # one small graph -> base-size program
    # every rung is pre-warmed, so any rung choice hits a compiled program
    for b in ladder:
        assert ("packed", b.n_pad, b.e_pad, b.g_pad) in engine._compiled


def test_scheduler_accepts_edge_featureless_graphs():
    """RawGraph's '(s, r, nf[, ef])' contract: 3-tuples must stream fine
    through a model that ignores edge features."""
    from repro.gnn import init
    from repro.gnn.models import paper_config
    from repro.serve.gnn_engine import GNNEngine
    from repro.serve.scheduler import StreamScheduler

    cfg = paper_config("gcn", edge_dim=1)
    eng = GNNEngine(cfg, init(jax.random.PRNGKey(0), cfg))
    graphs = [g[:3] for g in _graphs(4, seed=5)]
    rep = StreamScheduler(eng, capacity=2).run(graphs, qps=0.0)
    assert rep.num_requests == 4
    assert all(o.shape == (1, 1) for o in rep.outputs)


def test_latencies_include_queueing_delay(engine, scheduler):
    graphs = _graphs(12, seed=4)
    rep = scheduler.run(graphs, qps=0.0)  # all queued at t=0
    # the serial executor means later flushes complete later: latency of the
    # last-served request covers all earlier compute
    assert float(rep.latencies_s.max()) >= rep.compute_s * 0.9
    assert rep.makespan_s > 0 and rep.graphs_per_s > 0


_SHARDED_PACKED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import jax, numpy as np
from repro import runtime as RT
from repro.gnn import init
from repro.gnn.models import paper_config
from repro.serve.gnn_engine import GNNEngine
from repro.serve.scheduler import StreamScheduler

cfg = paper_config("gin")
params = init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
graphs = []
for _ in range(8):
    n = int(rng.integers(6, 16)); e = int(rng.integers(n, 2 * n))
    graphs.append((rng.integers(0, n, e).astype(np.int32),
                   rng.integers(0, n, e).astype(np.int32),
                   rng.normal(size=(n, cfg.feat_dim)).astype(np.float32),
                   rng.normal(size=(e, cfg.edge_dim)).astype(np.float32)))

plain = StreamScheduler(GNNEngine(cfg, params), capacity=2)
rep_plain = plain.run(graphs, qps=0.0)

mesh = RT.make_flat_mesh(2, axis="data")
sharded = StreamScheduler(GNNEngine(cfg, params, mesh=mesh), capacity=2)
rep_shard = sharded.run(graphs, qps=0.0)

for a, b in zip(rep_plain.outputs, rep_shard.outputs):
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
print("SHARDED_PACKED_OK")
"""


def test_sharded_packed_serving_matches_unsharded():
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_PACKED_SCRIPT],
        capture_output=True, text=True, cwd=ROOT,
    )
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "SHARDED_PACKED_OK" in r.stdout
