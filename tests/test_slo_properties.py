"""Adaptive-ladder and admission invariants, property-style.

The refit loop (``StreamScheduler._refit_ladder``) may reshape a
signature's rung geometry arbitrarily often while a stream is live, so
its safety conditions are stated as properties over arbitrary observation
windows and arbitrary traces rather than hand-picked examples:

  * geometry — after any refit the ladder is strictly increasing, every
    rung multiple lies in ``[1, capacity]``, the top rung is pinned at
    exactly ``capacity`` (admission capacity never shrinks), and at most
    ``max_rungs`` rungs survive;
  * admissibility — every graph size that fit the ladder before a refit
    still admits to some rung after it (the pinned top rung guarantees
    this; the property would catch un-pinning it);
  * no stranding — a refit while buckets are open never loses a request:
    every offered request is either served (finite latency, an output,
    exactly one flush) or typed-shed, and ``served + shed == offered``.

The deterministic seeded cases always run; when ``hypothesis`` is
installed (requirements-dev.txt) the same properties are additionally
fuzzed over randomly drawn windows and traces.
"""
import math

import numpy as np
import pytest

from conftest import scripted_executor
from repro.core.batching import BucketBudget
from repro.serve.scheduler import Request, StreamScheduler

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to the seeded cases only
    HAVE_HYPOTHESIS = False

BASE_SIG = (32, 96)  # ScriptedExecutor's smallest single-graph bucket


def make_graph(rng, n, e):
    return (
        rng.integers(0, n, e).astype(np.int32),
        rng.integers(0, n, e).astype(np.int32),
        rng.normal(size=(n, 4)).astype(np.float32),
        rng.normal(size=(e, 3)).astype(np.float32),
    )


def fresh_scheduler(capacity=8, max_rungs=4, **kw):
    kw.setdefault("adapt_ladder", True)
    kw.setdefault("max_wait_s", 0.015625)
    return StreamScheduler(scripted_executor(service_s=0.00390625),
                           capacity=capacity, max_rungs=max_rungs, **kw)


def assert_ladder_invariants(s, sig):
    ks = s.ladder_multiples(sig)
    assert ks, f"signature {sig} lost its ladder entirely"
    assert ks == sorted(set(ks)), f"not strictly increasing: {ks}"
    assert ks[0] >= 1 and ks[-1] == s.capacity, (
        f"top rung must stay pinned at capacity={s.capacity}: {ks}")
    # (len <= max_rungs holds only *post-refit* — the initially derived
    # ladder may be longer; check_window asserts it where a refit ran)
    nb, eb = sig
    for k, b in zip(ks, s._ladders[sig]):
        assert b == BucketBudget(n_pad=k * nb, e_pad=k * eb, g_pad=2 * k)


def force_refit(s, sig, window):
    """Install an observation window and refit, as the flush loop would."""
    if sig not in s._ladders:  # derive the initial ladder once
        rng = np.random.default_rng(0)
        s.ladder_for(Request(rid=0, graph=make_graph(rng, 4, 4), arrival_s=0.0))
    s._obs_multiples[sig] = list(window)
    s._refit_ladder(sig)


def check_window(window, capacity=8, max_rungs=4):
    s = fresh_scheduler(capacity=capacity, max_rungs=max_rungs)
    before = s._ladders  # (populated by force_refit's ladder_for)
    force_refit(s, BASE_SIG, window)
    assert_ladder_invariants(s, BASE_SIG)
    ks = s.ladder_multiples(BASE_SIG)
    assert len(ks) <= max_rungs
    # admissibility: anything that fits the base bucket fits the ladder's
    # smallest rung; anything admissible before (<= capacity multiples)
    # fits the pinned top rung
    nb, eb = BASE_SIG
    top = s._ladders[BASE_SIG][-1]
    assert top.admits(0, 0, 0, capacity * nb, capacity * eb)
    # observed demand is representable: each clamped observation has a
    # rung at or above it
    for k in window:
        want = min(max(int(k), 1), capacity)
        assert any(r >= want for r in ks), (window, ks, want)
    # the window is consumed — the next refit sees only fresh flushes
    assert s._obs_multiples[BASE_SIG] == []
    return before


def check_trace(sizes, deltas, priorities, slo_s, refit_every, seed):
    """End-to-end conservation on an arbitrary trace with refits live."""
    rng = np.random.default_rng(seed)
    graphs = [make_graph(rng, n, e) for n, e in sizes]
    arrivals = [float(f"{t:.6f}") for t in np.cumsum(deltas)]
    s = fresh_scheduler(capacity=4, max_rungs=3, refit_every=refit_every,
                        slo_s=slo_s, service_s=0.001)
    rep = s.run(graphs, arrivals=arrivals, priorities=priorities)

    # conservation: every offered request is served xor typed-shed
    assert rep.num_served + rep.num_shed == rep.num_requests == len(graphs)
    shed_rids = {x.rid for x in rep.shed}
    flushed_rids = [r for f in rep.flush_log for r in f.rids]
    assert len(flushed_rids) == len(set(flushed_rids)), "double flush"
    assert sorted(flushed_rids) == sorted(
        set(range(len(graphs))) - shed_rids), "stranded or phantom request"
    for i in range(len(graphs)):
        served = i not in shed_rids
        assert (rep.outputs[i] is not None) == served
        assert np.isfinite(rep.latencies_s[i]) == served
        if served:
            assert rep.latencies_s[i] >= 0.0
    assert sum(rep.batch_sizes) == rep.num_served
    assert rep.deadline_misses <= rep.num_served
    # whatever geometry the refits converged on is still well-formed
    for sig in s._ladders:
        assert_ladder_invariants(s, sig)
    return rep


# ---------------------------------------------------------- deterministic

SEED_WINDOWS = [
    [1],  # all-singleton demand: collapses to [1, capacity]
    [1, 1, 2, 2, 3, 3],  # small spread
    [8, 8, 8],  # demand saturates: [8] alone (top == only rung)
    [5],  # a midpoint the derived ladder lacks
    [1, 2, 3, 4, 5, 6, 7, 8],  # more distinct multiples than max_rungs
    [0, -3, 99],  # out-of-range observations clamp, never crash
    [3, 3, 3, 1, 7],
]


@pytest.mark.parametrize("window", SEED_WINDOWS, ids=[str(w) for w in SEED_WINDOWS])
def test_refit_geometry_invariants(window):
    check_window(window)


def test_refit_with_empty_window_is_a_noop():
    s = fresh_scheduler()
    force_refit(s, BASE_SIG, [])
    # derived geometry untouched: powers of two + 1.5x midpoints, top = 8
    assert s.ladder_multiples(BASE_SIG) == [1, 2, 3, 4, 6, 8]


def test_refit_respects_max_rungs_quantiles():
    s = fresh_scheduler(capacity=8, max_rungs=3)
    force_refit(s, BASE_SIG, [1, 2, 3, 4, 5, 6, 7, 8])
    ks = s.ladder_multiples(BASE_SIG)
    assert len(ks) <= 3 and ks[0] == 1 and ks[-1] == 8  # endpoints pinned


SEED_TRACES = [
    # (sizes, deltas_s, priorities, slo_s, refit_every, seed)
    ([(8, 12)] * 10, [0.001] * 10, [0] * 10, None, 2, 0),
    ([(8, 12), (40, 60), (100, 300), (8, 12)] * 3,
     [0.0, 0.002, 0.0, 0.01] * 3, [0, 1, 0, 1] * 3, 0.05, 3, 1),
    ([(16, 24)] * 20, [0.0] * 20, [i % 3 for i in range(20)], 0.02, 4, 2),
    ([(200, 600)] * 5, [0.5] * 5, [0] * 5, 0.001, 1, 3),  # tight SLO
    ([(4, 2)], [0.0], [7], None, 1, 4),  # single request, odd class
]


@pytest.mark.parametrize("case", SEED_TRACES, ids=[f"trace{i}" for i in range(len(SEED_TRACES))])
def test_trace_conservation_under_live_refits(case):
    check_trace(*case)


def test_shed_plus_served_exhaustive_under_overload():
    """2x-ish overload with a tight SLO: significant shedding, yet the
    ledger still balances and nothing is double-counted."""
    rep = check_trace(
        sizes=[(24, 48)] * 40,
        deltas=[0.0005] * 40,
        priorities=[i % 2 for i in range(40)],
        slo_s=0.01,
        refit_every=2,
        seed=5,
    )
    assert rep.num_shed > 0, "overload trace should shed"
    assert rep.num_served > 0, "overload trace should still serve"


# -------------------------------------------------------------- hypothesis

if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(window=st.lists(st.integers(-2, 12), min_size=1, max_size=64),
           capacity=st.integers(2, 16), max_rungs=st.integers(2, 6))
    def test_refit_geometry_invariants_fuzzed(window, capacity, max_rungs):
        check_window(window, capacity=capacity, max_rungs=max_rungs)

    trace_strategy = st.lists(
        st.tuples(
            st.integers(3, 120),  # nodes
            st.integers(2, 360),  # edges
            st.floats(0.0, 0.02, allow_nan=False, allow_infinity=False),
            st.integers(0, 2),  # QoS class
        ),
        min_size=1, max_size=24,
    )

    @settings(max_examples=25, deadline=None)
    @given(trace=trace_strategy,
           slo_s=st.one_of(st.none(), st.floats(0.001, 0.1)),
           refit_every=st.integers(1, 6), seed=st.integers(0, 2**16))
    def test_trace_conservation_fuzzed(trace, slo_s, refit_every, seed):
        sizes = [(n, e) for n, e, _, _ in trace]
        deltas = [d for _, _, d, _ in trace]
        priorities = [p for _, _, _, p in trace]
        check_trace(sizes, deltas, priorities, slo_s, refit_every, seed)
