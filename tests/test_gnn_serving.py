"""GNN serving engine: per-bucket compile-cache bookkeeping (warm-before-
timing in both modes) and the mesh-aware sharded batched path, which must
be bit-identical to the unsharded run (2 virtual devices, subprocess)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _graphs(n_graphs=8, feat=9, edge=3, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_graphs):
        n = int(rng.integers(6, 16))
        e = int(rng.integers(n, 2 * n))
        out.append(
            (
                rng.integers(0, n, e).astype(np.int32),
                rng.integers(0, n, e).astype(np.int32),
                rng.normal(size=(n, feat)).astype(np.float32),
                rng.normal(size=(e, edge)).astype(np.float32),
            )
        )
    return out


@pytest.fixture(scope="module")
def engine():
    from repro.gnn import init
    from repro.gnn.models import paper_config
    from repro.serve.gnn_engine import GNNEngine

    cfg = paper_config("gin")
    return GNNEngine(cfg, init(jax.random.PRNGKey(0), cfg))


def test_infer_batched_warms_each_signature_outside_timing(engine):
    graphs = _graphs(10)
    out, per_graph = engine.infer_batched(graphs, batch_size=4, n_pad=128, e_pad=384)
    assert out.shape == (10, 1)
    assert per_graph > 0
    key = ("batched", 128, 384, 4)
    cb = engine._compiled[key]
    assert len(cb.warm) == 1  # one trace signature, warmed exactly once
    assert cb.compile_s > 0
    assert engine.compile_seconds >= cb.compile_s
    # a second run re-uses the warm program: no new signatures, no compile
    before = cb.compile_s
    engine.infer_batched(graphs, batch_size=4, n_pad=128, e_pad=384)
    assert len(cb.warm) == 1
    assert cb.compile_s == before


def test_infer_stream_bucket_records(engine):
    graphs = _graphs(6)
    outs, lats, compile_s = engine.infer_stream(graphs)
    assert len(outs) == 6 and lats.shape == (6,)
    stream_keys = [k for k in engine._compiled if k[0] == "stream"]
    assert stream_keys, "stream buckets should be cached per (n_pad, e_pad)"
    assert compile_s > 0  # first visit to each bucket compiled untimed


def test_dgn_batched_matches_stream_eigvec(rng):
    """Batched mode must feed DGN the same per-graph Laplacian
    eigenvectors the stream mode computes (it used to pass zeros)."""
    from repro.data.pipeline import MOLHIV, MoleculeStream
    from repro.gnn import init
    from repro.gnn.models import paper_config
    from repro.serve.gnn_engine import GNNEngine

    cfg = paper_config("dgn")
    eng = GNNEngine(cfg, init(jax.random.PRNGKey(0), cfg))
    graphs = [g[:4] for g in MoleculeStream(MOLHIV, seed=2).take(4)]
    outs, _, _ = eng.infer_stream(graphs, with_eigvec=True)
    outs_b, _ = eng.infer_batched(graphs, batch_size=4, n_pad=256, e_pad=768,
                                  with_eigvec=True)
    for i in range(4):
        np.testing.assert_allclose(outs_b[i], outs[i][0], rtol=1e-4, atol=1e-5)


def test_engine_has_no_dead_eigvec_dim_param(engine):
    import inspect

    from repro.serve.gnn_engine import GNNEngine

    assert "eigvec_dim" not in inspect.signature(GNNEngine.__init__).parameters


_SHARDED_SERVE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import jax, numpy as np
from repro import runtime as RT
from repro.gnn import init
from repro.gnn.models import paper_config
from repro.serve.gnn_engine import GNNEngine

cfg = paper_config("gin")
params = init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
graphs = []
for _ in range(8):
    n = int(rng.integers(6, 16)); e = int(rng.integers(n, 2 * n))
    graphs.append((rng.integers(0, n, e).astype(np.int32),
                   rng.integers(0, n, e).astype(np.int32),
                   rng.normal(size=(n, cfg.feat_dim)).astype(np.float32),
                   rng.normal(size=(e, cfg.edge_dim)).astype(np.float32)))

plain = GNNEngine(cfg, params)
out_plain, _ = plain.infer_batched(graphs, batch_size=4, n_pad=128, e_pad=384)

mesh = RT.make_flat_mesh(2, axis="data")
sharded = GNNEngine(cfg, params, mesh=mesh)
assert sharded.rules["nodes"] == ("data",)
out_shard, _ = sharded.infer_batched(graphs, batch_size=4, n_pad=128, e_pad=384)
np.testing.assert_allclose(out_plain, out_shard, rtol=1e-4, atol=1e-5)
print("SHARDED_SERVE_OK")
"""


def test_sharded_batched_serving_matches_unsharded():
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_SERVE_SCRIPT],
        capture_output=True, text=True, cwd=ROOT,
    )
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "SHARDED_SERVE_OK" in r.stdout
