"""Per-architecture smoke tests: REDUCED same-family configs run one
forward/train step on CPU, asserting output shapes + no NaNs (full configs
are exercised only via the zero-allocation dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import params as P
from repro.configs import ARCHS, get_config, get_reduced
from repro.models import lm

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)
B, S = 2, 16


def _batch(cfg):
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            RNG.normal(size=(B, cfg.num_patches, cfg.d_model)), jnp.float32
        )
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            RNG.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_forward_and_train_step(arch):
    cfg = get_reduced(arch, dtype="float32")
    params = P.values(lm.init_params(KEY, cfg))
    batch = _batch(cfg)
    hidden, aux = lm.forward_hidden(params, batch, cfg)
    exp_s = S if cfg.family != "vlm" else S + cfg.num_patches
    assert hidden.shape == (B, exp_s, cfg.d_model)
    assert not bool(jnp.isnan(hidden).any()), "NaN in forward"
    loss, _ = lm.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: lm.loss_fn(p, batch, cfg)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_prefill_decode_consistency(arch):
    """decode_step after prefill == direct forward at the same position.

    MoE capacity is raised so no token drops occur: capacity routing is
    batch-composition-dependent, so prefill(S-1) and forward(S) may drop
    different tokens at tight capacity (correct behaviour, but it breaks
    the exact-consistency check)."""
    cfg = get_reduced(arch, dtype="float32", capacity_factor=8.0)
    params = P.values(lm.init_params(KEY, cfg))
    batch = _batch(cfg)
    cache, last_logits, t0 = lm.prefill(
        params, {**batch, "tokens": batch["tokens"][:, : S - 1]}, cfg, cache_len=S + 8
    )
    logits, _ = lm.decode_step(params, cache, batch["tokens"][:, S - 1 : S], t0, cfg)
    hidden, _ = lm.forward_hidden(params, batch, cfg)
    if cfg.family == "vlm":
        hidden = hidden[:, cfg.num_patches :]
    ref = lm.logits_fn(params, hidden[:, -1], cfg)
    rel = float(jnp.max(jnp.abs(logits - ref)) / (jnp.max(jnp.abs(ref)) + 1e-6))
    assert rel < 2e-2, f"{arch}: decode diverges from forward ({rel:.2e})"


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_validates_and_counts(arch):
    """Full published config builds (metadata only — no allocation)."""
    cfg = get_config(arch)
    cfg.validate()
    ps = jax.eval_shape(
        lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0)
    )
    n_params = sum(float(np.prod(l.shape)) for l in jax.tree.leaves(P.values(ps)))
    assert n_params > 5e7, f"{arch}: implausibly small ({n_params:.2e})"
    # spot-check published sizes (total params incl. embeddings)
    expected = {
        "mixtral-8x7b": (45e9, 50e9),
        "starcoder2-15b": (14e9, 17e9),
        "rwkv6-1.6b": (1.4e9, 2.2e9),
        "whisper-base": (0.05e9, 0.12e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "jamba-v0.1-52b": (49e9, 56e9),
    }
    if arch in expected:
        lo, hi = expected[arch]
        assert lo < n_params < hi, f"{arch}: {n_params:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_kv_padding_is_semantics_preserving():
    """kv_pad_to (tied-copy KV replication for TP) must not change outputs."""
    from repro.configs import get_reduced

    cfg0 = get_reduced("starcoder2-15b", dtype="float32")
    cfg1 = get_reduced("starcoder2-15b", dtype="float32", kv_pad_to=8)
    assert cfg1.kv_heads_effective == 8 and cfg0.kv_heads_effective == 2
    params = P.values(lm.init_params(KEY, cfg0))
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg0.vocab_size, (B, S)), jnp.int32)}
    h0, _ = lm.forward_hidden(params, batch, cfg0)
    h1, _ = lm.forward_hidden(params, batch, cfg1)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), rtol=1e-5, atol=1e-5)
