"""Optimizer unit tests: AdamW dynamics, schedule, clipping."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw


def test_adamw_converges_on_quadratic():
    # constant-ish lr phase: total_steps >> iterations so cosine decay
    # does not throttle the late steps
    cfg = adamw.AdamWConfig(lr=0.3, warmup_steps=5, total_steps=4000,
                            weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(16,)) * 5)}
    target = jnp.ones((16,))
    state = adamw.init(params)
    start = float(jnp.abs(params["w"] - target).max())
    for _ in range(400):
        g = {"w": 2 * (params["w"] - target)}
        params, state, m = adamw.update(cfg, g, state, params)
    end = float(jnp.abs(params["w"] - target).max())
    assert end < 0.05 * start, (start, end)


def test_warmup_cosine_schedule():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(adamw.schedule(cfg, jnp.asarray(0))) == 0.0
    assert np.isclose(float(adamw.schedule(cfg, jnp.asarray(10))), 1.0)
    end = float(adamw.schedule(cfg, jnp.asarray(100)))
    assert np.isclose(end, 0.1, atol=1e-3)  # decays to min_lr_ratio
    mid = float(adamw.schedule(cfg, jnp.asarray(55)))
    assert 0.1 < mid < 1.0


def test_grad_clipping_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=0, total_steps=10,
                            grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = adamw.init(params)
    g = {"w": jnp.full((4,), 1e6)}  # exploding gradient
    new_params, state, m = adamw.update(cfg, g, state, params)
    assert float(m["grad_norm"]) > 1e5
    # post-clip Adam step is bounded by ~lr regardless of raw magnitude
    assert float(jnp.abs(new_params["w"]).max()) < 10.0


def test_weight_decay_applies_to_matrices_only():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=10,
                            weight_decay=1.0, grad_clip=1e9)
    params = {"mat": jnp.ones((4, 4)), "vec": jnp.ones((4,))}
    state = adamw.init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    new_params, *_ = adamw.update(cfg, zeros, state, params)
    assert float(new_params["mat"].max()) < 1.0  # decayed
    assert np.isclose(float(new_params["vec"].max()), 1.0)  # not decayed


def test_moments_shapes_match_params():
    params = {"a": jnp.zeros((3, 5)), "b": {"c": jnp.zeros((7,))}}
    st = adamw.init(params)
    assert st["m"]["a"].shape == (3, 5)
    assert st["v"]["b"]["c"].shape == (7,)
    assert st["step"].dtype == jnp.int32
