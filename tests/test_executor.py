"""The Executor pipeline: facade parity, multi-tenant isolation, and the
two compile-cache regressions the refactor fixes.

* **Facade parity** — ``GNNEngine`` is a thin facade: for all six models
  x stream/batched/packed x fp32/int8, driving a fresh ``Executor``
  directly through the ``prepare_*`` family must produce *bitwise* the
  same logits the engine's mode methods produce.
* **Warm-signature regression** — the old ``infer_stream`` warmed on
  ``("eig", with_eigvec)`` alone, so a mid-stream dtype change in the
  same bucket recompiled inside the timed region.  The executor's one
  signature function keys on every leaf's shape+dtype.
* **num_graphs regression** — the old ``_bucket(key, num_graphs=...)``
  silently kept the *first* call's ``num_graphs`` on a cache hit; the
  executor makes it part of the cache key.
* **Multi-tenant** — two tenants share one scheduler and one bucket
  ladder without cross-contaminating compile caches or params; tenants
  with the same architecture share compiled programs while keeping their
  own parameters and warm bookkeeping.
"""
import jax
import numpy as np
import pytest

from repro.core.batching import BucketBudget, pack_eigvecs, pack_graphs, pack_layout
from repro.gnn import init
from repro.gnn.models import paper_config
from repro.serve.executor import Executor, prepared, trace_signature
from repro.serve.gnn_engine import GNNEngine
from repro.serve.scheduler import StreamScheduler

KEY = jax.random.PRNGKey(0)
MODELS = [("gcn", False), ("gin", False), ("gin", True), ("gat", False),
          ("pna", False), ("dgn", False)]


def _reduced_config(model, vn=False, **kw):
    base = dict(num_layers=2, virtual_node=vn)
    if model == "gat":
        base.update(heads=2, head_features=8)
    elif model in ("pna", "dgn"):
        base.update(hidden=16, head_hidden=(8,))
    else:
        base.update(hidden=16)
    base.update(kw)
    return paper_config(model, **base)


def _raw_graphs(rng, k=4, feat=9, edge=3):
    out = []
    for _ in range(k):
        n = int(rng.integers(5, 14))
        e = int(rng.integers(n, 2 * n))
        out.append((
            rng.integers(0, n, e).astype(np.int32),
            rng.integers(0, n, e).astype(np.int32),
            rng.normal(size=(n, feat)).astype(np.float32),
            rng.normal(size=(e, edge)).astype(np.float32),
        ))
    return out


def _bitwise(a, b, msg):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=msg)


# --------------------------------------------------------------- facade parity


@pytest.mark.parametrize("model,vn", MODELS)
@pytest.mark.parametrize("precision", ["fp32", "int8"])
def test_engine_facade_bitwise_equals_direct_executor(model, vn, precision, rng):
    """The engine's three mode paths, pinned via the facade, against the
    same calls staged by hand on a fresh Executor."""
    cfg = _reduced_config(model, vn)
    params = init(KEY, cfg)
    graphs = _raw_graphs(rng)
    eig = model == "dgn"
    eng = GNNEngine(cfg, params, buckets=((16, 32),), precision=precision)
    ex = Executor(buckets=((16, 32),))
    ex.register("m", cfg, params, precision=precision)

    outs, _, _ = eng.infer_stream(graphs, with_eigvec=eig)
    for i, g in enumerate(graphs):
        got, _ = ex.run(ex.prepare_stream(g, with_eigvec=eig), model="m")
        _bitwise(got[:1], outs[i], f"stream graph {i}")

    b_eng, _ = eng.infer_batched(graphs, batch_size=2, n_pad=32, e_pad=64,
                                 with_eigvec=eig)
    b_ex = np.concatenate([
        ex.run(ex.prepare_batched(graphs[i : i + 2], 2, 32, 64,
                                  with_eigvec=eig), model="m")[0][:2]
        for i in range(0, len(graphs), 2)
    ])
    _bitwise(b_ex, b_eng, "batched")

    budget = BucketBudget(n_pad=64, e_pad=128, g_pad=len(graphs))
    packed, meta = pack_graphs(graphs, budget)
    eigv = None
    if eig:
        from repro.data.pipeline import laplacian_eigvec

        eigv = pack_eigvecs(
            [laplacian_eigvec(s, r, nf.shape[0]) for s, r, nf, _ in graphs],
            meta,
        )
    p_eng, _ = eng.infer_packed(packed, budget, eigvec=eigv,
                                layout=pack_layout(packed))
    p_ex, _ = ex.run(ex.prepare_packed(packed, budget, eigvec=eigv,
                                       layout=pack_layout(packed), model="m"),
                     model="m")
    _bitwise(p_ex, p_eng, "packed")


# ------------------------------------------------------ warm-signature bug fix


def test_trace_signature_keys_on_leaf_dtypes(rng):
    from repro.core import graph as G

    g = _raw_graphs(rng, 1)[0]
    a = G.from_numpy(*g, n_pad=16, e_pad=32)
    half = (g[0], g[1], g[2].astype(np.float16), g[3])
    b = G.from_numpy(*half, n_pad=16, e_pad=32)
    assert trace_signature(a) != trace_signature(b)
    assert trace_signature(a) == trace_signature(a)


def test_stream_dtype_change_warms_outside_timed_region(rng):
    """Regression: a mid-stream dtype change in the same bucket is a new
    trace signature and must be warmed untimed (the old stream signature
    ``("eig", with_eigvec)`` let the recompile leak into the timed region)."""
    cfg = _reduced_config("gin")
    eng = GNNEngine(cfg, init(KEY, cfg), buckets=((16, 32),))
    g = _raw_graphs(rng, 1)[0]
    g_half = (g[0], g[1], g[2].astype(np.float16), g[3])

    eng.infer_stream([g])
    cb = eng._compiled[("stream", 16, 32)]
    assert len(cb.warm) == 1
    before = eng.compile_seconds
    _, _, compile_s = eng.infer_stream([g_half])  # same bucket, new dtype
    assert len(cb.warm) == 2, "dtype change must register a new warm signature"
    assert compile_s > 0 and eng.compile_seconds > before, (
        "the new signature's compile must be warmed (excluded from latency)"
    )
    # and once warm, neither signature compiles again
    steady = eng.compile_seconds
    eng.infer_stream([g, g_half])
    assert eng.compile_seconds == steady


# ------------------------------------------------------- num_graphs cache key


def test_num_graphs_is_part_of_the_program_cache_key(rng):
    """Regression: the old ``_bucket(key, num_graphs=...)`` kept the first
    call's ``num_graphs`` on a cache hit, silently mis-sizing the pooled
    buffers of every later caller."""
    from repro.core import graph as G

    cfg = _reduced_config("gin")
    ex = Executor(buckets=((16, 32),))
    ex.register("m", cfg, init(KEY, cfg))
    gs = []
    for _ in range(2):  # two tiny graphs that fit the (16, 32) batch pad
        n, e = 5, 6
        gs.append((rng.integers(0, n, e).astype(np.int32),
                   rng.integers(0, n, e).astype(np.int32),
                   rng.normal(size=(n, 9)).astype(np.float32),
                   rng.normal(size=(e, 3)).astype(np.float32)))
    g = G.batch_graphs(gs, n_pad=16, e_pad=32)
    out1, _ = ex.run(prepared(g, None, None, ("bucket", 16, 32), 1), model="m")
    out2, _ = ex.run(prepared(g, None, None, ("bucket", 16, 32), 2), model="m")
    assert out1.shape == (1, cfg.out_dim)
    assert out2.shape == (2, cfg.out_dim), (
        "second num_graphs must not reuse the first call's program"
    )
    assert len(ex._compiled) == 2


# ----------------------------------------------------------------- two tenants


def test_two_tenants_one_scheduler_match_solo_runs(rng):
    """gcn@int8 + gat@fp32 through ONE executor + ONE scheduler: outputs
    bitwise-equal to each model's solo scheduler run, zero recompiles
    after warmup, and no compile-cache cross-contamination."""
    cfg_a, cfg_b = _reduced_config("gcn"), _reduced_config("gat")
    params_a, params_b = init(KEY, cfg_a), init(jax.random.PRNGKey(1), cfg_b)
    graphs = _raw_graphs(rng, 8)

    ex = Executor(buckets=((16, 32),))
    ex.register("gcn8", cfg_a, params_a, precision="int8")
    ex.register("gat32", cfg_b, params_b)
    sched = StreamScheduler(ex, capacity=2)
    assert sched.prewarm == "lazy"
    models = ["gcn8" if i % 2 == 0 else "gat32" for i in range(len(graphs))]
    rep = sched.run(graphs, qps=0.0, models=models)

    # zero recompiles on a repeat pass over the same mixed stream
    warm = ex.compile_seconds
    rep2 = sched.run(graphs, qps=0.0, models=models)
    assert rep2.compile_s == 0.0 and ex.compile_seconds == warm

    # per-tenant flush partitioning at saturation equals the solo runs
    for name, cfg, params, precision in [
        ("gcn8", cfg_a, params_a, "int8"), ("gat32", cfg_b, params_b, "fp32"),
    ]:
        solo = StreamScheduler(
            GNNEngine(cfg, params, buckets=((16, 32),), precision=precision),
            capacity=2,
        )
        srep = solo.run([g for g, m in zip(graphs, models) if m == name],
                        qps=0.0)
        mine = [o for o, m in zip(rep.outputs, models) if m == name]
        for i, (a, b) in enumerate(zip(mine, srep.outputs)):
            _bitwise(a, b, f"{name} graph {i}")

    # caches don't cross tenants: every program key is one tenant's
    keys_a = {k for k in ex._compiled if k[0] == ex.tenant("gcn8").program_key}
    keys_b = {k for k in ex._compiled if k[0] == ex.tenant("gat32").program_key}
    assert keys_a and keys_b and not (keys_a & keys_b)
    assert keys_a | keys_b == set(ex._compiled)
    assert ex.tenant("gcn8").params is not ex.tenant("gat32").params


def test_same_architecture_tenants_share_programs_not_params(rng):
    """Two tenants with equal (cfg, precision) — e.g. A/B weight variants —
    share compiled programs (one cache entry per bucket) while serving
    their own params: distinct outputs, correct per-tenant warm
    bookkeeping."""
    cfg = _reduced_config("gin")
    ex = Executor(buckets=((16, 32),))
    ex.register("a", cfg, init(KEY, cfg))
    ex.register("b", cfg, init(jax.random.PRNGKey(7), cfg))
    g = _raw_graphs(rng, 1)[0]

    out_a, _ = ex.run(ex.prepare_stream(g), model="a")
    n_programs = len(ex._compiled)
    before = ex.compile_seconds
    out_b, _ = ex.run(ex.prepare_stream(g), model="b")
    assert len(ex._compiled) == n_programs, (
        "same-architecture tenants must share the compiled program"
    )
    # tenant b's first run still warms (its params signature is its own
    # warm key), and the outputs reflect b's params, not a's
    assert ex.compile_seconds >= before
    assert not np.array_equal(out_a, out_b)
    # steady state: neither tenant compiles again
    steady = ex.compile_seconds
    ex.run(ex.prepare_stream(g), model="a")
    ex.run(ex.prepare_stream(g), model="b")
    assert ex.compile_seconds == steady


def test_tenant_resolution_and_registration_errors(rng):
    cfg = _reduced_config("gin")
    ex = Executor()
    ex.register("only", cfg, init(KEY, cfg))
    assert ex.tenant() is ex.tenant("only")
    with pytest.raises(ValueError, match="already registered"):
        ex.register("only", cfg, init(KEY, cfg))
    with pytest.raises(KeyError, match="no tenant"):
        ex.tenant("missing")
    ex.register("second", cfg, init(KEY, cfg))
    with pytest.raises(KeyError, match="model name required"):
        ex.tenant()


def test_scheduler_rejects_mismatched_model_tags(rng):
    cfg = _reduced_config("gin")
    eng = GNNEngine(cfg, init(KEY, cfg), buckets=((16, 32),))
    sched = StreamScheduler(eng, capacity=2)
    with pytest.raises(ValueError, match="must tag every graph"):
        sched.run(_raw_graphs(rng, 3), models=["default"])


def test_scheduler_rejects_untagged_multitenant_stream_up_front(rng):
    """Ambiguous routing must fail at run() entry, not mid-stream at the
    first flush."""
    cfg = _reduced_config("gin")
    ex = Executor(buckets=((16, 32),))
    ex.register("a", cfg, init(KEY, cfg))
    ex.register("b", cfg, init(jax.random.PRNGKey(1), cfg))
    sched = StreamScheduler(ex, capacity=2)
    graphs = _raw_graphs(rng, 3)
    with pytest.raises(ValueError, match="untagged requests are ambiguous"):
        sched.run(graphs)
    with pytest.raises(ValueError, match="untagged requests are ambiguous"):
        sched.run(graphs, models=["a", None, "b"])


def test_facade_rejects_engine_level_executor_config(rng):
    """buckets/mesh/rules belong to the executor — passing them alongside
    an existing executor must error, not be silently dropped."""
    cfg = _reduced_config("gin")
    params = init(KEY, cfg)
    ex = Executor()
    with pytest.raises(ValueError, match="belong to the executor"):
        GNNEngine(cfg, params, buckets=((16, 32),), executor=ex)
    GNNEngine(cfg, params, executor=ex)  # defaults are fine


def test_facade_compile_seconds_is_per_tenant(rng):
    """Two facades sharing one executor: each reports only its own
    tenant's warm cost (and infer_stream's compile delta follows suit)."""
    cfg_a, cfg_b = _reduced_config("gcn"), _reduced_config("gat")
    ex = Executor(buckets=((16, 32),))
    a = GNNEngine(cfg_a, init(KEY, cfg_a), executor=ex, name="a")
    b = GNNEngine(cfg_b, init(jax.random.PRNGKey(1), cfg_b), executor=ex,
                  name="b")
    g = _raw_graphs(rng, 1)
    _, _, compile_a = a.infer_stream(g)
    assert compile_a > 0
    assert a.compile_seconds + a.warm_seconds == pytest.approx(compile_a)
    assert a.compile_seconds > 0 and a.warm_seconds > 0, (
        "the untimed total must split into trace+compile and first-run warm"
    )
    assert b.compile_seconds + b.warm_seconds == 0.0, (
        "b must not inherit a's warm cost"
    )
    _, _, compile_b = b.infer_stream(g)
    assert compile_b > 0
    assert a.compile_seconds + a.warm_seconds == pytest.approx(compile_a), (
        "b's warm must not move a's accounting"
    )
    assert ex.untimed_seconds == pytest.approx(compile_a + compile_b)
