"""Interpret-mode parity for the ``node_mlp`` and ``edge_softmax`` Pallas
kernels against the pure-jnp oracles (kernels/ref.py) — the same two-layer
coverage ``segment_reduce`` already has (test_segment_reduce_pallas.py):
the raw kernel contract under ragged shapes / explicit block sizes, and
the public ``ops.*(mode="kernel")`` semantics including padding and
empty-segment edge cases the model layers rely on."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.edge_softmax import edge_softmax as raw_edge_softmax
from repro.kernels.node_mlp import node_mlp as raw_node_mlp
from repro.kernels.ops import edge_softmax, node_mlp

RNG = np.random.default_rng(21)


# ----------------------------------------------------------------- node_mlp


@pytest.mark.parametrize("act", ["relu", "gelu", "none"])
@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (64, 64, 64, 64, 64, 64),  # clean multiples of every block
        (100, 130, 50, 64, 64, 64),  # all three dims ragged
        (8, 16, 8, 128, 128, 128),  # smaller than one block
        (130, 64, 200, 64, 128, 32),  # K split across several tiles
    ],
)
def test_raw_node_mlp_matches_oracle(act, m, k, n, bm, bn, bk):
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(k, n)) * 0.1, jnp.float32)
    b = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    got = raw_node_mlp(x, w, b, act, block_m=bm, block_n=bn, block_k=bk,
                       interpret=True)
    want = ref.node_mlp_ref(x, w, b, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_raw_node_mlp_bfloat16_accumulates_in_f32():
    x = jnp.asarray(RNG.normal(size=(64, 96)), jnp.bfloat16)
    w = jnp.asarray(RNG.normal(size=(96, 32)) * 0.1, jnp.bfloat16)
    b = jnp.asarray(RNG.normal(size=(32,)), jnp.float32)
    got = raw_node_mlp(x, w, b, "relu", interpret=True)
    want = ref.node_mlp_ref(x, w, b, "relu")
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_public_node_mlp_zero_rows_passthrough():
    # padded node rows are all-zero: relu(0*w + b) must be relu(b)
    w = jnp.asarray(RNG.normal(size=(16, 8)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(8,)), jnp.float32)
    out = node_mlp(jnp.zeros((4, 16)), w, b, "relu", mode="kernel")
    np.testing.assert_allclose(
        np.asarray(out), np.tile(np.maximum(np.asarray(b), 0.0), (4, 1)),
        rtol=1e-6, atol=1e-6,
    )


# -------------------------------------------------------------- edge_softmax


def _sorted_ids(e, n, pad_tail=0, skip_even=False):
    pool = np.arange(1, n, 2) if skip_even else np.arange(n)
    ids = np.sort(RNG.choice(pool, size=e)).astype(np.int32)
    if pad_tail:
        ids[-pad_tail:] = n
    return ids


@pytest.mark.parametrize("h", [1, 4])
@pytest.mark.parametrize("e,n", [(64, 16), (300, 70), (37, 19), (513, 129)])
def test_raw_edge_softmax_matches_oracle(h, e, n):
    ids = _sorted_ids(e, n, pad_tail=max(e // 10, 1))
    logits = jnp.asarray(RNG.normal(size=(e, h)) * 3, jnp.float32)
    got = raw_edge_softmax(logits, jnp.asarray(ids), n, interpret=True)
    want = ref.edge_softmax_ref(logits, jnp.asarray(ids), n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_edge_softmax_empty_segments_and_padding():
    # only odd segments populated + a padding tail: weights of real edges
    # sum to 1 per populated segment, padding edges get exactly 0
    ids = _sorted_ids(96, 20, pad_tail=9, skip_even=True)
    logits = jnp.asarray(RNG.normal(size=(96, 2)) * 3, jnp.float32)
    w = edge_softmax(logits, jnp.asarray(ids), 20, mode="kernel")
    np.testing.assert_allclose(
        np.asarray(w),
        np.asarray(ref.edge_softmax_ref(logits, jnp.asarray(ids), 20)),
        rtol=1e-5, atol=1e-6,
    )
    assert float(np.abs(np.asarray(w)[-9:]).max()) == 0.0
    sums = ref.segment_reduce_sorted_ref(w, jnp.asarray(ids), 20, "sum")
    counts = ref.segment_reduce_sorted_ref(
        jnp.ones_like(w), jnp.asarray(ids), 20, "sum"
    )
    np.testing.assert_allclose(
        np.asarray(sums), np.asarray((counts > 0).astype(np.float32)),
        atol=1e-5,
    )


def test_edge_softmax_all_edges_padding():
    ids = jnp.full((16,), 8, jnp.int32)  # every edge masked out
    logits = jnp.asarray(RNG.normal(size=(16, 3)), jnp.float32)
    w = edge_softmax(logits, ids, 8, mode="kernel")
    np.testing.assert_array_equal(np.asarray(w), np.zeros((16, 3), np.float32))


def test_edge_softmax_extreme_logits_stable():
    # the max-shift must keep exp() finite even for +/-1e4 logits
    ids = jnp.asarray([0, 0, 0, 1, 1, 2], jnp.int32)
    logits = jnp.asarray([[1e4], [1e4 - 1.0], [-1e4], [5.0], [-5.0], [0.0]],
                         jnp.float32)
    w = edge_softmax(logits, ids, 3, mode="kernel")
    assert np.isfinite(np.asarray(w)).all()
    want = ref.edge_softmax_ref(logits, ids, 3)
    np.testing.assert_allclose(np.asarray(w), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
