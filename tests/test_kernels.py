"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes, dtypes and reduction ops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


class TestKernelModeEnvOverride:
    """REPRO_KERNEL_MODE globally overrides the per-call ``mode`` so
    benches/CI can force a path without threading flags through configs."""

    def test_env_forces_reference(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_MODE", "reference")
        assert ops._resolve("kernel") == (False, False)
        assert ops._resolve("auto") == (False, False)

    def test_env_forces_kernel(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_MODE", "kernel")
        use_kernel, interpret = ops._resolve("reference")
        assert use_kernel and interpret == (jax.default_backend() != "tpu")

    def test_unset_env_leaves_mode_alone(self, monkeypatch):
        monkeypatch.delenv("REPRO_KERNEL_MODE", raising=False)
        assert ops._resolve("reference") == (False, False)

    def test_invalid_env_raises(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_MODE", "fastest")
        with pytest.raises(ValueError, match="REPRO_KERNEL_MODE"):
            ops._resolve("auto")

    def test_functional_through_public_op(self, monkeypatch):
        x = jnp.asarray(RNG.normal(size=(8, 8)), jnp.float32)
        w = jnp.asarray(RNG.normal(size=(8, 8)), jnp.float32)
        b = jnp.zeros((8,), jnp.float32)
        monkeypatch.setenv("REPRO_KERNEL_MODE", "reference")
        got = ops.node_mlp(x, w, b, "none", mode="kernel")
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.node_mlp_ref(x, w, b, "none")),
            rtol=1e-6, atol=1e-6,
        )


def _sorted_ids(e, n, pad_frac=0.1):
    ids = np.sort(RNG.integers(0, n, e)).astype(np.int32)
    k = int(e * pad_frac)
    if k:
        ids[-k:] = n  # padding tail
    return ids


@pytest.mark.parametrize("op", ["sum", "mean", "sqsum", "max", "min"])
@pytest.mark.parametrize(
    "e,n,f", [(64, 16, 8), (300, 70, 96), (512, 128, 128), (1000, 333, 40)]
)
def test_segment_reduce_matches_oracle(op, e, n, f):
    ids = _sorted_ids(e, n)
    vals = RNG.normal(size=(e, f)).astype(np.float32)
    got = ops.segment_reduce(jnp.asarray(vals), jnp.asarray(ids), n, op, mode="kernel")
    want = ref.segment_reduce_sorted_ref(jnp.asarray(vals), jnp.asarray(ids), n, op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_reduce_dtypes(dtype):
    ids = _sorted_ids(256, 64)
    vals = jnp.asarray(RNG.normal(size=(256, 32)), dtype)
    got = ops.segment_reduce(vals, jnp.asarray(ids), 64, "sum", mode="kernel")
    want = ref.segment_reduce_sorted_ref(vals, jnp.asarray(ids), 64, "sum")
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=2e-2, atol=2e-2
    )


def test_segment_reduce_empty_segments_are_zero():
    ids = jnp.asarray([0, 0, 5, 5, 5], jnp.int32)
    vals = jnp.ones((5, 4), jnp.float32)
    for op in ("sum", "mean", "max", "min"):
        out = ops.segment_reduce(vals, ids, 8, op, mode="kernel")
        assert float(jnp.abs(out[1:5]).max()) == 0.0, op
        assert float(jnp.abs(out[6:]).max()) == 0.0, op


@pytest.mark.parametrize("act", ["relu", "gelu", "none"])
@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (200, 130, 50), (128, 256, 384)])
def test_node_mlp_matches_oracle(act, m, k, n):
    x = RNG.normal(size=(m, k)).astype(np.float32)
    w = (RNG.normal(size=(k, n)) * 0.1).astype(np.float32)
    b = RNG.normal(size=(n,)).astype(np.float32)
    got = ops.node_mlp(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), act, mode="kernel")
    want = ref.node_mlp_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("h", [1, 4])
def test_edge_softmax_matches_oracle(h):
    ids = _sorted_ids(300, 70)
    logits = (RNG.normal(size=(300, h)) * 3).astype(np.float32)
    got = ops.edge_softmax(jnp.asarray(logits), jnp.asarray(ids), 70, mode="kernel")
    want = ref.edge_softmax_ref(jnp.asarray(logits), jnp.asarray(ids), 70)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_edge_softmax_sums_to_one():
    ids = _sorted_ids(300, 70, pad_frac=0.0)
    logits = (RNG.normal(size=(300, 2)) * 3).astype(np.float32)
    w = ops.edge_softmax(jnp.asarray(logits), jnp.asarray(ids), 70, mode="kernel")
    sums = ref.segment_reduce_sorted_ref(w, jnp.asarray(ids), 70, "sum")
    counts = ref.segment_reduce_sorted_ref(jnp.ones_like(w), jnp.asarray(ids), 70, "sum")
    np.testing.assert_allclose(
        np.asarray(sums), np.asarray((counts > 0).astype(np.float32)), atol=1e-5
    )


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("window", [None, 64])
def test_flash_attention_matches_oracle(hq, hkv, window):
    b, s, d = 2, 256, 64
    q = RNG.normal(size=(b, hq, s, d)).astype(np.float32)
    k = RNG.normal(size=(b, hkv, s, d)).astype(np.float32)
    v = RNG.normal(size=(b, hkv, s, d)).astype(np.float32)
    got = ops.flash_attention(
        *map(jnp.asarray, (q, k, v)), causal=True, window=window, mode="kernel"
    )
    want = ref.flash_attention_ref(
        *map(jnp.asarray, (q, k, v)), causal=True, window=window
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_blocked_attention_jnp_matches_flash_ref():
    """models.layers.blocked_attention (the dry-run path) against the
    kernel oracle: same math, different tiling."""
    from repro.models.config import ModelConfig
    from repro.models.layers import blocked_attention

    b, s, hq, hkv, d = 2, 128, 4, 2, 32
    cfg = ModelConfig(attn_chunk=32)
    q = jnp.asarray(RNG.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    got = blocked_attention(q, k, v, cfg, window=0)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)
    # sliding window variant
    got_w = blocked_attention(q, k, v, cfg, window=48)
    want_w = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=True,
        window=48,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w), rtol=2e-3, atol=2e-3)
