"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see
1 CPU device; only launch/dryrun.py (and the subprocess-based distributed
tests) force a placeholder device count."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def scripted_executor(service_s=0.001,
                      buckets=((32, 96), (64, 192), (128, 384), (256, 768))):
    """Executor stand-in with *scripted* service times, for deterministic
    scheduler simulations: a real ``Executor`` subclass (so the scheduler
    routes it as the multi-tenant surface) whose ``run`` returns the next
    scripted duration instead of measuring anything — every flush
    timestamp, shed decision, and latency in a ``VirtualClock`` run is
    then an exact function of the input trace.

    ``service_s`` is a constant, or a sequence consumed flush-by-flush
    (the last entry repeats once exhausted).
    """
    import dataclasses as _dc

    from repro.serve.executor import Executor

    class ScriptedExecutor(Executor):
        def __init__(self):
            super().__init__(buckets=buckets)
            cfg = _dc.make_dataclass("Cfg", ["model", "task"])("gin", "graph")
            self.tenants["default"] = _dc.make_dataclass(
                "FakeTenant", ["cfg", "share_layout"])(cfg, False)
            self._script = (list(service_s)
                            if isinstance(service_s, (list, tuple))
                            else [float(service_s)])
            self._calls = 0
            self.run_log = []

        def has_program(self, bucket_key, num_graphs, model=None):
            return True  # nothing to compile: eager prewarm is a no-op

        def warm(self, p, model=None):
            return 0.0

        def run(self, p, model=None):
            dt = self._script[min(self._calls, len(self._script) - 1)]
            self._calls += 1
            self.run_log.append((p.bucket_key, p.num_graphs, dt))
            return np.zeros((p.num_graphs, 1), np.float32), dt

    return ScriptedExecutor()


def random_molecule_batch(rng, n_graphs=4, n_pad=80, e_pad=160, feat=9, edge=3):
    from repro.core.graph import batch_graphs

    gs = []
    for _ in range(n_graphs):
        n = int(rng.integers(5, 18))
        e = int(rng.integers(n, 2 * n))
        s = rng.integers(0, n, e).astype(np.int32)
        r = rng.integers(0, n, e).astype(np.int32)
        nf = rng.normal(size=(n, feat)).astype(np.float32)
        ef = rng.normal(size=(e, edge)).astype(np.float32)
        gs.append((s, r, nf, ef))
    return batch_graphs(gs, n_pad=n_pad, e_pad=e_pad)
