"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see
1 CPU device; only launch/dryrun.py (and the subprocess-based distributed
tests) force a placeholder device count."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_molecule_batch(rng, n_graphs=4, n_pad=80, e_pad=160, feat=9, edge=3):
    from repro.core.graph import batch_graphs

    gs = []
    for _ in range(n_graphs):
        n = int(rng.integers(5, 18))
        e = int(rng.integers(n, 2 * n))
        s = rng.integers(0, n, e).astype(np.int32)
        r = rng.integers(0, n, e).astype(np.int32)
        nf = rng.normal(size=(n, feat)).astype(np.float32)
        ef = rng.normal(size=(e, edge)).astype(np.float32)
        gs.append((s, r, nf, ef))
    return batch_graphs(gs, n_pad=n_pad, e_pad=e_pad)
