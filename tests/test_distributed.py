"""Distributed substrate tests on 8 virtual devices (subprocess-isolated):
sharded message passing (allgather + all-to-all strategies), compressed
psum, and sharding-rule resolution."""
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_MP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.runtime import compat, make_sharded_mp
from repro.core import scatter_gather as sg

mesh = compat.make_mesh((8,), ("graph",))
P_total, n_local, f = 8, 4, 6
N = P_total * n_local
rng = np.random.default_rng(0)
E = 64
src = rng.integers(0, N, E).astype(np.int32)
dst = rng.integers(0, N, E).astype(np.int32)
x = rng.normal(size=(N, f)).astype(np.float32)
mask = np.ones((E,), bool)

phi = lambda m: m * 2.0  # simple message transform

# dense reference
ref = np.zeros((N, f), np.float32)
for s_, d_ in zip(src, dst):
    ref[d_] += 2.0 * x[s_]

# --- allgather strategy: edges arbitrarily distributed
fn = make_sharded_mp(mesh, "graph", phi, strategy="allgather")
out = fn(jnp.asarray(x), jnp.asarray(src), jnp.asarray(dst), jnp.asarray(mask))
np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)
print("ALLGATHER_OK")

# --- alltoall strategy: edges owned by their SOURCE shard
order = np.argsort(src // n_local, kind="stable")
src_s, dst_s = src[order], dst[order]
# pad per-shard edge counts equal: round-robin pad with masked edges
counts = np.bincount(src_s // n_local, minlength=P_total)
per = counts.max()
src_p = np.zeros((P_total, per), np.int32)
dst_p = np.zeros((P_total, per), np.int32)
msk_p = np.zeros((P_total, per), bool)
for p in range(P_total):
    e_p = np.where(src_s // n_local == p)[0]
    src_p[p, :len(e_p)] = src_s[e_p] % n_local   # shard-local row ids
    dst_p[p, :len(e_p)] = dst_s[e_p]             # global dst
    msk_p[p, :len(e_p)] = True
fn2 = make_sharded_mp(mesh, "graph", phi, strategy="alltoall", capacity=per * 2)
out2 = fn2(jnp.asarray(x), jnp.asarray(src_p.reshape(-1)),
           jnp.asarray(dst_p.reshape(-1)), jnp.asarray(msk_p.reshape(-1)))
np.testing.assert_allclose(np.asarray(out2), ref, rtol=1e-5, atol=1e-5)
print("ALLTOALL_OK")

# --- compressed psum
from repro.optim.compression import compressed_psum
from jax.sharding import PartitionSpec as P
g = rng.normal(size=(8, 128)).astype(np.float32)
want = g.sum(axis=0)
out3 = compat.shard_map(lambda xs: compressed_psum(xs[0], "graph")[None],
                        mesh=mesh, in_specs=P("graph", None),
                        out_specs=P("graph", None))(jnp.asarray(g))
got = np.asarray(out3[0])
rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
assert rel < 0.02, rel  # int8 quantization error bound
print("CPSUM_OK", rel)
"""


def _run(script):
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, cwd=ROOT
    )
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    return r.stdout


def test_sharded_message_passing_and_compressed_psum():
    out = _run(_MP_SCRIPT)
    assert "ALLGATHER_OK" in out
    assert "ALLTOALL_OK" in out
    assert "CPSUM_OK" in out


def test_sharding_rules_divisibility_fallback():
    from repro.runtime import partitioning as SH

    # simulate a 16-way axis via a fake mesh-shape mapping by checking the
    # pure resolver logic
    from jax.sharding import PartitionSpec

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # experts=8 NOT divisible by 16 -> falls through; mlp picks model
    spec = SH.resolve_spec(("experts", "embed", "mlp"), (8, 1024, 14336), FakeMesh())
    assert spec == PartitionSpec(None, None, "model")
    # experts=128 divisible -> experts take model; mlp must NOT reuse it
    spec2 = SH.resolve_spec(("experts", "embed", "mlp"), (128, 1024, 768), FakeMesh())
    assert spec2 == PartitionSpec("model", None, None)
    # batch over (pod, data): only data exists here
    class FakeMesh3:
        shape = {"pod": 2, "data": 16, "model": 16}

    spec3 = SH.resolve_spec(("batch", "seq"), (256, 4096), FakeMesh3())
    assert spec3 == PartitionSpec(("pod", "data"), None)
    # batch=1 divisible by nothing -> unsharded
    spec4 = SH.resolve_spec(("batch", "seq"), (1, 4096), FakeMesh3())
    assert spec4 == PartitionSpec(None, None)


def test_batch_rules_seq_sharding_for_small_batch():
    from repro.runtime import partitioning as SH

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    rules = SH.batch_rules(FakeMesh(), batch=1)
    assert rules["kv_seq"] == ("data",)
    assert rules["batch"] == ()
    rules2 = SH.batch_rules(FakeMesh(), batch=128)
    assert rules2["kv_seq"] == ()


def test_grad_compression_error_feedback_converges():
    """EF-int8 compression preserves optimization on a toy quadratic."""
    import jax
    import jax.numpy as jnp

    from repro.optim import compression as C

    w = jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)
    target = jnp.ones((64,))
    ef = {"w": jnp.zeros((64,))}
    losses = []
    for i in range(200):
        g = {"w": 2 * (w - target)}
        gq, ef = C.ef_compress(g, ef)
        w = w - 0.05 * gq["w"]
        losses.append(float(jnp.sum((w - target) ** 2)))
    assert losses[-1] < 1e-3 * losses[0]
