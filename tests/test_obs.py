"""Serving telemetry: exact span boundaries, registry counters, exporter
schemas, bitwise-deterministic traces, and the provably-free dark path.

Same discipline as ``tests/test_slo_sim.py``: every scenario scripts an
arrival trace + service times into the ``scripted_executor`` fake on a
``VirtualClock``, so every span boundary and counter value is an exact
float — assertions are equalities, never tolerances.  Timestamps are
binary fractions so the expected sums are exact in float64.
"""
import json

import numpy as np
import pytest

from conftest import scripted_executor
from repro.obs import MetricsRegistry, Tracer, export
from repro.obs.metrics import ServingInstruments, default_registry
from repro.serve.clock import VirtualClock
from repro.serve.scheduler import StreamScheduler

MW = 0.015625  # max_wait_s = 1/64: binary-exact
SVC = 0.00390625  # scripted flush compute = 1/256
A1 = 0.001953125  # second arrival = 1/512
DONE = A1 + SVC  # budget flush completion


def graph(n=8, e=12, feat=4, seed=0):
    rng = np.random.default_rng(seed)
    return (
        rng.integers(0, n, e).astype(np.int32),
        rng.integers(0, n, e).astype(np.int32),
        rng.normal(size=(n, feat)).astype(np.float32),
        rng.normal(size=(e, 3)).astype(np.float32),
    )


def run_budget_flush(tracer=None, metrics=None):
    """Two arrivals fill one capacity-1 bucket: a single ``budget`` flush
    at the second arrival — the smallest fully-scripted lifecycle."""
    ex = scripted_executor(service_s=SVC)
    s = StreamScheduler(ex, capacity=1, max_wait_s=MW,
                        tracer=tracer, metrics=metrics)
    rep = s.run([graph(seed=0), graph(seed=1)], arrivals=[0.0, A1])
    return ex, rep


def spans_by_name(tracer, name):
    return [s for s in tracer.spans if s.name == name]


# ----------------------------------------------------- exact span timeline


def test_scripted_run_emits_exact_span_boundaries():
    tracer = Tracer(VirtualClock())
    _, rep = run_budget_flush(tracer=tracer)
    assert rep.num_served == 2 and rep.flush_reasons == {"budget": 1}

    # recorded order is deterministic: admits, then the flush's pack/
    # unpack (inside _execute), then the timeline spans + responds
    assert [(s.name, s.track) for s in tracer.spans] == [
        ("admit", "scheduler"), ("admit", "scheduler"),
        ("pack", "host"), ("unpack", "host"),
        ("queue", "scheduler"), ("queue", "scheduler"),
        ("flush", "scheduler"), ("device", "device"),
        ("respond", "scheduler"), ("respond", "scheduler"),
    ]

    a0, a1 = spans_by_name(tracer, "admit")
    assert (a0.t0_s, a0.t1_s) == (0.0, None)
    assert (a1.t0_s, a1.t1_s) == (A1, None)
    assert dict(a0.attrs)["rid"] == 0 and dict(a1.attrs)["rid"] == 1
    assert dict(a0.attrs)["tenant"] == "default"
    assert dict(a0.attrs)["bucket"] == str((32, 96))

    q0, q1 = spans_by_name(tracer, "queue")
    assert (q0.t0_s, q0.t1_s) == (0.0, A1)  # rid 0 waits for the fill
    assert (q1.t0_s, q1.t1_s) == (A1, A1)  # rid 1 triggers the flush

    # host stages are zero-duration markers at the flush instant: the
    # VirtualClock does not move during host work
    (pack,), (unpack,) = (spans_by_name(tracer, n) for n in ("pack", "unpack"))
    assert (pack.t0_s, pack.t1_s) == (A1, A1)
    assert (unpack.t0_s, unpack.t1_s) == (A1, A1)
    assert dict(pack.attrs) == {"tenant": "default", "graphs": 2, "rung": 1}

    (fl,) = spans_by_name(tracer, "flush")
    assert (fl.t0_s, fl.t1_s) == (A1, DONE)
    assert dict(fl.attrs) == {"tenant": "default", "priority": 0,
                              "reason": "budget", "graphs": 2,
                              "sig": str((32, 96)), "rung": 1}

    (dev,) = spans_by_name(tracer, "device")
    assert (dev.t0_s, dev.t1_s) == (A1, DONE)
    assert dict(dev.attrs)["compute_s"] == SVC

    r0, r1 = spans_by_name(tracer, "respond")
    assert (r0.t0_s, r1.t0_s) == (DONE, DONE)
    assert dict(r0.attrs) == {"rid": 0, "latency_s": DONE, "miss": False}
    assert dict(r1.attrs) == {"rid": 1, "latency_s": DONE - A1, "miss": False}


def test_scripted_run_counts_exactly_in_the_registry():
    reg = MetricsRegistry()
    _, rep = run_budget_flush(metrics=reg)

    lab = dict(tenant="default", priority="0")
    assert reg.get("serve_requests_total").value(**lab) == 2
    assert reg.get("serve_admitted_total").value(**lab) == 2
    assert reg.get("serve_served_total").value(**lab) == 2
    assert reg.get("serve_shed_total").total() == 0
    assert reg.get("serve_deadline_misses_total").total() == 0
    assert reg.get("serve_flushes_total").value(reason="budget") == 1
    fg = reg.get("serve_flush_graphs")
    assert (fg.count(), fg.sum()) == (1, 2.0)
    lat = reg.get("serve_request_latency_seconds")
    assert lat.count(**lab) == 2
    assert lat.sum(**lab) == DONE + (DONE - A1)
    # first observation seeds the EWMA with the measured compute verbatim
    assert reg.get("serve_service_ewma_seconds").value(sig="32x96") == SVC
    assert reg.get("serve_queue_depth").value() == 0
    assert reg.get("serve_open_buckets").value() == 0
    # the registry and the report are views over the same events
    assert reg.get("serve_served_total").total() == rep.num_served
    assert reg.get("serve_flushes_total").total() == len(rep.flush_log)


def test_shed_and_miss_events_reach_tracer_registry_and_ledger():
    """queue_full sheds + a deadline miss land as structured events, and
    the admission ledger renders *from the registry*."""
    tracer, reg = Tracer(VirtualClock()), MetricsRegistry()
    ex = scripted_executor(service_s=SVC)
    s = StreamScheduler(ex, capacity=1, max_wait_s=MW, admit_limit=1,
                        slo_s=0.001, tracer=tracer, metrics=reg)
    # rid 0 admitted; rids 1-2 shed queue_full; SLO 1ms tightens the
    # bucket deadline to 0.001, and 0.001 + SVC overruns it -> one miss
    rep = s.run([graph(seed=i) for i in range(3)], arrivals=[0.0, 0.0, 0.0])

    assert rep.num_served == 1 and rep.num_shed == 2
    assert rep.deadline_misses == 1
    assert [x.reason for x in rep.shed] == ["queue_full", "queue_full"]

    sheds = spans_by_name(tracer, "shed")
    assert [(s.t0_s, dict(s.attrs)["rid"]) for s in sheds] == [(0.0, 1), (0.0, 2)]
    assert all(dict(s.attrs)["reason"] == "queue_full" for s in sheds)
    (resp,) = spans_by_name(tracer, "respond")
    assert dict(resp.attrs)["miss"] is True

    lab = dict(tenant="default", priority="0")
    assert reg.get("serve_shed_total").value(reason="queue_full", **lab) == 2
    assert reg.get("serve_deadline_misses_total").value(**lab) == 1
    assert export.admission_line(reg) == (
        "admission: served 1  shed 2 ({'queue_full': 2}); deadline misses 1"
    )


def test_admission_line_renders_compile_warm_split_and_aot_tally():
    """Once the executor has paid untimed work, the ledger shows the
    compile/warm split and the AOT cache outcome tally."""
    reg = MetricsRegistry()
    mi = ServingInstruments(reg)
    mi.served.inc(1, tenant="default", priority="0")
    mi.compile_seconds.inc(1.25)
    mi.warm_seconds.inc(0.5)
    mi.aot_cache.inc(2, result="hit")
    mi.aot_cache.inc(1, result="miss")
    assert export.admission_line(reg) == (
        "admission: served 1  shed 0 ({}); deadline misses 0; "
        "untimed compile 1.25s + warm 0.50s; aot hit 2 miss 1 stale 0"
    )


# --------------------------------------------------- bitwise-identical trace


def test_trace_json_is_bitwise_identical_across_runs():
    docs, snaps = [], []
    for _ in range(2):
        tracer, reg = Tracer(VirtualClock()), MetricsRegistry()
        run_budget_flush(tracer=tracer, metrics=reg)
        docs.append(export.trace_json(tracer))
        snaps.append(json.dumps(reg.snapshot(), sort_keys=True))
    assert docs[0] == docs[1]
    assert snaps[0] == snaps[1]


# ------------------------------------------------------- dark path is free


SLOW_SLO = 0.125  # 1/8: generous, so the free-path scenario serves all


def test_disabled_telemetry_is_provably_free():
    """No tracer/registry attached: identical flush log, latencies, and
    executor call sequence — the no-op sink changes nothing."""
    ex_on = scripted_executor(service_s=SVC)
    ex_off = scripted_executor(service_s=SVC)
    graphs = [graph(seed=i) for i in range(6)]
    arrivals = [0.0, A1, 2 * A1, 3 * A1, MW, MW + A1]
    kw = dict(capacity=2, max_wait_s=MW, slo_s=SLOW_SLO, admit_limit=3)
    rep_on = StreamScheduler(ex_on, tracer=Tracer(VirtualClock()),
                             metrics=MetricsRegistry(), **kw).run(
        graphs, arrivals=arrivals)
    rep_off = StreamScheduler(ex_off, **kw).run(graphs, arrivals=arrivals)

    assert rep_on.flush_log == rep_off.flush_log  # frozen dataclasses: exact
    assert rep_on.shed == rep_off.shed
    np.testing.assert_array_equal(rep_on.latencies_s, rep_off.latencies_s)
    assert ex_on.run_log == ex_off.run_log


def test_disabled_telemetry_adds_zero_compile_keys():
    """A real engine compiles the identical program-key set with and
    without telemetry attached — the sinks stage nothing into jit.  The
    telemetry pass doubles as the executor-accounting check: compile/
    warm/device events and counters land in the attached sinks."""
    import jax

    from repro.gnn import init
    from repro.gnn.models import paper_config
    from repro.serve.gnn_engine import GNNEngine

    cfg = paper_config("gin")
    params = init(jax.random.PRNGKey(0), cfg)
    graphs = [graph(seed=i, feat=9, e=16) for i in range(4)]

    keys = []
    for telemetry in (False, True):
        eng = GNNEngine(cfg, params)
        kw = {}
        if telemetry:
            tracer, reg = Tracer(VirtualClock()), MetricsRegistry()
            kw = dict(tracer=tracer, metrics=reg)
        rep = StreamScheduler(eng, capacity=2, max_wait_s=MW, **kw).run(
            graphs, arrivals=[0.0, A1, 2 * A1, 3 * A1])
        keys.append(set(eng._compiled))
    assert keys[0] == keys[1] and keys[0]

    # executor-side accounting from the telemetry pass: one program per
    # eager-warmed rung, warm time tracked outside the timed region, and
    # device seconds exactly the flush-compute view of the report
    assert reg.get("serve_programs_built_total").value() == len(keys[1])
    assert reg.get("serve_warms_total").value() == len(keys[1])
    assert reg.get("serve_compile_seconds_total").value() > 0
    assert reg.get("serve_device_seconds_total").value() == rep.compute_s
    assert spans_by_name(tracer, "program_build")
    assert spans_by_name(tracer, "warm")
    assert len(spans_by_name(tracer, "executor_run")) == len(rep.flush_log)


# ------------------------------------------------------------ kernel census


def test_kernel_dispatch_decisions_are_counted():
    from repro.kernels import ops

    reg = default_registry()
    c = reg.counter("kernels_dispatch_total")
    before = c.value(op="node_mlp", path="reference")
    x = np.zeros((4, 8), np.float32)
    w = np.zeros((8, 8), np.float32)
    b = np.zeros((8,), np.float32)
    ops.node_mlp(x, w, b, mode="reference")
    assert c.value(op="node_mlp", path="reference") == before + 1


# -------------------------------------------------------- exporter schemas


def test_registry_rejects_names_outside_the_catalog():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="closed"):
        reg.counter("serve_totally_new_total")
    with pytest.raises(ValueError, match="counter"):
        reg.gauge("serve_requests_total")  # catalog type mismatch
    with pytest.raises(ValueError, match="labels"):
        reg.counter("serve_requests_total", labels=("tenant",))


def test_metrics_snapshot_golden_schema_and_validation():
    reg = MetricsRegistry()
    run_budget_flush(metrics=reg)
    doc = reg.snapshot()
    assert doc["schema"] == "repro-metrics/v1"
    assert export.validate_metrics_snapshot(doc) == len(doc["metrics"])
    m = doc["metrics"]["serve_served_total"]
    assert m["type"] == "counter" and m["labelnames"] == ["tenant", "priority"]
    assert m["series"] == [
        {"labels": {"tenant": "default", "priority": "0"}, "value": 2.0}
    ]
    # an unregistered name fails validation — the surface is closed
    doc["metrics"]["serve_rogue_total"] = {
        "type": "counter", "help": "", "labelnames": [], "series": []}
    with pytest.raises(ValueError, match="unregistered"):
        export.validate_metrics_snapshot(doc)


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    run_budget_flush(metrics=reg)
    text = export.prometheus_text(reg)
    assert "# HELP serve_served_total" in text
    assert "# TYPE serve_served_total counter" in text
    assert 'serve_served_total{tenant="default",priority="0"} 2' in text
    assert 'serve_flushes_total{reason="budget"} 1' in text
    # cumulative histogram with the implicit +Inf bucket == count
    assert 'serve_flush_graphs_bucket{le="2"} 1' in text
    assert 'serve_flush_graphs_bucket{le="+Inf"} 1' in text
    assert "serve_flush_graphs_sum 2" in text
    assert "serve_flush_graphs_count 1" in text


def test_trace_event_export_golden_schema():
    tracer = Tracer(VirtualClock())
    run_budget_flush(tracer=tracer)
    doc = export.trace_events(tracer)
    assert export.validate_trace_events(doc) == len(tracer.spans)
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    names = {e["args"]["name"] for e in meta}
    assert "repro-serve" in names  # process row
    assert {"scheduler", "device", "host"} <= names  # one row per track
    flush = next(e for e in doc["traceEvents"]
                 if e["ph"] == "X" and e["name"] == "flush")
    assert flush["ts"] == round(A1 * 1e6, 3)
    assert flush["dur"] == round(SVC * 1e6, 3)
    assert flush["args"]["reason"] == "budget"
    respond = next(e for e in doc["traceEvents"] if e["name"] == "respond")
    assert respond["ph"] == "i" and respond["s"] == "t"
    with pytest.raises(ValueError, match="ph"):
        export.validate_trace_events(
            {"traceEvents": [{"name": "x", "ph": "B", "pid": 1, "tid": 1}]})


# ------------------------------------------------------------- svc_alpha


def test_svc_alpha_is_a_real_knob_with_exact_ewma():
    script = [SVC, 2 * SVC, 4 * SVC]
    for alpha, expect in ((0.5, None), (0.25, None), (1.0, 4 * SVC)):
        ex = scripted_executor(service_s=script)
        s = StreamScheduler(ex, capacity=1, max_wait_s=MW, svc_alpha=alpha,
                            metrics=(reg := MetricsRegistry()))
        # three isolated drain flushes: arrivals a bucket-lifetime apart
        s.run([graph(seed=i) for i in range(3)],
              arrivals=[0.0, 0.0625, 0.125])
        ewma = script[0]
        for dt in script[1:]:
            ewma = (1.0 - alpha) * ewma + alpha * dt
        if expect is not None:
            assert ewma == expect
        assert s.service_estimate_s((32, 96)) == ewma
        assert reg.get("serve_service_ewma_seconds").value(sig="32x96") == ewma
    with pytest.raises(ValueError, match="svc_alpha"):
        StreamScheduler(scripted_executor(), svc_alpha=0.0)
