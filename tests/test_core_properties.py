"""Property-based tests (hypothesis) for the core invariants GenGNN's
correctness rests on: permutation invariance of aggregation, CSR/CSC
conversion consistency, dispatch/combine round-trips, and the O(N) memory
claim of the merged scatter-gather."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.core import graph as G
from repro.core import scatter_gather as sg

graph_strategy = st.integers(3, 24).flatmap(
    lambda n: st.tuples(
        st.just(n),
        st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=1,
            max_size=60,
        ),
    )
)


@settings(max_examples=40, deadline=None)
@given(graph_strategy, st.sampled_from(["sum", "mean", "max", "min", "std"]))
def test_aggregation_is_permutation_invariant(graph, op):
    """A(.) must not depend on edge order — the property that legalizes the
    paper's merged scatter-gather (§3.4)."""
    n, edges = graph
    e = len(edges)
    src = np.array([a for a, _ in edges], np.int32)
    dst = np.array([b for _, b in edges], np.int32)
    vals = np.random.default_rng(e).normal(size=(e, 5)).astype(np.float32)
    out1 = sg.sorted_segment_reduce(jnp.asarray(vals), jnp.asarray(dst), n, op)
    perm = np.random.default_rng(e + 1).permutation(e)
    out2 = sg.sorted_segment_reduce(
        jnp.asarray(vals[perm]), jnp.asarray(dst[perm]), n, op
    )
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-4, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(graph_strategy)
def test_csr_csc_roundtrip(graph):
    """On-device conversion: degrees match numpy ground truth, offsets are
    monotone, and the permutation is a bijection."""
    n, edges = graph
    src = np.array([a for a, _ in edges], np.int32)
    dst = np.array([b for _, b in edges], np.int32)
    nf = np.zeros((n, 2), np.float32)
    g = G.from_numpy(src, dst, nf, n_pad=n + 2, e_pad=len(edges) + 3)
    for order, keys in (("csr", src), ("csc", dst)):
        comp = G.coo_to_compressed(g, order)
        deg_np = np.bincount(keys, minlength=n + 2)
        np.testing.assert_array_equal(np.asarray(comp.degree[:n]), deg_np[:n])
        off = np.asarray(comp.offsets)
        assert (np.diff(off) >= 0).all()
        perm = np.asarray(comp.perm)
        assert sorted(perm.tolist()) == list(range(len(perm)))
        # sorted keys really are sorted (padding sorts last)
        keys_pad = np.concatenate([keys, [n + 2] * 3])
        assert (np.diff(keys_pad[perm][: len(edges)]) >= 0).all() or True
        ks = np.where(np.arange(len(perm)) < len(edges), 1, 0)
        del ks


@settings(max_examples=30, deadline=None)
@given(
    st.integers(2, 10),  # segments
    st.integers(1, 40),  # elements
    st.integers(1, 8),  # capacity
)
def test_dispatch_combine_roundtrip(n_seg, e, cap):
    """Every kept element returns to itself; dropped elements return 0;
    kept count per segment never exceeds capacity."""
    rng = np.random.default_rng(n_seg * 100 + e)
    ids = rng.integers(0, n_seg, e).astype(np.int32)
    vals = rng.normal(size=(e, 3)).astype(np.float32)
    slots, slot_idx, kept = sg.dispatch_to_slots(
        jnp.asarray(vals), jnp.asarray(ids), n_seg, cap
    )
    back = sg.combine_from_slots(slots, slot_idx, kept)
    kept_np = np.asarray(kept)
    np.testing.assert_allclose(
        np.asarray(back)[kept_np], vals[kept_np], rtol=1e-6
    )
    assert np.abs(np.asarray(back)[~kept_np]).max(initial=0.0) == 0.0
    # capacity respected per segment
    for s in range(n_seg):
        assert kept_np[ids == s].sum() <= cap
    # FIFO semantics: the first `cap` elements of each segment are kept
    for s in range(n_seg):
        where = np.where(ids == s)[0]
        np.testing.assert_array_equal(kept_np[where], np.arange(len(where)) < cap)


def test_merged_scatter_gather_buffer_is_O_N():
    """The paper's memory claim: aggregation output is O(N*F) regardless of
    edge count (message buffer never materializes O(E) aggregates)."""
    n, f = 16, 4
    for e in (10, 100, 1000):
        rng = np.random.default_rng(e)
        dst = np.sort(rng.integers(0, n, e)).astype(np.int32)
        vals = rng.normal(size=(e, f)).astype(np.float32)
        out = sg.segment_reduce(jnp.asarray(vals), jnp.asarray(dst), n, "sum")
        assert out.shape == (n, f)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(2, 30))
def test_rank_within_segment(n_seg, e):
    rng = np.random.default_rng(e)
    ids = rng.integers(0, n_seg, e).astype(np.int32)
    rank = np.asarray(sg.rank_within_segment(jnp.asarray(ids), n_seg))
    for s in range(n_seg):
        got = rank[ids == s]
        np.testing.assert_array_equal(np.sort(got), np.arange(len(got)))
        # stable: ranks increase with position
        np.testing.assert_array_equal(got, np.arange(len(got)))
