"""Deterministic SLO-scheduler simulations on the VirtualClock.

Every test scripts an arrival trace and a service-time script into the
``scripted_executor`` fake, runs the real ``StreamScheduler`` event loop,
and asserts **exact float equality** on flush timestamps, latencies,
shed decisions, and priority ordering — no sleeps, no wall clock, no
tolerance.  Timestamps are binary fractions (1/64, 1/256, ...) so every
sum in the expectations is exact in float64; two runs of the same trace
must be bitwise identical.
"""
import math

import numpy as np
import pytest

from conftest import scripted_executor
from repro.serve.clock import RealClock, VirtualClock
from repro.serve.scheduler import Shed, StreamScheduler

MW = 0.015625  # max_wait_s = 1/64: binary-exact
SVC = 0.00390625  # 1/256
SLOW = 0.125  # 1/8


def graph(n=8, e=12, feat=4, seed=0):
    rng = np.random.default_rng(seed)
    return (
        rng.integers(0, n, e).astype(np.int32),
        rng.integers(0, n, e).astype(np.int32),
        rng.normal(size=(n, feat)).astype(np.float32),
        rng.normal(size=(e, 3)).astype(np.float32),
    )


def sched(ex, **kw):
    kw.setdefault("capacity", 4)
    kw.setdefault("max_wait_s", MW)
    return StreamScheduler(ex, **kw)


# ------------------------------------------------------------ virtual clock


def test_virtual_clock_is_explicit_and_monotone():
    c = VirtualClock()
    assert c.now() == 0.0
    assert c.advance_to(1.5) == 1.5
    assert c.advance(0.25) == 1.75
    assert c.now() == 1.75
    with pytest.raises(ValueError, match="backwards"):
        c.advance_to(1.0)
    with pytest.raises(ValueError, match="negative"):
        c.advance(-0.1)
    assert c.now() == 1.75  # failed advances leave time untouched


def test_real_clock_moves_forward():
    c = RealClock()
    a = c.now()
    assert c.now() >= a


# ----------------------------------------------------- exact flush timing


def test_exact_flush_times_and_latencies():
    """Low load: one deadline flush, one drain flush, every timestamp an
    exact function of the trace."""
    ex = scripted_executor(service_s=SVC)
    s = sched(ex)
    arrivals = [0.0, 0.0009765625, 0.0625]  # 0, 1/1024, 1/16
    rep = s.run([graph(seed=i) for i in range(3)], arrivals=arrivals)

    assert rep.num_served == 3 and rep.num_shed == 0
    f0, f1 = rep.flush_log
    # bucket opened at t=0, deadline MW; device idle -> starts at MW
    assert f0.rids == (0, 1) and f0.reason == "deadline"
    assert (f0.at_s, f0.start_s, f0.done_s) == (MW, MW, MW + SVC)
    # last arrival opens its own bucket; stream exhausted -> drain
    assert f1.rids == (2,) and f1.reason == "drain"
    assert (f1.at_s, f1.start_s, f1.done_s) == (
        0.0625 + MW, 0.0625 + MW, 0.0625 + MW + SVC)
    expect = np.array([
        MW + SVC - 0.0,
        MW + SVC - 0.0009765625,
        MW + SVC,
    ])
    assert np.array_equal(rep.latencies_s, expect)  # exact, no tolerance
    assert rep.flush_reasons == {"deadline": 1, "drain": 1}
    assert rep.compute_s == 2 * SVC
    assert rep.makespan_s == f1.done_s


def test_simulation_is_bitwise_reproducible():
    """Same trace, fresh scheduler + executor: identical report, bit for
    bit (flush log, latencies incl. nan positions, shed decisions)."""
    def once():
        ex = scripted_executor(service_s=[SLOW, SVC, SVC])
        s = sched(ex, slo_s=0.25, admit_limit=6)
        graphs = [graph(n=6 + i % 9, e=9 + (i * 5) % 13, seed=i)
                  for i in range(12)]
        arrivals = [i * 0.0078125 for i in range(12)]  # i/128
        priorities = [i % 2 for i in range(12)]
        return s.run(graphs, arrivals=arrivals, priorities=priorities)

    a, b = once(), once()
    assert a.flush_log == b.flush_log
    assert a.shed == b.shed
    assert np.array_equal(a.latencies_s, b.latencies_s, equal_nan=True)
    assert a.batch_sizes == b.batch_sizes
    assert a.flush_reasons == b.flush_reasons
    assert a.deadline_misses == b.deadline_misses
    assert a.makespan_s == b.makespan_s


def test_injected_clock_chains_runs_on_one_timeline():
    clock = VirtualClock()
    ex = scripted_executor(service_s=SVC)
    s = sched(ex, clock=clock)
    rep1 = s.run([graph()], arrivals=[0.0])
    assert clock.now() == rep1.flush_log[0].done_s
    # second run starts where the first finished; qps<=0 queues at now()
    rep2 = s.run([graph(seed=1)])
    assert rep2.flush_log[0].at_s == rep1.flush_log[0].done_s + MW


def test_scripted_arrivals_are_validated():
    ex = scripted_executor()
    s = sched(ex)
    with pytest.raises(ValueError, match="stamp every graph"):
        s.run([graph(), graph(seed=1)], arrivals=[0.0])
    with pytest.raises(ValueError, match="non-decreasing"):
        s.run([graph(), graph(seed=1)], arrivals=[1.0, 0.5])
    with pytest.raises(ValueError, match="predates the clock"):
        s.run([graph()], arrivals=[-1.0], qps=0.0)


# ------------------------------------------------------- priority ordering


def test_priority_orders_flushes_when_both_ready():
    """Two classes arrive together; with identical deadlines both buckets
    are ready at the same instant and the higher-priority class (lower
    number) takes the serial device first."""
    ex = scripted_executor(service_s=SLOW)
    s = sched(ex)
    rep = s.run([graph(seed=0), graph(seed=1)], arrivals=[0.0, 0.0],
                priorities=[1, 0])  # rid 0 is LOW priority, rid 1 HIGH
    f0, f1 = rep.flush_log
    assert f0.rids == (1,) and f0.priority == 0  # high class first
    assert f1.rids == (0,) and f1.priority == 1
    assert (f0.at_s, f0.done_s) == (MW, MW + SLOW)
    # the low-priority bucket waited for the device, not its deadline
    assert (f1.at_s, f1.start_s, f1.done_s) == (
        MW + SLOW, MW + SLOW, MW + 2 * SLOW)
    assert rep.latencies_s[1] < rep.latencies_s[0]


def test_same_priority_ties_break_by_bucket_age():
    """Equal class + equal readiness: the older bucket flushes first — a
    deterministic total order, never dict iteration luck."""
    ex = scripted_executor(service_s=SLOW)
    s = sched(ex, capacity=1)  # budget (32, 96, 2): distinct sigs needed
    # rid 0 -> bucket (32, 96); rid 1 -> bucket (64, 192): two open buckets
    rep = s.run([graph(n=8, e=12), graph(n=40, e=60, seed=1)],
                arrivals=[0.0, 0.0])
    assert [f.rids for f in rep.flush_log] == [(0,), (1,)]


# ------------------------------------------------ shedding / backpressure


def test_backlog_shed_is_typed_and_exact():
    ex = scripted_executor(service_s=SLOW)
    s = sched(ex, slo_s=0.2)
    arrivals = [0.0, 0.03125, 0.25]
    rep = s.run([graph(seed=i) for i in range(3)], arrivals=arrivals)

    # r0: deadline flush at MW, done MW + SLOW = 0.140625
    assert rep.flush_log[0].done_s == MW + SLOW
    # r1 arrives at 0.03125 with the device busy until 0.140625 and the
    # signature's service EWMA now at SLOW: projected delay exceeds SLO
    assert rep.shed == [Shed(
        rid=1, model=None, priority=0, reason="backlog",
        at_s=0.03125,
        projected_delay_s=(MW + SLOW - 0.03125) + SLOW,
        slo_s=0.2,
    )]
    assert rep.outputs[1] is None and math.isnan(rep.latencies_s[1])
    # r2 arrives after the backlog cleared: served within SLO
    assert rep.outputs[2] is not None
    assert rep.deadline_misses == 0
    assert rep.num_served + rep.num_shed == rep.num_requests == 3


def test_queue_full_shed_bounds_admitted_queue():
    ex = scripted_executor(service_s=SVC)
    s = sched(ex, admit_limit=2, max_wait_s=1.0)
    rep = s.run([graph(seed=i) for i in range(4)], arrivals=[0.0] * 4)
    assert [x.rid for x in rep.shed] == [2, 3]
    assert all(x.reason == "queue_full" for x in rep.shed)
    assert rep.num_served == 2 and sum(rep.batch_sizes) == 2
    assert rep.flush_log[0].rids == (0, 1)


def test_backlog_shed_counts_admitted_unflushed_work():
    """The projection must see work that is queued but not yet on the
    device: every open bucket (distinct QoS classes here) is one future
    flush, so arrivals project onto a growing pile even though
    device_free is still t0."""
    ex = scripted_executor()
    s = sched(ex, capacity=1, max_wait_s=1.0, slo_s=0.25, service_s=0.125)
    rep = s.run([graph(seed=i) for i in range(5)], arrivals=[0.0] * 5,
                priorities=[0, 1, 2, 3, 4])
    # rid0: nothing ahead, 1 x svc; rid1: one bucket + its own, exactly
    # the SLO (<= admits); rid2 on: two buckets ahead -> 3 x svc, shed —
    # and a shed opens no bucket, so the projection stays put
    assert [x.rid for x in rep.shed] == [2, 3, 4]
    assert all(x.reason == "backlog" for x in rep.shed)
    assert [x.projected_delay_s for x in rep.shed] == [0.125 * 3] * 3
    assert rep.num_served == 2


def test_admit_margin_guard_band_sheds_earlier():
    """margin=0.5 halves the usable budget: a projection that exactly
    equals the SLO admits at margin 1.0 but sheds at 0.5 — deadline
    accounting still uses the full SLO."""
    def trace(margin):
        ex = scripted_executor()
        s = sched(ex, capacity=1, max_wait_s=1.0, slo_s=0.25,
                  service_s=0.125, admit_margin=margin)
        return s.run([graph(seed=0), graph(seed=1)], arrivals=[0.0, 0.0],
                     priorities=[0, 1])

    full = trace(1.0)
    assert full.num_shed == 0  # rid1 projects exactly 0.25 == slo
    guarded = trace(0.5)
    assert [x.rid for x in guarded.shed] == [1]
    assert guarded.shed[0].slo_s == 0.25  # the full SLO, not the band
    with pytest.raises(ValueError, match="admit_margin"):
        sched(scripted_executor(), admit_margin=0.0)


def test_slo_by_class_beats_default_and_wildcard():
    ex = scripted_executor()
    s = sched(ex, slo_s=1.0,
              slo_by_class={(None, 1): 0.5, ("default", 1): 0.25})
    assert s.resolve_slo_s("default", 0) == 1.0  # default slo
    assert s.resolve_slo_s("other", 1) == 0.5  # wildcard class row
    assert s.resolve_slo_s("default", 1) == 0.25  # tenant-specific wins
    s2 = sched(ex)
    assert s2.resolve_slo_s("default", 0) == math.inf  # best-effort


def test_best_effort_requests_are_never_shed():
    """No SLO configured: arbitrarily deep backlog still admits (the
    historical greedy behaviour is the slo_s=None special case)."""
    ex = scripted_executor(service_s=SLOW)
    s = sched(ex)
    rep = s.run([graph(seed=i) for i in range(6)],
                arrivals=[i * 0.0078125 for i in range(6)])
    assert rep.num_shed == 0 and rep.num_served == 6


def test_deadline_miss_is_counted_not_hidden():
    """Admission was optimistic (no service estimate yet) but the flush
    ran long: the served request misses its SLO and the report says so."""
    ex = scripted_executor(service_s=SLOW)
    s = sched(ex, slo_s=0.0625)
    rep = s.run([graph()], arrivals=[0.0])
    assert rep.num_served == 1 and rep.num_shed == 0
    assert rep.latencies_s[0] == MW + SLOW  # > slo
    assert rep.deadline_misses == 1


def test_slo_tightens_bucket_deadline_below_max_wait():
    """A request whose SLO minus the service estimate lands before
    opened_at + max_wait must flush early enough to make it."""
    ex = scripted_executor(service_s=[SVC, SVC])
    s = sched(ex, slo_s=0.0078125, service_s=SVC)  # slo 1/128 < MW
    rep = s.run([graph()], arrivals=[0.0])
    f = rep.flush_log[0]
    assert f.at_s == 0.0078125 - SVC  # deadline - service estimate
    assert f.done_s == 0.0078125 - SVC + SVC == 0.0078125
    assert rep.deadline_misses == 0


# ------------------------------------------- flush-reason classification


def test_deadline_vs_drain_at_exactly_deadline_arrival():
    """An arrival landing at exactly a bucket's expiry: the expiry wins
    the tie and is classified "deadline" (the stream is not exhausted);
    the arrival then opens a fresh bucket whose flush is the "drain"."""
    ex = scripted_executor(service_s=SVC)
    s = sched(ex)
    rep = s.run([graph(seed=0), graph(seed=1)], arrivals=[0.0, MW])
    f0, f1 = rep.flush_log
    assert f0.rids == (0,) and f0.reason == "deadline" and f0.at_s == MW
    assert f1.rids == (1,) and f1.reason == "drain" and f1.at_s == 2 * MW


def test_drain_only_when_stream_exhausted():
    ex = scripted_executor(service_s=SVC)
    s = sched(ex)
    rep = s.run([graph(seed=i) for i in range(3)],
                arrivals=[0.0, 0.0625, 0.125])
    assert [f.reason for f in rep.flush_log] == [
        "deadline", "deadline", "drain"]


# --------------------------------------------------- empty / all-shed runs


def test_percentile_on_empty_report_is_nan_not_crash():
    ex = scripted_executor()
    rep = sched(ex).run([])
    assert rep.num_requests == 0
    assert math.isnan(rep.percentile_ms(50))
    assert math.isnan(rep.percentile_ms(99))
    assert rep.graphs_per_s == 0.0


def test_percentile_when_everything_shed_is_nan():
    """A non-empty offered stream can still serve nothing: the seeded
    service estimate already exceeds the SLO, so every arrival sheds."""
    ex = scripted_executor()
    s = sched(ex, slo_s=0.001, service_s=0.01)
    rep = s.run([graph(seed=i) for i in range(3)], arrivals=[0.0] * 3)
    assert rep.num_shed == 3 and rep.num_served == 0
    assert all(x.reason == "backlog" for x in rep.shed)
    assert math.isnan(rep.percentile_ms(99))
    assert rep.batch_sizes == [] and rep.flush_log == []


# ----------------------------------------------------- adaptive ladder


def test_adaptive_ladder_closes_unused_rungs_deterministically():
    ex = scripted_executor(service_s=SVC)
    s = sched(ex, capacity=8, adapt_ladder=True, refit_every=3)
    sig = (32, 96)
    # widely spaced singleton flushes: observed demand is all 1x
    rep = s.run([graph(seed=i) for i in range(3)],
                arrivals=[0.0, 0.25, 0.5])
    assert rep.num_served == 3
    # the derived ladder was 1,2,3,4,6,8; after a full window of 1x
    # demand only the hit rung and the pinned top survive
    assert s.ladder_multiples(sig) == [1, 8]
    # traffic is still admissible and still served after the refit
    rep2 = s.run([graph(seed=9)], arrivals=[0.0])
    assert rep2.num_served == 1 and rep2.flush_log[0].rung_multiple == 1


def test_refit_never_strands_an_open_bucket():
    """A refit triggered while another signature's bucket is open must
    not break that bucket's flush (it keeps its captured ladder)."""
    ex = scripted_executor(service_s=SVC)
    s = sched(ex, capacity=8, adapt_ladder=True, refit_every=2,
              max_wait_s=1.0)
    small = [graph(seed=i) for i in range(3)]  # sig (32, 96)
    big = graph(n=40, e=60, seed=7)  # sig (64, 192): its own open bucket
    rep = s.run([big, small[0], small[1], small[2]],
                arrivals=[0.0, 0.0, 0.25, 0.5])
    # smalls flush twice (refit fires in between); big drains at the end
    assert rep.num_served == 4
    assert rep.num_served + rep.num_shed == 4
    assert sorted(r for f in rep.flush_log for r in f.rids) == [0, 1, 2, 3]


def test_adaptive_ladder_opens_observed_midpoints():
    """Demand that lands between derived rungs (5x) gets its own rung
    after the refit window — close what traffic never hits, open what it
    does."""
    ex = scripted_executor(service_s=SVC)
    s = sched(ex, capacity=8, adapt_ladder=True, refit_every=2,
              max_wait_s=1.0)
    # 10 graphs of 16 nodes / 24 edges = 160 nodes -> ideal multiple 5
    batch = [graph(n=16, e=24, seed=i) for i in range(10)]
    rep = s.run(batch + batch, arrivals=[0.0] * 10 + [2.0] * 10)
    assert rep.num_served == 20
    assert 5 in s.ladder_multiples((32, 96))
    assert s.ladder_multiples((32, 96))[-1] == 8  # top rung pinned
