"""Guard: edge sorting belongs to ``core/`` — everyone else uses the plan.

The one-sort-per-graph invariant (paper §3.4, ``core/layout.py``) only
holds if no model, kernel wrapper, or serving module quietly re-derives
the edge order.  This checker walks every module under ``src/repro/``
outside ``core/`` and fails if it finds a call to:

  * ``sort_by_segment`` (the CSC sort primitive), bare or qualified;
  * ``argsort`` / ``lexsort`` in any spelling (bare import or attribute);
  * ``sort`` as an attribute of an array-library module (``jnp.sort``,
    ``np.sort``, ``jax.lax.sort``, ...) — Python's list ``.sort()`` and
    ``sorted()`` on host data stay allowed.

Modules that need the destination-ordered layout must accept a
``core.layout.GraphLayout`` (or go through ``core.layout.edge_plan`` /
``core.message_passing.gather_scatter``, whose fallback sorts live in
``core/``).  ``core/`` itself, tests, tools, and benchmarks are exempt —
tests deliberately exercise the per-call-sort parity path.

Exit code 1 with a per-call report when anything sorts out of bounds.

  python tools/check_no_raw_sort.py
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SRC = ROOT / "src" / "repro"
EXEMPT_PREFIX = ("core",)  # package parts under src/repro that may sort
BANNED_ANYWHERE = {"sort_by_segment", "argsort", "lexsort"}  # bare or attr
# `.sort(...)` is banned only on array-library modules: Python's list
# ``.sort()`` on host data stays allowed
ARRAY_MODULES = {"jnp", "np", "numpy", "lax", "jax"}


def _attr_root(node: ast.AST):
    """Leftmost Name of a dotted attribute chain (``jax.lax.sort`` -> jax)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _banned_call(func: ast.AST):
    """The offending name if this Call's func is a banned sort, else None."""
    if isinstance(func, ast.Name):
        return func.id if func.id in BANNED_ANYWHERE else None
    if isinstance(func, ast.Attribute):
        if func.attr in BANNED_ANYWHERE:
            return func.attr
        if func.attr == "sort" and _attr_root(func) in ARRAY_MODULES:
            return "sort"
    return None


def check_module(path: Path) -> list[str]:
    try:
        rel = path.relative_to(ROOT)
    except ValueError:  # e.g. a tmp file under test
        rel = path
    try:
        tree = ast.parse(path.read_text(), filename=str(rel))
    except SyntaxError as err:  # pragma: no cover - tier-1 would fail first
        return [f"{rel}: unparsable ({err})"]
    errors = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _banned_call(node.func)
        if name is not None:
            errors.append(
                f"{rel}:{node.lineno}: raw edge sort `{name}` outside core/ "
                f"— thread a core.layout.GraphLayout instead"
            )
    return errors


def main() -> int:
    errors = []
    checked = 0
    for path in sorted(SRC.rglob("*.py")):
        parts = path.relative_to(SRC).parts
        if parts[: len(EXEMPT_PREFIX)] == EXEMPT_PREFIX:
            continue
        checked += 1
        errors.extend(check_module(path))
    for e in errors:
        print(f"ERROR: {e}")
    if not errors:
        print(f"no-raw-sort check OK ({checked} modules outside core/)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
