"""Offline XLA flag sweep — benchmark candidate compiler-option sets per
model x bucket and commit the winners to ``src/repro/configs/xla_flags.json``.

The saxml ``llm_xla_flags.py`` pattern: latency-relevant XLA flags are
swept *offline* against the real serving programs, and only measured
winners are committed to a checked-in table the serving stack applies at
program-build time (``Executor._compiler_options`` ->
``Lowered.compile(compiler_options=...)``).  Serving never experiments;
it replays decisions this tool made.  The resolved flag set's hash folds
into the AOT cache fingerprint (``serve/aot.py``), so committing new
winners self-invalidates exactly the cached executables whose flags
changed — no manual cache flush.

Method, per model x bucket:

  1. every candidate set is *validated* by a try-compile first — an
     option the backend rejects (XLA raises INVALID_ARGUMENT for unknown
     names and unparsable values) is dropped with a note, never
     committed;
  2. the survivor sets (plus the empty default) compile the model's real
     packed program and run ``--reps`` timed executions on a
     representative molecule batch; the per-set score is the *minimum*
     latency (robust to scheduler noise);
  3. a candidate only wins if it beats the default by more than
     ``--threshold`` (default 2%) — ties go to the default, so the
     committed table stays minimal and a flag that merely doesn't hurt
     is never pinned.

Numerics-sensitive options (fast-math family) are deliberately absent
from the candidate pools: a winner must never change served outputs,
only how fast they are produced.

  PYTHONPATH=src python tools/autotune_xla.py --models gin,gcn
  PYTHONPATH=src python tools/autotune_xla.py --smoke --out /tmp/flags.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

# ---------------------------------------------------------------------------
# candidate pools (per backend)
# ---------------------------------------------------------------------------

# CPU: scheduler/codegen toggles only — every option here was probed to
# be accepted by this jaxlib pin's compiler_options surface, and none
# change numerics (fast-math and fast-min-max are excluded on purpose).
CPU_CANDIDATES = {
    "thunk-runtime-off": {"xla_cpu_use_thunk_runtime": False},
    "concurrency-sched": {
        "xla_cpu_enable_concurrency_optimized_scheduler": True,
    },
    "vec-width-512": {"xla_cpu_prefer_vector_width": 512},
    "single-thread-eigen": {"xla_cpu_multi_thread_eigen": False},
}

# TPU: the saxml llm_xla_flags.py latency set — scoped vmem sizing plus
# async collectives (a no-op for single-chip GNN serving, decisive for
# sharded meshes).  Validated by try-compile like everything else.
TPU_CANDIDATES = {
    "scoped-vmem-96m": {"xla_tpu_scoped_vmem_limit_kib": 98304},
    "async-collectives": {
        "xla_enable_async_all_gather": True,
        "xla_enable_async_collective_permute": True,
    },
    "latency-hiding": {
        "xla_latency_hiding_scheduler_rerun": 1,
    },
}


def candidate_sets(backend: str) -> dict:
    if backend == "cpu":
        return dict(CPU_CANDIDATES)
    if backend == "tpu":
        return dict(TPU_CANDIDATES)
    return {}


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------


def _workload(model: str, budget, n_graphs: int = 4):
    """(cfg, params, prepared) — one representative packed batch of real
    molecule graphs at the serving budget."""
    from repro.configs.gengnn_models import get_gnn_config
    from repro.core import batching as B
    from repro.data.pipeline import MOLHIV, MoleculeStream
    from repro.gnn import init

    cfg = get_gnn_config(model)
    params = init(jax.random.PRNGKey(0), cfg)
    graphs = [g[:4] for g in MoleculeStream(MOLHIV, seed=7).take(n_graphs)]
    need_eig = model == "dgn"
    eigvecs = None
    if need_eig:
        from repro.data.pipeline import laplacian_eigvec

        eigvecs = [laplacian_eigvec(s, r, nf.shape[0], nf.shape[0])
                   for s, r, nf, _ in graphs]
    prep, _ = B.pack_prepared(graphs, budget, eigvecs=eigvecs,
                              with_layout=True)
    return cfg, params, prep


def _validate(candidates: dict) -> tuple:
    """(accepted, rejected) — try-compile a trivial program under every
    candidate set; the backend's own INVALID_ARGUMENT is the filter."""
    import jax.numpy as jnp

    probe = jax.jit(lambda x: x @ x + 1.0).lower(jnp.ones((4, 4)))
    accepted, rejected = {}, {}
    for name, flags in candidates.items():
        try:
            probe.compile(compiler_options=dict(flags))
            accepted[name] = flags
        except Exception as err:  # noqa: BLE001 - the filter, not a failure
            rejected[name] = f"{type(err).__name__}: {str(err)[:120]}"
    return accepted, rejected


def _measure(model: str, budget, flag_sets: dict, reps: int) -> dict:
    """min-latency seconds per flag-set name for one model x budget,
    each measured on a fresh Executor (no cross-set compile reuse)."""
    from repro.serve.aot import XlaFlagConfig
    from repro.serve.executor import Executor

    results = {}
    for name, flags in flag_sets.items():
        ex = Executor(
            xla_flags=XlaFlagConfig(default=dict(flags)) if flags else None
        )
        cfg, params, prep = _workload(model, budget)
        ex.register(model, cfg, params)
        p = ex.prepare_packed(prep.graph, budget, eigvec=prep.eigvec,
                              layout=prep.layout, model=model)
        ex.warm(p, model=model)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            ex.run(p, model=model)
            best = min(best, time.perf_counter() - t0)
        results[name] = best
    return results


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--models", default="gcn,gin",
                    help="comma-separated model names to tune")
    ap.add_argument("--reps", type=int, default=20,
                    help="timed executions per candidate (score = min)")
    ap.add_argument("--pack", type=int, default=4,
                    help="packed budget = this many base (32,96) buckets")
    ap.add_argument("--threshold", type=float, default=0.02,
                    help="minimum fractional win over the default compile "
                         "for a candidate to be committed")
    ap.add_argument("--out", default="",
                    help="output table path (default: the checked-in "
                         "src/repro/configs/xla_flags.json)")
    ap.add_argument("--dry-run", action="store_true",
                    help="sweep and report, write nothing")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: one model, 3 reps, tiny threshold "
                         "checks the machinery end to end")
    args = ap.parse_args()
    if args.smoke:
        args.models, args.reps = args.models.split(",")[0], 3

    from repro.core.batching import BucketBudget
    from repro.serve.aot import (XlaFlagConfig, default_flags_path,
                                 environment_fingerprint)

    backend = jax.default_backend()
    accepted, rejected = _validate(candidate_sets(backend))
    for name, why in sorted(rejected.items()):
        print(f"[drop] {name}: rejected by {backend} backend ({why})")
    print(f"backend {backend}: {len(accepted)} candidate sets "
          f"({', '.join(sorted(accepted)) or 'none'}) + default")

    budget = BucketBudget(n_pad=32 * args.pack, e_pad=96 * args.pack,
                          g_pad=2 * args.pack)
    bucket_str = f"packed|{budget.n_pad}|{budget.e_pad}|{budget.g_pad}"
    models_out: dict = {}
    provenance: dict = {"tool": "tools/autotune_xla.py", "reps": args.reps,
                        "threshold": args.threshold, "backend": backend,
                        "bucket": bucket_str, "measurements": {},
                        "rejected": rejected}
    for model in args.models.split(","):
        sets = {"default": {}}
        sets.update(accepted)
        scores = _measure(model, budget, sets, args.reps)
        base = scores["default"]
        ranked = sorted(scores.items(), key=lambda kv: kv[1])
        provenance["measurements"][model] = {
            k: round(v * 1e6, 1) for k, v in ranked  # us, for the record
        }
        win_name, win_s = ranked[0]
        gain = (base - win_s) / base if base > 0 else 0.0
        line = "  ".join(f"{k}={v*1e6:.0f}us" for k, v in ranked)
        print(f"{model} @ {bucket_str}: {line}")
        if win_name != "default" and gain > args.threshold:
            models_out[model] = {"buckets": {bucket_str: dict(sets[win_name])}}
            print(f"  -> commit {win_name} ({gain*100:.1f}% faster)")
        else:
            print(f"  -> default wins (best alternative "
                  f"{gain*100:+.1f}%, threshold {args.threshold*100:.0f}%)")

    table = XlaFlagConfig(default={}, models=models_out)
    out = args.out or default_flags_path()
    if args.dry_run:
        print(f"dry run: would write {out}")
        return 0
    table.save(out, env=environment_fingerprint(), provenance=provenance)
    print(f"wrote {out} ({len(models_out)} model entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
