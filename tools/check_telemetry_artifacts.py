"""Validate the telemetry artifacts a serve run wrote — CI's schema gate.

Loads the ``--metrics-json`` snapshot and/or the ``--trace-out``
trace-event JSON a ``repro.launch.serve`` stream run produced and
validates them with the same ``obs.export`` validators the unit tests
use: the metrics document must be ``repro-metrics/v1`` with every metric
name in the closed ``obs.metrics.CATALOG`` (an unregistered name is a
hard failure — the metric surface is an API), and the trace document
must be well-formed Chrome/Perfetto trace events.  Exit 1 with the
validator's per-defect message on any failure.

  PYTHONPATH=src python tools/check_telemetry_artifacts.py \
      --metrics-json /tmp/metrics.json --trace-out /tmp/trace.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.obs import export  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--metrics-json", help="repro-metrics/v1 snapshot to check")
    ap.add_argument("--trace-out", help="Chrome trace-event JSON to check")
    args = ap.parse_args(argv)
    if not args.metrics_json and not args.trace_out:
        ap.error("nothing to check: pass --metrics-json and/or --trace-out")

    failures = 0
    if args.metrics_json:
        try:
            doc = json.loads(Path(args.metrics_json).read_text())
            n = export.validate_metrics_snapshot(doc)
            print(f"metrics OK: {args.metrics_json} ({n} catalog metrics)")
        except (OSError, ValueError) as err:
            print(f"ERROR: metrics {args.metrics_json}: {err}")
            failures += 1
    if args.trace_out:
        try:
            doc = json.loads(Path(args.trace_out).read_text())
            n = export.validate_trace_events(doc)
            print(f"trace OK: {args.trace_out} ({n} events)")
        except (OSError, ValueError) as err:
            print(f"ERROR: trace {args.trace_out}: {err}")
            failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
