"""Docs link checker: keep docs/*.md cross-references and the README
module map from rotting.

Two classes of reference are validated across README.md and docs/*.md:

  1. relative markdown links ``[text](path)`` — the target file must
     exist (resolved against the containing file's directory, anchors
     stripped; http(s)/mailto links are skipped);
  2. path-like tokens naming .py/.md files — backticked inline code and
     fenced code blocks (the README module map) are scanned for tokens
     such as ``src/repro/core/batching.py`` or ``compat.py``, and each
     must resolve to a real file: exactly from the repo root, or by
     unique-suffix match against the repo tree (so short forms like
     ``runtime/mesh.py`` stay valid until the file actually moves).

Exit code 1 with a per-reference report when anything dangles.

  python tools/check_docs_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", ".hypothesis"}

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
INLINE_CODE = re.compile(r"`([^`]+)`")
PATH_TOKEN = re.compile(r"^[A-Za-z0-9_./-]+\.(?:py|md)$")


def repo_files() -> list[Path]:
    out = []
    for p in ROOT.rglob("*"):
        if p.is_file() and not (set(p.relative_to(ROOT).parts) & SKIP_DIRS):
            out.append(p.relative_to(ROOT))
    return out


def doc_files() -> list[Path]:
    docs = sorted((ROOT / "docs").glob("*.md")) if (ROOT / "docs").is_dir() else []
    return [ROOT / "README.md"] + docs


def iter_path_tokens(text: str):
    """Path-like tokens from inline code spans and fenced code blocks."""
    for m in INLINE_CODE.finditer(text):
        yield m.group(1).strip()
    in_fence = False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            for tok in re.split(r"[\s(),:]+", line):
                yield tok


def check_file(md: Path, files: list[Path]) -> list[str]:
    text = md.read_text()
    try:
        rel = md.relative_to(ROOT)
    except ValueError:  # e.g. a tmp file under test
        rel = md
    errors = []

    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if path and not (md.parent / path).exists():
            errors.append(f"{rel}: broken link -> {target}")

    suffixes = {str(f): f for f in files}
    seen = set()
    for tok in iter_path_tokens(text):
        tok = tok.strip().rstrip(".,;:")
        if not PATH_TOKEN.match(tok) or tok in seen:
            continue
        seen.add(tok)
        if tok in suffixes or (ROOT / tok).exists():
            continue
        # suffix match: `runtime/mesh.py` / `compat.py` must name a real file
        hits = [f for f in files if str(f).endswith("/" + tok)]
        if not hits:
            errors.append(f"{rel}: dangling path reference `{tok}`")
    return errors


def main() -> int:
    files = repo_files()
    errors = []
    for md in doc_files():
        errors.extend(check_file(md, files))
    for e in errors:
        print(f"ERROR: {e}")
    if not errors:
        print(f"docs link check OK ({len(doc_files())} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
