"""Guard: the serving stack has ONE timing/compile path — ``serve/executor.py``.

The executor refactor's invariant is that ``time.perf_counter`` timing and
``jax.jit`` program construction exist exactly once in the GNN serving
stack (the executor's warm-before-timing path), so no serving mode can
quietly grow its own compile cache or timed region again — the drift that
produced the old mode x axis matrix, where every new axis had to be
hand-threaded through ``infer_stream`` / ``infer_batched`` /
``infer_packed`` separately.

This checker walks every module under ``src/repro/serve/`` and fails on
any *reference* (not just call — aliasing counts) to:

  * ``time.perf_counter`` / ``perf_counter`` / ``time.monotonic`` — a
    private timed region;
  * ``jax.jit`` / bare ``jit`` (imported from jax) / ``pjit`` — a private
    compile path;

outside ``serve/executor.py``.  Exemptions:

  * ``serve/executor.py`` itself — the one sanctioned path;
  * ``serve/engine.py`` — the LM prefill/decode server, a separate
    serving stack that predates the GNN executor and shares none of its
    bucket machinery (tracked as its own surface, not a GNN mode).

Exit code 1 with a per-reference report when anything times or compiles
out of bounds.

  python tools/check_engine_singlepath.py
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SERVE = ROOT / "src" / "repro" / "serve"
ALLOWED = "executor.py"  # the one timing/compile path
EXEMPT = {"engine.py"}  # the LM server: a separate, pre-executor stack
TIMING_ATTRS = {"perf_counter", "monotonic"}  # of the time module
TIMING_NAMES = {"perf_counter", "monotonic"}  # `from time import ...`
COMPILE_ATTRS = {"jit", "pjit"}  # of the jax module chain
COMPILE_NAMES = {"jit", "pjit"}  # bare `from jax import jit`
TIMING_MODULES = {"time"}
COMPILE_MODULES = {"jax", "jax.experimental.pjit"}


def _attr_root(node: ast.AST):
    """Leftmost Name of a dotted attribute chain (``jax.lax.sort`` -> jax)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _bound_names(tree: ast.AST):
    """(timing-module aliases, compile-module aliases, from-imported names)
    — ``import time as t`` / ``import jax as j`` alias the module itself,
    so attribute checks must resolve through the alias too; from-imports
    map the bound name back to its origin (``as`` renames count)."""
    time_mods, jax_mods, names = set(), set(), {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                bound = alias.asname or alias.name.split(".")[0]
                if alias.name in TIMING_MODULES or alias.name.split(".")[0] in TIMING_MODULES:
                    time_mods.add(bound)
                if alias.name.split(".")[0] == "jax":
                    jax_mods.add(bound)
        elif isinstance(node, ast.ImportFrom):
            if node.module in TIMING_MODULES | COMPILE_MODULES:
                for alias in node.names:
                    names[alias.asname or alias.name] = alias.name
    return time_mods, jax_mods, names


def check_module(path: Path) -> list[str]:
    try:
        rel = path.relative_to(ROOT)
    except ValueError:  # e.g. a tmp file under test
        rel = path
    try:
        tree = ast.parse(path.read_text(), filename=str(rel))
    except SyntaxError as err:  # pragma: no cover - tier-1 would fail first
        return [f"{rel}: unparsable ({err})"]
    time_mods, jax_mods, from_names = _bound_names(tree)
    errors = []
    for node in ast.walk(tree):
        bad = None
        if isinstance(node, ast.Attribute):
            root = _attr_root(node)
            if node.attr in TIMING_ATTRS and root in time_mods:
                bad = f"time.{node.attr} timing"
            elif node.attr in COMPILE_ATTRS and root in jax_mods:
                bad = f"jax.{node.attr} program construction"
        elif isinstance(node, ast.Name):
            origin = from_names.get(node.id)
            if origin in TIMING_NAMES:
                bad = f"{origin} timing"
            elif origin in COMPILE_NAMES:
                bad = f"{origin} program construction"
        if bad is not None:
            errors.append(
                f"{rel}:{node.lineno}: {bad} outside serve/executor.py "
                f"— route through the Executor's warm/run pipeline instead"
            )
    return errors


def main() -> int:
    errors = []
    checked = 0
    for path in sorted(SERVE.glob("*.py")):
        if path.name == ALLOWED or path.name in EXEMPT:
            continue
        checked += 1
        errors.extend(check_module(path))
    for e in errors:
        print(f"ERROR: {e}")
    if not errors:
        print(f"engine-singlepath check OK ({checked} serve/ modules share "
              f"the executor's one timing/compile path)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
