"""Guard: the serving stack has ONE timing/compile path — ``serve/executor.py``.

The executor refactor's invariant is that real-time reads and ``jax.jit``
program construction exist exactly once in the GNN serving stack (the
executor's warm-before-timing path), so no serving mode can quietly grow
its own compile cache or timed region again — the drift that produced
the old mode x axis matrix, where every new axis had to be hand-threaded
through ``infer_stream`` / ``infer_batched`` / ``infer_packed``
separately.  Since the SLO scheduler landed, the invariant is stricter:
scheduling logic runs entirely on the injectable ``serve/clock.py``
``Clock``, so *any* reference to the ``time`` module — including
wall-clock stamps via ``time.time`` — outside the executor and the clock
module is a determinism leak, not just a stray timer.

This checker walks every module under ``src/repro/serve/`` and fails on
any *reference* (not just call — aliasing counts) to:

  * ``time.perf_counter`` / ``time.monotonic`` / ``time.time`` (and
    their ``from time import ...`` forms) — a private timed region or a
    wall-clock read that would make scheduling non-reproducible;
  * ``jax.jit`` / bare ``jit`` (imported from jax) / ``pjit`` — a private
    compile path;

outside the sanctioned files.  Exemptions:

  * ``serve/executor.py`` — the one timing *and* compile path;
  * ``serve/clock.py`` — timing only: it wraps the real clock behind the
    injectable ``Clock`` interface (it is still checked for compile
    references — the clock must never grow a jit path);
  * ``serve/engine.py`` — compile only: the LM prefill/decode server is a
    separate serving stack with its own jitted prefill/decode programs,
    but its wall-time reads go through the injected ``Clock`` like
    everyone else's (it is still checked for timing references — the
    guard hole it used to enjoy is closed);
  * ``serve/aot.py`` — compile only: the persistent AOT cache
    deserializes finished executables (program construction by another
    name), and is — with the executor — the only serving module allowed
    near the lowering/serialization APIs.

Since the AOT cache landed, a fourth rule rides the walk: **executable
serialization is single-path**.  Any reference to
``jax.experimental.serialize_executable`` (module import, from-import of
``serialize`` / ``deserialize_and_load``, or attribute access through a
jax alias) outside ``serve/aot.py`` and ``serve/executor.py`` fails —
a module that serializes executables is a module that can quietly grow a
second persistence format with its own (unfingerprinted) invalidation
story.  The real calls live behind ``runtime/compat.py``'s
feature-detection; the serve/obs walk keeps everyone else out.

Since the pipelined execution mode landed, a third rule rides the same
walk: **threading is single-path too**.  Any import of ``threading`` /
``_thread`` / ``concurrent`` (including ``concurrent.futures``) outside
``serve/pipeline.py`` fails — the pipelined prepare/dispatch worker is
the one sanctioned threading surface, and everything else (scheduler,
executor, clock, telemetry) must stay single-threaded so VirtualClock
simulations remain bitwise deterministic.  ``serve/executor.py`` is NOT
exempt from this rule: it is walked too, with only its historical
timing/compile allowances.

The telemetry package ``src/repro/obs/`` is walked with the full rules
and no exemptions: spans and metrics may only read time through the
``Tracer``'s injected Clock, so a VirtualClock simulation stays bitwise
deterministic end to end, and the observability layer can never stage a
compile path or a worker thread of its own.

Exit code 1 with a per-reference report when anything times or compiles
out of bounds.

  python tools/check_engine_singlepath.py
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SERVE = ROOT / "src" / "repro" / "serve"
OBS = ROOT / "src" / "repro" / "obs"
ALLOWED = "executor.py"  # the one timing/compile path
TIMING_EXEMPT = {"clock.py"}  # the Clock interface: timing yes, compile no
# engine.py: the LM server's own jit pair; aot.py: executable
# (de)serialization is program construction by another name
COMPILE_EXEMPT = {"engine.py", "aot.py"}
THREADING_EXEMPT = {"pipeline.py"}  # the one sanctioned threading surface
SERIALIZE_EXEMPT = {"aot.py", "executor.py"}  # the one persistence surface
TIMING_ATTRS = {"perf_counter", "monotonic", "time"}  # of the time module
TIMING_NAMES = {"perf_counter", "monotonic", "time"}  # `from time import ...`
COMPILE_ATTRS = {"jit", "pjit"}  # of the jax module chain
COMPILE_NAMES = {"jit", "pjit"}  # bare `from jax import jit`
TIMING_MODULES = {"time"}
COMPILE_MODULES = {"jax", "jax.experimental.pjit"}
# executable-serialization surface: importing the module (any form) or
# reaching it through a jax alias is how a second persistence path
# starts, so the reference itself is the violation
SERIALIZE_MODULE = "jax.experimental.serialize_executable"
SERIALIZE_ATTRS = {"serialize_executable"}  # of the jax module chain
SERIALIZE_NAMES = {"serialize", "deserialize_and_load"}
# any import of these module trees is a threading violation: you cannot
# spawn a worker without importing one of them, so banning the import
# (every form: plain, aliased, from-import, submodule) suffices
THREADING_MODULES = {"threading", "_thread", "concurrent"}


def _attr_root(node: ast.AST):
    """Leftmost Name of a dotted attribute chain (``jax.lax.sort`` -> jax)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _bound_names(tree: ast.AST):
    """(timing-module aliases, compile-module aliases, from-imported names)
    — ``import time as t`` / ``import jax as j`` alias the module itself,
    so attribute checks must resolve through the alias too; from-imports
    map the bound name back to its origin (``as`` renames count)."""
    time_mods, jax_mods, names = set(), set(), {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                bound = alias.asname or alias.name.split(".")[0]
                if alias.name in TIMING_MODULES or alias.name.split(".")[0] in TIMING_MODULES:
                    time_mods.add(bound)
                if alias.name.split(".")[0] == "jax":
                    jax_mods.add(bound)
        elif isinstance(node, ast.ImportFrom):
            if node.module in TIMING_MODULES | COMPILE_MODULES | {SERIALIZE_MODULE}:
                for alias in node.names:
                    names[alias.asname or alias.name] = alias.name
    return time_mods, jax_mods, names


def _serialize_import(node: ast.AST):
    """The offending path when a node imports the executable-serialization
    module in any form (plain, aliased, or ``from jax.experimental
    import serialize_executable``)."""
    if isinstance(node, ast.Import):
        for alias in node.names:
            if alias.name == SERIALIZE_MODULE or \
                    alias.name.startswith(SERIALIZE_MODULE + "."):
                return alias.name
    elif isinstance(node, ast.ImportFrom) and node.module is not None:
        if node.module == SERIALIZE_MODULE or \
                node.module.startswith(SERIALIZE_MODULE + "."):
            return node.module
        for alias in node.names:
            if f"{node.module}.{alias.name}" == SERIALIZE_MODULE:
                return SERIALIZE_MODULE
    return None


def _threading_import(node: ast.AST):
    """The offending module path when a node imports from a banned
    threading module tree (root match: ``concurrent.futures`` counts)."""
    if isinstance(node, ast.Import):
        for alias in node.names:
            if alias.name.split(".")[0] in THREADING_MODULES:
                return alias.name
    elif isinstance(node, ast.ImportFrom) and node.module is not None:
        if node.module.split(".")[0] in THREADING_MODULES:
            return node.module
    return None


def check_module(path: Path, allow_timing: bool = False,
                 allow_compile: bool = False,
                 allow_threading: bool = False,
                 allow_serialize: bool = False) -> list[str]:
    """All violations in one module.  ``allow_timing`` skips the timing
    rules (for ``serve/clock.py``, which wraps the real clock) but never
    the compile rules; ``allow_compile`` is the inverse (for
    ``serve/engine.py``, whose prefill/decode jit pair is its own
    sanctioned surface) and never skips the timing rules;
    ``allow_threading`` skips the worker-thread import ban (for
    ``serve/pipeline.py`` only); ``allow_serialize`` skips the
    executable-serialization ban (for ``serve/aot.py`` and the
    executor) — each allowance skips nothing else."""
    try:
        rel = path.relative_to(ROOT)
    except ValueError:  # e.g. a tmp file under test
        rel = path
    try:
        tree = ast.parse(path.read_text(), filename=str(rel))
    except SyntaxError as err:  # pragma: no cover - tier-1 would fail first
        return [f"{rel}: unparsable ({err})"]
    time_mods, jax_mods, from_names = _bound_names(tree)
    errors = []
    for node in ast.walk(tree):
        bad = hint = None
        mod = _threading_import(node)
        if mod is not None and not allow_threading:
            errors.append(
                f"{rel}:{node.lineno}: import of {mod} outside "
                f"serve/pipeline.py — the pipelined prepare/dispatch worker "
                f"is the one sanctioned threading surface"
            )
            continue
        mod = _serialize_import(node)
        if mod is not None and not allow_serialize:
            errors.append(
                f"{rel}:{node.lineno}: import of {mod} outside "
                f"serve/aot.py — the AOT cache is the one executable-"
                f"persistence surface"
            )
            continue
        if isinstance(node, ast.Attribute):
            root = _attr_root(node)
            if node.attr in TIMING_ATTRS and root in time_mods:
                bad, hint = f"time.{node.attr} timing", "timing"
            elif node.attr in COMPILE_ATTRS and root in jax_mods:
                bad, hint = f"jax.{node.attr} program construction", "compile"
            elif node.attr in SERIALIZE_ATTRS and root in jax_mods:
                bad, hint = (f"jax...{node.attr} executable serialization",
                             "serialize")
        elif isinstance(node, ast.Name):
            origin = from_names.get(node.id)
            if origin in TIMING_NAMES:
                bad, hint = f"{origin} timing", "timing"
            elif origin in COMPILE_NAMES:
                bad, hint = f"{origin} program construction", "compile"
            elif origin in SERIALIZE_NAMES:
                bad, hint = f"{origin} executable serialization", "serialize"
        if bad is None or (hint == "timing" and allow_timing) \
                or (hint == "compile" and allow_compile) \
                or (hint == "serialize" and allow_serialize):
            continue
        fix = ("route timestamps through an injected serve/clock.py Clock"
               if hint == "timing"
               else "persist executables through serve/aot.py's AOTCache"
               if hint == "serialize"
               else "route through the Executor's warm/run pipeline instead")
        errors.append(
            f"{rel}:{node.lineno}: {bad} outside serve/executor.py — {fix}"
        )
    return errors


def main() -> int:
    errors = []
    checked = 0
    for path in sorted(SERVE.glob("*.py")):
        checked += 1
        # the executor is the sanctioned timing/compile path but gets no
        # threading pass — it is walked like everyone else for that rule
        sanctioned = path.name == ALLOWED
        errors.extend(check_module(
            path,
            allow_timing=sanctioned or path.name in TIMING_EXEMPT,
            allow_compile=sanctioned or path.name in COMPILE_EXEMPT,
            allow_threading=path.name in THREADING_EXEMPT,
            allow_serialize=path.name in SERIALIZE_EXEMPT,
        ))
    for path in sorted(OBS.glob("*.py")):
        checked += 1
        errors.extend(check_module(path))
    for e in errors:
        print(f"ERROR: {e}")
    if not errors:
        print(f"engine-singlepath check OK ({checked} serve/ + obs/ modules "
              f"share the executor's one timing/compile/threading path)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
