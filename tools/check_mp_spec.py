"""Guard: model layer bodies speak the (phi, A, gamma) contract only.

The fused megakernel (``kernels/fused_mp.py``) can only compile a layer
whose contract is *declarative* — an ``MPSpec`` plus operands, or the
closure form of ``core.message_passing.mp_layer`` with its named
aggregation helpers (``pna_aggregate``, ``dgn_aggregate``,
``gat_attention``).  A model that reaches past that contract and calls the
aggregation primitives directly re-creates the pre-refactor drift: its
layer silently stops being fusable and the fused/unfused A/B in
``benchmarks/bench_layout.py`` compares different computations.

This checker walks every module under ``src/repro/gnn/`` and fails on any
call, bare or attribute-qualified, to the aggregation primitives:

  * ``gather_scatter`` / ``segment_reduce`` / ``sorted_segment_reduce``
    (the core/kernels reduction entry points),
  * ``edge_softmax`` (GAT's primitive — reached via
    ``core.message_passing.gat_attention``, never directly),
  * ``segment_sum`` / ``sort_by_segment`` (the raw jax/core machinery).

Layer bodies route everything through ``core.message_passing`` —
``mp_layer`` (closure or spec form), ``global_pool``, and the named
aggregate helpers.  ``core/``, ``kernels/``, tests, and benchmarks are
exempt: they implement or deliberately A/B the primitives.

Exit code 1 with a per-call report on violation.

  python tools/check_mp_spec.py
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
GNN = ROOT / "src" / "repro" / "gnn"
BANNED = {
    "gather_scatter",
    "segment_reduce",
    "sorted_segment_reduce",
    "edge_softmax",
    "segment_sum",
    "sort_by_segment",
}


def _banned_call(func: ast.AST):
    """The offending name if this Call's func is a banned primitive."""
    if isinstance(func, ast.Name) and func.id in BANNED:
        return func.id
    if isinstance(func, ast.Attribute) and func.attr in BANNED:
        return func.attr
    return None


def check_module(path: Path) -> list[str]:
    try:
        rel = path.relative_to(ROOT)
    except ValueError:  # e.g. a tmp file under test
        rel = path
    try:
        tree = ast.parse(path.read_text(), filename=str(rel))
    except SyntaxError as err:  # pragma: no cover - tier-1 would fail first
        return [f"{rel}: unparsable ({err})"]
    errors = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _banned_call(node.func)
        if name is not None:
            errors.append(
                f"{rel}:{node.lineno}: model code calls aggregation "
                f"primitive `{name}` — go through core.message_passing "
                f"(mp_layer / MPSpec / the named aggregate helpers)"
            )
    return errors


def main() -> int:
    errors = []
    checked = 0
    for path in sorted(GNN.rglob("*.py")):
        checked += 1
        errors.extend(check_module(path))
    for e in errors:
        print(f"ERROR: {e}")
    if not errors:
        print(f"mp-spec contract check OK ({checked} modules under gnn/)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
