"""Streaming throughput: packed micro-batching vs one-graph-at-a-time.

The paper's real-time mode (batch-size-1 ``infer_stream``) pays one full
program dispatch per molecule; the scheduler packs a live stream into
shared padded buckets so the dispatch amortizes.  This bench sweeps
offered load (QPS) and reports, per point, the sustained throughput and
per-request latency percentiles — the latency-vs-throughput curve in
docs/SERVING.md is generated this way.

Acceptance checks (asserted when run standalone, reported-only when run
through the ``benchmarks.run`` driver so one noisy box can't abort the
other figure sections):
  * at equal base bucket sizes, packed streaming sustains >= 2x the
    graphs/sec of one-graph ``infer_stream`` (compute-time basis);
  * after the warmup pass, a second full sweep triggers zero recompiles
    (``engine.compile_seconds`` does not move).

  PYTHONPATH=src python benchmarks/bench_stream_throughput.py [n_graphs]
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro.data.pipeline import MOLHIV, MoleculeStream
from repro.gnn import init
from repro.gnn.models import paper_config
from repro.serve.gnn_engine import GNNEngine
from repro.serve.scheduler import StreamScheduler

MODEL = "gin"
CAPACITY = 16
MAX_WAIT_S = 0.002


def run(n_graphs: int = 64, strict: bool = True):
    cfg = paper_config(MODEL)
    params = init(jax.random.PRNGKey(0), cfg)
    eng = GNNEngine(cfg, params)
    graphs = MoleculeStream(MOLHIV, seed=0).take(n_graphs)

    # -- baseline: the paper's one-graph real-time mode (same buckets);
    # sustained graphs/sec = n / total compute, best of two passes to keep
    # a noisy-CPU spike from skewing the comparison
    _, lats_a, _ = eng.infer_stream([g[:4] for g in graphs])
    _, lats_b, _ = eng.infer_stream([g[:4] for g in graphs])
    base_gps = len(graphs) / float(min(np.sum(lats_a), np.sum(lats_b)))

    sched = StreamScheduler(eng, capacity=CAPACITY, max_wait_s=MAX_WAIT_S)

    # -- warmup pass: compiles every packed signature untimed
    sched.run(graphs, qps=0.0)
    warm_compile_s = eng.compile_seconds

    # -- saturation point: everything queued at t=0, pure compute
    # throughput (best of two passes, same noise rationale as above)
    sat = None
    for _ in range(2):
        rep = sched.run(graphs, qps=0.0)
        if sat is None or rep.compute_s < sat.compute_s:
            sat = rep
    packed_gps = sat.num_requests / sat.compute_s

    rows = [{
        "name": f"stream_{MODEL}_saturated",
        "graphs_per_s": round(packed_gps, 1),
        "derived": {
            "baseline_stream_gps": round(base_gps, 1),
            "amortization_x": round(packed_gps / base_gps, 2),
            "mean_batch": round(float(np.mean(sat.batch_sizes)), 2),
        },
    }]

    # -- offered-load sweep: latency vs throughput around the knee
    for frac in (0.25, 0.5, 1.0, 2.0):
        qps = frac * packed_gps
        rep = sched.run(graphs, qps=qps)
        rows.append({
            "name": f"stream_{MODEL}_qps{frac:g}x",
            "graphs_per_s": round(rep.graphs_per_s, 1),
            "derived": {
                "offered_qps": round(qps, 1),
                "p50_ms": round(rep.percentile_ms(50), 2),
                "p95_ms": round(rep.percentile_ms(95), 2),
                "p99_ms": round(rep.percentile_ms(99), 2),
                "mean_batch": round(float(np.mean(rep.batch_sizes)), 2),
                "flush_reasons": dict(rep.flush_reasons),
            },
        })

    # -- acceptance: amortization and zero recompiles after warmup
    amortized = packed_gps >= 2.0 * base_gps
    no_recompiles = eng.compile_seconds == warm_compile_s
    if strict:
        assert amortized, (
            f"packed streaming {packed_gps:.0f} graphs/s < 2x baseline {base_gps:.0f}"
        )
        assert no_recompiles, (
            f"recompiles after warmup: compile_seconds moved "
            f"{warm_compile_s:.3f} -> {eng.compile_seconds:.3f}"
        )
    elif not (amortized and no_recompiles):
        print(f"# WARNING: acceptance not met (amortized={amortized}, "
              f"no_recompiles={no_recompiles})")
    rows[0]["derived"]["recompile_s_after_warmup"] = round(
        eng.compile_seconds - warm_compile_s, 3
    )
    return rows


def main(strict: bool = False):
    # tolerate the benchmarks.run driver leaving its section name in argv
    n = int(sys.argv[1]) if len(sys.argv) > 1 and sys.argv[1].isdigit() else 64
    rows = run(n, strict=strict)
    for row in rows:
        print(f"{row['name']},{row['graphs_per_s']},{row['derived']}")
    return rows


if __name__ == "__main__":
    main(strict=True)
