"""Streaming throughput: packed micro-batching vs one-graph-at-a-time.

The paper's real-time mode (batch-size-1 ``infer_stream``) pays one full
program dispatch per molecule; the scheduler packs a live stream into
shared padded buckets so the dispatch amortizes.  This bench sweeps
offered load (QPS) and reports, per point, the sustained throughput and
per-request latency percentiles — the latency-vs-throughput curve in
docs/SERVING.md is generated this way.

Acceptance checks (asserted when run standalone, reported-only when run
through the ``benchmarks.run`` driver so one noisy box can't abort the
other figure sections):
  * at equal base bucket sizes, packed streaming sustains >= 2x the
    graphs/sec of one-graph ``infer_stream`` (compute-time basis);
  * after the warmup pass, a second full sweep triggers zero recompiles
    (``engine.compile_seconds`` does not move).

``--pipeline`` switches to the dispatch-ahead sweep: in-flight depth
{1, 2, 4} at 0.5x-2x of saturation with the real measured host-pack cost
folded into the virtual timeline (``host_cost="measured"``), recording
the pack/device overlap fraction from each point's trace.  At 0.5x load
it asserts the depth-1 free-host run is equivalent to the serial loop:
bitwise-equal outputs and the identical flush decision trace
(rids + reasons; timestamps differ only by re-measured device noise,
and ``start_s`` is definitionally the dispatch instant there).

  PYTHONPATH=src python benchmarks/bench_stream_throughput.py [n_graphs]
  PYTHONPATH=src python benchmarks/bench_stream_throughput.py --pipeline [n_graphs]
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro.data.pipeline import MOLHIV, MoleculeStream
from repro.gnn import init
from repro.gnn.models import paper_config
from repro.obs import Tracer
from repro.serve.clock import VirtualClock
from repro.serve.gnn_engine import GNNEngine
from repro.serve.pipeline import PipelineConfig, overlap_fraction
from repro.serve.scheduler import StreamScheduler

MODEL = "gin"
CAPACITY = 16
MAX_WAIT_S = 0.002


def run(n_graphs: int = 64, strict: bool = True):
    cfg = paper_config(MODEL)
    params = init(jax.random.PRNGKey(0), cfg)
    eng = GNNEngine(cfg, params)
    graphs = MoleculeStream(MOLHIV, seed=0).take(n_graphs)

    # -- baseline: the paper's one-graph real-time mode (same buckets);
    # sustained graphs/sec = n / total compute, best of two passes to keep
    # a noisy-CPU spike from skewing the comparison
    _, lats_a, _ = eng.infer_stream([g[:4] for g in graphs])
    _, lats_b, _ = eng.infer_stream([g[:4] for g in graphs])
    base_gps = len(graphs) / float(min(np.sum(lats_a), np.sum(lats_b)))

    sched = StreamScheduler(eng, capacity=CAPACITY, max_wait_s=MAX_WAIT_S)

    # -- warmup pass: compiles every packed signature untimed
    sched.run(graphs, qps=0.0)
    warm_compile_s = eng.compile_seconds

    # -- saturation point: everything queued at t=0, pure compute
    # throughput (best of two passes, same noise rationale as above)
    sat = None
    for _ in range(2):
        rep = sched.run(graphs, qps=0.0)
        if sat is None or rep.compute_s < sat.compute_s:
            sat = rep
    packed_gps = sat.num_requests / sat.compute_s

    rows = [{
        "name": f"stream_{MODEL}_saturated",
        "graphs_per_s": round(packed_gps, 1),
        "derived": {
            "baseline_stream_gps": round(base_gps, 1),
            "amortization_x": round(packed_gps / base_gps, 2),
            "mean_batch": round(float(np.mean(sat.batch_sizes)), 2),
        },
    }]

    # -- offered-load sweep: latency vs throughput around the knee
    for frac in (0.25, 0.5, 1.0, 2.0):
        qps = frac * packed_gps
        rep = sched.run(graphs, qps=qps)
        rows.append({
            "name": f"stream_{MODEL}_qps{frac:g}x",
            "graphs_per_s": round(rep.graphs_per_s, 1),
            "derived": {
                "offered_qps": round(qps, 1),
                "p50_ms": round(rep.percentile_ms(50), 2),
                "p95_ms": round(rep.percentile_ms(95), 2),
                "p99_ms": round(rep.percentile_ms(99), 2),
                "mean_batch": round(float(np.mean(rep.batch_sizes)), 2),
                "flush_reasons": dict(rep.flush_reasons),
            },
        })

    # -- acceptance: amortization and zero recompiles after warmup
    amortized = packed_gps >= 2.0 * base_gps
    no_recompiles = eng.compile_seconds == warm_compile_s
    if strict:
        assert amortized, (
            f"packed streaming {packed_gps:.0f} graphs/s < 2x baseline {base_gps:.0f}"
        )
        assert no_recompiles, (
            f"recompiles after warmup: compile_seconds moved "
            f"{warm_compile_s:.3f} -> {eng.compile_seconds:.3f}"
        )
    elif not (amortized and no_recompiles):
        print(f"# WARNING: acceptance not met (amortized={amortized}, "
              f"no_recompiles={no_recompiles})")
    rows[0]["derived"]["recompile_s_after_warmup"] = round(
        eng.compile_seconds - warm_compile_s, 3
    )
    return rows


def run_pipeline(n_graphs: int = 64, strict: bool = True):
    """``--pipeline``: dispatch-ahead depth sweep over offered load."""
    cfg = paper_config(MODEL)
    eng = GNNEngine(cfg, init(jax.random.PRNGKey(0), cfg))
    graphs = MoleculeStream(MOLHIV, seed=0).take(n_graphs)

    serial = StreamScheduler(eng, capacity=CAPACITY, max_wait_s=MAX_WAIT_S)
    serial.run(graphs, qps=0.0)  # warmup: compiles every rung untimed
    sat = None
    for _ in range(2):
        rep = serial.run(graphs, qps=0.0)
        if sat is None or rep.compute_s < sat.compute_s:
            sat = rep
    cap_gps = sat.num_requests / sat.compute_s

    # -- serial == depth-1 equivalence at 0.25x load: free host cost, same
    # arrivals.  Flush composition there is deadline/signature-driven (the
    # device is almost never the gate), so the decision trace must match
    # exactly and outputs must be bitwise-equal.  Timestamps are excluded
    # — each run re-measures live device seconds, and pipelined
    # ``start_s`` is the dispatch instant by definition.  One noisy pass
    # can still push ``device_free`` over a deadline and shift one bucket
    # boundary, so the pair retries a bounded number of times; the *exact*
    # scripted-time equivalence is pinned in tests/test_serve_pipeline.py.
    eq_qps = 0.25 * cap_gps
    decisions_equal = outputs_equal = False
    for _ in range(3):
        rep_ser = serial.run(graphs, qps=eq_qps)
        rep_d1 = StreamScheduler(
            eng, capacity=CAPACITY, max_wait_s=MAX_WAIT_S,
            pipeline=PipelineConfig(inflight=1, host_cost=None),
        ).run(graphs, qps=eq_qps)
        decisions_equal = (
            [(f.rids, f.reason) for f in rep_ser.flush_log]
            == [(f.rids, f.reason) for f in rep_d1.flush_log]
        )
        outputs_equal = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(rep_ser.outputs, rep_d1.outputs)
        )
        if decisions_equal and outputs_equal:
            break
    if strict:
        assert outputs_equal, "depth-1 pipelined outputs != serial"
        assert decisions_equal, (
            "depth-1 free-host flush decisions != serial at 0.5x load"
        )
    elif not (outputs_equal and decisions_equal):
        print(f"# WARNING: depth-1 equivalence not met "
              f"(outputs={outputs_equal}, decisions={decisions_equal})")

    rows = [{
        "name": f"stream_{MODEL}_pipe_equiv",
        "graphs_per_s": round(rep_d1.graphs_per_s, 1),
        "derived": {
            "serial_equals_depth1_outputs": outputs_equal,
            "serial_equals_depth1_decisions": decisions_equal,
            "offered_qps": round(eq_qps, 1),
        },
    }]

    # -- the sweep: real measured host-pack seconds on the virtual
    # timeline, per depth x load; overlap fraction from each trace
    tr = Tracer(VirtualClock())
    for depth in (1, 2, 4):
        sched = StreamScheduler(
            eng, capacity=CAPACITY, max_wait_s=MAX_WAIT_S, tracer=tr,
            pipeline=PipelineConfig(inflight=depth, host_cost="measured"),
        )
        for frac in (0.5, 1.0, 2.0):
            tr.clear()
            rep = sched.run(graphs, qps=frac * cap_gps)
            rows.append({
                "name": f"stream_{MODEL}_pipe_d{depth}_{frac:g}x",
                "graphs_per_s": round(rep.num_served / rep.makespan_s, 1),
                "derived": {
                    "inflight": depth,
                    "offered_qps": round(frac * cap_gps, 1),
                    "p50_ms": round(rep.percentile_ms(50), 2),
                    "p99_ms": round(rep.percentile_ms(99), 2),
                    "overlap_fraction": round(overlap_fraction(tr), 3),
                    "mean_batch": round(float(np.mean(rep.batch_sizes)), 2),
                },
            })
    return rows


def main(strict: bool = False):
    # tolerate the benchmarks.run driver leaving its section name in argv
    digits = [a for a in sys.argv[1:] if a.isdigit()]
    n = int(digits[0]) if digits else 64
    if "--pipeline" in sys.argv:
        rows = run_pipeline(n, strict=strict)
    else:
        rows = run(n, strict=strict)
    for row in rows:
        print(f"{row['name']},{row['graphs_per_s']},{row['derived']}")
    return rows


if __name__ == "__main__":
    main(strict=True)
