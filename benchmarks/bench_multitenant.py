"""Multi-tenant serving: one shared Executor vs N separate engines.

The executor refactor's serving claim, measured two ways:

  * **two-tenant mixed stream** (the acceptance case: gcn@int8 +
    gat@fp32) — one ``Executor`` + one ``StreamScheduler`` serving a
    round-robin mixed stream vs two stock ``GNNEngine`` +
    ``StreamScheduler`` pairs each serving their half.  Both arms use the
    same params per model, so per-request outputs are asserted
    *bitwise*-equal.  The shared arm warms its budget-ladder rungs
    traffic-driven (``prewarm="lazy"``: a (tenant, rung) program compiles
    — still strictly outside the timed region — only when the load first
    flushes it), while N independent engines must each eagerly warm their
    full ladder to guarantee zero recompiles under any load they might
    see alone.  One control plane seeing all tenants' traffic therefore
    compiles strictly fewer programs (asserted, deterministic) and spends
    less wall-clock warming (asserted in the full run; timing asserts are
    skipped under ``--smoke`` — a loaded CI box makes them flakes).  Both
    arms must serve a repeat pass with **zero recompiles** (asserted
    always).
  * **same-architecture tenant scaling** (N fine-tuned weight variants of
    one model, e.g. A/B serving) — programs are keyed by
    ``(cfg, precision, share_layout)``, never by parameter values, so N
    such tenants share ONE compiled program per rung where N separate
    engines hold N: the compile-cache (and executable-memory) footprint
    is N x smaller (asserted, deterministic, same eager prewarm on both
    arms for a like-for-like count).

  PYTHONPATH=src python benchmarks/bench_multitenant.py [--smoke]

``--smoke`` (CI) runs reduced configs and keeps every deterministic
assertion (program counts, bitwise parity, zero recompiles) while
skipping the wall-clock comparison; the committed full-run artifact
(BENCH_multitenant.json) is the perf claim.
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro.configs.gengnn_models import get_gnn_config
from repro.gnn import init
from repro.gnn.models import paper_config
from repro.serve.executor import Executor
from repro.serve.gnn_engine import GNNEngine
from repro.serve.scheduler import StreamScheduler

try:
    from benchmarks.bench_io import write_bench_json
except ImportError:  # executed as a script from benchmarks/
    from bench_io import write_bench_json

TENANTS = (("gcn", "int8"), ("gat", "fp32"))  # the acceptance pair
SAME_ARCH_N = 3
CAPACITY = 4
EVAL_SEED = 11
TIMING_REPS = 3  # min-of-k measured passes per arm (warm excluded already)


def _reduced(model):
    kw = dict(num_layers=2)
    if model == "gat":
        kw.update(heads=2, head_features=8)
    elif model in ("pna", "dgn"):
        kw.update(hidden=16, head_hidden=(8,))
    else:
        kw.update(hidden=16)
    return paper_config(model, **kw)


def _graphs(n_graphs, seed=EVAL_SEED, feat=9, edge=3):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_graphs):
        n = int(rng.integers(6, 24))
        e = int(rng.integers(n, 2 * n))
        out.append((
            rng.integers(0, n, e).astype(np.int32),
            rng.integers(0, n, e).astype(np.int32),
            rng.normal(size=(n, feat)).astype(np.float32),
            rng.normal(size=(e, edge)).astype(np.float32),
        ))
    return out


# ------------------------------------------------------- two-tenant mixed


def two_tenant(n_graphs: int, smoke: bool, strict: bool):
    cfgs = {
        m: (_reduced(m) if smoke else get_gnn_config(m)) for m, _ in TENANTS
    }
    params = {m: init(jax.random.PRNGKey(i), cfgs[m])
              for i, (m, _) in enumerate(TENANTS)}
    graphs = _graphs(n_graphs)
    names = [f"{m}:{p}" for m, p in TENANTS]
    models = [names[i % len(names)] for i in range(n_graphs)]

    # --- arm 1: N separate stock engines, each serving its own half ---
    sep_warm_s = 0.0
    sep_programs = 0
    sep_makespan_s = 0.0
    sep_outputs = {}
    for (m, prec), name in zip(TENANTS, names):
        eng = GNNEngine(cfgs[m], params[m], precision=prec)
        sched = StreamScheduler(eng, capacity=CAPACITY)
        mine = [g for g, tag in zip(graphs, models) if tag == name]
        sched.run(mine, qps=0.0)  # warm pass (eager full-ladder prewarm)
        sep_warm_s += eng.compile_seconds
        sep_programs += len(eng.executor._compiled)
        best = None
        for _ in range(TIMING_REPS):  # min-of-k: honest wall on a noisy box
            rep = sched.run(mine, qps=0.0)
            assert rep.compile_s == 0.0, f"{name}: separate engine recompiled"
            if best is None or rep.makespan_s < best.makespan_s:
                best = rep
        sep_makespan_s += best.makespan_s
        sep_outputs[name] = best.outputs

    # --- arm 2: one shared executor + one scheduler, mixed stream ---
    ex = Executor()
    for (m, prec), name in zip(TENANTS, names):
        ex.register(name, cfgs[m], params[m], precision=prec)
    sched = StreamScheduler(ex, capacity=CAPACITY)  # prewarm="lazy"
    sched.run(graphs, qps=0.0, models=models)  # warm pass (traffic-driven)
    shared_warm_s = ex.compile_seconds
    shared_programs = len(ex._compiled)
    rep = None
    for _ in range(TIMING_REPS):
        r = sched.run(graphs, qps=0.0, models=models)
        assert r.compile_s == 0.0, "shared executor recompiled after warmup"
        if rep is None or r.makespan_s < rep.makespan_s:
            rep = r

    # bitwise parity: same params, same per-tenant flush partitioning
    for name in names:
        mine = [o for o, tag in zip(rep.outputs, models) if tag == name]
        for i, (a, b) in enumerate(zip(mine, sep_outputs[name])):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{name} graph {i}: shared != separate",
            )

    derived = {
        "tenants": names,
        "n_graphs": n_graphs,
        "capacity": CAPACITY,
        "warm_s_shared": round(shared_warm_s, 3),
        "warm_s_separate": round(sep_warm_s, 3),
        "warm_speedup_x": round(sep_warm_s / max(shared_warm_s, 1e-9), 3),
        "programs_shared": shared_programs,
        "programs_separate": sep_programs,
        "graphs_per_s_shared": round(n_graphs / max(rep.makespan_s, 1e-12), 1),
        "graphs_per_s_separate": round(n_graphs / max(sep_makespan_s, 1e-12), 1),
        "recompile_s_after_warmup": 0.0,
        "bitwise_parity": True,
    }
    ok = shared_programs < sep_programs
    if strict:
        assert ok, f"shared ladder must warm fewer programs ({derived})"
        if not smoke:
            assert shared_warm_s < sep_warm_s, (
                f"shared-ladder warm time must beat {len(TENANTS)} separate "
                f"engines: {shared_warm_s:.2f}s vs {sep_warm_s:.2f}s"
            )
    elif not ok:  # pragma: no cover - report-only path
        print(f"# WARNING: multitenant acceptance not met ({derived})")
    return {"name": "multitenant_two_tenant", "us_per_call": 0.0,
            "derived": derived}


# ----------------------------------------------- same-architecture scaling


def same_arch(n_graphs: int, smoke: bool, strict: bool):
    """N weight-variant tenants of one architecture: the compile-cache
    footprint is the memory proxy — program count with eager prewarm on
    both arms, so the comparison is purely the sharing."""
    cfg = _reduced("gin") if smoke else get_gnn_config("gin")
    variants = [init(jax.random.PRNGKey(100 + i), cfg)
                for i in range(SAME_ARCH_N)]
    graphs = _graphs(n_graphs, seed=EVAL_SEED + 1)
    names = [f"gin@v{i}" for i in range(SAME_ARCH_N)]
    models = [names[i % SAME_ARCH_N] for i in range(n_graphs)]

    sep_programs = 0
    sep_warm_s = 0.0
    for name, p in zip(names, variants):
        eng = GNNEngine(cfg, p)
        sched = StreamScheduler(eng, capacity=CAPACITY)
        sched.run([g for g, tag in zip(graphs, models) if tag == name], qps=0.0)
        sep_programs += len(eng.executor._compiled)
        sep_warm_s += eng.compile_seconds

    ex = Executor()
    for name, p in zip(names, variants):
        ex.register(name, cfg, p)
    sched = StreamScheduler(ex, capacity=CAPACITY, prewarm="eager")
    sched.run(graphs, qps=0.0, models=models)
    shared_programs = len(ex._compiled)
    shared_warm_s = ex.compile_seconds
    rep = sched.run(graphs, qps=0.0, models=models)
    assert rep.compile_s == 0.0, "same-arch shared executor recompiled"

    derived = {
        "n_tenants": SAME_ARCH_N,
        "n_graphs": n_graphs,
        "programs_shared": shared_programs,
        "programs_separate": sep_programs,
        "program_footprint_ratio": round(sep_programs / max(shared_programs, 1), 2),
        "warm_s_shared": round(shared_warm_s, 3),
        "warm_s_separate": round(sep_warm_s, 3),
    }
    ok = sep_programs == SAME_ARCH_N * shared_programs
    if strict:
        assert ok, (
            f"{SAME_ARCH_N} same-arch tenants must share one program set "
            f"({derived})"
        )
    elif not ok:  # pragma: no cover - report-only path
        print(f"# WARNING: same-arch sharing not met ({derived})")
    return {"name": "multitenant_same_arch", "us_per_call": 0.0,
            "derived": derived}


# -------------------------------------------------------------------- run


def run(n_graphs: int, smoke: bool, strict: bool):
    rows = []
    for section in (two_tenant, same_arch):
        row = section(n_graphs, smoke, strict)
        rows.append(row)
        print(f"{row['name']},{row['us_per_call']},{row['derived']}", flush=True)
    return rows


# this bench writes its own BENCH json (below) so the assertion thresholds
# travel with the rows; the benchmarks.run driver must not also write one
WRITES_OWN_BENCH = True


def main(strict: bool = False):
    smoke = "--smoke" in sys.argv
    rows = run(n_graphs=12 if smoke else 48, smoke=smoke, strict=strict or smoke)
    # the smoke shape (CI) must not clobber the committed full-run artifact
    write_bench_json("multitenant_smoke" if smoke else "multitenant", rows,
                     config={"argv": sys.argv[1:], "tenants": [list(t) for t in TENANTS],
                             "same_arch_tenants": SAME_ARCH_N,
                             "capacity": CAPACITY, "timing_reps": TIMING_REPS,
                             "n_graphs": 12 if smoke else 48})
    return rows


if __name__ == "__main__":
    main(strict=True)
