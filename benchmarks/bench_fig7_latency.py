"""Fig. 7 analogue: per-graph inference latency for the six GNN models on
MolHIV/MolPCBA-statistics synthetic streams.

The paper measures on-board FPGA latency vs CPU/GPU baselines.  Offline,
no FPGA/GPU exists, so the reproducible claims are:
  (a) *generality*: all six models run unchanged through ONE engine;
  (b) engine (sorted-segment, O(N)-buffer) vs the dense-SpMM formulation
      (what GCN-only accelerators implement) — the paper's architectural
      comparison, both on the same backend;
  (c) batch-1 real-time mode vs padded batching (TPU-efficient mode).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.data.pipeline import MOLHIV, MOLPCBA, MoleculeStream
from repro.gnn import apply_dense, init, paper_config
from repro.serve.gnn_engine import GNNEngine

MODELS = ("gcn", "gin", "gin_vn", "gat", "pna", "dgn")
N_GRAPHS = 24


def _cfg(name):
    if name == "gin_vn":
        return paper_config("gin", virtual_node=True)
    return paper_config(name)


def run(dataset=MOLHIV, n_graphs=N_GRAPHS):
    rows = []
    key = jax.random.PRNGKey(0)
    graphs = MoleculeStream(dataset, seed=0).take(n_graphs)
    for name in MODELS:
        cfg = _cfg(name)
        params = init(key, cfg)
        eng = GNNEngine(cfg, params)
        outs, lats, compile_s = eng.infer_stream(
            [g[:4] for g in graphs], with_eigvec=(name == "dgn")
        )
        stream_us = float(np.mean(lats) * 1e6)
        # dense-SpMM baseline (per graph, padded to same bucket)
        from repro.core.graph import from_numpy

        dense_fn = jax.jit(lambda p, g, e: apply_dense(p, g, cfg, eigvec=e))
        lats_d = []
        for g in graphs:
            s, r, nf, ef = g[:4]
            nb, eb = eng._bucket_for(nf.shape[0], len(s))
            gp = from_numpy(s, r, nf, ef, n_pad=nb, e_pad=eb)
            eig = eng._eigvec(s, r, nf.shape[0], nb) if name == "dgn" else None
            dense_fn(params, gp, eig)[0].block_until_ready()  # compile/warm
            t0 = time.perf_counter()
            jax.block_until_ready(dense_fn(params, gp, eig))
            lats_d.append(time.perf_counter() - t0)
        dense_us = float(np.mean(lats_d) * 1e6)
        # batched mode
        _, per_graph_s = eng.infer_batched(
            graphs, batch_size=8, n_pad=8 * 64, e_pad=8 * 192,
            with_eigvec=(name == "dgn"),
        )
        rows.append({
            "name": f"fig7_{dataset.name}_{name}",
            "us_per_call": stream_us,
            "derived": {
                "dense_spmm_us": round(dense_us, 1),
                "engine_vs_dense_speedup": round(dense_us / stream_us, 2),
                "batched_us_per_graph": round(per_graph_s * 1e6, 1),
                "compile_s": round(compile_s, 2),
            },
        })
    return rows


def main():
    rows = run(MOLHIV) + run(MOLPCBA, n_graphs=12)
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    return rows


if __name__ == "__main__":
    main()
