"""Roofline table: reads the dry-run JSON records (launch/dryrun.py must
have run) and prints the per-(arch x shape x mesh) three-term analysis."""
from __future__ import annotations

import glob
import json
import os

OUT_DIR = os.path.join(os.path.dirname(__file__), "../experiments/dryrun")


def load_records(tag=""):
    recs = []
    for path in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("tag", "") != tag:
            continue
        recs.append(r)
    return recs


def run():
    rows = []
    for r in load_records():
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh'] if 'mesh' in r else ''}"
        if r.get("error"):
            rows.append({"name": name, "us_per_call": -1.0,
                         "derived": {"error": r["error"][:120]}})
            continue
        if r.get("skipped"):
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": {"skipped": r["skipped"][:80]}})
            continue
        rf = r["roofline"]
        rows.append({
            "name": name,
            "us_per_call": rf["step_lower_bound_s"] * 1e6,
            "derived": {
                "bound": rf["bound"],
                "compute_s": round(rf["compute_s"], 4),
                "memory_s": round(rf["memory_s"], 4),
                "collective_s": round(rf["collective_s"], 4),
                "roofline_fraction": round(rf["roofline_fraction"], 4),
                "useful_flops_ratio": round(rf.get("useful_flops_ratio", 0), 3),
            },
        })
    return rows


def main():
    rows = run()
    if not rows:
        print("roofline_no_dryrun_records,0.0,{'hint': 'run python -m repro.launch.dryrun --all first'}")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    return rows


if __name__ == "__main__":
    main()
