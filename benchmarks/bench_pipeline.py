"""Async host/device pipelining: dispatch-ahead vs the serial flush loop.

The serial scheduler alternates host and device: pack flush k, run it,
block, pack k+1 — at capacity the device idles for the whole host gap
(pack + eigvec + unpack + bookkeeping) between flushes.  The pipelined
mode (``StreamScheduler(pipeline=...)``) dispatches ahead through a
bounded in-flight window while a single modeled prepare worker packs the
next flush under the running one.

Methodology (honest on a 1-core CI box, where live threads cannot
actually overlap): the *measured* inputs are real — per-flush device
seconds from the serial saturation run and the serial host gap
``g = (wall - device) / flushes`` measured around it — and the speedup
claim is evaluated on the virtual timeline those costs are folded into:

  * serial-modeled:    ``PipelineConfig(inflight=1, host_cost=g,
    overlap=False)`` — each pack gates on the device going idle, which
    is exactly the serial loop's inline-blocking host;
  * pipelined-modeled: ``PipelineConfig(inflight=2, host_cost=g)`` — the
    prepare worker packs ahead, the window dispatches ahead.

Per-flush device time is re-measured live in both runs through the same
executor path, so the comparison differs only in timeline placement.
The expected ratio is ``(g + d) / max(g, d)`` for host gap g and flush
compute d.  A live threaded ``PipelinedStream`` row is reported too
(not gated — with one core the OS serializes the threads).

Acceptance (asserted standalone, reported-only under the ``run`` driver):
  * modeled pipelined throughput >= 1.5x modeled serial at saturation;
  * unloaded (0.25x capacity) modeled p50 within 5% of serial-modeled;
  * pipelined outputs bitwise-equal to the serial scheduler's for all
    six models (gcn, gin, gin+vn, gat, pna, dgn);
  * zero recompiles after warmup across the sweep;
  * overlap fraction > 0 recorded from the pipelined run's trace.

  PYTHONPATH=src python benchmarks/bench_pipeline.py [n_graphs] [--smoke]
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.data.pipeline import MOLHIV, MoleculeStream
from repro.gnn import init
from repro.gnn.models import paper_config
from repro.obs import Tracer
from repro.serve.clock import VirtualClock
from repro.serve.gnn_engine import GNNEngine
from repro.serve.pipeline import PipelineConfig, PipelinedStream, overlap_fraction
from repro.serve.scheduler import StreamScheduler

MODEL = "gin"
CAPACITY = 8
MAX_WAIT_S = 0.002
PARITY_MODELS = (("gcn", False), ("gin", False), ("gin", True),
                 ("gat", False), ("pna", False), ("dgn", False))


def _reduced(model, vn):
    base = dict(num_layers=2, virtual_node=vn)
    if model == "gat":
        base.update(heads=2, head_features=8)
    elif model in ("pna", "dgn"):
        base.update(hidden=16, head_hidden=(8,))
    else:
        base.update(hidden=16)
    return paper_config(model, **base)


def _parity_rows(graphs, smoke):
    """Serial vs pipelined scheduler, bitwise, per model.  Reduced configs
    keep the six-model sweep affordable; the executor path exercised is
    identical to the full-size one."""
    rows = []
    models = PARITY_MODELS[:2] if smoke else PARITY_MODELS
    for model, vn in models:
        cfg = _reduced(model, vn)
        eng = GNNEngine(cfg, init(jax.random.PRNGKey(0), cfg),
                        buckets=((64, 128), (128, 256)))
        eig = model == "dgn"
        ser = StreamScheduler(eng, capacity=4, max_wait_s=MAX_WAIT_S,
                              with_eigvec=eig).run(graphs)
        pipe = StreamScheduler(eng, capacity=4, max_wait_s=MAX_WAIT_S,
                               with_eigvec=eig,
                               pipeline=PipelineConfig(inflight=2)).run(graphs)
        bitwise = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(ser.outputs, pipe.outputs)
        )
        assert bitwise, f"{model}{'+vn' if vn else ''}: pipelined != serial"
        rows.append({
            "name": f"pipeline_parity_{model}{'_vn' if vn else ''}",
            "graphs_per_s": round(pipe.graphs_per_s, 1),
            "derived": {"bitwise_equal": bitwise,
                        "flushes": len(pipe.flush_log)},
        })
    return rows


def run(n_graphs: int = 256, strict: bool = True, smoke: bool = False):
    graphs = MoleculeStream(MOLHIV, seed=0).take(n_graphs)
    rows = _parity_rows(graphs[: min(n_graphs, 32)], smoke)

    cfg = paper_config(MODEL)
    eng = GNNEngine(cfg, init(jax.random.PRNGKey(0), cfg))
    serial = StreamScheduler(eng, capacity=CAPACITY, max_wait_s=MAX_WAIT_S)
    serial.run(graphs, qps=0.0)  # warmup: compiles every rung untimed
    warm_compile_s = eng.compile_seconds

    # -- serial saturation: measure the host gap the pipeline can hide.
    # Best of two passes so a noisy-CPU spike can't skew the model inputs.
    g = sat = None
    for _ in range(2):
        t0 = time.perf_counter()
        rep = serial.run(graphs, qps=0.0)
        wall = time.perf_counter() - t0
        gap = max(0.0, wall - rep.compute_s) / max(len(rep.flush_log), 1)
        if g is None or gap < g:
            g, sat = gap, rep
    d = sat.compute_s / max(len(sat.flush_log), 1)
    cap_gps = sat.num_served / max(sat.compute_s, 1e-9)

    def modeled(pipeline, qps):
        s = StreamScheduler(eng, capacity=CAPACITY, max_wait_s=MAX_WAIT_S,
                            pipeline=pipeline, clock=VirtualClock())
        return s.run(graphs, qps=qps)

    # -- the gated comparison: same measured costs, different placement
    ser_m = modeled(PipelineConfig(inflight=1, host_cost=g, overlap=False),
                    qps=0.0)
    tr = Tracer(VirtualClock())
    pipe_sched = StreamScheduler(eng, capacity=CAPACITY, max_wait_s=MAX_WAIT_S,
                                 pipeline=PipelineConfig(inflight=2, host_cost=g),
                                 clock=VirtualClock(), tracer=tr)
    pipe_m = pipe_sched.run(graphs, qps=0.0)
    speedup = ser_m.makespan_s / max(pipe_m.makespan_s, 1e-12)
    frac = overlap_fraction(tr)

    # -- unloaded: at 0.25x capacity the pipeline must not tax latency.
    # Mean over all served requests (not p50 — a single flush's jitter),
    # best of two passes per mode: each run re-measures live device time,
    # so the comparison must average that noise out, not resample it.
    def mean_lat(pipeline):
        return min(
            float(np.nanmean(modeled(pipeline, qps=0.25 * cap_gps).latencies_s))
            for _ in range(2)
        )

    ser_lo = mean_lat(PipelineConfig(inflight=1, host_cost=g, overlap=False))
    pipe_lo = mean_lat(PipelineConfig(inflight=2, host_cost=g))
    lat_ratio = pipe_lo / max(ser_lo, 1e-9)

    # -- depth sweep at saturation (modeled)
    by_depth = {}
    for depth in (1, 2, 4):
        rep = modeled(PipelineConfig(inflight=depth, host_cost=g), qps=0.0)
        by_depth[depth] = rep
        rows.append({
            "name": f"pipeline_{MODEL}_modeled_depth{depth}",
            "graphs_per_s": round(rep.num_served / rep.makespan_s, 1),
            "derived": {"makespan_ms": round(rep.makespan_s * 1e3, 2),
                        "p99_ms": round(rep.percentile_ms(99), 2)},
        })

    # the zero-recompile acceptance covers the packed sweep above; the
    # stream-mode section below compiles its own one-graph bucket
    # programs, so it is warmed separately before anything is timed
    no_recompiles = eng.compile_seconds == warm_compile_s
    sweep_recompile_s = eng.compile_seconds - warm_compile_s

    # -- live threaded run (reported, not gated: 1 CPU core serializes)
    eng.infer_stream(graphs)  # warm every stream-mode bucket untimed
    base_t0 = time.perf_counter()
    eng.infer_stream(graphs)
    serial_stream_wall = time.perf_counter() - base_t0
    _, stats = PipelinedStream(eng.executor, model=eng.name,
                               inflight=2).run(graphs)
    speedup_ok = speedup >= 1.5
    latency_ok = lat_ratio <= 1.05
    overlap_ok = frac > 0.0
    rows.insert(0, {
        "name": f"pipeline_{MODEL}_speedup",
        "graphs_per_s": round(pipe_m.num_served / pipe_m.makespan_s, 1),
        "derived": {
            "modeled_speedup_x": round(speedup, 3),
            "host_gap_ms": round(g * 1e3, 3),
            "mean_flush_ms": round(d * 1e3, 3),
            "expected_bound_x": round((g + d) / max(g, d, 1e-9), 3),
            "overlap_fraction": round(frac, 3),
            "unloaded_lat_ratio": round(lat_ratio, 4),
            "serial_modeled_gps": round(ser_m.num_served / ser_m.makespan_s, 1),
            "live_stream_serial_gps": round(len(graphs) / serial_stream_wall, 1),
            "live_stream_pipelined_gps": round(stats["graphs_per_s"], 1),
            "live_peak_inflight": stats["peak_inflight"],
            "recompile_s_after_warmup": round(sweep_recompile_s, 3),
            "speedup_ok": speedup_ok,
            "unloaded_latency_ok": latency_ok,
        },
    })
    if strict:
        assert speedup_ok, (
            f"modeled pipelined speedup {speedup:.2f}x < 1.5x at saturation "
            f"(host gap {g * 1e3:.2f}ms, flush {d * 1e3:.2f}ms) — "
            f"dispatch-ahead is not hiding the host gap"
        )
        assert latency_ok, (
            f"unloaded p50 ratio {lat_ratio:.3f} > 1.05 — pipelining must "
            f"be free when the device is idle"
        )
        assert overlap_ok, "trace recorded no pack/device overlap"
        assert no_recompiles, (
            f"recompiles after warmup: compile_seconds moved "
            f"{warm_compile_s:.3f} -> {eng.compile_seconds:.3f}"
        )
        # modeled depth-1 pipelining never beats depth-2 (window gates
        # dispatch, not pack) and depth 4 adds nothing over 2 with one
        # prepare worker + one device
        assert by_depth[2].makespan_s <= by_depth[1].makespan_s + 1e-9
    elif not (speedup_ok and latency_ok and overlap_ok and no_recompiles):
        print(f"# WARNING: acceptance not met (speedup={speedup:.2f}x, "
              f"lat_ratio={lat_ratio:.3f}, overlap={frac:.3f}, "
              f"no_recompiles={no_recompiles})")
    return rows


def main(strict: bool = False):
    smoke = "--smoke" in sys.argv
    digits = [a for a in sys.argv[1:] if a.isdigit()]
    n = int(digits[0]) if digits else (32 if smoke else 192)
    rows = run(n, strict=strict, smoke=smoke)
    for row in rows:
        print(f"{row['name']},{row['graphs_per_s']},{row['derived']}")
    return rows


if __name__ == "__main__":
    main(strict="--smoke" not in sys.argv)
