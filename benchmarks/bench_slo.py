"""SLO-aware admission under overload: p99 holds, goodput degrades gracefully.

Sweeps offered load from 0.5x to 2x of the measured saturation capacity
with a per-request SLO and backpressure enabled, and reports per point:
goodput (served graphs/s), served-latency percentiles, shed rate, and
deadline misses.  Without admission control, offered load beyond
capacity grows the queue without bound and p99 diverges; with it, the
scheduler sheds the excess at arrival (typed ``Shed`` results) and the
p99 of *served* requests stays inside the SLO while goodput plateaus at
capacity instead of collapsing.

A second section exercises ``adapt_ladder``: the rung geometry re-fits
to the observed flush-size histogram, and the row reports the geometry
before/after convergence plus any compile cost the refit incurred.

Acceptance (asserted standalone, reported-only under the ``run`` driver):
  * at 2x overload, p99 of served requests <= the SLO;
  * goodput at 2x overload >= 0.6x goodput at 1x (graceful, not a cliff);
  * overload sheds (the queue is actually bounded) but never everything;
  * zero recompiles after warmup across the whole sweep.

  PYTHONPATH=src python benchmarks/bench_slo.py [n_graphs] [--smoke]
"""
from __future__ import annotations

import sys
from collections import Counter

import jax
import numpy as np

from repro.data.pipeline import MOLHIV, MoleculeStream
from repro.gnn import init
from repro.gnn.models import paper_config
from repro.obs.metrics import MetricsRegistry
from repro.serve.gnn_engine import GNNEngine
from repro.serve.scheduler import StreamScheduler

MODEL = "gin"
CAPACITY = 8
MAX_WAIT_S = 0.002
ADMIT_MARGIN = 0.7
FRACS = (0.5, 0.75, 1.0, 1.5, 2.0)


def _point_row(name, rep, qps, slo_s):
    return {
        "name": name,
        "graphs_per_s": round(rep.graphs_per_s, 1),
        "derived": {
            "offered_qps": round(qps, 1),
            "slo_ms": round(slo_s * 1e3, 2),
            "p50_ms": round(rep.percentile_ms(50), 2),
            "p95_ms": round(rep.percentile_ms(95), 2),
            "p99_ms": round(rep.percentile_ms(99), 2),
            "served": rep.num_served,
            "shed": rep.num_shed,
            "shed_rate": round(rep.shed_rate, 3),
            "deadline_misses": rep.deadline_misses,
            "shed_reasons": dict(Counter(x.reason for x in rep.shed)),
            "mean_batch": round(float(np.mean(rep.batch_sizes)), 2)
            if rep.batch_sizes else 0.0,
        },
    }


def run(n_graphs: int = 256, strict: bool = True, smoke: bool = False):
    cfg = paper_config(MODEL)
    params = init(jax.random.PRNGKey(0), cfg)
    eng = GNNEngine(cfg, params)
    graphs = MoleculeStream(MOLHIV, seed=0).take(n_graphs)

    # -- capacity probe: best-effort saturation (everything queued at t=0,
    # no SLO), best of two passes so one noisy-CPU spike can't skew the
    # load points derived from it
    probe = StreamScheduler(eng, capacity=CAPACITY, max_wait_s=MAX_WAIT_S)
    probe.run(graphs, qps=0.0)  # warmup: compiles every rung untimed
    sat = None
    for _ in range(2):
        rep = probe.run(graphs, qps=0.0)
        if sat is None or rep.compute_s < sat.compute_s:
            sat = rep
    cap_gps = sat.num_served / sat.compute_s
    mean_flush_s = sat.compute_s / max(len(sat.batch_sizes), 1)
    # generous but bounded: an admitted request must be able to clear the
    # queue-projection plus batching wait plus one real flush
    slo_s = max(0.02, 10.0 * mean_flush_s)

    # the guard band absorbs full-bucket flushes that legitimately insert
    # ahead of a deadline-waiting batch after its members were admitted.
    # The attached registry double-counts nothing: StreamReport aggregates
    # and registry counters are views over the same flush/shed events, and
    # the consistency assert below pins that.
    registry = MetricsRegistry()
    sched = StreamScheduler(
        eng, capacity=CAPACITY, max_wait_s=MAX_WAIT_S,
        slo_s=slo_s, admit_limit=4 * CAPACITY, admit_margin=ADMIT_MARGIN,
        service_s=mean_flush_s, metrics=registry,
    )
    warm_compile_s = eng.compile_seconds

    rows = [{
        "name": f"slo_{MODEL}_capacity",
        "graphs_per_s": round(cap_gps, 1),
        "derived": {
            "slo_ms": round(slo_s * 1e3, 2),
            "mean_flush_ms": round(mean_flush_s * 1e3, 3),
            "admit_limit": 4 * CAPACITY,
            "admit_margin": ADMIT_MARGIN,
        },
    }]
    fracs = (0.5, 2.0) if smoke else FRACS
    by_frac = {}
    for frac in fracs:
        qps = frac * cap_gps
        rep = sched.run(graphs, qps=qps)
        by_frac[frac] = rep
        rows.append(_point_row(f"slo_{MODEL}_load{frac:g}x", rep, qps, slo_s))

    # -- acceptance
    over = by_frac[2.0]
    p99_ok = over.percentile_ms(99) <= slo_s * 1e3
    served_floor = over.num_served > 0
    sheds_under_overload = over.num_shed > 0
    graceful = True
    if 1.0 in by_frac:
        graceful = over.graphs_per_s >= 0.6 * by_frac[1.0].graphs_per_s
    no_recompiles = eng.compile_seconds == warm_compile_s
    # -- telemetry consistency: the registry counts the sweep's events
    # exactly as the reports do (two surfaces, one record stream).
    # Always asserted — a divergence is a bookkeeping bug, not noise.
    reps = list(by_frac.values())
    reg_counts = tuple(int(registry.get(n).total()) for n in (
        "serve_served_total", "serve_shed_total",
        "serve_deadline_misses_total", "serve_flushes_total"))
    rep_counts = (sum(r.num_served for r in reps),
                  sum(r.num_shed for r in reps),
                  sum(r.deadline_misses for r in reps),
                  sum(len(r.flush_log) for r in reps))
    assert reg_counts == rep_counts, (
        f"registry {reg_counts} != StreamReport {rep_counts} for "
        f"(served, shed, misses, flushes) — the two telemetry surfaces "
        f"must be views over the same events"
    )
    rows[0]["derived"].update({
        "p99_within_slo_at_2x": p99_ok,
        "graceful_degradation": graceful,
        "sheds_under_overload": sheds_under_overload,
        "recompile_s_after_warmup": round(eng.compile_seconds - warm_compile_s, 3),
        "registry_consistent": reg_counts == rep_counts,
    })
    if strict:
        assert p99_ok, (
            f"p99 {over.percentile_ms(99):.2f}ms exceeds SLO {slo_s * 1e3:.2f}ms "
            f"at 2x overload — admission control is not holding the line"
        )
        assert sheds_under_overload and served_floor, (
            f"2x overload should shed some and serve some "
            f"(served={over.num_served}, shed={over.num_shed})"
        )
        assert graceful, (
            f"goodput cliff at 2x: {over.graphs_per_s:.0f} < 0.6x of 1x point"
        )
        assert no_recompiles, (
            f"recompiles after warmup: compile_seconds moved "
            f"{warm_compile_s:.3f} -> {eng.compile_seconds:.3f}"
        )
    elif not (p99_ok and graceful and sheds_under_overload and no_recompiles):
        print(f"# WARNING: acceptance not met (p99_ok={p99_ok}, "
              f"graceful={graceful}, sheds={sheds_under_overload}, "
              f"no_recompiles={no_recompiles})")

    # -- adaptive ladder: geometry converges to observed demand (its lazy
    # rung warms are untimed but tracked, so report them rather than
    # folding them into the sweep's zero-recompile acceptance)
    if not smoke:
        ad = StreamScheduler(
            eng, capacity=CAPACITY, max_wait_s=MAX_WAIT_S, slo_s=slo_s,
            adapt_ladder=True, refit_every=8, max_rungs=4,
            service_s=mean_flush_s,
        )
        compile_before = eng.compile_seconds
        ad.run(graphs, qps=cap_gps)  # first pass: observe + refit
        sig = max(ad._ladders, key=lambda k: len(ad._obs_multiples.get(k, [])),
                  default=None)
        rep = ad.run(graphs, qps=cap_gps)  # converged geometry
        rows.append({
            "name": f"slo_{MODEL}_adaptive",
            "graphs_per_s": round(rep.graphs_per_s, 1),
            "derived": {
                "ladder_multiples": ad.ladder_multiples(sig) if sig else [],
                "max_rungs": 4,
                "p99_ms": round(rep.percentile_ms(99), 2),
                "refit_compile_s": round(eng.compile_seconds - compile_before, 3),
            },
        })
    return rows


def main(strict: bool = False):
    smoke = "--smoke" in sys.argv
    digits = [a for a in sys.argv[1:] if a.isdigit()]
    # the full stream must be long enough that a 2x burst outruns the SLO
    # (the backlog grows at ~capacity graphs/s of deficit; a short stream
    # drains before the projection ever exceeds the budget)
    n = int(digits[0]) if digits else (24 if smoke else 256)
    rows = run(n, strict=strict, smoke=smoke)
    for row in rows:
        print(f"{row['name']},{row['graphs_per_s']},{row['derived']}")
    return rows


if __name__ == "__main__":
    main(strict="--smoke" not in sys.argv)
