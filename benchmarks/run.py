"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines and, per section, writes the
same rows machine-readably as ``BENCH_<section>.json`` (schema: name,
config, metrics, timestamp — see benchmarks/bench_io.py) so the perf
trajectory is tracked across PRs.  Sections:
  fig7   per-model GNN inference latency (engine vs dense-SpMM, stream vs batch)
  stream packed micro-batched streaming vs one-graph mode (QPS sweep)
  slo    SLO-aware admission: overload sweep (p99 holds, goodput plateaus)
  pipeline  dispatch-ahead execution: modeled speedup vs serial host gap
  fig8   large-graph DGN (Cora/CiteSeer/PubMed sizes)
  fig9   NE/MP pipelining speed-ups (sweep + MolHIV + virtual node)
  table4 per-model resource footprint (params/FLOPs/bytes/VMEM tiles)
  quant  fp32 vs int8/ap_fixed: logit error + packed throughput
  layout shared GraphLayout plan: sort counts + stream latency + recompiles
  multitenant  shared Executor vs N separate engines (warm time, programs)
  coldstart  AOT cache: cold vs warm-disk restart (subprocess), flag deltas
  roofline  per-(arch x shape x mesh) dry-run roofline terms
"""
import sys


def main() -> None:
    sections = sys.argv[1:] or [
        "fig9", "table4", "fig8", "fig7", "stream", "slo", "pipeline",
        "quant", "layout", "multitenant", "coldstart", "roofline"
    ]
    from benchmarks import (
        bench_coldstart,
        bench_fig7_latency,
        bench_fig8_large_graph,
        bench_fig9_pipeline,
        bench_layout,
        bench_multitenant,
        bench_pipeline,
        bench_quant,
        bench_roofline,
        bench_slo,
        bench_stream_throughput,
        bench_table4_resources,
    )
    from benchmarks.bench_io import write_bench_json

    mods = {
        "fig7": bench_fig7_latency,
        "fig8": bench_fig8_large_graph,
        "fig9": bench_fig9_pipeline,
        "table4": bench_table4_resources,
        "stream": bench_stream_throughput,
        "slo": bench_slo,
        "pipeline": bench_pipeline,
        "quant": bench_quant,
        "layout": bench_layout,
        "multitenant": bench_multitenant,
        "coldstart": bench_coldstart,
        "roofline": bench_roofline,
    }
    for s in sections:
        print(f"# --- {s} ---", flush=True)
        rows = mods[s].main()
        if rows and not getattr(mods[s], "WRITES_OWN_BENCH", False):
            write_bench_json(s, rows, config={"argv": sys.argv[1:]})


if __name__ == '__main__':
    main()
