"""Table 4 analogue: per-model resource footprint.

FPGA resources (LUT/FF/BRAM/DSP) have no TPU meaning; the TPU-native
equivalents reported per GNN model are: parameter bytes, per-graph FLOPs,
bytes accessed (jitted on this backend), and the kernels' VMEM working set
per grid cell (from BlockSpec shapes — the analogue of BRAM allocation).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.graph import batch_graphs
from repro.data.pipeline import MOLHIV, MoleculeStream
from repro.gnn import apply, init, paper_config

MODELS = ("gcn", "gin", "gin_vn", "gat", "pna", "dgn")

# kernels' VMEM tile bytes: (block shapes x dtype) per pallas_call grid cell
KERNEL_VMEM = {
    "segment_reduce": (256 * 128 + 128 * 128 + 256 * 1) * 4,  # msgs + out + ids
    "node_mlp": (128 * 128 * 3 + 128) * 4,  # x, w, acc tiles + bias row
}


def _cfg(name):
    if name == "gin_vn":
        return paper_config("gin", virtual_node=True)
    return paper_config(name)


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    graphs = [g[:4] for g in MoleculeStream(MOLHIV, seed=0).take(8)]
    g = batch_graphs(graphs, n_pad=8 * 64, e_pad=8 * 192)
    eig = jax.numpy.zeros((8 * 64,), jax.numpy.float32)
    for name in MODELS:
        cfg = _cfg(name)
        params = init(key, cfg)
        n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        fn = jax.jit(lambda p, gg, ee: apply(p, gg, cfg, eigvec=ee))
        compiled = fn.lower(params, g, eig).compile()
        ca = compiled.cost_analysis() or {}
        rows.append({
            "name": f"table4_{name}",
            "us_per_call": 0.0,
            "derived": {
                "params": n_params,
                "param_bytes": n_params * 4,
                "flops_per_batch8": int(ca.get("flops", 0)),
                "bytes_per_batch8": int(ca.get("bytes accessed", 0)),
                "kernel_vmem_bytes": KERNEL_VMEM,
            },
        })
    return rows


def main():
    rows = run()
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    return rows


if __name__ == "__main__":
    main()
