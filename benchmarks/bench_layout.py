"""Shared-GraphLayout plan: sort counts and forward latency (paper §3.4).

The tentpole claim of the one-sort-per-graph refactor, measured three ways:

  * **sort count, trace level** — ``sort`` ops in the forward jaxpr per
    model: the seed per-call-sort path re-sorts in every aggregation
    (5-16 per forward), the shared plan built in-forward has exactly 1,
    a pack-time plan handed into the program has 0.  Asserted.
  * **sort count, compiled level** — the same scan over the compiled HLO.
    XLA's CSE already deduplicates the seed path's *identical* per-layer
    sorts on this backend, which is exactly why the plan must be
    structural: CSE is an optimizer courtesy that evaporates under
    ``lax.scan`` over layers, donated buffers, or non-identical key
    recomputation — and it can never remove the *last* sort, while the
    pack-time plan compiles to a program with **zero** sort ops.
    Asserted: shared <= 1, preplanned == 0.
  * **single-graph latency** — interleaved min-of-k timing (the only
    honest wall-clock on a noisy shared box) of one large graph
    (N=8192, E=32768, where one O(E log E) sort is a real fraction of
    the forward) for the sort-heavy models GAT/PNA/DGN: seed path vs
    the preplanned zero-sort program.  Asserted >= ``MIN_SPEEDUP``.
    Molecule-scale stream latencies through the full engine are also
    reported (unasserted: at 32-node scale, dispatch overhead and box
    noise dominate any sort arithmetic).

Also asserted: a second scheduler pass over a packed stream adds zero
compile seconds — the plan rides the existing bucket signature, so
layout threading introduces no recompiles.

  PYTHONPATH=src python benchmarks/bench_layout.py [--smoke] [--fused]

``--smoke`` (CI) keeps every deterministic assertion (sort counts, zero
recompiles) and skips the wall-clock sweep — timing asserts on a loaded
CI box are flakes, the committed full-run artifact is the perf claim.

``--fused`` measures the megakernel lowering instead (the PR on top of
the plan: one (phi, A, gamma) pass per layer, ``kernels/fused_mp.py``):

  * deterministic — fused == unfused **bitwise** in fp32 per model, the
    fused preplanned jaxpr still has zero sorts, and fused traffic adds
    zero recompiles after warmup (it rides the same bucket signatures);
  * wall-clock (full run) — interleaved min-of-k fused vs unfused on the
    preplanned large graph; asserted **on TPU backends**: fused is not
    slower on at least ``FUSED_MIN_WINS`` of the six models (GAT opts
    out — its ratio is pure noise around 1.0 — and at molecule scale
    dispatch noise swamps the fusion win, hence a wins-count not a
    per-model floor).  Off-TPU the ratios are recorded as evidence,
    like the int8 gate below: on CPU ``mode="auto"`` runs the fused
    *reference* — the same XLA ops restructured, no VMEM residency —
    so the measured ratios hover at 0.94–1.05x and a CPU wins-gate
    would pin this box's process noise, not the kernel design;
  * int8 — fused-int8 vs unfused-fp32 ratio for GCN/GIN, asserted
    >= 1.0 **only on TPU backends**: XLA's CPU int8 dot is several times
    slower than its f32 GEMM (no VNNI/AMX path here), so off-TPU the
    ratio is recorded as evidence, not gated — the W8A8 win is a claim
    about the MXU, and pretending otherwise would just pin a number
    about this container's BLAS.
"""
from __future__ import annotations

import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout as LY
from repro.core.graph import batch_graphs, from_numpy
from repro.data.pipeline import MOLHIV, MoleculeStream, laplacian_eigvec
from repro.gnn import init
from repro.gnn.models import apply, paper_config
from repro.serve.gnn_engine import GNNEngine
from repro.serve.scheduler import StreamScheduler

try:
    from benchmarks.bench_io import write_bench_json
except ImportError:  # executed as a script from benchmarks/
    from bench_io import write_bench_json

from repro.configs.gengnn_models import GNN_MODELS, get_gnn_config

MIN_SPEEDUP = 1.0  # floor for the large-graph interleaved min-of-k ratio
SORT_HEAVY = ("gat", "pna", "dgn")
LARGE_N, LARGE_E = 8192, 32768
TIMING_REPS = 15
EVAL_SEED = 7

# --fused gates (see module doc): fused must not lose on this many of the
# six models at large-graph scale; both timing gates are TPU-only — on
# CPU the fused path is the reference restructuring, so the ratios are
# recorded as evidence, not asserted
FUSED_MIN_WINS = 3
FUSED_INT8_MODELS = ("gcn", "gin")


# ----------------------------------------------------------- sort counting


def count_jaxpr_sorts(jaxpr) -> int:
    """Recursively count ``sort`` primitives (argsort lowers to one)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "sort":
            n += 1
        for v in eqn.params.values():
            for x in v if isinstance(v, (list, tuple)) else [v]:
                if hasattr(x, "jaxpr"):
                    inner = x.jaxpr
                    n += count_jaxpr_sorts(getattr(inner, "jaxpr", inner))
    return n


def count_hlo_sorts(fn, *args) -> int:
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    # op applications look like `%sort.0 = (s32[...], ...) sort(...)` —
    # match the call site, not metadata mentions of "argsort"
    return len(re.findall(r" sort\(", hlo))


def sort_counts(cfg, params, g, eig):
    """{jaxpr,hlo} x {seed,shared,preplanned} sort counts for one forward."""
    lay = LY.build_layout(g)
    seed_fn = lambda p, gg, e: apply(p, gg, cfg, eigvec=e, share_layout=False)  # noqa: E731
    shared_fn = lambda p, gg, e: apply(p, gg, cfg, eigvec=e)  # noqa: E731
    plan_fn = lambda p, gg, e, l: apply(p, gg, cfg, eigvec=e, layout=l)  # noqa: E731
    return {
        "jaxpr_seed": count_jaxpr_sorts(
            jax.make_jaxpr(seed_fn)(params, g, eig).jaxpr),
        "jaxpr_shared": count_jaxpr_sorts(
            jax.make_jaxpr(shared_fn)(params, g, eig).jaxpr),
        "jaxpr_preplanned": count_jaxpr_sorts(
            jax.make_jaxpr(plan_fn)(params, g, eig, lay).jaxpr),
        "hlo_shared": count_hlo_sorts(shared_fn, params, g, eig),
        "hlo_preplanned": count_hlo_sorts(plan_fn, params, g, eig, lay),
    }


# ----------------------------------------------------------------- timing


def _large_graph(with_eigvec):
    rng = np.random.default_rng(0)
    n, e = LARGE_N, LARGE_E
    g = batch_graphs(
        [(rng.integers(0, n, e).astype(np.int32),
          rng.integers(0, n, e).astype(np.int32),
          rng.normal(size=(n, 9)).astype(np.float32),
          rng.normal(size=(e, 3)).astype(np.float32))],
        n_pad=n + 1, e_pad=e,
    )
    eig = (jnp.asarray(rng.normal(size=(n + 1,)), jnp.float32)
           if with_eigvec else None)
    return g, eig


def _interleaved_ms(fn_a, fn_b, reps):
    """min-of-k over strictly interleaved calls — the only timing that
    survives this box's ~20% process-level noise.  -> (ms_a, ms_b)."""
    jax.block_until_ready(fn_a())
    jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        tb.append(time.perf_counter() - t0)
    return min(ta) * 1e3, min(tb) * 1e3


def large_graph_win(cfg, params, with_eigvec, reps=TIMING_REPS):
    """Interleaved min-of-k seed vs preplanned on one large graph."""
    g, eig = _large_graph(with_eigvec)
    seed_fn = jax.jit(
        lambda p, gg, ee: apply(p, gg, cfg, eigvec=ee, share_layout=False))
    plan_fn = jax.jit(
        lambda p, gg, ee, l: apply(p, gg, cfg, eigvec=ee, layout=l))
    lay = jax.tree.map(jnp.asarray, LY.host_layout(g))
    return _interleaved_ms(
        lambda: seed_fn(params, g, eig),
        lambda: plan_fn(params, g, eig, lay),
        reps,
    )


def fused_large_graph_win(cfg, params, with_eigvec, reps=TIMING_REPS):
    """Interleaved min-of-k unfused vs fused, both preplanned (the PR-4
    zero-sort path is the baseline the megakernel must beat)."""
    g, eig = _large_graph(with_eigvec)
    un_fn = jax.jit(
        lambda p, gg, ee, l: apply(p, gg, cfg, eigvec=ee, layout=l))
    fu_fn = jax.jit(
        lambda p, gg, ee, l: apply(p, gg, cfg, eigvec=ee, layout=l,
                                   fused=True))
    lay = jax.tree.map(jnp.asarray, LY.host_layout(g))
    return _interleaved_ms(
        lambda: un_fn(params, g, eig, lay),
        lambda: fu_fn(params, g, eig, lay),
        reps,
    )


def fused_int8_vs_fp32(cfg, params, with_eigvec, reps=TIMING_REPS):
    """Interleaved min-of-k: unfused fp32 vs fused W8A8, both preplanned
    — the in-kernel quantize/requant claim (gated on TPU only)."""
    from repro.quant import apply as QA

    qparams, _ = QA.quantize_model(params, cfg, (),
                                   QA.precision_qconfig("int8"))
    g, eig = _large_graph(with_eigvec)
    fp_fn = jax.jit(
        lambda p, gg, ee, l: apply(p, gg, cfg, eigvec=ee, layout=l))
    q_fn = jax.jit(
        lambda p, gg, ee, l: apply(p, gg, cfg, eigvec=ee, layout=l,
                                   fused=True))
    lay = jax.tree.map(jnp.asarray, LY.host_layout(g))
    return _interleaved_ms(
        lambda: fp_fn(params, g, eig, lay),
        lambda: q_fn(qparams, g, eig, lay),
        reps,
    )


def stream_latency_us(cfg, params, graphs, with_eigvec, share):
    eng = GNNEngine(cfg, params, share_layout=share)
    # one untimed pass to absorb compile + cache warm, one measured
    eng.infer_stream(graphs, with_eigvec=with_eigvec)
    _, lats, _ = eng.infer_stream(graphs, with_eigvec=with_eigvec)
    return float(np.mean(lats) * 1e6)


def packed_recompile_s(cfg, params, graphs, with_eigvec, fused=False):
    eng = GNNEngine(cfg, params, fused=fused)
    sched = StreamScheduler(eng, capacity=4, max_wait_s=0.002,
                            with_eigvec=with_eigvec)
    sched.run(graphs, qps=0.0)  # warm every ladder rung untimed
    warm_s = eng.compile_seconds
    sched.run(graphs, qps=0.0)
    return eng.compile_seconds - warm_s


# -------------------------------------------------------------------- run


def run(n_graphs: int = 48, with_timing: bool = True, strict: bool = True):
    rows = []
    for name in GNN_MODELS:
        cfg = get_gnn_config(name)
        params = init(jax.random.PRNGKey(0), cfg)
        graphs = [g[:4] for g in MoleculeStream(MOLHIV, seed=EVAL_SEED).take(n_graphs)]
        with_eigvec = name == "dgn"

        s, r, nf, ef = graphs[0]
        g0 = from_numpy(s, r, nf, ef, n_pad=32, e_pad=96)
        eig = (jnp.asarray(laplacian_eigvec(s, r, nf.shape[0], 32))
               if with_eigvec else None)
        sorts = sort_counts(cfg, params, g0, eig)
        recompile = packed_recompile_s(cfg, params, graphs, with_eigvec)

        derived = dict(sorts)
        derived["packed_recompile_s_after_warmup"] = round(recompile, 4)
        derived["n_graphs"] = n_graphs
        us_shared = 0.0
        if with_timing:
            us_seed = stream_latency_us(cfg, params, graphs, with_eigvec,
                                        share=False)
            us_shared = stream_latency_us(cfg, params, graphs, with_eigvec,
                                          share=True)
            derived["stream_us_seed"] = round(us_seed, 1)
            derived["stream_us_shared"] = round(us_shared, 1)
            if name in SORT_HEAVY:
                ms_seed, ms_plan = large_graph_win(cfg, params, with_eigvec)
                win = ms_seed / max(ms_plan, 1e-9)
                derived["large_graph_ms_seed"] = round(ms_seed, 1)
                derived["large_graph_ms_preplanned"] = round(ms_plan, 1)
                derived["large_graph_speedup_x"] = round(win, 3)

        rows.append({"name": f"layout_{name}",
                     "us_per_call": round(us_shared, 1), "derived": derived})
        print(f"layout_{name},{round(us_shared, 1)},{derived}", flush=True)

        ok = (sorts["jaxpr_shared"] == 1 and sorts["jaxpr_preplanned"] == 0
              and sorts["jaxpr_seed"] > 1 and sorts["hlo_shared"] <= 1
              and sorts["hlo_preplanned"] == 0 and recompile == 0.0)
        if strict:
            assert ok, f"{name}: layout acceptance failed ({derived})"
            if with_timing and name in SORT_HEAVY:
                win = derived["large_graph_speedup_x"]
                assert win >= MIN_SPEEDUP, (
                    f"{name}: zero-sort program should not be slower than the "
                    f"seed path at N={LARGE_N}/E={LARGE_E}: {win:.3f}x "
                    f"({derived['large_graph_ms_seed']} -> "
                    f"{derived['large_graph_ms_preplanned']} ms)"
                )
        elif not ok:
            print(f"# WARNING: {name} layout acceptance not met ({derived})")
    return rows


def run_fused(n_graphs: int = 48, with_timing: bool = True,
              strict: bool = True):
    """The --fused shape: megakernel vs unfused, per model (module doc)."""
    on_tpu = jax.default_backend() == "tpu"
    rows, wins = [], 0
    for name in GNN_MODELS:
        cfg = get_gnn_config(name)
        params = init(jax.random.PRNGKey(0), cfg)
        graphs = [g[:4] for g in
                  MoleculeStream(MOLHIV, seed=EVAL_SEED).take(n_graphs)]
        with_eigvec = name == "dgn"

        # deterministic: bitwise fp32 parity on a molecule-scale batch
        s, r, nf, ef = graphs[0]
        g0 = from_numpy(s, r, nf, ef, n_pad=32, e_pad=96)
        eig = (jnp.asarray(laplacian_eigvec(s, r, nf.shape[0], 32))
               if with_eigvec else None)
        lay = LY.for_model(None, g0, cfg.model, avg_degree=cfg.avg_degree,
                           eigvec=eig)
        un = np.asarray(apply(params, g0, cfg, eigvec=eig, layout=lay))
        fu = np.asarray(apply(params, g0, cfg, eigvec=eig, layout=lay,
                              fused=True))
        bitwise = bool((un == fu).all())
        fused_sorts = count_jaxpr_sorts(jax.make_jaxpr(
            lambda p, gg, e, l: apply(p, gg, cfg, eigvec=e, layout=l,
                                      fused=True)
        )(params, g0, eig, lay).jaxpr)
        recompile = packed_recompile_s(cfg, params, graphs, with_eigvec,
                                       fused=True)
        derived = {
            "fp32_bitwise_vs_unfused": bitwise,
            "jaxpr_preplanned_fused": fused_sorts,
            "packed_recompile_s_after_warmup": round(recompile, 4),
            "n_graphs": n_graphs,
        }
        ms_fused = 0.0
        if with_timing:
            ms_un, ms_fused = fused_large_graph_win(cfg, params, with_eigvec)
            speedup = ms_un / max(ms_fused, 1e-9)
            wins += speedup >= 1.0
            derived["large_graph_ms_unfused"] = round(ms_un, 1)
            derived["large_graph_ms_fused"] = round(ms_fused, 1)
            derived["fused_speedup_x"] = round(speedup, 3)
            if name in FUSED_INT8_MODELS:
                ms_fp, ms_q = fused_int8_vs_fp32(cfg, params, with_eigvec)
                ratio = ms_fp / max(ms_q, 1e-9)
                derived["fused_int8_vs_fp32_x"] = round(ratio, 3)
                if strict and on_tpu:
                    assert ratio >= 1.0, (
                        f"{name}: fused W8A8 slower than fp32 on TPU "
                        f"({ratio:.3f}x)"
                    )
        rows.append({"name": f"fused_{name}",
                     "us_per_call": round(ms_fused * 1e3, 1),
                     "derived": derived})
        print(f"fused_{name},{round(ms_fused * 1e3, 1)},{derived}",
              flush=True)
        ok = bitwise and fused_sorts == 0 and recompile == 0.0
        if strict:
            assert ok, f"{name}: fused acceptance failed ({derived})"
        elif not ok:
            print(f"# WARNING: {name} fused acceptance not met ({derived})")
    if with_timing:
        if strict and on_tpu:
            assert wins >= FUSED_MIN_WINS, (
                f"fused megakernel won on only {wins}/6 models at "
                f"N={LARGE_N}/E={LARGE_E} (need >= {FUSED_MIN_WINS})"
            )
        elif not on_tpu:
            print(f"# CPU backend: fused won {wins}/6 "
                  f"(recorded, gated on TPU only — module doc)")
    return rows


# this bench writes its own BENCH json (below) so the assertion thresholds
# travel with the rows; the benchmarks.run driver must not also write one
WRITES_OWN_BENCH = True


def main(strict: bool = False):
    smoke = "--smoke" in sys.argv
    fused = "--fused" in sys.argv
    runner = run_fused if fused else run
    rows = runner(n_graphs=8 if smoke else 48, with_timing=not smoke,
                  strict=strict or smoke)
    # the smoke shape (CI) must not clobber the committed full-run artifact
    tag = "layout_fused" if fused else "layout"
    write_bench_json(tag + ("_smoke" if smoke else ""), rows,
                     config={"argv": sys.argv[1:], "min_speedup": MIN_SPEEDUP,
                             "fused_min_wins": FUSED_MIN_WINS,
                             "sort_heavy_models": list(SORT_HEAVY),
                             "large_graph": [LARGE_N, LARGE_E],
                             "timing_reps": TIMING_REPS,
                             "n_graphs": 8 if smoke else 48})
    return rows


if __name__ == "__main__":
    main(strict=True)
