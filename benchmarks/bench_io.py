"""Machine-readable benchmark output: one ``BENCH_<name>.json`` per run.

Schema (consumed by perf-trajectory tooling; keep stable):

    {"name": str, "config": dict, "metrics": list-of-rows,
     "env": dict, "timestamp": iso8601}

``metrics`` is whatever row list the benchmark's ``run()`` produced (the
same dicts its CSV lines print).  ``env`` is the serving stack's
environment fingerprint (``serve.aot.environment_fingerprint``: jax /
jaxlib versions, backend, device kind, topology) — two BENCH files are
only comparable when their fingerprints match, and the perf-trajectory
tooling can now refuse to diff across a toolchain bump instead of
reporting it as a regression.  Output directory defaults to the current
working directory; override with ``REPRO_BENCH_DIR``.
"""
from __future__ import annotations

import json
import os
from datetime import datetime, timezone


def _environment() -> dict:
    try:
        from repro.serve.aot import environment_fingerprint

        env = dict(environment_fingerprint())
        env.pop("schema", None)
        env.pop("flags", None)  # per-program, not per-environment
        return env
    except Exception:  # noqa: BLE001 - a bench must never die on metadata
        return {}


def write_bench_json(name: str, metrics, config: dict | None = None,
                     out_dir: str | None = None) -> str:
    out_dir = out_dir or os.environ.get("REPRO_BENCH_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    payload = {
        "name": name,
        "config": config or {},
        "metrics": metrics,
        "env": _environment(),
        "timestamp": datetime.now(timezone.utc).isoformat(timespec="seconds"),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
        f.write("\n")
    print(f"# wrote {path}", flush=True)
    return path
