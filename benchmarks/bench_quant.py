"""Quantized vs fp32 serving: output error and packed throughput.

The paper's accelerator runs entirely in ``ap_fixed``; this bench measures
what the JAX reproduction's quantized paths cost in accuracy and buy in
throughput.  For each of the six models it serves the same eval stream
through the packed micro-batcher (``StreamScheduler`` -> ``infer_packed``)
at fp32, int8 (dynamic per-node activation scales, the serving default),
int8-static (calibrated per-tensor scales) and — in full mode —
ap_fixed<16,6> emulation, and reports:

  * graph-logit MAE and sign agreement vs fp32-packed (the
    serving-equivalence claim: same routing decisions).  Sign agreement
    is computed over *decidable* logits, |fp32 logit| >= 2% of the mean
    |fp32 logit|: a logit the fp32 model itself puts indistinguishably
    close to zero has no stable sign at any finite precision;
  * packed saturation throughput per precision (compute-time basis; on
    CPU the int8 path is slower — XLA's CPU int8 matmul is not the MXU —
    so this column is informative off-TPU, not a win);
  * recompiles after warmup (must be zero — quantized buckets ride the
    same budget-ladder pre-warm as fp32).

Acceptance, asserted per model when run standalone (reported-only under
the benchmarks.run driver):
  int8 (dynamic):  MAE <= max(0.02, 10% of mean |fp32 logit|), decidable
                   sign agreement >= 99%, zero recompiles after warmup;
  int8-static:     finite outputs, MAE <= max(0.05, 15%), zero recompiles.

  PYTHONPATH=src python benchmarks/bench_quant.py [--smoke] [--fused]

``--smoke`` is the CI shape: fewer graphs, no fixed-mode engines, same
correctness assertions (timing gates are full-run only, as in
bench_multitenant: an 8-graph window is ~20% noisy on a shared box).

``--fused`` serves the quantized tenants through the megakernel
(``GNNEngine(fused=True)``: W8A8 quantize/accumulate/requant inside one
(phi, A, gamma) pass) and adds two columns + two gates:

  * ``int8_fused_gain_x`` — fused-int8 vs unfused-int8 throughput;
    asserted >= ``FUSED_GAIN_FLOOR`` for the models whose gamma matmul
    actually moves into the kernel (``GATE_FUSED_GAIN``; GCN's only
    linear runs before aggregation so fusion changes little, GAT opts
    out entirely, and PNA's four-aggregator scaler tower costs more
    in-pass than CPU fusion saves — all three record-only);
  * ``int8_speedup_x`` (already recorded) — asserted >= 1.0 **on TPU
    backends only**: XLA's CPU int8 dot is several times slower than its
    f32 GEMM, so off-TPU this column documents the backend, not the
    design (the committed artifact records it either way).
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro.data.pipeline import MOLHIV, MoleculeStream
from repro.gnn import init
from repro.serve.gnn_engine import GNNEngine
from repro.serve.scheduler import StreamScheduler

try:
    from benchmarks.bench_io import write_bench_json
except ImportError:  # executed as a script from benchmarks/
    from bench_io import write_bench_json

from repro.configs.gengnn_models import GNN_MODELS, get_gnn_config

MAE_REL_TOL = {"int8": 0.10, "int8-static": 0.15}
MAE_ABS_FLOOR = {"int8": 0.02, "int8-static": 0.05}
SIGN_TOL = 0.99  # asserted for the dynamic path
DECIDABLE_FRAC = 0.02  # |fp32 logit| >= this x mean |fp32 logit|
CALIB_SEED, EVAL_SEED = 97, 2

# --fused gates (see module doc).  PNA is record-only alongside GCN/GAT:
# its gamma consumes four aggregations x three degree scalers, and at
# molecule scale that extra in-pass work outweighs what fusing the final
# matmul saves on CPU (measured ~0.5-0.6x; the TPU MXU path is where the
# four-way reduction fuses profitably).
GATE_FUSED_GAIN = ("gin", "gin_vn", "dgn")
FUSED_GAIN_FLOOR = 1.0
TIMED_REPS = 3  # best-of-k packed throughput; single reps are ~20% noisy


def _packed_eval(engine, graphs, capacity, with_eigvec):
    """Serve ``graphs`` packed (saturation mode); returns (logits,
    graphs_per_s, recompile_s_after_warmup).  Throughput is best-of-
    ``TIMED_REPS`` — min compute time is the only stable statistic on a
    noisy box, and every rep must produce identical logits anyway."""
    sched = StreamScheduler(engine, capacity=capacity, max_wait_s=0.002,
                            with_eigvec=with_eigvec)
    sched.run(graphs, qps=0.0)  # warm every ladder rung untimed
    warm_s = engine.compile_seconds
    best_gps, logits = 0.0, None
    for _ in range(TIMED_REPS):
        rep = sched.run(graphs, qps=0.0)
        best_gps = max(best_gps, rep.num_requests / rep.compute_s)
        logits = np.array([float(o[0, 0]) for o in rep.outputs])
    return logits, best_gps, engine.compile_seconds - warm_s


def _compare(name, prec, logits, fp32_logits):
    mae = float(np.abs(logits - fp32_logits).mean())
    decidable = (np.abs(fp32_logits)
                 >= DECIDABLE_FRAC * np.abs(fp32_logits).mean())
    sign = float((np.sign(logits[decidable])
                  == np.sign(fp32_logits[decidable])).mean())
    mae_tol = max(MAE_ABS_FLOOR[prec],
                  MAE_REL_TOL[prec] * float(np.abs(fp32_logits).mean()))
    return mae, sign, mae_tol, int(decidable.sum())


def run(n_calib: int = 16, n_eval: int = 48, capacity: int = 8,
        with_fixed: bool = True, strict: bool = True, fused: bool = False,
        gate_timing: bool = True):
    on_tpu = jax.default_backend() == "tpu"
    calib = [g[:4] for g in MoleculeStream(MOLHIV, seed=CALIB_SEED).take(n_calib)]
    evalg = MoleculeStream(MOLHIV, seed=EVAL_SEED).take(n_eval)
    rows = []
    for name in GNN_MODELS:
        cfg = get_gnn_config(name)
        params = init(jax.random.PRNGKey(0), cfg)
        engines = {
            "fp32": GNNEngine(cfg, params),
            "int8": GNNEngine(cfg, params, precision="int8", fused=fused),
            "int8-static": GNNEngine(cfg, params, precision="int8-static",
                                     calib_graphs=calib, fused=fused),
        }
        if fused:
            # the unfused-int8 twin the fused gain is measured against
            engines["int8-unfused"] = GNNEngine(cfg, params,
                                                precision="int8")
        if with_fixed:
            engines["fixed"] = GNNEngine(cfg, params, precision="fixed")
        logits, gps, recompile = {}, {}, {}
        for prec, eng in engines.items():
            logits[prec], gps[prec], recompile[prec] = _packed_eval(
                eng, evalg, capacity, with_eigvec=(name == "dgn")
            )
        mae, sign, mae_tol, n_dec = _compare(
            name, "int8", logits["int8"], logits["fp32"]
        )
        mae_s, sign_s, mae_tol_s, _ = _compare(
            name, "int8-static", logits["int8-static"], logits["fp32"]
        )
        derived = {
            "mae_tol": round(mae_tol, 4),
            "sign_agreement": round(sign, 4),
            "decidable_logits": n_dec,
            "logit_scale": round(float(np.abs(logits["fp32"]).mean()), 4),
            "static_mae": round(mae_s, 5),
            "static_sign_agreement": round(sign_s, 4),
            "fp32_graphs_per_s": round(gps["fp32"], 1),
            "int8_graphs_per_s": round(gps["int8"], 1),
            "int8_speedup_x": round(gps["int8"] / gps["fp32"], 2),
            "int8_recompile_s_after_warmup": round(recompile["int8"], 4),
            "quantized_linears": engines["int8"].quant_report.quantized,
            "fp32_linears": engines["int8"].quant_report.kept_fp32,
            "n_eval": n_eval,
        }
        if fused:
            derived["fused"] = True
            derived["int8_unfused_graphs_per_s"] = round(gps["int8-unfused"], 1)
            derived["int8_fused_gain_x"] = round(
                gps["int8"] / gps["int8-unfused"], 2
            )
        if with_fixed:
            derived["fixed16_mae"] = round(
                float(np.abs(logits["fixed"] - logits["fp32"]).mean()), 5
            )
        rows.append({"name": f"quant_{name}", "int8_mae": round(mae, 5),
                     "derived": derived})
        ok_dyn = (np.isfinite(logits["int8"]).all() and mae <= mae_tol
                  and sign >= SIGN_TOL and recompile["int8"] == 0.0)
        ok_static = (np.isfinite(logits["int8-static"]).all()
                     and mae_s <= mae_tol_s
                     and recompile["int8-static"] == 0.0)
        if strict:
            assert ok_dyn, (
                f"{name}: int8 acceptance failed (finite="
                f"{bool(np.isfinite(logits['int8']).all())}, mae={mae:.4f} "
                f"(tol {mae_tol:.4f}), sign={sign:.3f} (tol {SIGN_TOL}), "
                f"recompile_s={recompile['int8']:.4f})"
            )
            assert ok_static, (
                f"{name}: int8-static acceptance failed (mae={mae_s:.4f} "
                f"(tol {mae_tol_s:.4f}), "
                f"recompile_s={recompile['int8-static']:.4f})"
            )
            if fused and gate_timing and name in GATE_FUSED_GAIN:
                gain = derived["int8_fused_gain_x"]
                assert gain >= FUSED_GAIN_FLOOR, (
                    f"{name}: fused int8 slower than unfused int8 "
                    f"({gain:.2f}x < {FUSED_GAIN_FLOOR}x)"
                )
            if fused and gate_timing and on_tpu:
                assert derived["int8_speedup_x"] >= 1.0, (
                    f"{name}: fused int8 slower than fp32 on TPU "
                    f"({derived['int8_speedup_x']:.2f}x)"
                )
        elif not (ok_dyn and ok_static):
            print(f"# WARNING: {name} quant acceptance not met "
                  f"(mae={mae:.4f}, sign={sign:.3f}, static_mae={mae_s:.4f})")
    return rows


# this bench writes its own BENCH json (below) so the tolerance metadata
# and run shape always travel with the rows; the benchmarks.run driver
# must not also write a generic one
WRITES_OWN_BENCH = True


def main(strict: bool = False):
    smoke = "--smoke" in sys.argv
    fused = "--fused" in sys.argv
    if smoke:
        rows = run(n_calib=4, n_eval=8, capacity=2, with_fixed=False,
                   strict=strict, fused=fused, gate_timing=False)
    else:
        rows = run(strict=strict, fused=fused)
    for row in rows:
        print(f"{row['name']},{row['int8_mae']},{row['derived']}")
    # the smoke shape (CI) must not clobber the committed full-run artifact
    tag = "quant_fused" if fused else "quant"
    write_bench_json(tag + "_smoke" if smoke else tag, rows,
                     config={"argv": sys.argv[1:], "strict": strict,
                             "mae_rel_tol": MAE_REL_TOL,
                             "mae_abs_floor": MAE_ABS_FLOOR,
                             "sign_tol": SIGN_TOL,
                             "decidable_frac": DECIDABLE_FRAC,
                             "gate_fused_gain": list(GATE_FUSED_GAIN),
                             "fused_gain_floor": FUSED_GAIN_FLOOR})
    return rows


if __name__ == "__main__":
    main(strict=True)
