"""Fig. 8 analogue: DGN with the Large Graph Extension on Cora / CiteSeer
/ PubMed-sized node-classification graphs.

Graph sizes and feature dims match Table 5 exactly; contents are synthetic
(datasets are not bundled offline).  The large-graph path exercises (a)
feature-dim reduction first (encoder), (b) node-tiled message passing via
the same segment core, (c) node-level outputs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import from_numpy
from repro.gnn import apply, init, paper_config

# Table 5: nodes, edges, feature dim
BENCHMARKS = {
    "cora": (2708, 10556, 1433),
    "citeseer": (3327, 9104, 3703),
    "pubmed": (19717, 88648, 500),
}


def make_graph(name, rng):
    n, e, f = BENCHMARKS[name]
    s = rng.integers(0, n, e).astype(np.int32)
    r = rng.integers(0, n, e).astype(np.int32)
    nf = (rng.random((n, f)) < 0.01).astype(np.float32)  # sparse bag-of-words-ish
    return s, r, nf


def run():
    rows = []
    rng = np.random.default_rng(0)
    for name in BENCHMARKS:
        n, e, f = BENCHMARKS[name]
        cfg = paper_config("dgn", feat_dim=f, task="node", out_dim=7, edge_dim=1)
        params = init(jax.random.PRNGKey(0), cfg)
        s, r, nf = make_graph(name, rng)
        n_pad = -(-n // 128) * 128
        e_pad = -(-e // 128) * 128
        g = from_numpy(s, r, nf, None, n_pad=n_pad, e_pad=e_pad)
        eig = jnp.asarray(rng.normal(size=(n_pad,)), jnp.float32)
        fn = jax.jit(lambda p, gg, ee: apply(p, gg, cfg, eigvec=ee))
        fn(params, g, eig).block_until_ready()  # compile
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(params, g, eig))
            ts.append(time.perf_counter() - t0)
        rows.append({
            "name": f"fig8_dgn_{name}",
            "us_per_call": float(np.mean(ts) * 1e6),
            "derived": {"nodes": n, "edges": e, "feat_dim": f,
                        "us_per_node": round(float(np.mean(ts)) * 1e6 / n, 3)},
        })
    return rows


def main():
    rows = run()
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    return rows


if __name__ == "__main__":
    main()
