"""Cold start vs warm-disk restart: the AOT cache's kill-the-warm-up claim.

Every scenario launches a **fresh Python process** (the only honest
restart) that loads a saved checkpoint, builds the serving stack over a
shared AOT cache directory, prewarms the whole bucket ladder, and serves
a first request.  The child reports three timings:

  * ``wall_s`` — full subprocess wall clock (interpreter + jax import +
    everything), measured by the parent;
  * ``serve_ready_s`` — checkpoint-in-hand to ladder-warm (the serving
    stack's own cost: construct + register + compile-or-load);
  * ``first_request_s`` — checkpoint-in-hand to first served response.

``serve_ready_s`` / ``first_request_s`` exclude interpreter and JAX
import time on purpose: that cost is identical with and without the
cache (orthogonal to what this PR changes) and docs/SERVING.md says so.
The acceptance bar: a **warm-disk restart serves its first request in
under one second**, with zero fresh lowerings and every cache load a
hit.  Scenarios cover single-tenant, multi-tenant (two models on one
executor), and the autotuned-vs-default XLA flag delta (steady-state
latency of the tuned packed program, min-of-k).

  PYTHONPATH=src python benchmarks/bench_coldstart.py [--smoke]

``--smoke`` (CI) runs reduced configs with a generous threshold (a
loaded CI box is not a latency lab) while keeping every deterministic
assertion: warm runs must hit on every load and never trace.  The
committed full-run artifact (BENCH_coldstart.json) carries the <1s
claim.
"""
from __future__ import annotations

import json
import os
import pickle
import subprocess
import sys
import tempfile
import time

try:
    from benchmarks.bench_io import write_bench_json
except ImportError:  # executed as a script from benchmarks/
    from bench_io import write_bench_json

CAPACITY = 4
STEADY_REPS = 10
EVAL_SEED = 23
_MARK = "COLDSTART_JSON "


def _cfg(model, reduced):
    from repro.configs.gengnn_models import get_gnn_config
    from repro.gnn.models import paper_config

    if not reduced:
        return get_gnn_config(model)
    kw = dict(num_layers=2)
    if model == "gat":
        kw.update(heads=2, head_features=8)
    else:
        kw.update(hidden=16)
    return paper_config(model, **kw)


def _graphs(n_graphs, feat=9, edge=3):
    import numpy as np

    rng = np.random.default_rng(EVAL_SEED)
    out = []
    for _ in range(n_graphs):
        n = int(rng.integers(6, 24))
        e = int(rng.integers(n, 2 * n))
        out.append((
            rng.integers(0, n, e).astype(np.int32),
            rng.integers(0, n, e).astype(np.int32),
            rng.normal(size=(n, feat)).astype(np.float32),
            rng.normal(size=(e, edge)).astype(np.float32),
        ))
    return out


# ------------------------------------------------------------- the child


def child(state_path: str) -> None:
    """One restarted serving process.  Prints a ``COLDSTART_JSON`` line
    the parent parses; everything else is free-form."""
    with open(state_path) as f:
        state = json.load(f)
    with open(state["blob"], "rb") as f:
        blob = pickle.load(f)

    import numpy as np

    from repro.core.batching import BucketBudget, pack_prepared
    from repro.serve.aot import AOTCache, XlaFlagConfig
    from repro.serve.executor import Executor
    from repro.serve.scheduler import StreamScheduler

    # serving-stack epoch: checkpoint in hand, imports done
    t0 = time.perf_counter()
    flags = XlaFlagConfig.load() if state["flags"] == "table" else None
    ex = Executor(aot_cache=AOTCache(state["cache_dir"]), xla_flags=flags)
    for t in state["tenants"]:
        ex.register(t["name"], _cfg(t["model"], state["reduced"]),
                    blob["params"][t["name"]], precision=t["precision"])
    sched = StreamScheduler(ex, capacity=CAPACITY, max_wait_s=0.002)
    graphs = blob["graphs"]
    names = [t["name"] for t in state["tenants"]]
    models = [names[i % len(names)] for i in range(len(graphs))] \
        if len(names) > 1 else None
    sched.prewarm_ladders(graphs, models=models)
    serve_ready_s = time.perf_counter() - t0
    rep = sched.run(graphs[:1], models=models[:1] if models else None)
    assert rep.num_served == 1
    first_request_s = time.perf_counter() - t0

    # steady state at the autotuner's bucket (packed|128|384|8): the flag
    # table's winners live there, so this is where the delta shows
    budget = BucketBudget(n_pad=32 * CAPACITY, e_pad=96 * CAPACITY,
                          g_pad=2 * CAPACITY)
    steady_us = {}
    for name in names:
        prep, _ = pack_prepared(graphs[:4], budget, with_layout=True)
        p = ex.prepare_packed(prep.graph, budget, eigvec=prep.eigvec,
                              layout=prep.layout, model=name)
        ex.warm(p, model=name)
        best = min(ex.run(p, model=name)[1] for _ in range(STEADY_REPS))
        steady_us[name] = round(best * 1e6, 1)

    print(_MARK + json.dumps({
        "serve_ready_s": round(serve_ready_s, 4),
        "first_request_s": round(first_request_s, 4),
        "steady_us": steady_us,
        "aot": ex.aot_stats(),
        "lowered": ex.lowered_count,
        "compile_s": round(ex.compile_seconds, 4),
        "warm_s": round(ex.warm_seconds, 4),
    }))


# ------------------------------------------------------------ the parent


def _spawn(state: dict, workdir: str) -> dict:
    state_path = os.path.join(workdir, "state.json")
    with open(state_path, "w") as f:
        json.dump(state, f)
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    t0 = time.perf_counter()
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", state_path],
        capture_output=True, text=True, env=env, cwd=root,
    )
    wall_s = time.perf_counter() - t0
    assert r.returncode == 0, f"child failed:\n{r.stdout}\n{r.stderr}"
    line = next(l for l in r.stdout.splitlines() if l.startswith(_MARK))
    out = json.loads(line[len(_MARK):])
    out["wall_s"] = round(wall_s, 3)
    return out


def _checkpoint(tenants, reduced, workdir, n_graphs=8) -> str:
    """Init params once, save as a numpy checkpoint — the realistic
    restart loads weights from disk instead of re-running jitted init."""
    import jax
    import numpy as np

    from repro.gnn import init

    params = {}
    for i, t in enumerate(tenants):
        tree = init(jax.random.PRNGKey(i), _cfg(t["model"], reduced))
        params[t["name"]] = jax.tree_util.tree_map(np.asarray, tree)
    blob = os.path.join(workdir, "checkpoint.pkl")
    with open(blob, "wb") as f:
        pickle.dump({"params": params, "graphs": _graphs(n_graphs)}, f)
    return blob


def run(smoke: bool, strict: bool):
    limit_s = 30.0 if smoke else 1.0  # warm first-request bound
    single = [{"name": "gin", "model": "gin", "precision": "fp32"}]
    multi = [{"name": "gcn", "model": "gcn", "precision": "fp32"},
             {"name": "gin", "model": "gin", "precision": "fp32"}]
    rows = []
    with tempfile.TemporaryDirectory() as workdir:
        scenarios = [
            ("single_default_flags", single, "none"),
            ("single_autotuned", single, "table"),
            ("multitenant_autotuned", multi, "table"),
        ]
        for label, tenants, flags in scenarios:
            blob = _checkpoint(tenants, smoke, workdir)
            cache_dir = os.path.join(workdir, f"cache_{label}")
            state = {"blob": blob, "cache_dir": cache_dir, "flags": flags,
                     "tenants": tenants, "reduced": smoke}
            for phase in ("cold", "warm"):
                out = _spawn(state, workdir)
                row = {"name": f"coldstart_{label}_{phase}",
                       "us_per_call": 0.0,
                       "derived": {"tenants": [t["name"] for t in tenants],
                                   "flags": flags, "phase": phase, **out}}
                rows.append(row)
                print(f"{row['name']},{row['us_per_call']},{row['derived']}",
                      flush=True)
                if phase == "cold":
                    assert out["lowered"] > 0 and out["aot"]["hit"] == 0
                else:
                    assert out["lowered"] == 0, (
                        f"{label}: warm restart traced {out['lowered']}x")
                    assert out["aot"]["miss"] == 0 == out["aot"]["stale"], out
                    assert out["aot"]["hit"] > 0
                    if strict:
                        assert out["first_request_s"] < limit_s, (
                            f"{label}: warm-disk restart took "
                            f"{out['first_request_s']:.2f}s to first request "
                            f"(limit {limit_s:.0f}s)"
                        )

    # the flag-table delta: steady-state latency, tuned vs default, from
    # the two single-tenant warm rows (same checkpoint, same graphs)
    by_name = {r["name"]: r["derived"] for r in rows}
    base = by_name["coldstart_single_default_flags_warm"]["steady_us"]["gin"]
    tuned = by_name["coldstart_single_autotuned_warm"]["steady_us"]["gin"]
    delta = {"name": "coldstart_flag_delta", "us_per_call": tuned,
             "derived": {"model": "gin", "default_us": base,
                         "autotuned_us": tuned,
                         "speedup_x": round(base / max(tuned, 1e-9), 3)}}
    rows.append(delta)
    print(f"{delta['name']},{delta['us_per_call']},{delta['derived']}",
          flush=True)
    return rows


# this bench writes its own BENCH json so the smoke shape never clobbers
# the committed full-run artifact
WRITES_OWN_BENCH = True


def main(strict: bool = False):
    if "--child" in sys.argv:
        child(sys.argv[sys.argv.index("--child") + 1])
        return []
    smoke = "--smoke" in sys.argv
    rows = run(smoke=smoke, strict=strict or smoke)
    write_bench_json("coldstart_smoke" if smoke else "coldstart", rows,
                     config={"argv": sys.argv[1:], "capacity": CAPACITY,
                             "steady_reps": STEADY_REPS,
                             "warm_first_request_limit_s":
                                 30.0 if smoke else 1.0})
    return rows


if __name__ == "__main__":
    main(strict=True)
