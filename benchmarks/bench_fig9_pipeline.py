"""Fig. 9: NE/MP pipelining speed-ups over the paper's synthetic sweep
(100k-random-graph study reproduced with 1k graphs per grid point) and the
MolHIV + virtual-node measurements (Fig. 9(b)/(c)).

Expected paper bands: fixed/non 1.2-1.5x, streaming/fixed 1.15-1.37x,
streaming/non 1.53-1.92x; MolHIV: 1.38x / 1.63x; +VN: 1.40x / 1.61x.
"""
from __future__ import annotations

import numpy as np

from repro.core.pipeline_sim import (
    PipelineCosts,
    random_degree_graph,
    simulate,
    virtual_node_graph,
)
from repro.data.pipeline import MOLHIV, MoleculeStream


def sweep(n_graphs=50):
    rng = np.random.default_rng(0)
    rows = []
    for avg_deg in (2, 3, 4, 6):
        for pct in (0.01, 0.05, 0.1):
            rs = []
            for _ in range(n_graphs):
                deg = random_degree_graph(rng, 500, avg_deg, pct)
                rs.append(simulate(deg))
            agg = {k: float(np.mean([r[k] for r in rs]))
                   for k in ("fixed_over_non", "streaming_over_fixed", "streaming_over_non")}
            rows.append({
                "name": f"fig9a_deg{avg_deg}_pct{int(pct*100)}",
                "us_per_call": 0.0,
                "derived": {k: round(v, 3) for k, v in agg.items()},
            })
    return rows


def molhiv(n_graphs=200, with_vn=False):
    stream = MoleculeStream(MOLHIV, seed=0)
    rs = []
    rng = np.random.default_rng(1)
    for i in range(n_graphs):
        s, r, nf, ef, _ = stream.graph_at(i)
        deg = np.bincount(s, minlength=nf.shape[0]).astype(float)
        if with_vn:
            deg = np.concatenate([[nf.shape[0]], deg])  # VN emitted first
        rs.append(simulate(deg))
    return {
        "name": "fig9b_molhiv" + ("_vn" if with_vn else ""),
        "us_per_call": 0.0,
        "derived": {
            "fixed_over_non": round(float(np.mean([r["fixed_over_non"] for r in rs])), 3),
            "streaming_over_non": round(float(np.mean([r["streaming_over_non"] for r in rs])), 3),
        },
    }


def run():
    return sweep() + [molhiv(), molhiv(with_vn=True)]


def main():
    rows = run()
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    return rows


if __name__ == "__main__":
    main()
